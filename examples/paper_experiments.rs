//! Run the paper's full evaluation (scaled) in one shot and print every
//! table/figure summary. Heavier than the benches; scale with
//! `HEIPA_SEEDS=1,2` and `HEIPA_TOPS=2,6` (defaults: seed 1; tops 2 and 6).
//!
//! ```bash
//! HEIPA_TOPS=2,6 cargo run --release --example paper_experiments
//! ```

use heipa::algo::Algorithm;
use heipa::engine::Engine;
use heipa::graph::gen;
use heipa::harness::{self, profiles::ProfileInput, stats};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_defaults();
    let seeds = harness::seeds_from_env(&[1]);
    let hierarchies = if std::env::var("HEIPA_TOPS").is_ok() {
        harness::machines_from_env()
    } else {
        vec![
            heipa::topology::Machine::hier("4:8:2", "1:10:100")?,
            heipa::topology::Machine::hier("4:8:6", "1:10:100")?,
        ]
    };
    let instances = gen::smoke_suite();
    let algos = [
        Algorithm::GpuHm,
        Algorithm::GpuHmUltra,
        Algorithm::GpuIm,
        Algorithm::SharedMapF,
        Algorithm::SharedMapS,
        Algorithm::IntMapF,
        Algorithm::IntMapS,
        Algorithm::Jet,
    ];
    eprintln!(
        "running {} algos x {} instances x {} hierarchies x {} seeds …",
        algos.len(),
        instances.len(),
        hierarchies.len(),
        seeds.len()
    );
    let records = harness::run_matrix(&engine, &algos, &instances, &hierarchies, &seeds, 0.03);
    harness::write_csv(&records, std::path::Path::new("paper_experiments.csv"))?;

    // Quality profile (Fig. 2 right).
    let names: Vec<String> = algos.iter().map(|a| a.name().to_string()).collect();
    let quality: Vec<Vec<f64>> = algos
        .iter()
        .map(|a| {
            records
                .iter()
                .filter(|r| r.algorithm == *a)
                .map(|r| r.comm_cost)
                .collect()
        })
        .collect();
    let profile = ProfileInput { algorithm_names: names.clone(), quality };
    println!("\n== mean overhead over best solution (paper Fig. 2) ==");
    for (name, pct) in profile.mean_overhead_pct() {
        println!("  {name:>14}: +{pct:.1}%");
    }
    println!("\n== best-solution fractions (tau = 1) ==");
    for (name, frac) in profile.best_fractions() {
        println!("  {name:>14}: {:.1}%", frac * 100.0);
    }

    // Speedups vs SharedMap-S (Fig. 2 left).
    let base: Vec<f64> = records
        .iter()
        .filter(|r| r.algorithm == Algorithm::SharedMapS)
        .map(|r| r.device_ms)
        .collect();
    println!("\n== speedup vs sharedmap-s (geomean / max) ==");
    for a in algos {
        if a == Algorithm::SharedMapS {
            continue;
        }
        let mine: Vec<f64> = records
            .iter()
            .filter(|r| r.algorithm == a)
            .map(|r| r.device_ms)
            .collect();
        let (geo, mx, _) = stats::speedup_summary(&base, &mine);
        println!("  {:>14}: {geo:.1}x geomean, {mx:.1}x max", a.name());
    }
    println!("\nwrote paper_experiments.csv");
    Ok(())
}
