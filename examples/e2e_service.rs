//! End-to-end driver: the full system on a real small workload.
//!
//! Starts the mapping-as-a-service coordinator and exercises every layer
//! of the **asynchronous job API**:
//!
//!   TCP protocol (submit → job id → wait → result, graph-as-resource
//!   sessions, cancel) → MapRequest → MapSpec → engine job queue +
//!   worker pool → (router, GPU-IM / GPU-HM-ultra device pipelines) →
//!   PJRT-offloaded QAP polish (AOT JAX/Pallas kernel) → MapOutcome →
//!   metrics.
//!
//! Reports the paper's headline metric (communication cost J) per request
//! plus speedup vs the serial SharedMap-S baseline — the baseline runs
//! through the *library* front-end of the same engine API, demonstrating
//! that both paths share one code path. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_service
//! ```

use heipa::algo::Algorithm;
use heipa::coordinator::protocol::{self, ServeOptions};
use heipa::coordinator::service::{Service, ServiceConfig};
use heipa::coordinator::{MapReply, MapRequest};
use heipa::engine::{Engine, MapSpec};
use heipa::graph::gen;
use heipa::partition;
use heipa::topology::Machine;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // Two engine workers: jobs submitted together overlap.
    let svc = Arc::new(Service::with_config(ServiceConfig {
        artifacts_dir: "artifacts".into(),
        workers: 2,
        ..ServiceConfig::default()
    }));

    // --- 1. TCP smoke: the async job lifecycle over the wire. ----------
    let addr = spawn_tcp(svc.clone());
    {
        let mut conn = std::net::TcpStream::connect(addr)?;
        let mut reader = BufReader::new(conn.try_clone()?);
        let mut send = |conn: &mut std::net::TcpStream, line: &str| -> anyhow::Result<String> {
            writeln!(conn, "{line}")?;
            let mut reply = String::new();
            reader.read_line(&mut reply)?;
            Ok(reply.trim_end().to_string())
        };
        assert!(send(&mut conn, "ping")?.starts_with("ok version="));
        // Upload-once/map-many: pin a task graph server-side…
        let put = send(&mut conn, "graph put name=halo csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6")?;
        assert!(put.starts_with("ok graph=halo"), "bad graph put reply: {put}");
        // …then submit against it: the reply arrives before the solve.
        let submitted = send(
            &mut conn,
            "submit graph=halo algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3",
        )?;
        assert!(submitted.starts_with("ok job="), "bad submit reply: {submitted}");
        let job: u64 = submitted
            .split_whitespace()
            .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
            .expect("job id");
        let waited = send(&mut conn, &format!("wait job={job}"))?;
        assert!(waited.contains("state=done"), "bad wait reply: {waited}");
        let result = send(&mut conn, &format!("result job={job}"))?;
        assert!(result.starts_with("ok id="), "bad result reply: {result}");
        println!("TCP job API OK: {submitted} → {result}\n");
    }

    // --- 2. Batched workload over the full stack. -----------------------
    let workload = [
        ("rgg15", "4:8:2", None),
        ("rgg15", "4:8:6", Some(Algorithm::GpuIm)),
        ("del15", "4:8:2", None),
        ("del15", "4:8:6", Some(Algorithm::GpuIm)),
        ("wal_598a", "4:8:4", None),
        ("sten_shipsec", "4:8:4", Some(Algorithm::GpuIm)),
    ];
    let requests: Vec<MapRequest> = workload
        .iter()
        .map(|&(inst, hier, algorithm)| MapRequest {
            instance: inst.into(),
            algorithm, // None → router decides
            hierarchy: hier.into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            polish: true,
            return_mapping: true,
            ..MapRequest::default()
        })
        .collect();

    println!(
        "| instance | hierarchy | routed to | J | imb | host ms | GPU ms (modeled) | polish ΔJ | speedup vs sharedmap-s |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    // submit_batch enqueues the whole batch before the first wait, so
    // both engine workers stay busy; replies come back in request order.
    let responses = svc.submit_batch(requests);
    // Library-path baseline: the same engine API, in process.
    let engine = Engine::with_defaults();
    let mut speedups: Vec<f64> = Vec::new();
    for (&(inst, hier, _), resp) in workload.iter().zip(responses) {
        let reply: MapReply = resp?;
        let out = &reply.outcome;
        // Validate the mapping end-to-end.
        let g = gen::generate_by_name(inst);
        let h = Machine::hier(hier, "1:10:100")?;
        assert_eq!(out.mapping.len(), g.n(), "requested mapping");
        partition::validate_mapping(&out.mapping, g.n(), h.k()).map_err(anyhow::Error::msg)?;
        assert!(
            partition::is_balanced(&g, &out.mapping, h.k(), 0.034),
            "{inst}: imbalance {:.4}",
            partition::imbalance(&g, &out.mapping, h.k())
        );
        let j_check = partition::comm_cost(&g, &out.mapping, &h);
        assert!((j_check - out.comm_cost).abs() < 1e-6 * j_check.max(1.0));

        // Serial baseline for the headline speedup.
        let baseline = engine.map(
            &MapSpec::named(inst)
                .hierarchy(hier)
                .distance("1:10:100")
                .algo(Some(Algorithm::SharedMapS)),
        )?;
        let speedup = baseline.host_ms / out.device_ms.max(1e-9);
        speedups.push(speedup);
        println!(
            "| {} | {} | {} | {:.0} | {:.4} | {:.1} | {:.2} | {:.0} | {:.0}x |",
            inst,
            hier,
            out.algorithm.name(),
            out.comm_cost,
            out.imbalance,
            out.host_ms,
            out.device_ms,
            out.polish_improvement,
            speedup
        );
    }

    let geo = heipa::harness::stats::geomean(&speedups);
    let m = svc.metrics();
    println!(
        "\nheadline: geometric-mean modeled speedup vs SharedMap-S = {geo:.0}x \
         (paper: GPU-IM 1454x, GPU-HM-ultra 22x on the full testbed)"
    );
    println!(
        "service metrics: {} requests, {} completed, {} failures, {} cancelled, per-algorithm {:?}",
        m.requests, m.completed, m.failures, m.cancelled, m.per_algorithm
    );
    Ok(())
}

/// Bind an ephemeral port and serve the coordinator protocol on it — the
/// very accept loop `heipa serve` runs.
fn spawn_tcp(svc: Arc<Service>) -> std::net::SocketAddr {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = protocol::serve_listener(svc, listener, ServeOptions::default());
    });
    addr
}
