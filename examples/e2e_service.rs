//! End-to-end driver: the full system on a real small workload.
//!
//! Starts the mapping-as-a-service coordinator, submits a batched stream
//! of mapping requests for the paper's workload families (rgg/del/mesh
//! task graphs) across machine hierarchies, exercising every layer:
//!
//!   TCP protocol → MapRequest → MapSpec → engine (router, GPU-IM /
//!   GPU-HM-ultra device pipelines) → PJRT-offloaded QAP polish
//!   (AOT JAX/Pallas kernel) → MapOutcome → metrics.
//!
//! Reports the paper's headline metric (communication cost J) per request
//! plus speedup vs the serial SharedMap-S baseline — the baseline runs
//! through the *library* front-end of the same engine API, demonstrating
//! that both paths share one code path. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_service
//! ```

use heipa::algo::Algorithm;
use heipa::coordinator::service::Service;
use heipa::coordinator::{MapReply, MapRequest};
use heipa::engine::{Engine, MapSpec};
use heipa::graph::gen;
use heipa::partition;
use heipa::topology::Machine;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let svc = Arc::new(Service::start("artifacts".into(), 0));

    // --- 1. TCP smoke: drive one request through the wire protocol. ----
    let addr = spawn_tcp(svc.clone());
    {
        let mut conn = std::net::TcpStream::connect(addr)?;
        writeln!(conn, "ping")?;
        writeln!(
            conn,
            "map instance=sten_cop20k algorithm=gpu-im hierarchy=4:8:2 distance=1:10:100 eps=0.03 seed=1"
        )?;
        let mut lines = BufReader::new(conn).lines();
        let pong = lines.next().unwrap()?;
        assert!(pong.contains("pong"), "bad ping reply: {pong}");
        let reply = lines.next().unwrap()?;
        assert!(reply.starts_with("ok "), "bad map reply: {reply}");
        println!("TCP protocol OK: {reply}\n");
    }

    // --- 2. Batched workload over the full stack. -----------------------
    let workload = [
        ("rgg15", "4:8:2", None),
        ("rgg15", "4:8:6", Some(Algorithm::GpuIm)),
        ("del15", "4:8:2", None),
        ("del15", "4:8:6", Some(Algorithm::GpuIm)),
        ("wal_598a", "4:8:4", None),
        ("sten_shipsec", "4:8:4", Some(Algorithm::GpuIm)),
    ];
    let requests: Vec<MapRequest> = workload
        .iter()
        .map(|&(inst, hier, algorithm)| MapRequest {
            instance: inst.into(),
            algorithm, // None → router decides
            hierarchy: hier.into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            polish: true,
            return_mapping: true,
            ..MapRequest::default()
        })
        .collect();

    println!(
        "| instance | hierarchy | routed to | J | imb | host ms | GPU ms (modeled) | polish ΔJ | speedup vs sharedmap-s |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    let responses = svc.submit_batch(requests);
    // Library-path baseline: the same engine API, in process.
    let engine = Engine::with_defaults();
    let mut speedups: Vec<f64> = Vec::new();
    for (&(inst, hier, _), resp) in workload.iter().zip(responses) {
        let reply: MapReply = resp?;
        let out = &reply.outcome;
        // Validate the mapping end-to-end.
        let g = gen::generate_by_name(inst);
        let h = Machine::hier(hier, "1:10:100")?;
        assert_eq!(out.mapping.len(), g.n(), "requested mapping");
        partition::validate_mapping(&out.mapping, g.n(), h.k()).map_err(anyhow::Error::msg)?;
        assert!(
            partition::is_balanced(&g, &out.mapping, h.k(), 0.034),
            "{inst}: imbalance {:.4}",
            partition::imbalance(&g, &out.mapping, h.k())
        );
        let j_check = partition::comm_cost(&g, &out.mapping, &h);
        assert!((j_check - out.comm_cost).abs() < 1e-6 * j_check.max(1.0));

        // Serial baseline for the headline speedup.
        let baseline = engine.map(
            &MapSpec::named(inst)
                .hierarchy(hier)
                .distance("1:10:100")
                .algo(Some(Algorithm::SharedMapS)),
        )?;
        let speedup = baseline.host_ms / out.device_ms.max(1e-9);
        speedups.push(speedup);
        println!(
            "| {} | {} | {} | {:.0} | {:.4} | {:.1} | {:.2} | {:.0} | {:.0}x |",
            inst,
            hier,
            out.algorithm.name(),
            out.comm_cost,
            out.imbalance,
            out.host_ms,
            out.device_ms,
            out.polish_improvement,
            speedup
        );
    }

    let geo = heipa::harness::stats::geomean(&speedups);
    let m = svc.metrics();
    println!(
        "\nheadline: geometric-mean modeled speedup vs SharedMap-S = {geo:.0}x \
         (paper: GPU-IM 1454x, GPU-HM-ultra 22x on the full testbed)"
    );
    println!(
        "service metrics: {} requests, {} failures, per-algorithm {:?}",
        m.requests, m.failures, m.per_algorithm
    );
    Ok(())
}

/// Bind an ephemeral port and serve the coordinator protocol on it.
fn spawn_tcp(svc: Arc<Service>) -> std::net::SocketAddr {
    use heipa::coordinator::protocol;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let svc = svc.clone();
            std::thread::spawn(move || {
                let reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    let reply = match protocol::parse_command(&line) {
                        Ok(protocol::Command::Ping) => "ok pong=1".to_string(),
                        Ok(protocol::Command::Metrics) => protocol::render_metrics(&svc.metrics()),
                        Ok(protocol::Command::Map(req)) => match svc.submit(req) {
                            Ok(resp) => protocol::render_response(&resp),
                            Err(e) => protocol::render_error(&e),
                        },
                        Err(e) => protocol::render_error(&e),
                    };
                    if writeln!(writer, "{reply}").is_err() {
                        break;
                    }
                }
            });
        }
    });
    addr
}
