//! Topology sweep: how the mapping cost and the adaptive imbalance react
//! as the machine grows (the paper's hierarchy family `4:8:{1..6}`,
//! `D = 1:10:100`).
//!
//! Also demonstrates the Eq. 2 ablation: with the adaptive ε′ disabled,
//! hierarchical multisection can violate the global balance constraint.
//!
//! ```bash
//! cargo run --release --example topology_sweep
//! ```

use heipa::algo::gpu_hm::{gpu_hm, GpuHmConfig};
use heipa::graph::gen;
use heipa::par::Pool;
use heipa::partition::{comm_cost, imbalance};
use heipa::topology::{paper_hierarchies, Hierarchy};

fn main() -> anyhow::Result<()> {
    let g = gen::delaunay_like(128, 7); // del-family mesh, 16k vertices
    println!("task graph: {}", g.summary());
    let pool = Pool::default();
    let eps = 0.03;

    println!("\n| hierarchy | k | J (GPU-HM) | imbalance | J/k (norm.) |");
    println!("|---|---|---|---|---|");
    for h in paper_hierarchies() {
        let m = gpu_hm(&pool, &g, &h, eps, 1, &GpuHmConfig::default_flavor(), None);
        let j = comm_cost(&g, &m, &h);
        println!(
            "| {} | {} | {:.0} | {:.4} | {:.1} |",
            h.label(),
            h.k(),
            j,
            imbalance(&g, &m, h.k()),
            j / h.k() as f64
        );
    }

    // Eq. 2 ablation on the largest machine.
    let h = Hierarchy::parse("4:8:6", "1:10:100")?;
    let adaptive = GpuHmConfig::default_flavor();
    let fixed = GpuHmConfig { adaptive: false, ..GpuHmConfig::default_flavor() };
    let m_a = gpu_hm(&pool, &g, &h, eps, 1, &adaptive, None);
    let m_f = gpu_hm(&pool, &g, &h, eps, 1, &fixed, None);
    println!("\nEq. 2 adaptive imbalance ablation (k = {}):", h.k());
    println!(
        "  adaptive ε': J = {:.0}, imbalance = {:.4} (guaranteed ≤ ε = {eps})",
        comm_cost(&g, &m_a, &h),
        imbalance(&g, &m_a, h.k())
    );
    println!(
        "  fixed ε   : J = {:.0}, imbalance = {:.4} (can exceed ε)",
        comm_cost(&g, &m_f, &h),
        imbalance(&g, &m_f, h.k())
    );
    Ok(())
}
