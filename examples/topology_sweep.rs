//! Topology sweep: how the mapping cost and the adaptive imbalance react
//! as the machine grows (the paper's hierarchy family `4:8:{1..6}`,
//! `D = 1:10:100`).
//!
//! Also demonstrates the Eq. 2 ablation through the engine's solver
//! options: with `adaptive = 0`, hierarchical multisection can violate
//! the global balance constraint.
//!
//! ```bash
//! cargo run --release --example topology_sweep
//! ```

use heipa::algo::Algorithm;
use heipa::engine::{Engine, MapSpec};
use heipa::graph::gen;
use heipa::topology::{paper_hierarchies, Machine};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let g = Arc::new(gen::delaunay_like(128, 7)); // del-family mesh, 16k vertices
    println!("task graph: {}", g.summary());
    let engine = Engine::with_defaults();
    let base = MapSpec::in_memory(g).algo(Some(Algorithm::GpuHm)).eps(0.03);

    println!("\n| hierarchy | k | J (GPU-HM) | imbalance | J/k (norm.) |");
    println!("|---|---|---|---|---|");
    for h in paper_hierarchies() {
        let h = Machine::from(h);
        let r = engine.map(&base.clone().topology(&h))?;
        println!(
            "| {} | {} | {:.0} | {:.4} | {:.1} |",
            h.label(),
            r.k,
            r.comm_cost,
            r.imbalance,
            r.comm_cost / r.k as f64
        );
    }

    // Eq. 2 ablation on the largest machine.
    let h = Machine::hier("4:8:6", "1:10:100")?;
    let r_adaptive = engine.map(&base.clone().topology(&h))?;
    let r_fixed = engine.map(&base.clone().topology(&h).option("adaptive", "0"))?;
    println!("\nEq. 2 adaptive imbalance ablation (k = {}):", h.k());
    println!(
        "  adaptive ε': J = {:.0}, imbalance = {:.4} (guaranteed ≤ ε = 0.03)",
        r_adaptive.comm_cost, r_adaptive.imbalance
    );
    println!(
        "  fixed ε   : J = {:.0}, imbalance = {:.4} (can exceed ε)",
        r_fixed.comm_cost, r_fixed.imbalance
    );
    Ok(())
}
