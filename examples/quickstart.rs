//! Quickstart: map a task graph onto a hierarchical machine in a few
//! lines — the library's front door is one `Engine` and one `MapSpec`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use heipa::algo::Algorithm;
use heipa::engine::{Engine, MapSpec};
use heipa::graph::gen;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // A task graph: 2^15-point random geometric graph (the paper's rgg
    // family, scaled), standing in for a scientific-simulation workload.
    let g = Arc::new(gen::rgg(1 << 15, gen::rgg_paper_radius(1 << 15), 42));
    println!("task graph: {}", g.summary());

    // A supercomputer: 4 PEs/processor, 8 processors/node, 2 nodes;
    // intra-processor traffic costs 1, intra-node 10, inter-node 100.
    // The spec carries the whole problem; the engine owns pool + runtime.
    let engine = Engine::with_defaults();
    let spec = MapSpec::in_memory(g).hierarchy("4:8:2").distance("1:10:100");
    println!("machine: k={} PEs", spec.parse_hierarchy()?.k());

    // The paper's two GPU algorithms plus the strongest CPU baseline.
    for algo in [Algorithm::GpuIm, Algorithm::GpuHmUltra, Algorithm::SharedMapF] {
        let r = engine.map(&spec.clone().algo(Some(algo)))?;
        println!(
            "{:>14}: J = {:>12.0}  imbalance = {:.4}  host = {:>8.1} ms  modeled-GPU = {:>7.2} ms",
            r.algorithm.name(),
            r.comm_cost,
            r.imbalance,
            r.host_ms,
            r.device_ms
        );
    }
    println!("\n(lower J is better; GPU algorithms also report modeled RTX-4090 time — DESIGN.md §1)");
    Ok(())
}
