// EXPECT: 0
// AT: topology/fixture_annotated.rs
//! A reviewed `unsafe` site outside the allowlist, explicitly annotated:
//! both rules are satisfied.

pub fn peek(v: &[u32]) -> u32 {
    // lint: allow-unsafe
    // SAFETY: caller guarantees v is non-empty.
    unsafe { *v.get_unchecked(0) }
}
