// EXPECT: 0
// AT: engine/fixture_good.rs
//! Clean fixture: no unsafe, every Relaxed justified.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    // relaxed: monotone statistics counter, read approximately.
    c.fetch_add(1, Ordering::Relaxed)
}

pub fn stringly() -> &'static str {
    // The keyword inside a string must not trip the lint:
    "unsafe Ordering::Relaxed"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relaxed_in_tests_needs_no_comment() {
        let c = AtomicU64::new(0);
        bump(&c);
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }
}
