// EXPECT: 1
// AT: par/fixture_bad_safety.rs
//! `unsafe` under `par/` (allowlisted by prefix) but with no SAFETY
//! comment: rule B fires.

pub fn peek(v: &[u32]) -> u32 {
    unsafe { *v.get_unchecked(0) }
}
