// EXPECT: 1
// AT: engine/fixture_bad_unsafe.rs
//! `unsafe` in a file outside `par/` and the allowlist: rule A fires even
//! though the SAFETY comment satisfies rule B.

pub fn peek(v: &[u32]) -> u32 {
    // SAFETY: caller guarantees v is non-empty.
    unsafe { *v.get_unchecked(0) }
}
