// EXPECT: 1
// AT: engine/fixture_bad_relaxed.rs
//! `Ordering::Relaxed` with no justification comment: rule C fires.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}
