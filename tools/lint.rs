//! Static unsafe-contract lint for the device substrate.
//!
//! Standalone (std-only, no Cargo needed):
//!
//! ```text
//! rustc -O tools/lint.rs -o /tmp/heipa-lint
//! /tmp/heipa-lint rust/src                  # lint the tree
//! /tmp/heipa-lint --self-test tools/lint_fixtures
//! /tmp/heipa-lint rust/src --report lint-report.txt
//! ```
//!
//! Rules (comments and string/char literals are stripped before keyword
//! matching; `tools/../shadow` implementations must stay in sync):
//!
//! * **A — unsafe allowlist.** The word `unsafe` may appear only in files
//!   under `par/` or in the seeded [`ALLOWLIST`], unless the site carries a
//!   `lint: allow-unsafe` annotation on the same line's comment or on a
//!   comment line directly above. New unsafe code elsewhere must either be
//!   moved behind the `par` primitives or explicitly annotated and
//!   reviewed.
//! * **B — SAFETY comments.** Every line bearing `unsafe` must reach a
//!   comment containing `SAFETY` (or `Safety`, covering `# Safety` rustdoc
//!   sections) by walking up through lines that are blank, comments,
//!   attributes, or themselves bear `unsafe`.
//! * **C — Relaxed justifications.** Every `Ordering::Relaxed` outside a
//!   `#[cfg(test)] mod` region must have a comment containing `relaxed:`
//!   (case-insensitive) on the same line or within the 12 preceding lines.
//!
//! Exit status: 0 when clean, 1 when problems were found (or a self-test
//! fixture disagreed with its `EXPECT:` header), 2 on usage errors.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files outside `par/` that legitimately contain `unsafe` today (each
/// site individually carries a SAFETY comment; rule B still applies).
/// Grow this list deliberately — prefer routing new code through the
/// `par::SharedMut` / `par::AtomicList` primitives instead.
const ALLOWLIST: &[&str] = &[
    "coarsen/contract_cas.rs",
    "graph/mod.rs",
    "graph/subgraph.rs",
    "multilevel/hierarchy.rs",
    "refine/jet_loop.rs",
    "refine/jet_lp.rs",
    "refine/rebalance.rs",
];

/// One finding: file-relative path, 1-based line, message.
struct Problem {
    rel: String,
    line: usize,
    msg: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut report: Option<PathBuf> = None;
    let mut self_test = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--self-test" => self_test = true,
            "--report" => {
                i += 1;
                match args.get(i) {
                    Some(p) => report = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("--report needs a file argument");
                        return ExitCode::from(2);
                    }
                }
            }
            "--help" | "-h" => {
                eprintln!("usage: lint [--self-test] [--report FILE] DIR");
                return ExitCode::SUCCESS;
            }
            other => {
                if root.is_some() {
                    eprintln!("unexpected argument: {other}");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(other));
            }
        }
        i += 1;
    }
    let root = root.unwrap_or_else(|| PathBuf::from("rust/src"));
    if !root.is_dir() {
        eprintln!("not a directory: {}", root.display());
        return ExitCode::from(2);
    }

    if self_test {
        return run_self_test(&root);
    }

    let mut problems = Vec::new();
    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    files.sort();
    for path in &files {
        let rel = rel_of(path, &root);
        match fs::read_to_string(path) {
            Ok(src) => lint_source(&src, &rel, &mut problems),
            Err(e) => {
                eprintln!("cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }

    let mut out = String::new();
    for p in &problems {
        out.push_str(&format!("{}:{}: {}\n", p.rel, p.line, p.msg));
    }
    out.push_str(&format!(
        "-- {} problem(s) in {} file(s)\n",
        problems.len(),
        files.len()
    ));
    print!("{out}");
    if let Some(r) = report {
        if let Err(e) = fs::write(&r, &out) {
            eprintln!("cannot write report {}: {e}", r.display());
            return ExitCode::from(2);
        }
    }
    if problems.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Self-test mode: every `*.rs` fixture carries an `// EXPECT: N` header;
/// the lint must report exactly `N` problems for that file (fixtures are
/// linted as if they lived at the repo-relative path named by an optional
/// `// AT: path` header, default the fixture's own file name).
fn run_self_test(dir: &Path) -> ExitCode {
    let mut files = Vec::new();
    collect_rs_files(dir, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("self-test: no fixtures under {}", dir.display());
        return ExitCode::from(2);
    }
    let mut failed = 0usize;
    for path in &files {
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let mut expect: Option<usize> = None;
        let mut at: Option<String> = None;
        for line in src.lines().take(5) {
            let t = line.trim();
            if let Some(rest) = t.strip_prefix("// EXPECT:") {
                expect = rest.trim().parse().ok();
            } else if let Some(rest) = t.strip_prefix("// AT:") {
                at = Some(rest.trim().to_string());
            }
        }
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let rel = at.unwrap_or_else(|| name.clone());
        let expect = match expect {
            Some(n) => n,
            None => {
                eprintln!("self-test: {name} lacks an `// EXPECT: N` header");
                failed += 1;
                continue;
            }
        };
        let mut problems = Vec::new();
        lint_source(&src, &rel, &mut problems);
        if problems.len() == expect {
            println!("self-test: {name} ok ({expect} problem(s))");
        } else {
            println!(
                "self-test: {name} FAILED — expected {expect}, found {}:",
                problems.len()
            );
            for p in &problems {
                println!("    {}:{}: {}", p.rel, p.line, p.msg);
            }
            failed += 1;
        }
    }
    if failed == 0 {
        println!("self-test: all {} fixture(s) ok", files.len());
        ExitCode::SUCCESS
    } else {
        println!("self-test: {failed} fixture(s) FAILED");
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

fn rel_of(path: &Path, root: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Split one physical line into (code, comment) with string/char literals
/// removed from the code part. `in_block` tracks `/* ... */` across lines.
fn strip_line(line: &str, in_block: &mut bool) -> (String, String) {
    let mut code = String::new();
    let mut comment = String::new();
    let b: Vec<char> = line.chars().collect();
    let n = b.len();
    let mut i = 0;
    let mut in_str = false;
    while i < n {
        if *in_block {
            // inside /* ... */
            if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                *in_block = false;
                i += 2;
            } else {
                comment.push(b[i]);
                i += 1;
            }
            continue;
        }
        if in_str {
            if b[i] == '\\' {
                i += 2;
                continue;
            }
            if b[i] == '"' {
                in_str = false;
            }
            i += 1;
            continue;
        }
        match b[i] {
            '"' => {
                in_str = true;
                i += 1;
            }
            '\'' => {
                // char literal ('x', '\n') or lifetime ('a) — skip the
                // closed forms, treat lifetimes as plain code.
                if i + 2 < n && b[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && b[j] != '\'' {
                        j += 1;
                    }
                    i = if j < n { j + 1 } else { i + 2 };
                } else if i + 2 < n && b[i + 2] == '\'' {
                    i += 3;
                } else {
                    i += 1;
                }
            }
            '/' if i + 1 < n && b[i + 1] == '/' => {
                comment.extend(&b[i + 2..]);
                i = n;
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                *in_block = true;
                i += 2;
            }
            c => {
                code.push(c);
                i += 1;
            }
        }
    }
    (code, comment)
}

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Word-boundary match of `word` in `text`.
fn has_word(text: &str, word: &str) -> bool {
    let tb = text.as_bytes();
    let mut from = 0;
    while let Some(pos) = text[from..].find(word) {
        let a = from + pos;
        let b = a + word.len();
        let left_ok = a == 0 || !is_word_char(tb[a - 1] as char);
        let right_ok = b == text.len() || !is_word_char(tb[b] as char);
        if left_ok && right_ok {
            return true;
        }
        from = b;
    }
    false
}

fn lint_source(src: &str, rel: &str, problems: &mut Vec<Problem>) {
    let mut code_lines: Vec<String> = Vec::new();
    let mut comment_lines: Vec<String> = Vec::new();
    let mut in_block = false;
    for line in src.lines() {
        let (c, m) = strip_line(line, &mut in_block);
        code_lines.push(c);
        comment_lines.push(m);
    }
    let nlines = code_lines.len();

    // Start of the `#[cfg(test)] mod …` region, if any (the Relaxed rule
    // does not apply inside tests; unsafe rules still do).
    let mut test_start = nlines;
    'scan: for i in 0..nlines {
        let squeezed: String =
            code_lines[i].chars().filter(|c| !c.is_whitespace()).collect();
        if squeezed.contains("#[cfg(test)]") {
            let hi = (i + 4).min(nlines);
            for j in (i + 1)..hi {
                if has_word(&code_lines[j], "mod") {
                    test_start = i;
                    break 'scan;
                }
            }
        }
    }

    let in_par = rel.starts_with("par/");
    let allowed_file = in_par || ALLOWLIST.contains(&rel);

    for i in 0..nlines {
        let c = &code_lines[i];
        if has_word(c, "unsafe") {
            // Rule A: allowlist or per-site annotation.
            if !allowed_file {
                let mut marked = comment_lines[i].contains("lint: allow-unsafe");
                let mut k = i;
                while !marked && k > 0 {
                    k -= 1;
                    if !code_lines[k].trim().is_empty() {
                        break;
                    }
                    if comment_lines[k].contains("lint: allow-unsafe") {
                        marked = true;
                    }
                    if comment_lines[k].is_empty() {
                        break;
                    }
                }
                if !marked {
                    problems.push(Problem {
                        rel: rel.to_string(),
                        line: i + 1,
                        msg: "unsafe outside allowlist (add to tools/lint.rs \
                              ALLOWLIST or annotate `// lint: allow-unsafe`)"
                            .to_string(),
                    });
                }
            }
            // Rule B: a SAFETY comment must be reachable upwards.
            let mut ok = comment_lines[i].contains("SAFETY")
                || comment_lines[i].contains("Safety");
            let mut j = i;
            while !ok && j > 0 {
                j -= 1;
                let mj = &comment_lines[j];
                if mj.contains("SAFETY") || mj.contains("Safety") {
                    ok = true;
                    break;
                }
                let cj = code_lines[j].trim();
                if cj.is_empty() || cj.starts_with("#[") || has_word(&code_lines[j], "unsafe")
                {
                    continue;
                }
                break;
            }
            if !ok {
                problems.push(Problem {
                    rel: rel.to_string(),
                    line: i + 1,
                    msg: "`unsafe` without a SAFETY comment".to_string(),
                });
            }
        }
        // Rule C: Relaxed justification (non-test code only).
        if c.contains("Ordering::Relaxed") && i < test_start {
            let lo = i.saturating_sub(12);
            let ok = (lo..=i).any(|j| comment_lines[j].to_lowercase().contains("relaxed:"));
            if !ok {
                problems.push(Problem {
                    rel: rel.to_string(),
                    line: i + 1,
                    msg: "Ordering::Relaxed without a `relaxed:` justification comment"
                        .to_string(),
                });
            }
        }
    }
}
