#!/usr/bin/env python3
"""Soft bench-regression diff: compare freshly produced BENCH_*.json
files against the in-repo baselines (rust/benches/baselines/).

Matches records by their identity fields (every string-valued field:
bench/graph/mode/scheme/scenario/...), then compares the measurement
fields. Time-like fields (wall_ms, p99_ms, host_ms, device_ms) warn past
--time-ratio (default 1.5x); quality fields (j, objective) warn past
--quality-ratio (default 1.05x). Empty baselines (the schema skeletons)
are skipped silently.

Exit code is always 0 unless --strict is passed: CI runs this as a
non-blocking soft-warning step, because smoke-sized wall clocks on
shared runners are too noisy to gate merges on.

Stdlib only; no third-party imports.
"""

import argparse
import json
import os
import sys

TIME_KEYS = ("wall_ms", "p99_ms", "host_ms", "device_ms")
QUALITY_KEYS = ("j", "objective")


def load_records(path):
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        return doc.get("records", [])
    if isinstance(doc, list):
        return doc
    return []


def identity(rec):
    """The identity of a record: its string-valued fields, sorted."""
    return tuple(sorted((k, v) for k, v in rec.items() if isinstance(v, str)))


def index(records):
    by_id = {}
    for rec in records:
        by_id.setdefault(identity(rec), []).append(rec)
    return by_id


def diff_file(name, baseline_path, current_path, time_ratio, quality_ratio):
    base = load_records(baseline_path)
    cur = load_records(current_path)
    warnings = []
    if not base:
        print(f"{name}: baseline is an empty skeleton, nothing to compare")
        return warnings
    if not cur:
        warnings.append(f"{name}: current run produced no records (baseline has {len(base)})")
        return warnings
    base_by_id, cur_by_id = index(base), index(cur)
    for key, base_recs in base_by_id.items():
        cur_recs = cur_by_id.get(key)
        if cur_recs is None:
            label = " ".join(f"{k}={v}" for k, v in key)
            warnings.append(f"{name}: record [{label}] vanished from the current run")
            continue
        for b, c in zip(base_recs, cur_recs):
            label = " ".join(f"{k}={v}" for k, v in key)
            for field, ratio in [(f, time_ratio) for f in TIME_KEYS] + [
                (f, quality_ratio) for f in QUALITY_KEYS
            ]:
                bv, cv = b.get(field), c.get(field)
                if not isinstance(bv, (int, float)) or not isinstance(cv, (int, float)):
                    continue
                if bv > 1e-9 and cv > ratio * bv:
                    warnings.append(
                        f"{name}: [{label}] {field} {bv:.3f} -> {cv:.3f} "
                        f"({cv / bv:.2f}x, threshold {ratio:.2f}x)"
                    )
    return warnings


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="benches/baselines", help="baseline directory")
    ap.add_argument("--current", default=".", help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--time-ratio", type=float, default=1.5)
    ap.add_argument("--quality-ratio", type=float, default=1.05)
    ap.add_argument("--strict", action="store_true", help="exit 1 when any warning fires")
    args = ap.parse_args()

    names = sorted(
        f for f in os.listdir(args.baseline) if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print(f"no BENCH_*.json baselines under {args.baseline}", file=sys.stderr)
        return 1

    all_warnings = []
    for name in names:
        current_path = os.path.join(args.current, name)
        if not os.path.exists(current_path):
            print(f"{name}: not produced by this run, skipping")
            continue
        all_warnings += diff_file(
            name,
            os.path.join(args.baseline, name),
            current_path,
            args.time_ratio,
            args.quality_ratio,
        )

    if all_warnings:
        print(f"\n{len(all_warnings)} bench-diff warning(s):")
        for w in all_warnings:
            print(f"  WARNING: {w}")
    else:
        print("\nbench-diff: no regressions past thresholds")
    return 1 if (args.strict and all_warnings) else 0


if __name__ == "__main__":
    sys.exit(main())
