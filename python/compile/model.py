"""L2 compute graph: the device-side QAP swap step.

`qap_step(W, D, P)` composes the L1 Pallas kernels into the computation
the Rust coordinator executes per refinement sweep:

* `delta` — exact objective change for all k x k block swaps,
* `j`     — the current block-level communication cost.

This module is build-time only: `aot.py` lowers `qap_step` once per padded
size and the Rust runtime executes the artifacts; Python is never on the
request path.
"""

import jax

from .kernels import qap_swap


def qap_step(w: jax.Array, d: jax.Array, p: jax.Array):
    """One device sweep: (delta[k,k], j[]) from W, D, one-hot P."""
    delta, j = qap_swap.qap_swap_kernel(w, d, p)
    return delta, j


def qap_step_jit(k: int):
    """Jitted `qap_step` specialized to f32[k,k] inputs."""
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((k, k), jnp.float32)
    return jax.jit(qap_step).lower(spec, spec, spec)
