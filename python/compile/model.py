"""L2 compute graphs: the device-side programs the Rust runtime executes.

* `qap_step(W, D, P)` — one QAP swap scoring sweep (delta[k,k], j),
* `qap_sweep(W, D, sigma, k)` — a batch of greedy swap sweeps with sigma
  resident on device,
* `match_round` / `contract_gather` / `jet_round` — the batched
  multilevel graph kernels over a padded edge list (one launch per
  superstep).

This module is build-time only: `aot.py` lowers each program once per
padded size and the Rust runtime executes the artifacts; Python is never
on the request path. The graph/batched kernels need `jax_enable_x64`
(aot.py sets it before lowering).
"""

import jax

from .kernels import graph, qap_batch, qap_swap


def qap_step(w: jax.Array, d: jax.Array, p: jax.Array):
    """One device sweep: (delta[k,k], j[]) from W, D, one-hot P."""
    delta, j = qap_swap.qap_swap_kernel(w, d, p)
    return delta, j


def qap_step_jit(k: int):
    """Jitted `qap_step` specialized to f32[k,k] inputs."""
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((k, k), jnp.float32)
    return jax.jit(qap_step).lower(spec, spec, spec)


def qap_sweep_jit(k: int):
    """Jitted `qap_sweep`: f32[k,k] W/D, i32[k] sigma, i64[1] actual k."""
    import jax.numpy as jnp

    mat = jax.ShapeDtypeStruct((k, k), jnp.float32)
    sig = jax.ShapeDtypeStruct((k,), jnp.int32)
    kk = jax.ShapeDtypeStruct((1,), jnp.int64)
    return jax.jit(qap_batch.qap_sweep).lower(mat, mat, sig, kk)


def _edge_specs(n: int):
    """Shape specs for the padded edge list of graph class `n` (m = 8n)."""
    import jax.numpy as jnp

    m = 8 * n
    return (
        jax.ShapeDtypeStruct((m,), jnp.int32),  # eu
        jax.ShapeDtypeStruct((m,), jnp.int32),  # adj
        jax.ShapeDtypeStruct((m,), jnp.float64),  # ew
    )


def match_round_jit(n: int):
    """Jitted one-launch preference-matching round for graph class `n`."""
    import jax.numpy as jnp

    eu, adj, ew = _edge_specs(n)
    vw = jax.ShapeDtypeStruct((n,), jnp.float64)
    mate = jax.ShapeDtypeStruct((n,), jnp.int32)
    nm = jax.ShapeDtypeStruct((2,), jnp.int64)
    maxw = jax.ShapeDtypeStruct((1,), jnp.float64)
    seed = jax.ShapeDtypeStruct((1,), jnp.uint64)
    return jax.jit(graph.match_round).lower(eu, adj, ew, vw, mate, nm, maxw, seed)


def contract_gather_jit(n: int):
    """Jitted contraction endpoint-gather for graph class `n`."""
    import jax.numpy as jnp

    eu, adj, _ = _edge_specs(n)
    cmap = jax.ShapeDtypeStruct((n,), jnp.int32)
    nm = jax.ShapeDtypeStruct((2,), jnp.int64)
    return jax.jit(graph.contract_gather).lower(eu, adj, cmap, nm)


def jet_round_jit(n: int):
    """Jitted Jet candidate-selection superstep for graph class `n`."""
    import jax.numpy as jnp

    eu, adj, ew = _edge_specs(n)
    part = jax.ShapeDtypeStruct((n,), jnp.int32)
    locked = jax.ShapeDtypeStruct((n,), jnp.int32)
    dmat = jax.ShapeDtypeStruct((graph.JET_K, graph.JET_K), jnp.float64)
    nmk = jax.ShapeDtypeStruct((3,), jnp.int64)
    return jax.jit(graph.jet_round).lower(eu, adj, ew, part, locked, dmat, nmk)
