# L1: Pallas/device kernels for the paper's compute hot-spots: the dense
# QAP swap search (qap_swap, batched in qap_batch) and the irregular
# multilevel graph phases (graph: matching, contraction, Jet gains).
