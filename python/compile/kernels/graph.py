"""L1 device kernels for the irregular multilevel graph phases.

The paper's coarsening/refinement hot spots are CSR sweeps; here each is
reformulated as one *batched* device program over a padded edge list so a
whole superstep is a single PJRT launch:

* `match_round`     — one round of heavy-edge preference matching:
                      per-edge ratings -> per-vertex best preference ->
                      mutual handshake, all on device,
* `contract_gather` — the gather half of CAS contraction: map both edge
                      endpoints through the coarse map in one launch,
* `jet_round`       — Jet candidate selection: dense per-vertex block
                      connectivity (segment-sum) x distance matrix
                      (Pallas f64 matmul) -> best destination + gain.

Graphs are padded to the compiled class size `n` (with `m = 8·n` edge
slots); the actual `n`/`m`/`k` arrive as scalar operands so one artifact
serves every graph below its class. Ratings replicate the Rust host
bit-for-bit: `rating_exp2 = w²/(c(u)·c(v))` plus the `1e-12`-scaled
splitmix64 edge noise from `rust/src/rng.rs`, so device and CPU matchings
agree exactly. Requires `jax_enable_x64` (f64 weights, u64 noise).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Jet kernels are compiled for one dense-block class: k <= 256.
JET_K = 256

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(h: jax.Array) -> jax.Array:
    """One splitmix64 draw from state `h` (uint64, wrapping) — the exact
    finalizer in `rust/src/rng.rs::splitmix64`."""
    h = h + _GOLDEN
    z = h
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


def _edge_noise(u: jax.Array, v: jax.Array, seed: jax.Array) -> jax.Array:
    """Symmetric per-edge tie-break noise in [0,1) — bit-for-bit
    `rust/src/rng.rs::edge_noise` (min/max endpoint packing, one
    splitmix64 draw, 53-bit mantissa scaling)."""
    a = jnp.minimum(u, v).astype(jnp.uint64)
    b = jnp.maximum(u, v).astype(jnp.uint64)
    h = seed ^ ((a << np.uint64(32)) | b)
    x = _splitmix64(h)
    return (x >> np.uint64(11)).astype(jnp.float64) * 2.0**-53


def match_round(eu, adj, ew, vw, mate, nm, maxw, seed):
    """One preference-matching round over the padded directed edge list.

    Inputs: `eu`/`adj` i32[M] edge endpoints, `ew` f64[M], `vw` f64[N]
    (i64 vertex weights, exact below 2^53), `mate` i32[N] with -1 =
    unmatched, `nm` i64[2] = [n, m], `maxw` f64[1] max pair weight,
    `seed` u64[1]. Returns `(pref i32[N], mate' i32[N])`; the host counts
    `mate' != mate` (two per new pair, as the CPU kernel does) and decides
    the stop condition.
    """
    big_n = vw.shape[0]
    n, m = nm[0], nm[1]
    iota_v = jnp.arange(big_n, dtype=jnp.int32)
    iota_e = jnp.arange(eu.shape[0], dtype=jnp.int64)

    # Per-edge rating, -inf where the edge can't participate: padding,
    # either endpoint matched, or the pair weight cap exceeded.
    valid = (
        (iota_e < m)
        & (mate[eu] == -1)
        & (mate[adj] == -1)
        & (vw[eu] + vw[adj] <= maxw[0])
    )
    r = (ew * ew) / (vw[eu] * vw[adj]) + 1e-12 * _edge_noise(eu, adj, seed[0])
    r = jnp.where(valid, r, -jnp.inf)

    # Best preference per vertex: max rating, ties to the smallest
    # neighbor id — two segment passes reproduce the CPU scan's
    # `r > best || (r == best && u < best_u)` rule exactly.
    best = jax.ops.segment_max(r, eu, num_segments=big_n)
    is_best = valid & (r == best[eu])
    cand = jnp.where(is_best, adj, jnp.int32(big_n))
    pref = jax.ops.segment_min(cand, eu, num_segments=big_n)
    pref = jnp.where((pref >= 0) & (pref < jnp.int32(big_n)), pref, jnp.int32(-1))

    # Mutual handshake: v and pref[v] chose each other.
    pp = pref[jnp.clip(pref, 0, big_n - 1)]
    mutual = (pref >= 0) & (pp == iota_v) & (iota_v.astype(jnp.int64) < n)
    mate_new = jnp.where(mutual, pref, mate)
    return pref, mate_new


def contract_gather(eu, adj, cmap, nm):
    """CAS-contraction gather: both endpoints of every edge mapped through
    the coarse vertex map in one launch. Inputs `eu`/`adj` i32[M], `cmap`
    i32[N], `nm` i64[2] = [n, m]; returns `(cu i32[M], cv i32[M])` with -1
    in the padding slots (the host reads only the first `m`)."""
    iota_e = jnp.arange(eu.shape[0], dtype=jnp.int64)
    live = iota_e < nm[1]
    cu = jnp.where(live, cmap[eu], jnp.int32(-1))
    cv = jnp.where(live, cmap[adj], jnp.int32(-1))
    return cu, cv


def _matmul_f64_kernel(a_ref, b_ref, o_ref):
    """Rectangular f64 tile-matmul, accumulated over the inner grid axis
    (same revisited-VMEM-tile idiom as `qap_swap._matmul_kernel`)."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float64)


def matmul_f64(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tiled Pallas matmul C = A @ B for f64 A[n,k], B[k,k]."""
    n, k = a.shape
    assert b.shape == (k, k)
    bt = 128
    grid = (n // bt, k // bt, k // bt)
    return pl.pallas_call(
        _matmul_f64_kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float64),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bt), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bt, bt), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bt, bt), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)


def jet_round(eu, adj, ew, part, locked, dmat, nmk):
    """Jet candidate selection for one LP superstep.

    Dense per-vertex block connectivity `conn[v,b] = Σ w(v,u)·[part(u)=b]`
    by segment-sum, then `G = conn @ D` (Pallas f64 matmul) gives every
    move's gain at once: `gain(v, from→b) = G[v,from] − G[v,b]` (paper
    Eq. 1 for the Comm objective, exactly `refine::Objective::gain`).

    Inputs: `eu`/`adj` i32[M], `ew` f64[M], `part` i32[N], `locked`
    i32[N] (non-zero = skip), `dmat` f64[256,256] zero-padded distance
    matrix, `nmk` i64[3] = [n, m, k]. Returns `(dest i32[N], gain
    f64[N])`: the best destination block per vertex (ties to the smallest
    block id, matching the CPU scan) or -1 for locked/padded/no-move
    vertices; the host applies the Jet filter to `gain`.
    """
    big_n = part.shape[0]
    n, m, k = nmk[0], nmk[1], nmk[2]
    iota_v = jnp.arange(big_n, dtype=jnp.int32)
    iota_e = jnp.arange(eu.shape[0], dtype=jnp.int64)
    iota_b = jnp.arange(JET_K, dtype=jnp.int64)

    ids = eu * JET_K + part[adj]
    vals = jnp.where(iota_e < m, ew, 0.0)
    conn = jax.ops.segment_sum(vals, ids, num_segments=big_n * JET_K)
    g = matmul_f64(conn.reshape(big_n, JET_K), dmat)

    frm = part
    g_from = jnp.take_along_axis(g, frm[:, None].astype(jnp.int64), axis=1)
    score = g_from - g
    movable = (iota_b[None, :] < k) & (iota_b[None, :] != frm[:, None].astype(jnp.int64))
    score = jnp.where(movable, score, -jnp.inf)
    dest = jnp.argmax(score, axis=1).astype(jnp.int32)
    gain = jnp.take_along_axis(score, dest[:, None].astype(jnp.int64), axis=1)[:, 0]

    ok = (iota_v.astype(jnp.int64) < n) & (locked == 0) & jnp.isfinite(gain)
    dest = jnp.where(ok, dest, jnp.int32(-1))
    gain = jnp.where(ok, gain, 0.0)
    return dest, gain
