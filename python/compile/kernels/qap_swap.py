"""L1 Pallas kernels for the device-side QAP swap search.

The paper's GPU hot spots are irregular CSR sweeps; the dense hot spot of
the *two-phase* pipeline — evaluating all O(k^2) block-swap candidates on
the communication model graph — is reformulated here for matrix units
(DESIGN.md "Hardware adaptation"):

    E     = P @ D @ P^T          (permuted distance matrix)
    M     = W @ E                (all row-interaction sums; E symmetric)
    delta = 2*(M + M^T - diag(M) - diag(M)^T + 2 * W ⊙ E)
    J     = sum(W ⊙ E)

`delta[x, y]` is the exact change of the mapping objective J if blocks x
and y swap PEs; two matmuls amortize the whole O(k^3) sweep onto the MXU.

Kernels are written with `pl.pallas_call(..., interpret=True)`: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode lowers
them to plain HLO (numerics identical; real-TPU tiling estimated in
EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edge for the matmul grid. 128 matches the MXU systolic array; the
# k=32/64 variants use a single full-size tile.
def _tile(k: int) -> int:
    return min(k, 128)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile, accumulated over the k-grid axis.

    The output block's index map ignores the k axis, so the same VMEM tile
    is revisited across k steps — the standard Pallas accumulation idiom
    (no HBM round-trips between partial products).
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tiled Pallas matmul C = A @ B for square f32 matrices."""
    k = a.shape[0]
    assert a.shape == (k, k) and b.shape == (k, k)
    bt = _tile(k)
    n_k = k // bt
    grid = (k // bt, k // bt, n_k)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bt), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bt, bt), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bt, bt), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)


def _combine_kernel(m_ref, mt_ref, drow_ref, dcol_ref, w_ref, e_ref, o_ref):
    """delta = 2*(M + M^T - diag_row - diag_col + 2*W.*E), elementwise."""
    o_ref[...] = 2.0 * (
        m_ref[...]
        + mt_ref[...]
        - drow_ref[...]
        - dcol_ref[...]
        + 2.0 * w_ref[...] * e_ref[...]
    )


def combine(m, mt, drow, dcol, w, e):
    """Elementwise delta combination as a tiled Pallas kernel."""
    k = m.shape[0]
    bt = _tile(k)
    grid = (k // bt, k // bt)
    spec = pl.BlockSpec((bt, bt), lambda i, j: (i, j))
    return pl.pallas_call(
        _combine_kernel,
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=spec,
        interpret=True,
    )(m, mt, drow, dcol, w, e)


def _weighted_sum_kernel(w_ref, e_ref, o_ref):
    """Tile-wise partial sums of W ⊙ E (reduced outside)."""
    o_ref[0, 0] = jnp.sum(w_ref[...] * e_ref[...])


def weighted_cost(w: jax.Array, e: jax.Array) -> jax.Array:
    """J = sum(W ⊙ E) via a tiled Pallas partial-reduction."""
    k = w.shape[0]
    bt = _tile(k)
    grid = (k // bt, k // bt)
    partials = pl.pallas_call(
        _weighted_sum_kernel,
        out_shape=jax.ShapeDtypeStruct((k // bt, k // bt), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bt), lambda i, j: (i, j)),
            pl.BlockSpec((bt, bt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        interpret=True,
    )(w, e)
    return jnp.sum(partials)


def qap_swap_kernel(w: jax.Array, d: jax.Array, p: jax.Array):
    """Full device step: (delta, J) from W, D and one-hot assignment P."""
    pd = matmul(p, d)  # P @ D
    e = matmul(pd, p.T)  # (P @ D) @ P^T
    m = matmul(w, e)  # W @ E  (E symmetric)
    diag = jnp.diagonal(m)
    drow = jnp.broadcast_to(diag[:, None], m.shape)
    dcol = jnp.broadcast_to(diag[None, :], m.shape)
    delta = combine(m, m.T, drow, dcol, w, e)
    j = weighted_cost(w, e)
    return delta, j
