"""Pure-jnp / numpy oracles for the Pallas kernels.

Two layers of reference:
* `*_ref` — vectorized jnp implementations of the same math (used to test
  the Pallas kernels shape-by-shape under hypothesis), and
* `swap_delta_brute` — an O(k^4) literal re-evaluation of J for every swap
  (used to certify the *math*, not just the kernels).
"""

import jax.numpy as jnp
import numpy as np


def permuted_distance_ref(d, p):
    """E = P @ D @ P^T."""
    return p @ d @ p.T


def cost_ref(w, d, p):
    """J = sum(W ⊙ E)."""
    e = permuted_distance_ref(d, p)
    return jnp.sum(w * e)


def swap_delta_ref(w, d, p):
    """Vectorized delta matrix (same math the kernel implements)."""
    e = permuted_distance_ref(d, p)
    m = w @ e  # E symmetric
    diag = jnp.diagonal(m)
    return 2.0 * (m + m.T - diag[:, None] - diag[None, :] + 2.0 * w * e)


def onehot(sigma, k):
    """One-hot permutation matrix P[x, sigma[x]] = 1."""
    p = np.zeros((k, k), dtype=np.float32)
    p[np.arange(len(sigma)), np.asarray(sigma)] = 1.0
    return p


def cost_brute(w, d, sigma):
    """J by definition: sum_{x,y} W[x,y] * D[sigma_x, sigma_y]."""
    k = w.shape[0]
    j = 0.0
    for x in range(k):
        for y in range(k):
            j += w[x, y] * d[sigma[x], sigma[y]]
    return j


def swap_delta_brute(w, d, sigma):
    """delta[x,y] = J(after swapping sigma_x, sigma_y) - J(before)."""
    k = w.shape[0]
    base = cost_brute(w, d, sigma)
    out = np.zeros((k, k), dtype=np.float64)
    for x in range(k):
        for y in range(k):
            s = list(sigma)
            s[x], s[y] = s[y], s[x]
            out[x, y] = cost_brute(w, d, s) - base
    return out
