"""L1 batched QAP sweep: sigma stays on device across swap sweeps.

The legacy `qap_step` artifact scores all K² swaps in one launch but the
host downloads the full delta matrix every sweep and re-uploads the
one-hot assignment. `qap_sweep` bakes [`SWEEPS`] greedy sweeps into a
single program — "device proposes, device applies": each `fori_loop`
iteration rebuilds P from the on-device `sigma`, reuses the Pallas
`qap_swap_kernel` to score every candidate, and applies the single best
swap when it improves beyond the legacy `-1e-6` threshold. Only the final
`sigma` (K i32) crosses back to the host.

Padding: rows/cols ≥ k are masked out of the argmin (their W rows are
zero but their *diagonal* M terms are not, so unmasked padding swaps
could look improving); padded `sigma` entries are -1 so `one_hot` leaves
their P rows zero, exactly like the host-built padding.
"""

import jax
import jax.numpy as jnp

from . import qap_swap

# Greedy best-swap steps baked per launch; the host loops launches for
# larger sweep budgets and stops when sigma reaches a fixed point.
SWEEPS = 16


def qap_sweep(w: jax.Array, d: jax.Array, sigma: jax.Array, kk: jax.Array):
    """`SWEEPS` on-device greedy swap sweeps; returns (sigma i32[K], j f32[1])."""
    kp = w.shape[0]
    iota = jnp.arange(kp, dtype=jnp.int32)
    k = kk[0].astype(jnp.int32)
    valid = (
        (iota[:, None] < k) & (iota[None, :] < k) & (iota[:, None] != iota[None, :])
    )

    def body(_, carry):
        sigma, _j = carry
        p = jax.nn.one_hot(sigma, kp, dtype=jnp.float32)
        delta, j = qap_swap.qap_swap_kernel(w, d, p)
        masked = jnp.where(valid, delta, jnp.inf)
        idx = jnp.argmin(masked)
        x = (idx // kp).astype(jnp.int32)
        y = (idx % kp).astype(jnp.int32)
        improving = masked.reshape(-1)[idx] < -1e-6
        sx, sy = sigma[x], sigma[y]
        sigma = sigma.at[x].set(jnp.where(improving, sy, sx))
        sigma = sigma.at[y].set(jnp.where(improving, sx, sy))
        return sigma, j

    sigma, j = jax.lax.fori_loop(0, SWEEPS, body, (sigma, jnp.float32(0.0)))
    return sigma, jnp.reshape(j, (1,))
