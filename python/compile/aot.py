"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Interchange is HLO **text**, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: `python -m compile.aot --out-dir ../artifacts` (from python/).
"""

import argparse
import pathlib

import jax

# The graph/batched kernels carry f64 edge weights and u64 noise state;
# x64 must be on before anything is traced. The f32 QAP kernels pin their
# dtypes explicitly and are unaffected.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

# Padded QAP kernel sizes; must match
# rust/src/runtime/offload.rs::QAP_KERNEL_SIZES.
QAP_SIZES = (32, 64, 256)

# Padded graph classes (n; edge slots m = 8n); must match
# rust/src/runtime/device.rs::GRAPH_CLASSES.
GRAPH_SIZES = (1024, 4096, 16384)


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (return_tuple=True so the
    Rust side can unwrap with to_tupleN)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: pathlib.Path) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []

    def emit(name: str, lowered) -> None:
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")

    for k in QAP_SIZES:
        emit(f"qap_step_k{k}", model.qap_step_jit(k))
        emit(f"qap_sweep_k{k}", model.qap_sweep_jit(k))
    for n in GRAPH_SIZES:
        emit(f"match_round_n{n}", model.match_round_jit(n))
        emit(f"contract_gather_n{n}", model.contract_gather_jit(n))
        emit(f"jet_round_n{n}", model.jet_round_jit(n))
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
