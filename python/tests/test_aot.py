"""AOT pipeline checks: lowering produces parseable HLO text with the
expected entry signature, and the lowered computation still computes the
right numbers when executed through XLA (not the jax trace)."""

import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_hlo_text_structure():
    lowered = model.qap_step_jit(32)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # Three f32[32,32] parameters, tuple result.
    assert text.count("f32[32,32]") >= 3
    assert "ENTRY" in text


def test_build_all_writes_expected_files():
    with tempfile.TemporaryDirectory() as td:
        out = pathlib.Path(td)
        written = aot.build_all(out)
        names = sorted(p.name for p in written)
        want = [f"qap_{kind}_k{k}.hlo.txt" for k in aot.QAP_SIZES for kind in ("step", "sweep")]
        want += [
            f"{kernel}_n{n}.hlo.txt"
            for n in aot.GRAPH_SIZES
            for kernel in ("match_round", "contract_gather", "jet_round")
        ]
        assert names == sorted(want)
        for p in written:
            assert p.stat().st_size > 1000


@pytest.mark.parametrize("k", [32, 64])
def test_compiled_executable_matches_ref(k):
    # Compile (XLA, not trace) and execute: the exact path Rust takes.
    compiled = jax.jit(model.qap_step).lower(
        jax.ShapeDtypeStruct((k, k), jnp.float32),
        jax.ShapeDtypeStruct((k, k), jnp.float32),
        jax.ShapeDtypeStruct((k, k), jnp.float32),
    ).compile()
    rng = np.random.default_rng(k)
    w = rng.integers(0, 9, size=(k, k)).astype(np.float32)
    w = w + w.T
    np.fill_diagonal(w, 0)
    d = rng.choice([1.0, 10.0], size=(k, k)).astype(np.float32)
    d = np.maximum(d, d.T)
    np.fill_diagonal(d, 0)
    sigma = rng.permutation(k)
    p = ref.onehot(sigma, k)
    delta, j = compiled(jnp.array(w), jnp.array(d), jnp.array(p))
    want = ref.swap_delta_ref(jnp.array(w), jnp.array(d), jnp.array(p))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(want), rtol=1e-4, atol=1e-2)
    assert abs(float(j) - float(ref.cost_ref(jnp.array(w), jnp.array(d), jnp.array(p)))) < 1e-2
