"""Pallas kernels vs pure-jnp references, swept with hypothesis.

Certifies (a) the kernel implementations against the vectorized jnp math
and (b) the math itself against a brute-force re-evaluation of J for
every swap.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qap_swap, ref


def random_instance(k, seed, weight_scale=20.0):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, int(weight_scale), size=(k, k)).astype(np.float32)
    w = w + w.T
    np.fill_diagonal(w, 0.0)
    # Hierarchical-ish distance: random symmetric with zero diagonal.
    d = rng.choice([1.0, 10.0, 100.0], size=(k, k)).astype(np.float32)
    d = np.maximum(d, d.T)
    np.fill_diagonal(d, 0.0)
    sigma = rng.permutation(k)
    return w, d, sigma


# --- certify the math against brute force (small k) ---------------------


@pytest.mark.parametrize("k", [2, 3, 5, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_math_matches_brute_force(k, seed):
    w, d, sigma = random_instance(k, seed)
    p = ref.onehot(sigma, k)
    got = np.asarray(ref.swap_delta_ref(jnp.array(w), jnp.array(d), jnp.array(p)))
    want = ref.swap_delta_brute(w, d, sigma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("k", [2, 4, 7])
def test_cost_math_matches_brute_force(k):
    w, d, sigma = random_instance(k, 9)
    p = ref.onehot(sigma, k)
    got = float(ref.cost_ref(jnp.array(w), jnp.array(d), jnp.array(p)))
    want = ref.cost_brute(w, d, sigma)
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))


# --- certify the Pallas kernels against the jnp references --------------


@pytest.mark.parametrize("k", [32, 64, 256])
def test_matmul_matches_jnp(k):
    rng = np.random.default_rng(k)
    a = rng.standard_normal((k, k)).astype(np.float32)
    b = rng.standard_normal((k, k)).astype(np.float32)
    got = np.asarray(qap_swap.matmul(jnp.array(a), jnp.array(b)))
    want = a @ b
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("k", [32, 64, 256])
def test_full_kernel_matches_ref(k):
    w, d, sigma = random_instance(k, k + 1)
    p = ref.onehot(sigma, k)
    delta, j = qap_swap.qap_swap_kernel(jnp.array(w), jnp.array(d), jnp.array(p))
    want_delta = ref.swap_delta_ref(jnp.array(w), jnp.array(d), jnp.array(p))
    want_j = ref.cost_ref(jnp.array(w), jnp.array(d), jnp.array(p))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(want_delta), rtol=1e-4, atol=1e-2)
    assert abs(float(j) - float(want_j)) < 1e-4 * max(1.0, float(want_j))


def test_kernel_on_padded_input():
    # Zero-padding (what the Rust side does for k < k_pad) must leave the
    # real sub-block intact.
    k, kp = 6, 32
    w, d, sigma = random_instance(k, 3)
    wp = np.zeros((kp, kp), np.float32)
    dp = np.zeros((kp, kp), np.float32)
    pp = np.zeros((kp, kp), np.float32)
    wp[:k, :k] = w
    dp[:k, :k] = d
    pp[:k, :k] = ref.onehot(sigma, k)
    delta, j = qap_swap.qap_swap_kernel(jnp.array(wp), jnp.array(dp), jnp.array(pp))
    want = ref.swap_delta_brute(w, d, sigma)
    np.testing.assert_allclose(np.asarray(delta)[:k, :k], want, rtol=1e-4, atol=1e-2)
    assert abs(float(j) - ref.cost_brute(w, d, sigma)) < 1e-2


# --- hypothesis sweep over shapes/values ---------------------------------


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([2, 3, 4, 6, 8, 12]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1.0, 5.0, 50.0]),
)
def test_hypothesis_delta_math(k, seed, scale):
    w, d, sigma = random_instance(k, seed, weight_scale=scale)
    p = ref.onehot(sigma, k)
    got = np.asarray(ref.swap_delta_ref(jnp.array(w), jnp.array(d), jnp.array(p)))
    want = ref.swap_delta_brute(w, d, sigma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)
    # Diagonal must be exactly zero-change.
    np.testing.assert_allclose(np.diagonal(got), 0.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_kernel_vs_ref_k32(seed):
    k = 32
    w, d, sigma = random_instance(k, seed)
    p = ref.onehot(sigma, k)
    delta, j = qap_swap.qap_swap_kernel(jnp.array(w), jnp.array(d), jnp.array(p))
    want = ref.swap_delta_ref(jnp.array(w), jnp.array(d), jnp.array(p))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(want), rtol=1e-4, atol=1e-2)
    assert float(j) >= 0.0
