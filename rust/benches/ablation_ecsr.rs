//! Ablation A3: the extended CSR format (paper §4, "Extended CSR
//! Format") — flat edge-parallel kernels vs nested vertex-parallel loops.
//!
//! Measured on the connectivity-table build (the structure behind every
//! gain computation): edge-parallel initialization via ECSR vs the
//! vertex-parallel refill. The paper credits ECSR for GPU-IM's ~1.47x
//! edge over Jet; here the modeled launch/work accounting shows the same
//! balance effect (identical work items, better distribution) and host
//! wall-clock shows the 1-core overhead difference.

use heipa::graph::{gen, EdgeList};
use heipa::par::cost::DeviceTimer;
use heipa::par::Pool;
use heipa::refine::gains::ConnTable;
use heipa::rng::Rng;

fn main() {
    let pool = Pool::default();
    let k = 64;
    let instances = ["rgg16", "road_eu", "sten_shipsec"];

    println!("== Ablation A3: extended CSR (edge-parallel) vs vertex-parallel ==");
    println!("| instance | n | 2m | edge-par host ms | vertex-par host ms | edge-par device ms | vertex-par device ms |");
    println!("|---|---|---|---|---|---|---|");
    for name in instances {
        let g = gen::generate_by_name(name);
        let mut rng = Rng::new(1);
        let part: Vec<u32> = (0..g.n()).map(|_| rng.below(k as u64) as u32).collect();
        let el = EdgeList::build(&g);

        let t_e = DeviceTimer::start();
        let table_e = ConnTable::build(&pool, &g, &el, &part, k);
        let m_e = t_e.stop();

        let t_v = DeviceTimer::start();
        let table_v = ConnTable::build_vertex_par(&pool, &g, &part, k);
        let m_v = t_v.stop();

        // Differential check: both builds agree.
        let mut a = Vec::new();
        let mut b = Vec::new();
        for v in (0..g.n()).step_by(97) {
            table_e.gather(v, &mut a);
            table_v.gather(v, &mut b);
            a.sort_unstable_by_key(|&(x, _)| x);
            b.sort_unstable_by_key(|&(x, _)| x);
            assert_eq!(a.len(), b.len(), "{name} v={v}");
        }

        println!(
            "| {name} | {} | {} | {:.1} | {:.1} | {:.3} | {:.3} |",
            g.n(),
            g.num_directed(),
            m_e.host_ms,
            m_v.host_ms,
            m_e.device_ms,
            m_v.device_ms
        );
    }
    println!("\n(on a real GPU the edge-parallel variant additionally wins by load balance on\nskewed degrees; the paper attributes GPU-IM's 1.47x edge over Jet largely to ECSR)");
}
