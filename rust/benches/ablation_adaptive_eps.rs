//! Ablation A1: the adaptive imbalance ε′ of Eq. 2 vs a fixed ε in
//! hierarchical multisection (GPU-HM). The adaptive variant guarantees
//! the final k-way mapping is ε-balanced; the fixed variant lets
//! per-level imbalances compound (Schulz & Woydt report both worse
//! balance and worse mapping quality without it).
//!
//! Both variants run through the engine: the fixed one is just the spec
//! option `adaptive = 0`.

use heipa::algo::Algorithm;
use heipa::engine::{Engine, MapSpec};

fn main() {
    let engine = Engine::with_defaults();
    let eps = 0.03;
    let instances = ["sten_cop20k", "wal_598a", "del15", "rgg15", "road_deu"];

    println!("== Ablation A1: Eq. 2 adaptive imbalance (GPU-HM, k = 128, ε = {eps}) ==");
    println!("| instance | J adaptive | J fixed | imb adaptive | imb fixed | fixed violates ε? |");
    println!("|---|---|---|---|---|---|");
    let mut violations = 0;
    for name in instances {
        let base = MapSpec::named(name)
            .hierarchy("4:8:4")
            .distance("1:10:100")
            .eps(eps)
            .algo(Some(Algorithm::GpuHm));
        let adaptive = engine.map(&base.clone()).unwrap();
        let fixed = engine.map(&base.option("adaptive", "0")).unwrap();
        let violates = fixed.imbalance > eps + 1e-6;
        violations += violates as u32;
        println!(
            "| {name} | {:.0} | {:.0} | {:.4} | {:.4} | {} |",
            adaptive.comm_cost,
            fixed.comm_cost,
            adaptive.imbalance,
            fixed.imbalance,
            if violates { "YES" } else { "no" }
        );
        assert!(
            adaptive.imbalance <= eps + 0.005,
            "adaptive variant must stay ε-balanced on {name}: {}",
            adaptive.imbalance
        );
    }
    println!("\nfixed-ε violated the global balance constraint on {violations}/{} instances;", instances.len());
    println!("the adaptive variant never did (its guarantee, paper §4.1).");
}
