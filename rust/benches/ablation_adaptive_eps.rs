//! Ablation A1: the adaptive imbalance ε′ of Eq. 2 vs a fixed ε in
//! hierarchical multisection (GPU-HM). The adaptive variant guarantees
//! the final k-way mapping is ε-balanced; the fixed variant lets
//! per-level imbalances compound (Schulz & Woydt report both worse
//! balance and worse mapping quality without it).

use heipa::algo::gpu_hm::{gpu_hm, GpuHmConfig};
use heipa::graph::gen;
use heipa::par::Pool;
use heipa::partition::{comm_cost, imbalance};
use heipa::topology::Hierarchy;

fn main() {
    let pool = Pool::default();
    let h = Hierarchy::parse("4:8:4", "1:10:100").unwrap();
    let eps = 0.03;
    let instances = ["sten_cop20k", "wal_598a", "del15", "rgg15", "road_deu"];

    println!("== Ablation A1: Eq. 2 adaptive imbalance (GPU-HM, k = {}, ε = {eps}) ==", h.k());
    println!("| instance | J adaptive | J fixed | imb adaptive | imb fixed | fixed violates ε? |");
    println!("|---|---|---|---|---|---|");
    let mut violations = 0;
    for name in instances {
        let g = gen::generate_by_name(name);
        let adaptive = gpu_hm(&pool, &g, &h, eps, 1, &GpuHmConfig::default_flavor(), None);
        let fixed_cfg = GpuHmConfig { adaptive: false, ..GpuHmConfig::default_flavor() };
        let fixed = gpu_hm(&pool, &g, &h, eps, 1, &fixed_cfg, None);
        let (ja, jf) = (comm_cost(&g, &adaptive, &h), comm_cost(&g, &fixed, &h));
        let (ia, iff) = (imbalance(&g, &adaptive, h.k()), imbalance(&g, &fixed, h.k()));
        let violates = iff > eps + 1e-6;
        violations += violates as u32;
        println!(
            "| {name} | {ja:.0} | {jf:.0} | {ia:.4} | {iff:.4} | {} |",
            if violates { "YES" } else { "no" }
        );
        assert!(ia <= eps + 0.005, "adaptive variant must stay ε-balanced on {name}: {ia}");
    }
    println!("\nfixed-ε violated the global balance constraint on {violations}/{} instances;", instances.len());
    println!("the adaptive variant never did (its guarantee, paper §4.1).");
}
