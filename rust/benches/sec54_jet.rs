//! §5.4: GPU-based comparison — plain edge-cut Jet vs GPU-IM.
//!
//! Jet minimizes edge-cut (distance vector 1:…:1), so its partitions are
//! structurally unfit for the 1:10:100 machine. Paper reference: Jet's
//! partitions cost +45.3% over GPU-IM on average (+90.3% over
//! SharedMap-S), while GPU-IM is ~1.47x faster than Jet (1.43x small /
//! 1.56x large graphs) thanks to the extended CSR format.

use heipa::algo::Algorithm;
use heipa::graph::gen;
use heipa::harness::{self, stats};
use heipa::engine::Engine;

fn main() {
    let engine = Engine::with_defaults();
    let seeds = harness::seeds_from_env(&[1]);
    let hierarchies = harness::machines_from_env();
    let instances = gen::smoke_suite();
    let algos = [Algorithm::Jet, Algorithm::JetUltra, Algorithm::GpuIm, Algorithm::SharedMapS];

    let records = harness::run_matrix(&engine, &algos, &instances, &hierarchies, &seeds, 0.03);

    let grab = |a: Algorithm, f: fn(&harness::ExpRecord) -> f64| -> Vec<f64> {
        records.iter().filter(|r| r.algorithm == a).map(f).collect()
    };
    let j_jet = grab(Algorithm::Jet, |r| r.comm_cost);
    let j_jet_u = grab(Algorithm::JetUltra, |r| r.comm_cost);
    let j_im = grab(Algorithm::GpuIm, |r| r.comm_cost);
    let j_sms = grab(Algorithm::SharedMapS, |r| r.comm_cost);

    let pct = |a: &[f64], b: &[f64]| -> f64 {
        100.0 * (stats::mean(&a.iter().zip(b).map(|(&x, &y)| x / y - 1.0).collect::<Vec<_>>()))
    };
    println!("== §5.4: communication-cost penalty of edge-cut partitions ==");
    println!("  jet vs gpu-im      : +{:.1}%  (paper +45.3%)", pct(&j_jet, &j_im));
    println!("  jet vs sharedmap-s : +{:.1}%  (paper +90.3%)", pct(&j_jet, &j_sms));
    println!(
        "  jet-ultra vs jet   : {:+.1}%  (paper: ultra is even worse — lower cut ≠ lower J)",
        pct(&j_jet_u, &j_jet)
    );

    println!("\n== §5.4: runtime, gpu-im vs jet (modeled device time) ==");
    let t_jet = grab(Algorithm::Jet, |r| r.device_ms);
    let t_im = grab(Algorithm::GpuIm, |r| r.device_ms);
    let (geo, mx, mn) = stats::speedup_summary(&t_jet, &t_im);
    println!("  gpu-im speedup over jet: geomean {geo:.2}x  min {mn:.2}x  max {mx:.2}x");
    println!("  (paper: 1.47x geomean; 0.21–1.95x small, 1.21–2.22x large)");
}
