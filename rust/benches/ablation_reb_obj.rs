//! Ablation A2: rebalancing loss objective — edge-cut vs J(C, D, Π).
//!
//! The paper adapted Alg. 5 to the mapping objective, observed *no*
//! quality improvement, and shipped the cheaper edge-cut loss ("edge-cut
//! loss and communication cost loss correlate; poor rebalancing moves are
//! corrected by the next label propagation"). This bench reproduces that
//! design decision through the engine's `rebalance_comm_obj` option.

use heipa::algo::Algorithm;
use heipa::engine::{Engine, MapSpec};

fn main() {
    let engine = Engine::with_defaults();
    let instances = ["sten_cop20k", "wal_598a", "del15", "rgg15"];

    println!("== Ablation A2: rebalance loss objective (GPU-IM, k = 64) ==");
    println!("| instance | J (cut loss) | J (J loss) | ΔJ | time cut (ms) | time J (ms) |");
    println!("|---|---|---|---|---|---|");
    let mut ratio_sum = 0.0;
    for name in instances {
        let base = MapSpec::named(name)
            .hierarchy("4:8:2")
            .distance("1:10:100")
            .eps(0.03)
            .algo(Some(Algorithm::GpuIm));
        let cut = engine.map(&base.clone()).unwrap();
        let jobj = engine.map(&base.option("rebalance_comm_obj", "1")).unwrap();
        ratio_sum += jobj.comm_cost / cut.comm_cost;
        println!(
            "| {name} | {:.0} | {:.0} | {:+.1}% | {:.2} | {:.2} |",
            cut.comm_cost,
            jobj.comm_cost,
            100.0 * (jobj.comm_cost / cut.comm_cost - 1.0),
            cut.device_ms,
            jobj.device_ms
        );
    }
    let mean_pct = 100.0 * (ratio_sum / instances.len() as f64 - 1.0);
    println!("\nmean ΔJ of the J-loss rebalancer: {mean_pct:+.1}%");
    println!("(paper: no improvement — the cheaper edge-cut loss ships; §4.2 Alg. 5 note)");
}
