//! Ablation A2: rebalancing loss objective — edge-cut vs J(C, D, Π).
//!
//! The paper adapted Alg. 5 to the mapping objective, observed *no*
//! quality improvement, and shipped the cheaper edge-cut loss ("edge-cut
//! loss and communication cost loss correlate; poor rebalancing moves are
//! corrected by the next label propagation"). This bench reproduces that
//! design decision.

use heipa::algo::gpu_im::{gpu_im, GpuImConfig};
use heipa::graph::gen;
use heipa::par::cost::DeviceTimer;
use heipa::par::Pool;
use heipa::partition::comm_cost;
use heipa::topology::Hierarchy;

fn main() {
    let pool = Pool::default();
    let h = Hierarchy::parse("4:8:2", "1:10:100").unwrap();
    let instances = ["sten_cop20k", "wal_598a", "del15", "rgg15"];

    println!("== Ablation A2: rebalance loss objective (GPU-IM, k = {}) ==", h.k());
    println!("| instance | J (cut loss) | J (J loss) | ΔJ | time cut (ms) | time J (ms) |");
    println!("|---|---|---|---|---|---|");
    let mut ratio_sum = 0.0;
    for name in instances {
        let g = gen::generate_by_name(name);
        let t1 = DeviceTimer::start();
        let m_cut = gpu_im(&pool, &g, &h, 0.03, 1, &GpuImConfig::default(), None);
        let m1 = t1.stop();
        let cfg_j = GpuImConfig { rebalance_with_comm_obj: true, ..Default::default() };
        let t2 = DeviceTimer::start();
        let m_j = gpu_im(&pool, &g, &h, 0.03, 1, &cfg_j, None);
        let m2 = t2.stop();
        let (jc, jj) = (comm_cost(&g, &m_cut, &h), comm_cost(&g, &m_j, &h));
        ratio_sum += jj / jc;
        println!(
            "| {name} | {jc:.0} | {jj:.0} | {:+.1}% | {:.2} | {:.2} |",
            100.0 * (jj / jc - 1.0),
            m1.device_ms,
            m2.device_ms
        );
    }
    println!(
        "\nmean quality ratio J-loss/cut-loss = {:.3} (paper: ≈1.0 — no improvement, so the\ncheaper edge-cut loss ships as the default)",
        ratio_sum / instances.len() as f64
    );
}
