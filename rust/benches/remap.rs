//! Incremental-remapping perf harness: (1) warm vs cold time-to-result
//! for patch→map cycles on a pinned session graph (the subsystem's
//! headline number), and (2) batch vs sequential submission throughput
//! for fleets of small same-machine jobs. Per-cycle wall p50/p99 and
//! modeled device ms land in `BENCH_remap.json` (override the path with
//! `HEIPA_BENCH_OUT`; set `HEIPA_BENCH_SMOKE=1` for a seconds-scale CI
//! run).

use heipa::algo::Algorithm;
use heipa::engine::{Engine, EngineConfig, MapSpec, RemapKind};
use heipa::graph::{gen, CsrGraph};
use heipa::incremental::GraphPatch;
use heipa::par::cost::DeviceTimer;
use heipa::Vertex;
use std::sync::Arc;
use std::time::Instant;

struct Record {
    bench: &'static str,
    graph: String,
    mode: &'static str,
    /// Median per-cycle (or total, for throughput rows) wall ms.
    wall_ms: f64,
    p99_ms: f64,
    device_ms: f64,
    /// Cycles measured (patch-map) or jobs retired (batch rows).
    jobs: usize,
    /// Jobs per second for throughput rows, 0 otherwise.
    jobs_per_sec: f64,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(records: &[Record], path: &str) {
    let mut out = String::from("{\n  \"bench\": \"remap\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"{}\", \"graph\": \"{}\", \"mode\": \"{}\", \"wall_ms\": {:.3}, \
             \"p99_ms\": {:.3}, \"device_ms\": {:.3}, \"jobs\": {}, \"jobs_per_sec\": {:.2}}}{}\n",
            json_escape(r.bench),
            json_escape(&r.graph),
            r.mode,
            r.wall_ms,
            r.p99_ms,
            r.device_ms,
            r.jobs,
            r.jobs_per_sec,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).expect("write bench json");
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

/// A rotation of non-adjacent vertex pairs to patch in and back out —
/// each cycle perturbs the graph without unbounded growth.
fn patch_pairs(g: &CsrGraph, want: usize) -> Vec<(Vertex, Vertex)> {
    let n = g.n() as Vertex;
    let mut pairs = Vec::new();
    let mut u = 0u32;
    while pairs.len() < want && u < n {
        let v = n - 1 - (pairs.len() as Vertex % (n / 2));
        if u != v && g.find_edge(u, v).is_none() {
            pairs.push((u.min(v), u.max(v)));
        }
        u += 7;
    }
    pairs
}

/// Measured patch→map cycles on a fresh engine; `force_cold` pins
/// `remap.max_region_frac=0` so every cycle pays the full multilevel
/// solve — the baseline the warm path is judged against.
fn patch_map_cycles(
    g: &Arc<CsrGraph>,
    cycles: usize,
    threads: usize,
    force_cold: bool,
) -> (Vec<f64>, f64) {
    let e = Engine::new(EngineConfig { threads, workers: 1, ..Default::default() });
    e.put_graph("sess", g.clone());
    let mut spec = MapSpec::named("sess")
        .hierarchy("2:4")
        .distance("1:10")
        .algo(Some(Algorithm::GpuIm))
        .seed(1);
    if force_cold {
        spec = spec.option("remap.max_region_frac", "0");
    }
    e.map(&spec).unwrap();
    let pairs = patch_pairs(g, cycles.div_ceil(2).max(1));
    let mut walls = Vec::with_capacity(cycles);
    let mut device_ms = 0.0;
    for c in 0..cycles {
        let (u, v) = pairs[(c / 2) % pairs.len()];
        let ops = if c % 2 == 0 { format!("ae:{u}:{v}:1.0") } else { format!("re:{u}:{v}") };
        let patch = GraphPatch::parse(&ops).unwrap();
        let t = DeviceTimer::start();
        e.patch_graph("sess", &patch).unwrap();
        let out = e.map(&spec.clone().seed(2 + c as u64)).unwrap();
        let m = t.stop();
        let want = if force_cold { RemapKind::Cold } else { RemapKind::Warm };
        assert_eq!(out.remap, Some(want), "cycle {c} took the wrong path");
        walls.push(m.host_ms);
        device_ms += m.device_ms;
    }
    walls.sort_by(|a, b| a.total_cmp(b));
    (walls, device_ms)
}

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_remap.json".to_string());
    let threads = if smoke { 2 } else { 4 };
    let cycles = if smoke { 6 } else { 20 };

    let graphs: Vec<(String, Arc<CsrGraph>)> = if smoke {
        vec![("rgg11".into(), Arc::new(gen::rgg(1 << 11, gen::rgg_paper_radius(1 << 11), 3)))]
    } else {
        vec![
            ("rgg15".into(), Arc::new(gen::rgg(1 << 15, gen::rgg_paper_radius(1 << 15), 3))),
            ("stencil128".into(), Arc::new(gen::stencil9(128, 128, 7))),
        ]
    };

    let mut records = Vec::new();
    println!("| bench | graph | mode | p50 ms | p99 ms | jobs/s |");
    println!("|---|---|---|---|---|---|");

    // Part 1: warm vs cold time-to-result per patch→map cycle.
    for (name, g) in &graphs {
        for (mode, force_cold) in [("warm", false), ("cold", true)] {
            let (walls, dev) = patch_map_cycles(g, cycles, threads, force_cold);
            let (p50, p99) = (percentile(&walls, 0.5), percentile(&walls, 0.99));
            println!("| patch-map | {name} | {mode} | {p50:.2} | {p99:.2} | - |");
            records.push(Record {
                bench: "patch-map",
                graph: name.clone(),
                mode,
                wall_ms: p50,
                p99_ms: p99,
                device_ms: dev,
                jobs: walls.len(),
                jobs_per_sec: 0.0,
            });
        }
    }

    // Part 2: batch vs sequential submission throughput. Small jobs on
    // one shared graph/machine so the worker drain can pack a whole
    // batch into one worker-pool pass.
    let bg = Arc::new(gen::grid2d(64, 64, false));
    let fleet = if smoke { 8 } else { 32 };
    let specs: Vec<MapSpec> = (0..fleet)
        .map(|s| {
            MapSpec::in_memory(bg.clone())
                .hierarchy("2:2")
                .distance("1:10")
                .algo(Some(Algorithm::GpuIm))
                .seed(1 + s as u64)
        })
        .collect();
    for (mode, batched) in [("sequential", false), ("batch", true)] {
        let e = Engine::new(EngineConfig { threads, workers: 2, ..Default::default() });
        let t0 = Instant::now();
        let handles: Vec<_> = if batched {
            e.submit_batch(&specs, Default::default()).unwrap()
        } else {
            specs.iter().map(|s| e.submit(s).unwrap()).collect()
        };
        for h in &handles {
            h.wait().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        let jps = fleet as f64 / (wall / 1e3).max(1e-9);
        println!("| submit | grid64 | {mode} | {wall:.2} | - | {jps:.1} |");
        records.push(Record {
            bench: "submit",
            graph: "grid64".into(),
            mode,
            wall_ms: wall,
            p99_ms: 0.0,
            device_ms: 0.0,
            jobs: fleet,
            jobs_per_sec: jps,
        });
    }

    write_json(&records, &out_path);
    println!("\nwrote {} records to {out_path}", records.len());

    // Headline: warm speedup per graph.
    for (name, _) in &graphs {
        let grab = |mode: &str| -> Option<f64> {
            records
                .iter()
                .find(|r| r.bench == "patch-map" && r.graph == *name && r.mode == mode)
                .map(|r| r.wall_ms)
        };
        if let (Some(warm), Some(cold)) = (grab("warm"), grab("cold")) {
            if warm > 0.0 {
                println!(
                    "{name}: cold {cold:.2} ms vs warm {warm:.2} ms per cycle \
                     ({:.2}x time-to-result)",
                    cold / warm
                );
            }
        }
    }
}
