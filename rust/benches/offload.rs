//! P1 (§Perf): the PJRT-offloaded QAP swap search vs the host
//! implementation — quality parity and per-sweep cost of the
//! AOT-compiled JAX/Pallas kernel at every padded size.
//!
//! Requires `make artifacts`; skips gracefully without them.

use heipa::algo::qap;
use heipa::partition::comm_cost_blocks;
use heipa::rng::Rng;
use heipa::runtime::{offload, Runtime};
use heipa::topology::Machine;

fn random_bmat(k: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut b = vec![0.0; k * k];
    for x in 0..k {
        for y in x + 1..k {
            let w = if rng.f64() < 0.3 { rng.below(100) as f64 } else { 0.0 };
            b[x * k + y] = w;
            b[y * k + x] = w;
        }
    }
    b
}

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("offload bench: PJRT client failed to start; skipping");
        return;
    };
    if !rt.available("qap_step_k32") {
        eprintln!("offload bench: artifacts missing — run `make artifacts`; skipping");
        return;
    }
    println!("PJRT platform: {}", rt.platform());

    let cases = [("2:4:4", 4u64), ("4:8:2", 5), ("4:8:6", 6)];
    println!("\n| k | pad | J init | J host | J device | host ms | device ms | device sweeps ms/sweep |");
    println!("|---|---|---|---|---|---|---|---|");
    for (hier, seed) in cases {
        let h = Machine::hier(hier, "1:10:100").unwrap();
        let k = h.k();
        let d = h.oracle();
        let bmat = random_bmat(k, seed);
        let mut rng = Rng::new(seed ^ 0xff);
        let mut sigma0: Vec<u32> = (0..k as u32).collect();
        rng.shuffle(&mut sigma0);
        let j0 = comm_cost_blocks(&bmat, k, &sigma0, &d);

        let mut s_host = sigma0.clone();
        let t0 = std::time::Instant::now();
        qap::swap_refine(&bmat, k, &mut s_host, &d, 30);
        let host_ms = t0.elapsed().as_secs_f64() * 1e3;
        let j_host = comm_cost_blocks(&bmat, k, &s_host, &d);

        let mut s_dev = sigma0.clone();
        let t1 = std::time::Instant::now();
        offload::swap_refine_offload(&rt, &bmat, k, &h, &mut s_dev, 30).unwrap();
        let dev_ms = t1.elapsed().as_secs_f64() * 1e3;
        let j_dev = comm_cost_blocks(&bmat, k, &s_dev, &d);

        // Per-sweep kernel cost (after warm-up compile).
        let warm = std::time::Instant::now();
        let sweeps = 10;
        for _ in 0..sweeps {
            let _ = offload::qap_step_device(&rt, &bmat, k, &h, &s_dev).unwrap();
        }
        let per_sweep = warm.elapsed().as_secs_f64() * 1e3 / sweeps as f64;

        println!(
            "| {k} | {} | {j0:.0} | {j_host:.0} | {j_dev:.0} | {host_ms:.1} | {dev_ms:.1} | {per_sweep:.2} |",
            offload::qap_kernel_size(k).unwrap()
        );
        assert!(j_dev <= j0, "device refinement must not worsen");
    }
    println!("\n(device quality must track host quality; per-sweep time is the amortized cost of\nthe AOT-compiled two-matmul Pallas kernel incl. upload/download)");
}
