//! Device-offload crossover harness: CPU worker pool vs batched PJRT
//! launches, per phase × graph size.
//!
//! Phases: `match` (preference matching), `contract` (CAS contraction
//! with the device gather), `refine` (the Jet loop with the device
//! candidate kernel) at one graph per compiled class, plus `polish` (the
//! batched QAP swap search vs the host loop at every padded k). Each row
//! records best-of-N wall time per backend and lands in
//! `BENCH_offload.json` (override with `HEIPA_BENCH_OUT`; set
//! `HEIPA_BENCH_SMOKE=1` for a seconds-scale CI run) — the crossover is
//! read straight off the `cpu_ms`/`device_ms` columns.
//!
//! Requires `make artifacts`; skips gracefully without them.

use heipa::algo::qap;
use heipa::coarsen::contract_cas::contract_cas;
use heipa::coarsen::match_par::preference_matching;
use heipa::coarsen::{matching_to_map, serial_hem};
use heipa::graph::{gen, CsrGraph, EdgeList};
use heipa::par::Pool;
use heipa::partition::{comm_cost_blocks, l_max};
use heipa::refine::jet_loop::{jet_refine, JetConfig};
use heipa::refine::Objective;
use heipa::rng::Rng;
use heipa::runtime::{device, offload, Runtime};
use heipa::topology::Machine;
use heipa::Block;
use std::sync::Arc;

struct Record {
    phase: &'static str,
    graph: String,
    n: usize,
    cpu_ms: f64,
    device_ms: f64,
}

fn write_json(records: &[Record], path: &str) {
    let mut out = String::from("{\n  \"bench\": \"offload\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        let speedup = if r.device_ms > 0.0 { r.cpu_ms / r.device_ms } else { 0.0 };
        out.push_str(&format!(
            "    {{\"bench\": \"offload\", \"phase\": \"{}\", \"graph\": \"{}\", \"n\": {}, \
             \"cpu_ms\": {:.3}, \"device_ms\": {:.3}, \"speedup\": {:.3}}}{}\n",
            r.phase,
            r.graph,
            r.n,
            r.cpu_ms,
            r.device_ms,
            speedup,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).expect("write bench json");
}

/// Best-of-`reps` wall milliseconds of `f`.
fn best_of(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = std::time::Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn random_bmat(k: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut b = vec![0.0; k * k];
    for x in 0..k {
        for y in x + 1..k {
            let w = if rng.f64() < 0.3 { rng.below(100) as f64 } else { 0.0 };
            b[x * k + y] = w;
            b[y * k + x] = w;
        }
    }
    b
}

/// The graph-kernel phases at one size; device timings run inside an
/// activated session with the graph anchored (first call per backend is
/// an untimed warm-up so AOT compilation stays out of the crossover).
fn graph_phases(records: &mut Vec<Record>, g: &Arc<CsrGraph>, label: &str, reps: usize) {
    let pool = Pool::new(4);
    let n = g.n();
    let m = Machine::hier("2:2", "1:10").unwrap();
    let k = m.k();
    let lmax = l_max(g.total_vweight(), k, 0.03);
    let mate = serial_hem(g, i64::MAX, 11);
    let (map, nc) = matching_to_map(&mate);
    let el = EdgeList::build(g);
    let mut rng = Rng::new(17);
    let part0: Vec<Block> = (0..n).map(|_| rng.below(k as u64) as Block).collect();

    let cpu_match = best_of(reps, || {
        let _ = preference_matching(g, &pool, i64::MAX, 7, 8);
    });
    let cpu_contract = best_of(reps, || {
        let _ = contract_cas(&pool, g, &el, &map, nc);
    });
    let cpu_refine = best_of(reps, || {
        let mut part = part0.clone();
        jet_refine(&pool, g, &el, &mut part, k, lmax, &Objective::Comm(&m), &JetConfig::default());
    });

    let (dev_match, dev_contract, dev_refine) = {
        let _guard = device::activate("artifacts");
        let _scope = device::graph_scope(g);
        if !device::graph_kernels_available() {
            eprintln!("offload bench: graph kernels unavailable; device columns zeroed");
            (0.0, 0.0, 0.0)
        } else {
            let _ = best_of(1, || {
                let _ = preference_matching(g, &pool, i64::MAX, 7, 8);
                let _ = contract_cas(&pool, g, &el, &map, nc);
            });
            let dm = best_of(reps, || {
                let _ = preference_matching(g, &pool, i64::MAX, 7, 8);
            });
            let dc = best_of(reps, || {
                let _ = contract_cas(&pool, g, &el, &map, nc);
            });
            let dr = best_of(reps, || {
                let mut part = part0.clone();
                jet_refine(
                    &pool,
                    g,
                    &el,
                    &mut part,
                    k,
                    lmax,
                    &Objective::Comm(&m),
                    &JetConfig::default(),
                );
            });
            (dm, dc, dr)
        }
    };

    records.push(Record { phase: "match", graph: label.into(), n, cpu_ms: cpu_match, device_ms: dev_match });
    records.push(Record { phase: "contract", graph: label.into(), n, cpu_ms: cpu_contract, device_ms: dev_contract });
    records.push(Record { phase: "refine", graph: label.into(), n, cpu_ms: cpu_refine, device_ms: dev_refine });
}

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_offload.json".to_string());
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("offload bench: PJRT client failed to start; skipping");
        return;
    };
    if !rt.available("qap_step_k32") {
        eprintln!("offload bench: artifacts missing — run `make artifacts`; skipping");
        return;
    }
    println!("PJRT platform: {}", rt.platform());
    let reps = if smoke { 1 } else { 3 };
    let mut records: Vec<Record> = Vec::new();

    // Per-phase crossover, one graph per compiled class.
    let sizes: &[(usize, usize)] = if smoke { &[(30, 30)] } else { &[(30, 30), (60, 60), (120, 120)] };
    println!("\n| phase | graph | n | cpu ms | device ms |");
    println!("|---|---|---|---|---|");
    let before = records.len();
    for &(w, h) in sizes {
        let g = Arc::new(gen::grid2d(w, h, false));
        graph_phases(&mut records, &g, &format!("grid2d_{w}x{h}"), reps);
    }
    for r in &records[before..] {
        println!(
            "| {} | {} | {} | {:.2} | {:.2} |",
            r.phase, r.graph, r.n, r.cpu_ms, r.device_ms
        );
    }

    // Polish: batched QAP swap search vs the host loop.
    let cases: &[(&str, u64)] =
        if smoke { &[("2:4:4", 4)] } else { &[("2:4:4", 4), ("4:8:2", 5), ("4:8:6", 6)] };
    println!("\n| k | pad | J init | J host | J device | host ms | device ms | device ms/sweep |");
    println!("|---|---|---|---|---|---|---|---|");
    for &(hier, seed) in cases {
        let h = Machine::hier(hier, "1:10:100").unwrap();
        let k = h.k();
        let d = h.oracle();
        let bmat = random_bmat(k, seed);
        let mut rng = Rng::new(seed ^ 0xff);
        let mut sigma0: Vec<u32> = (0..k as u32).collect();
        rng.shuffle(&mut sigma0);
        let j0 = comm_cost_blocks(&bmat, k, &sigma0, &d);

        let mut s_host = sigma0.clone();
        let t0 = std::time::Instant::now();
        qap::swap_refine(&bmat, k, &mut s_host, &d, 30);
        let host_ms = t0.elapsed().as_secs_f64() * 1e3;
        let j_host = comm_cost_blocks(&bmat, k, &s_host, &d);

        let mut s_dev = sigma0.clone();
        let t1 = std::time::Instant::now();
        offload::swap_refine_offload(&rt, &bmat, k, &h, &mut s_dev, 30).unwrap();
        let dev_ms = t1.elapsed().as_secs_f64() * 1e3;
        let j_dev = comm_cost_blocks(&bmat, k, &s_dev, &d);

        // Per-sweep kernel cost (after warm-up compile).
        let warm = std::time::Instant::now();
        let sweeps = if smoke { 2 } else { 10 };
        for _ in 0..sweeps {
            let _ = offload::qap_step_device(&rt, &bmat, k, &h, &s_dev).unwrap();
        }
        let per_sweep = warm.elapsed().as_secs_f64() * 1e3 / sweeps as f64;

        println!(
            "| {k} | {} | {j0:.0} | {j_host:.0} | {j_dev:.0} | {host_ms:.1} | {dev_ms:.1} | {per_sweep:.2} |",
            offload::qap_kernel_size(k).unwrap()
        );
        assert!(j_dev <= j0, "device refinement must not worsen");
        records.push(Record {
            phase: "polish",
            graph: format!("qap_{hier}"),
            n: k,
            cpu_ms: host_ms,
            device_ms: dev_ms,
        });
    }

    write_json(&records, &out_path);
    println!(
        "\nwrote {out_path} ({} records)\n(crossover: device wins where device_ms < cpu_ms; \
         graph-kernel device rows include the one-time graph upload amortized across rounds)",
        records.len()
    );
}
