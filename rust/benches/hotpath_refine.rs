//! Hot-path perf harness: gpu_im end-to-end plus refine-only timings on
//! rgg / stencil graphs at 1, 2 and 4 threads, comparing the two
//! conn-table update strategies (paper §4.2). Seeds the perf trajectory:
//! wall-clock *and* modeled device ms land in `BENCH_hotpath.json`
//! (override the path with `HEIPA_BENCH_OUT`; set `HEIPA_BENCH_SMOKE=1`
//! for a seconds-scale CI run on tiny graphs).

use heipa::algo::gpu_im::{gpu_im, GpuImConfig};
use heipa::graph::{gen, CsrGraph, EdgeList};
use heipa::par::cost::DeviceTimer;
use heipa::par::Pool;
use heipa::partition::l_max;
use heipa::refine::jet_loop::{jet_refine_with, JetConfig};
use heipa::refine::{ConnUpdate, Objective, RefineWorkspace};
use heipa::rng::Rng;
use heipa::topology::Machine;

struct Record {
    bench: &'static str,
    graph: String,
    threads: usize,
    conn: &'static str,
    wall_ms: f64,
    device_ms: f64,
    objective: f64,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(records: &[Record], path: &str) {
    let mut out = String::from("{\n  \"bench\": \"hotpath_refine\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"{}\", \"graph\": \"{}\", \"threads\": {}, \"conn\": \"{}\", \
             \"wall_ms\": {:.3}, \"device_ms\": {:.3}, \"objective\": {:.3}}}{}\n",
            json_escape(r.bench),
            json_escape(&r.graph),
            r.threads,
            json_escape(r.conn),
            r.wall_ms,
            r.device_ms,
            r.objective,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).expect("write bench json");
}

/// Best-of-`reps` measurement of `f` (wall ms, modeled device ms, result).
fn measure<T>(reps: usize, mut f: impl FnMut() -> T) -> (f64, f64, T) {
    let mut best_wall = f64::INFINITY;
    let mut best_dev = f64::INFINITY;
    let mut last = None;
    for _ in 0..reps {
        let t = DeviceTimer::start();
        let r = f();
        let m = t.stop();
        best_wall = best_wall.min(m.host_ms);
        best_dev = best_dev.min(m.device_ms);
        last = Some(r);
    }
    (best_wall, best_dev, last.unwrap())
}

fn refine_only(
    pool: &Pool,
    g: &CsrGraph,
    el: &EdgeList,
    h: &Machine,
    conn: ConnUpdate,
    reps: usize,
) -> (f64, f64, f64) {
    let k = h.k();
    let lmax = l_max(g.total_vweight(), k, 0.03);
    let mut rng = Rng::new(42);
    let init: Vec<u32> = (0..g.n()).map(|_| rng.below(k as u64) as u32).collect();
    let cfg = JetConfig { conn_update: conn, ..Default::default() };
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);
    let (wall, dev, stats) = measure(reps, || {
        let mut part = init.clone();
        jet_refine_with(pool, g, el, &mut part, k, lmax, &Objective::Comm(h), &cfg, &mut ws)
    });
    (wall, dev, stats.final_objective)
}

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let reps = if smoke { 1 } else { 3 };

    let graphs: Vec<(String, CsrGraph)> = if smoke {
        vec![
            ("rgg10".into(), gen::rgg(1 << 10, gen::rgg_paper_radius(1 << 10), 3)),
            ("stencil24".into(), gen::stencil9(24, 24, 7)),
        ]
    } else {
        vec![
            ("rgg15".into(), gen::rgg(1 << 15, gen::rgg_paper_radius(1 << 15), 3)),
            ("stencil128".into(), gen::stencil9(128, 128, 7)),
        ]
    };
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();

    let mut records = Vec::new();
    println!("| bench | graph | threads | conn | wall ms | device ms |");
    println!("|---|---|---|---|---|---|");
    for (name, g) in &graphs {
        let el = EdgeList::build(g);
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);

            // End-to-end gpu_im.
            let (wall, dev, mapping) = measure(reps, || {
                gpu_im(&pool, g, &h, 0.03, 1, &GpuImConfig::default(), None)
            });
            let j = heipa::partition::comm_cost(g, &mapping, &h);
            println!("| gpu_im | {name} | {threads} | - | {wall:.2} | {dev:.2} |");
            records.push(Record {
                bench: "gpu_im",
                graph: name.clone(),
                threads,
                conn: "auto",
                wall_ms: wall,
                device_ms: dev,
                objective: j,
            });

            // Refine-only, per conn-update strategy.
            for (label, conn) in [
                ("refill", ConnUpdate::Refill),
                ("delta", ConnUpdate::Delta),
                ("auto", ConnUpdate::Auto),
            ] {
                let (wall, dev, j) = refine_only(&pool, g, &el, &h, conn, reps);
                println!("| refine | {name} | {threads} | {label} | {wall:.2} | {dev:.2} |");
                records.push(Record {
                    bench: "refine",
                    graph: name.clone(),
                    threads,
                    conn: label,
                    wall_ms: wall,
                    device_ms: dev,
                    objective: j,
                });
            }
        }
    }

    write_json(&records, &out_path);
    println!("\nwrote {} records to {out_path}", records.len());

    // Headline: multi-threaded refine, delta vs refill.
    let grab = |threads: usize, conn: &str| -> Vec<f64> {
        records
            .iter()
            .filter(|r| r.bench == "refine" && r.threads == threads && r.conn == conn)
            .map(|r| r.wall_ms)
            .collect()
    };
    for threads in [2usize, 4] {
        let refill: f64 = grab(threads, "refill").iter().sum();
        let delta: f64 = grab(threads, "delta").iter().sum();
        if delta > 0.0 {
            println!(
                "refine @{threads} threads: refill {refill:.2} ms vs delta {delta:.2} ms \
                 ({:.2}x)",
                refill / delta
            );
        }
    }
}
