//! Micro-benchmarks of the device-kernel building blocks — the §Perf
//! baseline: par primitives, ECSR build, matching, CAS contraction,
//! subgraph extraction, conn-table build, one LP step.
//!
//! Prints host throughput (items/µs) per kernel; the optimization log in
//! EXPERIMENTS.md §Perf tracks these numbers across iterations.

use heipa::coarsen::contract_cas::contract_cas;
use heipa::coarsen::{match_par::preference_matching, matching_to_map};
use heipa::graph::{gen, subgraph, EdgeList};
use heipa::par::Pool;
use heipa::refine::gains::ConnTable;
use heipa::refine::jet_lp::{Filter, JetLp};
use heipa::refine::Objective;
use heipa::rng::Rng;
use heipa::topology::{DistanceOracle, Machine};

fn time_ms(f: impl FnOnce()) -> f64 {
    let t = std::time::Instant::now();
    f();
    t.elapsed().as_secs_f64() * 1e3
}

fn main() {
    let pool = Pool::default();
    println!("threads = {}", pool.threads());
    let g = gen::rgg(1 << 16, gen::rgg_paper_radius(1 << 16), 3);
    println!("graph: {}", g.summary());
    let n = g.n();
    let md = g.num_directed();

    // Pool primitives.
    let iters = 20;
    let t_for = time_ms(|| {
        for _ in 0..iters {
            pool.parallel_for(md, |_| {});
        }
    }) / iters as f64;
    let t_red = time_ms(|| {
        for _ in 0..iters {
            let _ = pool.reduce_sum_u64(md, |i| i as u64);
        }
    }) / iters as f64;
    let t_scan = time_ms(|| {
        for _ in 0..iters {
            let _ = pool.scan_exclusive(n, |_| 1);
        }
    }) / iters as f64;
    println!("\n| kernel | ms | items/us |");
    println!("|---|---|---|");
    println!("| parallel_for(2m) | {t_for:.3} | {:.0} |", md as f64 / t_for / 1e3);
    println!("| parallel_reduce(2m) | {t_red:.3} | {:.0} |", md as f64 / t_red / 1e3);
    println!("| parallel_scan(n) | {t_scan:.3} | {:.0} |", n as f64 / t_scan / 1e3);

    // ECSR build.
    let t_ecsr = time_ms(|| {
        let _ = EdgeList::build_par(&pool, &g);
    });
    println!("| ecsr build | {t_ecsr:.3} | {:.0} |", md as f64 / t_ecsr / 1e3);
    let el = EdgeList::build(&g);

    // Matching.
    let mut mate = Vec::new();
    let t_match = time_ms(|| {
        mate = preference_matching(&g, &pool, i64::MAX, 1, 8);
    });
    println!("| preference matching | {t_match:.3} | {:.0} |", md as f64 / t_match / 1e3);

    // Contraction.
    let (map, nc) = matching_to_map(&mate);
    let t_contract = time_ms(|| {
        let _ = contract_cas(&pool, &g, &el, &map, nc);
    });
    println!("| cas contraction | {t_contract:.3} | {:.0} |", md as f64 / t_contract / 1e3);

    // Subgraph extraction (4 blocks).
    let mut rng = Rng::new(2);
    let part4: Vec<u32> = (0..n).map(|_| rng.below(4) as u32).collect();
    let t_sub = time_ms(|| {
        let _ = subgraph::build_all_subgraphs(&pool, &g, &part4, 4);
    });
    println!("| subgraph build (k=4) | {t_sub:.3} | {:.0} |", md as f64 / t_sub / 1e3);

    // Conn table + one LP step.
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();
    let k = h.k();
    let part: Vec<u32> = (0..n).map(|_| rng.below(k as u64) as u32).collect();
    let mut conn_opt = None;
    let t_conn = time_ms(|| {
        conn_opt = Some(ConnTable::build(&pool, &g, &el, &part, k));
    });
    println!("| conn table build | {t_conn:.3} | {:.0} |", md as f64 / t_conn / 1e3);
    let conn = conn_opt.unwrap();
    let mut lp = JetLp::new(n);
    // Hot path uses the dense-row oracle (as jet_refine does for small k).
    let oracle = DistanceOracle::dense(&h);
    let t_lp = time_ms(|| {
        let _ = lp.run(&pool, &g, &conn, &part, &Objective::Oracle(&oracle), Filter::NonNegative);
    });
    println!("| jet LP step (k={k}, dense oracle) | {t_lp:.3} | {:.0} |", md as f64 / t_lp / 1e3);
    let mut lp2 = JetLp::new(n);
    let t_lp_o = time_ms(|| {
        let _ = lp2.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative);
    });
    println!("| jet LP step (k={k}, oracle) | {t_lp_o:.3} | {:.0} |", md as f64 / t_lp_o / 1e3);
}
