//! Multilevel-subsystem perf harness: (1) hierarchy build cost of the
//! matching vs cluster coarsening schemes on regular and irregular
//! graphs, and (2) cold vs hierarchy-cached time-to-result of repeat
//! engine jobs on a pinned session graph (the upload-once/map-many
//! pattern). Wall-clock *and* modeled device ms land in
//! `BENCH_multilevel.json` (override the path with `HEIPA_BENCH_OUT`;
//! set `HEIPA_BENCH_SMOKE=1` for a seconds-scale CI run).

use heipa::algo::Algorithm;
use heipa::cancel::CancelToken;
use heipa::engine::{Engine, EngineConfig, MapSpec};
use heipa::graph::builder::GraphBuilder;
use heipa::graph::{gen, CsrGraph};
use heipa::multilevel::{BuildParams, CoarsenConfig, CoarseHierarchy, SchemeKind};
use heipa::par::cost::DeviceTimer;
use heipa::par::Pool;
use std::sync::Arc;

struct Record {
    bench: &'static str,
    graph: String,
    scheme: &'static str,
    mode: &'static str,
    wall_ms: f64,
    device_ms: f64,
    levels: usize,
    coarsest_n: usize,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(records: &[Record], path: &str) {
    let mut out = String::from("{\n  \"bench\": \"multilevel\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"{}\", \"graph\": \"{}\", \"scheme\": \"{}\", \"mode\": \"{}\", \
             \"wall_ms\": {:.3}, \"device_ms\": {:.3}, \"levels\": {}, \"coarsest_n\": {}}}{}\n",
            json_escape(r.bench),
            json_escape(&r.graph),
            r.scheme,
            r.mode,
            r.wall_ms,
            r.device_ms,
            r.levels,
            r.coarsest_n,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).expect("write bench json");
}

/// Best-of-`reps` measurement of `f` (wall ms, modeled device ms, result).
fn measure<T>(reps: usize, mut f: impl FnMut() -> T) -> (f64, f64, T) {
    let mut best_wall = f64::INFINITY;
    let mut best_dev = f64::INFINITY;
    let mut last = None;
    for _ in 0..reps {
        let t = DeviceTimer::start();
        let r = f();
        let m = t.stop();
        best_wall = best_wall.min(m.host_ms);
        best_dev = best_dev.min(m.device_ms);
        last = Some(r);
    }
    (best_wall, best_dev, last.unwrap())
}

/// A forest of wide stars — the irregular, matching-hostile shape the
/// cluster scheme exists for.
fn star_forest(stars: u32, leaves: u32) -> CsrGraph {
    let mut b = GraphBuilder::new((stars * (leaves + 1)) as usize);
    for s in 0..stars {
        let hub = s * (leaves + 1);
        for i in 1..=leaves {
            b.add_edge(hub, hub + i, 1.0);
        }
    }
    b.build()
}

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_multilevel.json".to_string());
    let reps = if smoke { 1 } else { 3 };

    let graphs: Vec<(String, Arc<CsrGraph>)> = if smoke {
        vec![
            ("rgg11".into(), Arc::new(gen::rgg(1 << 11, gen::rgg_paper_radius(1 << 11), 3))),
            ("stars-2k".into(), Arc::new(star_forest(40, 49))),
        ]
    } else {
        vec![
            ("rgg15".into(), Arc::new(gen::rgg(1 << 15, gen::rgg_paper_radius(1 << 15), 3))),
            ("stencil128".into(), Arc::new(gen::stencil9(128, 128, 7))),
            ("stars-50k".into(), Arc::new(star_forest(500, 99))),
        ]
    };

    let mut records = Vec::new();
    println!("| bench | graph | scheme/mode | wall ms | device ms | levels | coarsest n |");
    println!("|---|---|---|---|---|---|---|");

    // Part 1: scheme shoot-out on raw hierarchy builds.
    let pool = Pool::new(if smoke { 2 } else { 4 });
    for (name, g) in &graphs {
        for (label, scheme) in [
            ("matching", SchemeKind::Matching),
            ("cluster", SchemeKind::Cluster),
            ("auto", SchemeKind::Auto),
        ] {
            let cfg = CoarsenConfig { scheme, ..CoarsenConfig::device() };
            let params = BuildParams { coarsest: 64.max(g.n() / 256), lmax: i64::MAX, seed: cfg.salt };
            let (wall, dev, hier) = measure(reps, || {
                CoarseHierarchy::build(&pool, g.clone(), &params, &cfg, &CancelToken::new(), None)
                    .expect("uncancelled build")
            });
            println!(
                "| build | {name} | {label} | {wall:.2} | {dev:.2} | {} | {} |",
                hier.levels(),
                hier.coarsest().n()
            );
            records.push(Record {
                bench: "build",
                graph: name.clone(),
                scheme: label,
                mode: "cold",
                wall_ms: wall,
                device_ms: dev,
                levels: hier.levels(),
                coarsest_n: hier.coarsest().n(),
            });
        }
    }

    // Part 2: cold vs cached time-to-result on a pinned session graph.
    for (name, g) in &graphs {
        let engine = Engine::new(EngineConfig { threads: if smoke { 2 } else { 4 }, ..Default::default() });
        engine.put_graph("sess", g.clone());
        let spec = MapSpec::named("sess")
            .hierarchy("4:4")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm))
            .return_mapping(false);
        // Cold: the first job builds (and caches) the hierarchy.
        let t = DeviceTimer::start();
        let cold_out = engine.map(&spec.clone().seed(1)).unwrap();
        let cold = t.stop();
        // Cached: repeat jobs (fresh seeds) skip coarsening entirely.
        let (warm_wall, warm_dev, warm_out) = measure(reps.max(2), || {
            let seed = 2 + records.len() as u64;
            engine.map(&spec.clone().seed(seed)).unwrap()
        });
        assert_eq!(cold_out.hierarchy_cache, Some(false));
        assert_eq!(warm_out.hierarchy_cache, Some(true));
        for (mode, wall, dev) in
            [("cold", cold.host_ms, cold.device_ms), ("cached", warm_wall, warm_dev)]
        {
            println!("| job | {name} | {mode} | {wall:.2} | {dev:.2} | - | - |");
            records.push(Record {
                bench: "job",
                graph: name.clone(),
                scheme: "auto",
                mode,
                wall_ms: wall,
                device_ms: dev,
                levels: 0,
                coarsest_n: 0,
            });
        }
    }

    write_json(&records, &out_path);
    println!("\nwrote {} records to {out_path}", records.len());

    // Headline: cached speedup per graph.
    for (name, _) in &graphs {
        let grab = |mode: &str| -> Option<f64> {
            records
                .iter()
                .find(|r| r.bench == "job" && r.graph == *name && r.mode == mode)
                .map(|r| r.wall_ms)
        };
        if let (Some(cold), Some(cached)) = (grab("cold"), grab("cached")) {
            if cached > 0.0 {
                println!(
                    "{name}: cold {cold:.2} ms vs cached {cached:.2} ms ({:.2}x time-to-result)",
                    cold / cached
                );
            }
        }
    }
}
