//! Sustained service-throughput harness: a fixed-duration stream of
//! mixed cold/warm mapping jobs against one in-process coordinator
//! [`Service`], driven through the real wire dispatcher
//! (`protocol::handle_command`) on a pinned session graph. Per-job wall
//! p50/p99 and jobs/sec per mode land in `BENCH_service.json` (override
//! the path with `HEIPA_BENCH_OUT`; set `HEIPA_BENCH_SMOKE=1` for a
//! seconds-scale CI run).
//!
//! Modes:
//! * `cold`  — every job pays the full multilevel solve
//!   (`opt.remap.max_region_frac=0` disables warm starts);
//! * `warm`  — patch→map cycles with the warm path open
//!   (`opt.remap.max_region_frac=1`);
//! * `mixed` — alternating cold/warm, the steady-state shape of a
//!   session-serving deployment.

use heipa::coordinator::protocol::handle_command;
use heipa::coordinator::service::{Service, ServiceConfig};
use heipa::graph::gen;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Record {
    mode: &'static str,
    graph: String,
    wall_ms: f64,
    p99_ms: f64,
    jobs: usize,
    jobs_per_sec: f64,
    warm_hits: usize,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(records: &[Record], path: &str) {
    let mut out = String::from("{\n  \"bench\": \"service\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"sustained\", \"graph\": \"{}\", \"mode\": \"{}\", \
             \"wall_ms\": {:.3}, \"p99_ms\": {:.3}, \"jobs\": {}, \"jobs_per_sec\": {:.2}, \
             \"warm_hits\": {}}}{}\n",
            json_escape(&r.graph),
            r.mode,
            r.wall_ms,
            r.p99_ms,
            r.jobs,
            r.jobs_per_sec,
            r.warm_hits,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).expect("write bench json");
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

/// One fixed-duration stream of blocking `map` jobs in `mode`, on a
/// fresh service with the session graph pinned. Returns sorted per-job
/// wall times, the stream's wall seconds, and the warm-path hit count.
fn sustained(graph_name: &str, mode: &'static str, duration: Duration) -> (Vec<f64>, f64, usize) {
    let svc = Service::with_config(ServiceConfig { threads: 2, workers: 2, ..Default::default() });
    let g = match graph_name {
        "rgg12" => Arc::new(gen::rgg(1 << 12, gen::rgg_paper_radius(1 << 12), 3)),
        _ => Arc::new(gen::stencil9(96, 96, 7)),
    };
    // A non-adjacent vertex pair to patch in and back out each warm
    // cycle (perturbation without unbounded growth).
    let (pu, pv) = {
        let n = g.n() as u32;
        let mut found = (0, n / 2);
        'outer: for u in 0..n.min(64) {
            for v in (n / 2)..(n / 2 + 64).min(n) {
                if u != v && g.find_edge(u, v).is_none() {
                    found = (u, v);
                    break 'outer;
                }
            }
        }
        found
    };
    svc.put_graph("sess", g);
    let map_line = |frac: &str, seed: u64| {
        format!(
            "map graph=sess algorithm=gpu-im hierarchy=2:4 distance=1:10 eps=0.05 seed={seed} \
             opt.remap.max_region_frac={frac}"
        )
    };
    // Prime the hierarchy cache so warm cycles have a state to start from.
    let first = handle_command(&svc, &map_line("1", 1));
    assert!(first.starts_with("ok "), "{first}");
    let mut walls = Vec::new();
    let mut warm_hits = 0usize;
    let mut edge_flip = false;
    let t0 = Instant::now();
    let mut seed = 2u64;
    while t0.elapsed() < duration {
        let warm_job = match mode {
            "cold" => false,
            "warm" => true,
            _ => seed % 2 == 0,
        };
        if warm_job {
            // Perturb the session graph, then remap with the warm path
            // open — the patch keeps the warm region small.
            let ops =
                if edge_flip { format!("re:{pu}:{pv}") } else { format!("ae:{pu}:{pv}:1.0") };
            edge_flip = !edge_flip;
            let patched = handle_command(&svc, &format!("graph patch name=sess ops={ops}"));
            assert!(patched.starts_with("ok "), "{patched}");
        }
        let line = map_line(if warm_job { "1" } else { "0" }, seed);
        let t = Instant::now();
        let reply = handle_command(&svc, &line);
        let wall = t.elapsed().as_secs_f64() * 1e3;
        assert!(reply.starts_with("ok "), "{reply}");
        if reply.contains(" remap=warm") {
            warm_hits += 1;
        }
        walls.push(wall);
        seed += 1;
    }
    let total_s = t0.elapsed().as_secs_f64();
    walls.sort_by(|a, b| a.total_cmp(b));
    (walls, total_s, warm_hits)
}

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_service.json".to_string());
    let duration = Duration::from_millis(if smoke { 1000 } else { 10_000 });
    let graphs: &[&str] = if smoke { &["rgg12"] } else { &["rgg12", "stencil96"] };

    let mut records = Vec::new();
    println!("| graph | mode | p50 ms | p99 ms | jobs | jobs/s | warm hits |");
    println!("|---|---|---|---|---|---|---|");
    for graph in graphs {
        for mode in ["cold", "warm", "mixed"] {
            let (walls, total_s, warm_hits) = sustained(graph, mode, duration);
            let (p50, p99) = (percentile(&walls, 0.5), percentile(&walls, 0.99));
            let jps = walls.len() as f64 / total_s.max(1e-9);
            println!(
                "| {graph} | {mode} | {p50:.2} | {p99:.2} | {} | {jps:.1} | {warm_hits} |",
                walls.len()
            );
            records.push(Record {
                mode,
                graph: graph.to_string(),
                wall_ms: p50,
                p99_ms: p99,
                jobs: walls.len(),
                jobs_per_sec: jps,
                warm_hits,
            });
        }
    }
    write_json(&records, &out_path);
    println!("\nwrote {} records to {out_path}", records.len());

    // Headline: sustained mixed throughput vs all-cold, per graph.
    for graph in graphs {
        let grab = |mode: &str| -> Option<f64> {
            records.iter().find(|r| r.graph == *graph && r.mode == mode).map(|r| r.jobs_per_sec)
        };
        if let (Some(cold), Some(mixed)) = (grab("cold"), grab("mixed")) {
            if cold > 0.0 {
                println!(
                    "{graph}: {cold:.1} jobs/s all-cold vs {mixed:.1} jobs/s mixed ({:.2}x)",
                    mixed / cold
                );
            }
        }
    }
}
