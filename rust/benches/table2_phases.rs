//! Table 2: runtime distribution across GPU-IM phases, small vs large
//! graphs, plus absolute per-phase times for the cop20k_A and europe_osm
//! stand-ins on the 4:8:6 hierarchy (modeled device time).
//!
//! Paper reference (shares): small — Coarsening 13.0%, Contraction 3.5%,
//! Init 13.9%, Uncontr. 0.1%, Refine+Reb 65.2%, Misc 4.3%;
//! large — 11.6 / 11.2 / 4.2 / 0.2 / 45.5 / 27.2.

use heipa::algo::Algorithm;
use heipa::engine::{Engine, MapSpec};
use heipa::metrics::{Phase, PhaseBreakdown};

fn main() {
    let engine = Engine::with_defaults();

    let small = ["sten_cop20k", "sten_cubes", "wal_598a"];
    let large = ["rgg16", "road_eu"];

    let mut small_agg = PhaseBreakdown::default();
    let mut large_agg = PhaseBreakdown::default();
    let mut named: Vec<(&str, PhaseBreakdown)> = Vec::new();

    for (group, names, agg) in
        [("small", &small[..], &mut small_agg), ("large", &large[..], &mut large_agg)]
    {
        for name in names {
            let spec = MapSpec::named(*name)
                .hierarchy("4:8:6")
                .distance("1:10:100")
                .algo(Some(Algorithm::GpuIm))
                .return_mapping(false);
            let out = engine.map(&spec).unwrap();
            eprintln!("table2: {group} {name} (n={})", out.n);
            let phases = out.phases.expect("gpu-im reports phases");
            agg.merge(&phases);
            if *name == "sten_cop20k" || *name == "road_eu" {
                named.push((*name, phases));
            }
        }
    }

    let paper_small = [13.02, 3.49, 13.85, 0.14, 65.22, 4.28];
    let paper_large = [11.59, 11.16, 4.23, 0.24, 45.53, 27.24];
    println!("== Table 2: GPU-IM phase shares (modeled device time) ==");
    println!("| phase | small (ours) | small (paper) | large (ours) | large (paper) |");
    println!("|---|---|---|---|---|");
    for (i, ph) in Phase::all().into_iter().enumerate() {
        println!(
            "| {} | {:.2}% | {:.2}% | {:.2}% | {:.2}% |",
            ph.label(),
            small_agg.share(ph),
            paper_small[i],
            large_agg.share(ph),
            paper_large[i]
        );
    }

    println!("\n== absolute per-phase times (ms, modeled; paper column = RTX 4090) ==");
    let paper_cop = [4.351, 1.010, 11.116, 0.046, 24.359, 1.193];
    let paper_osm = [41.020, 38.694, 7.244, 1.523, 116.598, 115.469];
    for (name, phases) in &named {
        let paper = if *name == "sten_cop20k" { &paper_cop } else { &paper_osm };
        let stand = if *name == "sten_cop20k" { "cop20k_A" } else { "europe_osm" };
        println!("\n{name} (stand-in for {stand}):");
        println!("| phase | ours ms | paper ms |");
        println!("|---|---|---|");
        for (i, ph) in Phase::all().into_iter().enumerate() {
            println!("| {} | {:.3} | {:.3} |", ph.label(), phases.device_ms(ph), paper[i]);
        }
        println!("| Total | {:.3} | {:.3} |", phases.total_device_ms(), paper.iter().sum::<f64>());
    }
}
