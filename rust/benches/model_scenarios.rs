//! Machine-model scenarios: torus / fat-tree / dragonfly machines driven
//! end to end through the engine (CLI-equivalent path), so the
//! non-hierarchical models stay exercised by CI.
//!
//! `HEIPA_BENCH_SMOKE=1` shrinks the graphs to CI size. Writes
//! `BENCH_models.json` (`HEIPA_BENCH_OUT` overrides).

use heipa::algo::Algorithm;
use heipa::engine::{Engine, EngineConfig, MapSpec};
use heipa::graph::gen;
use heipa::harness::scenario_presets;
use heipa::partition::validate_mapping;
use std::sync::Arc;

fn main() {
    let smoke = std::env::var("HEIPA_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("HEIPA_BENCH_OUT").unwrap_or_else(|_| "BENCH_models.json".to_string());
    let engine = Engine::new(EngineConfig { threads: if smoke { 1 } else { 0 }, ..Default::default() });

    let mut rows = Vec::new();
    println!("| scenario | machine | k | algo | n | J | imb | host ms | device ms |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for sc in scenario_presets() {
        let machine = sc.machine();
        let g = Arc::new(if smoke {
            // CI-sized stand-ins with the same shapes.
            match sc.name {
                "torus-halo" => gen::torus3d(8, 8, 4),
                "fattree-stencil" => gen::stencil9(24, 24, 1),
                _ => gen::rgg(1_200, gen::rgg_paper_radius(1_200) * 1.2, 9),
            }
        } else {
            sc.graph()
        });
        for algo in [Algorithm::GpuHm, Algorithm::GpuIm] {
            let spec = MapSpec::in_memory(g.clone())
                .topology(&machine)
                .algo(Some(algo))
                .eps(0.03)
                .seed(1);
            let r = engine.map(&spec).expect("scenario maps");
            validate_mapping(&r.mapping, r.n, r.k).expect("valid mapping");
            assert!(r.comm_cost > 0.0);
            println!(
                "| {} | {} | {} | {} | {} | {:.0} | {:.4} | {:.1} | {:.2} |",
                sc.name,
                machine.label(),
                r.k,
                r.algorithm.name(),
                r.n,
                r.comm_cost,
                r.imbalance,
                r.host_ms,
                r.device_ms
            );
            rows.push(format!(
                "{{\"scenario\":\"{}\",\"machine\":\"{}\",\"algo\":\"{}\",\"n\":{},\"k\":{},\"j\":{:.3},\"imbalance\":{:.5},\"host_ms\":{:.3},\"device_ms\":{:.3}}}",
                sc.name,
                machine.label(),
                r.algorithm.name(),
                r.n,
                r.k,
                r.comm_cost,
                r.imbalance,
                r.host_ms,
                r.device_ms
            ));
        }
    }
    let json = format!("[\n  {}\n]\n", rows.join(",\n  "));
    std::fs::write(&out_path, json).expect("write bench output");
    println!("\nwrote {out_path}");
}
