//! Figure 2 (both panels): CPU-based comparison — GPU-HM-ultra and GPU-IM
//! vs SharedMap-F/-S and IntMap-F/-S.
//!
//! Left: speedup over SharedMap-S (GPU algorithms use the modeled device
//! time, CPU baselines their wall time — DESIGN.md §1). Right:
//! performance profile / mean overhead of the communication cost.
//!
//! Paper reference: quality order SharedMap-S (+0.2%) < GPU-HM-ultra
//! (+12.2%) < IntMap-S (+14.4%) < IntMap-F (+20.9%) < SharedMap-F
//! (+30.8%) < GPU-IM (+33.1%); speedups vs SharedMap-S: GPU-IM 1454.6x
//! geomean / 12376.9x max, GPU-HM-ultra 22.4x / 934.7x, SharedMap-F
//! 42.7x, IntMap-F 36.7x, IntMap-S 11.7x.

use heipa::algo::Algorithm;
use heipa::graph::gen;
use heipa::harness::{self, profiles, stats};
use heipa::engine::Engine;

fn main() {
    let engine = Engine::with_defaults();
    let seeds = harness::seeds_from_env(&[1]);
    let hierarchies = harness::machines_from_env();
    let instances = gen::smoke_suite();
    let algos = [
        Algorithm::GpuHmUltra,
        Algorithm::GpuIm,
        Algorithm::SharedMapF,
        Algorithm::SharedMapS,
        Algorithm::IntMapF,
        Algorithm::IntMapS,
    ];

    eprintln!(
        "fig2_cpu: {} instances x {} hierarchies x {} seeds",
        instances.len(),
        hierarchies.len(),
        seeds.len()
    );
    let records = harness::run_matrix(&engine, &algos, &instances, &hierarchies, &seeds, 0.03);

    println!("== Figure 2 (right): quality ==");
    let names: Vec<String> = algos.iter().map(|a| a.name().to_string()).collect();
    let quality: Vec<Vec<f64>> = algos
        .iter()
        .map(|a| records.iter().filter(|r| r.algorithm == *a).map(|r| r.comm_cost).collect())
        .collect();
    let input = profiles::ProfileInput { algorithm_names: names, quality };
    let paper = [
        ("gpu-hm-ultra", 12.2),
        ("gpu-im", 33.1),
        ("sharedmap-f", 30.8),
        ("sharedmap-s", 0.2),
        ("intmap-f", 20.9),
        ("intmap-s", 14.4),
    ];
    println!("mean overhead over best (ours vs paper):");
    let overheads = input.mean_overhead_pct();
    for (name, paper_pct) in paper {
        let ours = overheads.get(name).copied().unwrap_or(f64::NAN);
        println!("  {name:>14}: +{ours:.1}%  (paper +{paper_pct}%)");
    }
    println!("\nbest-solution fractions (paper: sharedmap-s 82.7%, gpu-hm-ultra 17.3%):");
    for (name, frac) in input.best_fractions() {
        println!("  {name:>14}: {:.1}%", frac * 100.0);
    }
    let p = input.compute(&profiles::tau_grid(2.0, 10));
    print!("\n{}", profiles::profile_markdown(&p));

    println!("\n== Figure 2 (left): speedup over sharedmap-s ==");
    let base: Vec<f64> = records
        .iter()
        .filter(|r| r.algorithm == Algorithm::SharedMapS)
        .map(|r| r.device_ms)
        .collect();
    let paper_speed = [
        ("gpu-hm-ultra", 22.4, 934.7),
        ("gpu-im", 1454.6, 12376.9),
        ("sharedmap-f", 42.7, f64::NAN),
        ("intmap-f", 36.7, f64::NAN),
        ("intmap-s", 11.7, f64::NAN),
    ];
    for (name, paper_geo, paper_max) in paper_speed {
        let a = Algorithm::from_name(name).unwrap();
        let mine: Vec<f64> =
            records.iter().filter(|r| r.algorithm == a).map(|r| r.device_ms).collect();
        let (geo, mx, _) = stats::speedup_summary(&base, &mine);
        println!(
            "  {name:>14}: geomean {geo:.1}x  max {mx:.1}x  (paper {paper_geo}x / {paper_max}x)"
        );
    }
}
