//! Figure 1 (both panels): own comparison — GPU-HM vs GPU-HM-ultra vs
//! GPU-IM. Left: performance profile of solution quality. Right: speedup
//! over GPU-HM-ultra (the quality baseline).
//!
//! Scale with `HEIPA_TOPS=1,…,6` (hierarchy tops) and `HEIPA_SEEDS`.
//! Paper reference: GPU-HM geomean speedup 6.5x (max 9.1x), GPU-IM 64.9x
//! (max 150.1x); ultra best on 95.3% of instances.

use heipa::algo::Algorithm;
use heipa::graph::gen;
use heipa::harness::{self, profiles, stats};
use heipa::engine::Engine;

fn main() {
    let engine = Engine::with_defaults();
    let seeds = harness::seeds_from_env(&[1]);
    let hierarchies = harness::machines_from_env();
    let instances = gen::smoke_suite();
    let algos = [Algorithm::GpuHm, Algorithm::GpuHmUltra, Algorithm::GpuIm];

    eprintln!("fig1_own: {} instances x {} hierarchies x {} seeds", instances.len(), hierarchies.len(), seeds.len());
    let records = harness::run_matrix(&engine, &algos, &instances, &hierarchies, &seeds, 0.03);

    let names: Vec<String> = algos.iter().map(|a| a.name().to_string()).collect();
    let quality: Vec<Vec<f64>> = algos
        .iter()
        .map(|a| records.iter().filter(|r| r.algorithm == *a).map(|r| r.comm_cost).collect())
        .collect();
    let input = profiles::ProfileInput { algorithm_names: names, quality };

    println!("== Figure 1 (left): performance profile (communication cost) ==");
    let p = input.compute(&profiles::tau_grid(1.5, 12));
    print!("{}", profiles::profile_markdown(&p));
    println!("\nbest-solution fractions (paper: ultra 95.3%, GPU-HM 4.7%, GPU-IM 0%):");
    for (name, frac) in input.best_fractions() {
        println!("  {name:>14}: {:.1}%", frac * 100.0);
    }
    println!("\nmean overhead over best (paper: ultra +0.2%, GPU-HM +5.1%, GPU-IM +17.4%):");
    for (name, pct) in input.mean_overhead_pct() {
        println!("  {name:>14}: +{pct:.1}%");
    }

    println!("\n== Figure 1 (right): speedup over gpu-hm-ultra (modeled device time) ==");
    let base: Vec<f64> = records
        .iter()
        .filter(|r| r.algorithm == Algorithm::GpuHmUltra)
        .map(|r| r.device_ms)
        .collect();
    for a in [Algorithm::GpuHm, Algorithm::GpuIm] {
        let mine: Vec<f64> =
            records.iter().filter(|r| r.algorithm == a).map(|r| r.device_ms).collect();
        let (geo, mx, mn) = stats::speedup_summary(&base, &mine);
        println!("  {:>10}: geomean {geo:.1}x  max {mx:.1}x  min {mn:.1}x", a.name());
    }
    println!("  (paper: gpu-hm 6.5x geomean / 9.1x max; gpu-im 64.9x / 150.1x)");
}
