//! Integration tests of the unified multilevel subsystem:
//!
//! * hierarchy invariants — every level strictly shrinks, every map is a
//!   valid surjection onto the coarser vertex set, and contraction
//!   preserves total vertex weight — for both schemes, device and serial;
//! * cached-hierarchy determinism parity: a prebuilt (engine-cached)
//!   hierarchy yields bit-identical solver output to an inline build;
//! * the cluster scheme keeps coarsening where matchings stall;
//! * the engine's hierarchy cache end to end: a second job on a pinned
//!   session graph skips the Coarsening/Contraction phases and reports a
//!   hit through the metrics.

use heipa::algo::Algorithm;
use heipa::cancel::CancelToken;
use heipa::engine::{Engine, EngineConfig, MapSpec};
use heipa::graph::builder::GraphBuilder;
use heipa::graph::{gen, CsrGraph};
use heipa::metrics::Phase;
use heipa::multilevel::{BuildParams, CoarsenConfig, CoarseHierarchy, SchemeKind};
use heipa::par::Pool;
use std::sync::Arc;

fn params(coarsest: usize, lmax: i64) -> BuildParams {
    BuildParams { coarsest, lmax, seed: 42 }
}

/// Exhaustive invariant check on top of `CoarseHierarchy::validate`:
/// recompute the per-level weight totals independently.
fn check_invariants(h: &CoarseHierarchy) {
    h.validate().unwrap();
    for lev in 0..h.levels() {
        let fine = h.graph(lev);
        let coarse = h.graph(lev + 1);
        assert!(coarse.n() < fine.n(), "level {lev} must strictly shrink");
        let map = h.map(lev);
        let mut w = vec![0i64; coarse.n()];
        for v in 0..fine.n() {
            w[map[v] as usize] += fine.vw[v];
        }
        assert_eq!(w, coarse.vw, "level {lev}: coarse vertex weights must be member sums");
    }
}

#[test]
fn hierarchy_invariants_hold_for_every_scheme() {
    let g = Arc::new(gen::rgg(4_000, 0.045, 11));
    let pool = Pool::new(2);
    for scheme in [SchemeKind::Matching, SchemeKind::Cluster, SchemeKind::Auto] {
        let cfg = CoarsenConfig { scheme, ..CoarsenConfig::device() };
        let h = CoarseHierarchy::build(&pool, g.clone(), &params(128, i64::MAX), &cfg, &CancelToken::new(), None)
            .unwrap();
        assert!(h.levels() >= 1, "{scheme:?}: expected at least one coarsening level");
        assert!(h.coarsest().n() <= 128 || h.stalled(), "{scheme:?}: target not reached");
        check_invariants(&h);
        assert_eq!(h.matched_fractions().len(), h.levels());
        assert!(h.matched_fractions().iter().all(|f| (0.0..=1.0).contains(f)), "{scheme:?}");
    }
    // Serial builds satisfy the same invariants.
    let cfg = CoarsenConfig::serial(160);
    let hs = CoarseHierarchy::build_serial(&g, &params(160, i64::MAX), &cfg, &CancelToken::new()).unwrap();
    check_invariants(&hs);
}

/// A forest of stars — the canonical matching-hostile instance.
fn star_forest(stars: u32, leaves: u32) -> CsrGraph {
    let mut b = GraphBuilder::new((stars * (leaves + 1)) as usize);
    for s in 0..stars {
        let hub = s * (leaves + 1);
        for i in 1..=leaves {
            b.add_edge(hub, hub + i, 1.0);
        }
    }
    b.build()
}

#[test]
fn cluster_and_auto_coarsen_star_forests_further_than_pure_matching() {
    // 20 stars of 49 leaves: a matching removes one pair per star, so a
    // level keeps 980/1000 > STALL_FRACTION of its vertices and stalls.
    let g = Arc::new(star_forest(20, 49));
    let pool = Pool::new(1);
    let build = |scheme: SchemeKind| {
        // Two-hop fallback disabled to isolate the scheme comparison.
        let cfg = CoarsenConfig { scheme, max_twohop_passes: 0, ..CoarsenConfig::device() };
        CoarseHierarchy::build(&pool, g.clone(), &params(64, i64::MAX), &cfg, &CancelToken::new(), None)
            .unwrap()
    };
    let stalled = build(SchemeKind::Matching);
    assert!(stalled.stalled(), "pure matching must stall on wide stars");
    assert_eq!(stalled.coarsest().n(), g.n(), "stalled on the first level");
    let cluster = build(SchemeKind::Cluster);
    let auto = build(SchemeKind::Auto);
    assert!(
        cluster.coarsest().n() < stalled.coarsest().n() / 4,
        "cluster ({}) must out-coarsen stalled matching ({})",
        cluster.coarsest().n(),
        stalled.coarsest().n()
    );
    assert!(
        auto.coarsest().n() < stalled.coarsest().n() / 4,
        "auto must fall back to clustering on stalled levels"
    );
    check_invariants(&cluster);
    check_invariants(&auto);
}

#[test]
fn gpu_im_output_is_identical_through_a_cached_hierarchy_end_to_end() {
    // Engine-level determinism parity: three runs — cold (populates the
    // cache), warm (hit), and a fresh engine (no cache at all) — must
    // produce the same mapping bit for bit.
    let g = Arc::new(gen::stencil9(40, 40, 3));
    let spec = MapSpec::in_memory(g.clone())
        .hierarchy("2:2:2")
        .distance("1:10:100")
        .algo(Some(Algorithm::GpuIm))
        .seed(5);
    let warm_engine = Engine::new(EngineConfig { threads: 1, ..Default::default() });
    let cold = warm_engine.map(&spec).unwrap();
    let warm = warm_engine.map(&spec).unwrap();
    assert_eq!(cold.hierarchy_cache, Some(false));
    assert_eq!(warm.hierarchy_cache, Some(true));
    assert_eq!(cold.mapping, warm.mapping, "cache hit must be bit-identical");
    let fresh = Engine::new(EngineConfig { threads: 1, ..Default::default() }).map(&spec).unwrap();
    assert_eq!(cold.mapping, fresh.mapping, "cache must not change results across engines");
}

#[test]
fn second_job_on_a_pinned_graph_skips_coarsening_phases() {
    // The acceptance path: pin a session graph, submit twice with
    // different seeds, and observe the hierarchy cache short-circuit the
    // Coarsening/Contraction phases of the second outcome.
    let e = Engine::new(EngineConfig { threads: 1, ..Default::default() });
    e.put_graph("sess", Arc::new(gen::rgg(3_000, 0.05, 9)));
    let spec = MapSpec::named("sess").hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm));
    let first = e.map(&spec.clone().seed(1)).unwrap();
    let second = e.map(&spec.seed(2)).unwrap();
    assert_eq!((e.hierarchy_cache_misses(), e.hierarchy_cache_hits()), (1, 1));
    let p1 = first.phases.as_ref().unwrap();
    let p2 = second.phases.as_ref().unwrap();
    assert!(p1.device_ms(Phase::Coarsening) > 0.0);
    assert!(p1.device_ms(Phase::Contraction) > 0.0);
    assert!(p2.device_ms(Phase::Coarsening) == 0.0, "hit must skip coarsening");
    assert!(p2.device_ms(Phase::Contraction) == 0.0, "hit must skip contraction");
    // Both are full, valid mappings regardless of the cache path.
    heipa::partition::validate_mapping(&first.mapping, first.n, first.k).unwrap();
    heipa::partition::validate_mapping(&second.mapping, second.n, second.k).unwrap();
}

#[test]
fn jet_and_gpu_im_share_hierarchy_cache_entries() {
    // The hierarchy is objective-agnostic: same graph, same (k, eps),
    // same coarsening key — the edge-cut Jet solver reuses the entry the
    // mapping solver built.
    let e = Engine::new(EngineConfig { threads: 1, ..Default::default() });
    e.put_graph("sess", Arc::new(gen::grid2d(40, 40, false)));
    let base = MapSpec::named("sess").hierarchy("2:2").distance("1:10");
    e.map(&base.clone().algo(Some(Algorithm::GpuIm))).unwrap();
    let jet = e.map(&base.algo(Some(Algorithm::Jet))).unwrap();
    assert_eq!(jet.hierarchy_cache, Some(true), "jet must reuse the gpu-im hierarchy");
    assert_eq!(e.hierarchy_cache_misses(), 1);
    assert_eq!(e.hierarchy_cache_hits(), 1);
}

#[test]
fn run_matrix_seed_sweep_coarsens_once_per_cell_shape() {
    // The upload-once/map-many payoff for the harness: a 3-seed sweep
    // over one in-memory graph and one machine builds exactly one
    // hierarchy per (graph, k, eps) key and serves the rest from cache.
    let e = Engine::new(EngineConfig { threads: 1, ..Default::default() });
    let spec = MapSpec::in_memory(Arc::new(gen::rgg(2_500, 0.05, 4)))
        .hierarchy("2:2")
        .distance("1:10")
        .algo(Some(Algorithm::GpuIm))
        .seeds(vec![1, 2, 3]);
    let outs = e.map_all_seeds(&spec).unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(e.hierarchy_cache_misses(), 1, "one build for the whole sweep");
    assert_eq!(e.hierarchy_cache_hits(), 2);
    // Seeds still diversify the results (initial mapping + refinement
    // remain seed-driven even though coarsening is shared).
    assert!(outs.iter().all(|o| o.comm_cost > 0.0));
}
