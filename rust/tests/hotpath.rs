//! Integration coverage for the hot-path overhaul: persistent-pool reuse,
//! delta conn-table parity across thread counts, and incremental-objective
//! agreement with exact re-reductions.

use heipa::graph::{gen, EdgeList};
use heipa::par::Pool;
use heipa::partition::{comm_cost, is_balanced, l_max, validate_mapping};
use heipa::refine::gains::ConnTable;
use heipa::refine::jet_loop::{jet_refine, jet_refine_with, JetConfig};
use heipa::refine::{ConnUpdate, Objective, RefineWorkspace};
use heipa::rng::Rng;
use heipa::topology::Machine;
use heipa::{Block, Vertex};

/// Thread count of this process from /proc (Linux); None elsewhere.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

#[test]
fn persistent_pool_survives_100_plus_kernels_without_thread_growth() {
    let pool = Pool::new(4);
    let n = 30_000;
    let expect = (n as u64 - 1) * n as u64 / 2;
    // Warm up, then sample the thread count early and late in a long
    // sequence of kernels: a pool that respawned workers per launch (or
    // leaked them) would drift; persistent workers keep it flat. Other
    // tests in this binary may run concurrently, hence the slack.
    for _ in 0..10 {
        assert_eq!(pool.reduce_sum_u64(n, |i| i as u64), expect);
    }
    let early = os_thread_count();
    for round in 0..140u64 {
        let s = pool.reduce_sum_u64(n, |i| i as u64 + round);
        assert_eq!(s, expect + round * n as u64, "round {round}");
        let scan = pool.scan_exclusive(n, |_| 2);
        assert_eq!(scan[n], 2 * n as u64);
    }
    let late = os_thread_count();
    if let (Some(a), Some(b)) = (early, late) {
        assert!(
            b <= a + 16,
            "thread count grew from {a} to {b} across 140 kernels — worker leak"
        );
    }
}

#[test]
fn delta_conn_table_parity_at_1_2_4_threads() {
    // Unit-weight rgg: all fp arithmetic is exact, so the delta-updated
    // table must be *identical* (in gathered (block, weight) sets) to a
    // fresh edge-parallel build — per the paper's strategy-2 contract.
    let g = gen::rgg(3_000, 0.045, 9);
    let k = 12;
    let el = EdgeList::build(&g);
    for threads in [1, 2, 4] {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(31);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let table = ConnTable::build(&pool, &g, &el, &part, k);
        let mut old_of = vec![0 as Block; g.n()];
        for _round in 0..5 {
            let mut moved: Vec<Vertex> =
                (0..200).map(|_| rng.below(g.n() as u64) as Vertex).collect();
            moved.sort_unstable();
            moved.dedup();
            for &v in &moved {
                old_of[v as usize] = part[v as usize];
                let mut b = rng.below(k as u64) as Block;
                if b == part[v as usize] {
                    b = (b + 1) % k as Block;
                }
                part[v as usize] = b;
            }
            table.update_delta(&pool, &g, &part, &moved, &old_of);
        }
        let fresh = ConnTable::build(&pool, &g, &el, &part, k);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for v in 0..g.n() {
            table.gather(v, &mut a);
            fresh.gather(v, &mut b);
            a.sort_unstable_by_key(|&(x, _)| x);
            b.sort_unstable_by_key(|&(x, _)| x);
            assert_eq!(a, b, "v={v} threads={threads}");
        }
    }
}

#[test]
fn incremental_objective_agrees_with_exact_after_resync() {
    let g = gen::stencil9(26, 26, 13);
    let h = Machine::hier("2:2:2", "1:10:100").unwrap();
    let k = h.k();
    let lmax = l_max(g.total_vweight(), k, 0.03);
    let el = EdgeList::build(&g);
    for threads in [1, 2, 4] {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(7);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        // Force several resyncs along the way; the reported objective is
        // always an exact reduction and must match an independent serial
        // evaluation of the returned mapping.
        let cfg = JetConfig { resync_every: 2, ..Default::default() };
        let stats = jet_refine(&pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &cfg);
        let exact = comm_cost(&g, &part, &h);
        assert!(
            (stats.final_objective - exact).abs() < 1e-6 * exact.max(1.0),
            "threads={threads}: tracked {} vs exact {exact}",
            stats.final_objective
        );
        assert!(is_balanced(&g, &part, k, 0.031), "threads={threads}");
    }
}

#[test]
fn refine_with_shared_workspace_across_graph_sizes() {
    // The multilevel pattern: one workspace, multiple graphs of different
    // sizes through the same buffers (coarse → fine order like gpu_im's
    // uncoarsening chain, then a *larger* graph to exercise growth).
    let h = Machine::hier("2:2", "1:10").unwrap();
    let k = h.k();
    let pool = Pool::new(2);
    let mut ws = RefineWorkspace::with_capacity(1_000, k);
    for (w, ht) in [(12, 12), (20, 20), (40, 40)] {
        let g = gen::grid2d(w, ht, false);
        let lmax = l_max(g.total_vweight(), k, 0.05);
        let el = EdgeList::build(&g);
        let mut rng = Rng::new(3);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let before = comm_cost(&g, &part, &h);
        jet_refine_with(
            &pool,
            &g,
            &el,
            &mut part,
            k,
            lmax,
            &Objective::Comm(&h),
            &JetConfig::default(),
            &mut ws,
        );
        validate_mapping(&part, g.n(), k).unwrap();
        assert!(is_balanced(&g, &part, k, 0.051), "{w}x{ht}");
        assert!(comm_cost(&g, &part, &h) < before, "{w}x{ht} did not improve");
    }
}

#[test]
fn forced_delta_strategy_runs_and_stays_correct_multithreaded() {
    let g = gen::rgg(4_000, 0.04, 21);
    let h = Machine::hier("4:2", "1:10").unwrap();
    let k = h.k();
    let lmax = l_max(g.total_vweight(), k, 0.05);
    let el = EdgeList::build(&g);
    let pool = Pool::new(4);
    let mut rng = Rng::new(2);
    let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
    let before = comm_cost(&g, &part, &h);
    let cfg = JetConfig { conn_update: ConnUpdate::Delta, ..Default::default() };
    let stats = jet_refine(&pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &cfg);
    assert!(stats.conn_delta_rounds > 0, "delta strategy never ran");
    assert_eq!(stats.conn_refill_rounds, 0);
    assert!(is_balanced(&g, &part, k, 0.051));
    assert!(comm_cost(&g, &part, &h) < before);
}
