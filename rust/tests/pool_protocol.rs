//! The Pool park/wake/join protocol, verified two ways:
//!
//! 1. **Deterministic interleaving exploration** of a state-machine
//!    replica of `par::WorkerSet` (`model_*` tests). The real protocol is
//!    a condvar-with-predicate-loop design: every wait re-checks its
//!    predicate under the state mutex, so the protocol is fully described
//!    by its *atomic mutex sections*. The model enumerates every
//!    interleaving of those sections by DFS and checks, in all of them:
//!    each worker runs each epoch's job exactly once, the submitter's
//!    barrier never completes early, the job slot is never observed empty
//!    by a woken worker, and no reachable state deadlocks.
//! 2. **Stress tests against the real `Pool`** (`real_*` tests):
//!    concurrent submitters through pool clones, repeated spawn/join
//!    cycles, and panic recovery on both the inline and the worker path.
//!
//! The model intentionally mirrors `worker_loop` / `WorkerSet::run` /
//! `CompletionGuard` step for step — if the protocol in `par/mod.rs`
//! changes shape, change the model with it.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use heipa::par::Pool;

// ---------------------------------------------------------------------------
// Part 1: exhaustive interleaving exploration of the protocol model.
// ---------------------------------------------------------------------------

const WORKERS: usize = 2;
const EPOCHS: u64 = 2;

/// Submitter program counter: for each epoch `Publish → Inline → Barrier →
/// Retire`, then `Shutdown`, then `Joined` (terminal).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum SubPc {
    Publish,
    Inline,
    Barrier,
    Retire,
    Shutdown,
    Joined,
}

/// Worker program counter: `Park` (predicate wait) → `Run` → `Finish` →
/// back to `Park`; `Exited` is terminal.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum WorkPc {
    Park,
    Run,
    Finish,
    Exited,
}

/// One reachable protocol state. Everything a mutex section can observe or
/// mutate lives here; `runs` tracks how often worker `w` executed epoch
/// `e`'s job (the exactly-once ledger the invariants are written against).
#[derive(Clone, PartialEq, Eq, Hash)]
struct ModelState {
    epoch: u64,
    job_present: bool,
    active: usize,
    shutdown: bool,
    sub: SubPc,
    sub_epoch: u64,
    work: [WorkPc; WORKERS],
    seen: [u64; WORKERS],
    /// runs[w][e-1] = times worker w ran epoch e (0 = submitter-inline
    /// share is tracked in `inline_runs`).
    runs: [[u8; EPOCHS as usize]; WORKERS],
    inline_runs: [u8; EPOCHS as usize],
}

impl ModelState {
    fn initial() -> Self {
        ModelState {
            epoch: 0,
            job_present: false,
            active: 0,
            shutdown: false,
            sub: SubPc::Publish,
            sub_epoch: 0,
            work: [WorkPc::Park; WORKERS],
            seen: [0; WORKERS],
            runs: [[0; EPOCHS as usize]; WORKERS],
            inline_runs: [0; EPOCHS as usize],
        }
    }

    fn terminal(&self) -> bool {
        self.sub == SubPc::Joined && self.work.iter().all(|&w| w == WorkPc::Exited)
    }

    /// All states reachable by letting one actor execute its next atomic
    /// mutex section. An empty result on a non-terminal state is a
    /// deadlock (condvar waits appear as steps that are simply not
    /// enabled until their predicate holds — exactly the semantics of a
    /// predicate re-check loop under the mutex).
    fn successors(&self) -> Vec<ModelState> {
        let mut out = Vec::new();

        // Submitter.
        match self.sub {
            SubPc::Publish => {
                // WorkerSet::run: publish job, arm the barrier, bump epoch,
                // notify_all — one mutex section.
                let mut s = self.clone();
                s.job_present = true;
                s.active = WORKERS;
                s.epoch = s.epoch.wrapping_add(1);
                s.sub_epoch = s.epoch;
                s.sub = SubPc::Inline;
                out.push(s);
            }
            SubPc::Inline => {
                // The submitter runs its inline share (outside the lock).
                let mut s = self.clone();
                s.inline_runs[(s.sub_epoch - 1) as usize] += 1;
                s.sub = SubPc::Barrier;
                out.push(s);
            }
            SubPc::Barrier => {
                // CompletionGuard: enabled only once every spawned worker
                // has retired the epoch.
                if self.active == 0 {
                    let mut s = self.clone();
                    s.sub = SubPc::Retire;
                    out.push(s);
                }
            }
            SubPc::Retire => {
                // run() epilogue: clear the job slot.
                let mut s = self.clone();
                s.job_present = false;
                s.sub = if s.sub_epoch < EPOCHS { SubPc::Publish } else { SubPc::Shutdown };
                out.push(s);
            }
            SubPc::Shutdown => {
                // Drop for WorkerSet: set shutdown, notify, then join.
                let mut s = self.clone();
                s.shutdown = true;
                s.sub = SubPc::Joined;
                out.push(s);
            }
            SubPc::Joined => {}
        }

        // Workers.
        for w in 0..WORKERS {
            match self.work[w] {
                WorkPc::Park => {
                    // worker_loop wait: wake on shutdown or a fresh epoch.
                    if self.shutdown {
                        let mut s = self.clone();
                        s.work[w] = WorkPc::Exited;
                        out.push(s);
                    } else if self.epoch != self.seen[w] {
                        // The `st.job.expect("epoch bumped without a job")`
                        // in worker_loop — the protocol must make this
                        // unreachable, so the model asserts it.
                        assert!(
                            self.job_present,
                            "protocol violation: worker {w} woke on epoch {} with no job",
                            self.epoch
                        );
                        let mut s = self.clone();
                        s.seen[w] = s.epoch;
                        s.work[w] = WorkPc::Run;
                        out.push(s);
                    }
                    // Spurious wakeups re-enter the same wait: no new state.
                }
                WorkPc::Run => {
                    // Job body runs outside the lock.
                    let mut s = self.clone();
                    s.runs[w][(s.seen[w] - 1) as usize] += 1;
                    s.work[w] = WorkPc::Finish;
                    out.push(s);
                }
                WorkPc::Finish => {
                    // Retire section: active -= 1, notify done_cv at zero.
                    assert!(self.active > 0, "active underflow by worker {w}");
                    let mut s = self.clone();
                    s.active -= 1;
                    s.work[w] = WorkPc::Park;
                    out.push(s);
                }
                WorkPc::Exited => {}
            }
        }
        out
    }

    fn check_invariants(&self) {
        for w in 0..WORKERS {
            for e in 0..EPOCHS as usize {
                assert!(
                    self.runs[w][e] <= 1,
                    "worker {w} ran epoch {} twice",
                    e + 1
                );
                // A worker may never have run an epoch the submitter has
                // not yet published.
                if (e as u64) >= self.epoch {
                    assert_eq!(self.runs[w][e], 0, "worker {w} ran unpublished epoch {}", e + 1);
                }
            }
        }
        // When the submitter is past the barrier of epoch `sub_epoch`,
        // every worker must have run it exactly once (barrier soundness).
        if matches!(self.sub, SubPc::Retire | SubPc::Shutdown)
            || (self.sub == SubPc::Publish && self.sub_epoch > 0)
        {
            let e = (self.sub_epoch - 1) as usize;
            for w in 0..WORKERS {
                assert_eq!(
                    self.runs[w][e], 1,
                    "barrier for epoch {} completed before worker {w} ran",
                    self.sub_epoch
                );
            }
            assert_eq!(self.inline_runs[e], 1, "submitter inline share of epoch {}", self.sub_epoch);
        }
    }

    fn check_terminal(&self) {
        for w in 0..WORKERS {
            for e in 0..EPOCHS as usize {
                assert_eq!(self.runs[w][e], 1, "worker {w} epoch {} run count", e + 1);
            }
        }
        for e in 0..EPOCHS as usize {
            assert_eq!(self.inline_runs[e], 1, "inline epoch {} run count", e + 1);
        }
        assert_eq!(self.active, 0);
        assert!(!self.job_present, "job slot must be retired at shutdown");
    }
}

/// DFS over every interleaving of atomic protocol steps. State-space size
/// for 2 workers × 2 epochs is a few thousand states — enumerated
/// exhaustively with memoization on visited states.
#[test]
fn model_every_interleaving_is_exactly_once_and_deadlock_free() {
    let mut visited: HashSet<ModelState> = HashSet::new();
    let mut stack = vec![ModelState::initial()];
    let mut terminals = 0usize;
    while let Some(st) = stack.pop() {
        if !visited.insert(st.clone()) {
            continue;
        }
        st.check_invariants();
        let succ = st.successors();
        if succ.is_empty() {
            assert!(
                st.terminal(),
                "deadlock: no actor can step, sub={:?} work={:?} active={} epoch={}",
                st.sub,
                st.work,
                st.active,
                st.epoch
            );
            st.check_terminal();
            terminals += 1;
            continue;
        }
        stack.extend(succ);
    }
    assert!(terminals > 0, "exploration never reached a terminal state");
    // Sanity: the exploration is genuinely branching (not a single path).
    assert!(visited.len() > 100, "suspiciously small state space: {}", visited.len());
}

/// Same exploration with the shutdown raced against a *parked* worker that
/// never got a final epoch: workers must still exit (the wait predicate
/// checks `shutdown` first) and never touch the cleared job slot.
#[test]
fn model_shutdown_wakes_parked_workers() {
    // Re-run the exploration with EPOCHS effectively 0 for one worker by
    // checking the already-covered invariant differently: every terminal
    // state of the full model has all workers Exited. This test pins the
    // property that termination is reached from *every* reachable state,
    // i.e. shutdown cannot strand a worker parked on work_cv.
    let mut visited: HashSet<ModelState> = HashSet::new();
    let mut stack = vec![ModelState::initial()];
    while let Some(st) = stack.pop() {
        if !visited.insert(st.clone()) {
            continue;
        }
        let succ = st.successors();
        if succ.is_empty() {
            assert!(st.work.iter().all(|&w| w == WorkPc::Exited), "worker stranded at shutdown");
        }
        stack.extend(succ);
    }
}

// ---------------------------------------------------------------------------
// Part 2: the real Pool under stress.
// ---------------------------------------------------------------------------

/// Big enough that `dispatchable` actually fans out to the workers
/// (2 * MIN_CHUNK = 8192 in par/mod.rs).
const DISPATCH_N: usize = 20_000;

#[test]
fn real_concurrent_submitters_share_one_worker_set() {
    let pool = Pool::new(4);
    let hits = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let p = pool.clone();
            let h = hits.clone();
            std::thread::spawn(move || {
                for round in 0..8 {
                    let sum = p.reduce_sum_u64(DISPATCH_N, |i| (i as u64) + t + round);
                    let base: u64 = (0..DISPATCH_N as u64).sum();
                    assert_eq!(sum, base + (t + round) * DISPATCH_N as u64);
                    h.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();
    for th in threads {
        th.join().expect("submitter thread panicked");
    }
    assert_eq!(hits.load(Ordering::Relaxed), 32);
}

#[test]
fn real_repeated_spawn_and_join_cycles() {
    for round in 0..25 {
        let pool = Pool::new(1 + round % 4);
        let counter = AtomicUsize::new(0);
        pool.parallel_for(DISPATCH_N, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), DISPATCH_N);
        // Drop joins the workers; a wedged park/wake protocol would hang
        // here long before any CI timeout.
    }
}

#[test]
fn real_pool_survives_kernel_panic_and_keeps_working() {
    let pool = Pool::new(4);
    for _ in 0..3 {
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(DISPATCH_N, |i| {
                if i == DISPATCH_N / 2 {
                    panic!("seeded kernel panic");
                }
            });
        }));
        assert!(r.is_err(), "seeded panic must propagate to the submitter");
        // The same pool must keep functioning after the unwind.
        let sum = pool.reduce_sum_u64(DISPATCH_N, |i| i as u64);
        assert_eq!(sum, (0..DISPATCH_N as u64).sum::<u64>());
    }
}

#[test]
fn real_inline_path_panic_also_recovers() {
    let pool = Pool::new(2);
    // n below the dispatch threshold: the kernel runs inline on the
    // submitting thread, exercising the other unwind path.
    let r = catch_unwind(AssertUnwindSafe(|| {
        pool.parallel_for(100, |i| {
            if i == 50 {
                panic!("inline panic");
            }
        });
    }));
    assert!(r.is_err());
    let sum = pool.reduce_sum_u64(DISPATCH_N, |i| i as u64);
    assert_eq!(sum, (0..DISPATCH_N as u64).sum::<u64>());
}
