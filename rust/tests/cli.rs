//! CLI black-box tests: run the `heipa` binary end to end.

use std::path::PathBuf;
use std::process::Command;

fn heipa() -> Command {
    Command::new(env!("CARGO_BIN_EXE_heipa"))
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("heipa_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_subcommands() {
    let out = heipa().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["gen", "map", "eval", "phases", "suite", "serve", "client"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_subcommand_fails_with_message() {
    let out = heipa().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn map_then_eval_roundtrip() {
    let dir = tmpdir();
    let part = dir.join("mapping.txt");
    let out = heipa()
        .args([
            "map", "--graph", "sten_cop20k", "--algo", "gpu-im", "--hier", "2:2:2",
            "--dist", "1:10:100", "--eps", "0.03", "--seed", "1", "--out",
            part.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("J="), "no J in output: {text}");
    // Parse J from the map output.
    let j_map: f64 = text
        .split_whitespace()
        .find_map(|t| t.strip_prefix("J=").and_then(|v| v.parse().ok()))
        .expect("J field");

    let out = heipa()
        .args([
            "eval", "--graph", "sten_cop20k", "--part", part.to_str().unwrap(), "--hier",
            "2:2:2", "--dist", "1:10:100",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let j_eval: f64 = text
        .split_whitespace()
        .find_map(|t| t.strip_prefix("J=").and_then(|v| v.parse().ok()))
        .expect("J field");
    assert!((j_map - j_eval).abs() < 1e-3 * j_map.max(1.0), "{j_map} != {j_eval}");
}

#[test]
fn gen_writes_metis_files() {
    let dir = tmpdir().join("suite");
    let out = heipa()
        .args(["gen", "--suite", "smoke", "--out-dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert_eq!(files.len(), 5, "expected 5 smoke instances");
    // And a generated file is loadable via map --graph <path>.
    let one = dir.join("sten_cop20k.graph");
    let out = heipa()
        .args(["map", "--graph", one.to_str().unwrap(), "--algo", "sharedmap-f", "--hier", "2:2", "--dist", "1:10"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn valueless_boolean_flags_do_not_swallow_the_next_flag() {
    // `--polish` directly before another flag must parse as `polish=1`,
    // not consume `--out` as its value.
    let dir = tmpdir();
    let part = dir.join("polished.txt");
    let out = heipa()
        .args([
            "map", "--graph", "sten_cont300", "--algo", "jet", "--hier", "2:2:2",
            "--dist", "1:10:100", "--polish", "--out", part.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("polish_dj="), "no polish field: {text}");
    assert!(part.exists(), "--out not honored after a bare --polish");
    // Explicit values still work.
    let out = heipa()
        .args(["map", "--graph", "sten_cont300", "--algo", "jet", "--hier", "2:2", "--dist", "1:10", "--polish", "1"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn config_file_reaches_the_engine_and_flags_override_it() {
    let dir = tmpdir();
    let cfg = dir.join("run.conf");
    std::fs::write(
        &cfg,
        "graph = sten_cop20k\nhierarchy = 2:2:2\ndistance = 1:10:100\n\
         algorithm = sharedmap-f\neps = 0.05\nseeds = 3\n",
    )
    .unwrap();
    // Config alone supplies graph, algorithm, hierarchy and seed.
    let out = heipa().args(["map", "--config", cfg.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("algo=sharedmap-f"), "config algorithm ignored: {text}");
    assert!(text.contains("seed=3"), "config seed ignored: {text}");
    assert!(text.contains("k=8"), "config hierarchy ignored: {text}");
    // A CLI flag beats the file key.
    let out = heipa()
        .args(["map", "--config", cfg.to_str().unwrap(), "--algo", "gpu-im", "--seed", "4"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("algo=gpu-im"), "--algo did not override config: {text}");
    assert!(text.contains("seed=4"), "--seed did not override config: {text}");
}

#[test]
fn map_supports_auto_routing_and_multi_seed() {
    let out = heipa()
        .args(["map", "--graph", "wal_598a", "--algo", "auto", "--hier", "2:2", "--dist", "1:10", "--seed", "1,2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // Small instance routes to the quality flavor; both seeds print.
    assert!(text.contains("algo=gpu-hm-ultra"), "router did not engage: {text}");
    assert!(text.contains("seed=1") && text.contains("seed=2"), "missing per-seed lines: {text}");
    assert!(text.contains("best: seed="), "missing best line: {text}");
}

#[test]
fn phases_prints_table2_rows() {
    let out = heipa().args(["phases", "--graph", "wal_598a", "--hier", "2:4", "--dist", "1:10"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for row in ["Coarsening", "Contraction", "Init. Part.", "Refine + Reb.", "Total"] {
        assert!(text.contains(row), "missing row {row}: {text}");
    }
}

#[test]
fn map_and_eval_accept_topology_specs() {
    // The acceptance path: `heipa map --topology torus:4x4x4` and a
    // fat-tree spec produce valid mappings end to end, and `eval` scores
    // the written mapping under the same machine model.
    let dir = tmpdir();
    for (tag, spec, k) in
        [("torus", "torus:4x4x4", 64), ("fattree", "fattree:3:2,4,4/1,5,20", 32)]
    {
        let part = dir.join(format!("{tag}.txt"));
        let out = heipa()
            .args([
                "map", "--graph", "sten_cop20k", "--algo", "gpu-im", "--topology", spec,
                "--seed", "1", "--out", part.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{spec} stderr: {}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains(&format!("k={k}")), "{spec}: wrong k: {text}");
        let j_map: f64 = text
            .split_whitespace()
            .find_map(|t| t.strip_prefix("J=").and_then(|v| v.parse().ok()))
            .expect("J field");

        let out = heipa()
            .args([
                "eval", "--graph", "sten_cop20k", "--part", part.to_str().unwrap(),
                "--topology", spec,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{spec} eval stderr: {}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout);
        let j_eval: f64 = text
            .split_whitespace()
            .find_map(|t| t.strip_prefix("J=").and_then(|v| v.parse().ok()))
            .expect("J field");
        assert!((j_map - j_eval).abs() < 1e-3 * j_map.max(1.0), "{spec}: {j_map} != {j_eval}");
    }
    // Bad specs are a clean CLI error.
    let out = heipa()
        .args(["map", "--graph", "sten_cop20k", "--topology", "torus:0x4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

/// A running `heipa serve` child, killed on drop (even when the test
/// panics mid-way).
struct ServeProc {
    child: std::process::Child,
    addr: String,
}

impl ServeProc {
    fn start(extra: &[&str]) -> ServeProc {
        let mut cmd = heipa();
        cmd.args(["serve", "--addr", "127.0.0.1:0"]).args(extra);
        cmd.stdout(std::process::Stdio::piped()).stderr(std::process::Stdio::null());
        let mut child = cmd.spawn().unwrap();
        // `serve` prints "… listening on <addr>" right after binding.
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(stdout), &mut line).unwrap();
        let addr = line
            .rsplit("listening on ")
            .next()
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| panic!("no bound address in `{line}`"));
        ServeProc { child, addr }
    }

    fn client(&self, send: &str) -> String {
        let out = heipa().args(["client", "--addr", &self.addr, "--send", send]).output().unwrap();
        assert!(
            out.status.success(),
            "client `{send}` failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).trim_end().to_string()
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn serve_and_client_drive_the_async_job_api_end_to_end() {
    let server = ServeProc::start(&["--workers", "2", "--queue-cap", "16"]);

    // submit returns a job id before the solve completes.
    let submitted = server.client(
        "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 seed=1",
    );
    assert!(submitted.starts_with("ok job="), "{submitted}");
    let job: u64 = submitted
        .split_whitespace()
        .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
        .expect("job id");

    // wait → done; result → the outcome line.
    let waited = server.client(&format!("wait job={job}"));
    assert!(waited.contains("state=done"), "{waited}");
    let result = server.client(&format!("result job={job}"));
    assert!(result.starts_with("ok id="), "{result}");
    assert!(result.contains(" j="), "{result}");

    // cancel flow: a sleeping job cancelled from a separate client call.
    let slow = server.client(
        "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 opt.__sleep_ms=60000",
    );
    let slow_job: u64 = slow
        .split_whitespace()
        .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
        .expect("job id");
    let cancelled = server.client(&format!("cancel job={slow_job}"));
    assert!(cancelled.starts_with("ok job="), "{cancelled}");
    let waited = server.client(&format!("wait job={slow_job}"));
    assert!(waited.contains("state=cancelled"), "{waited}");

    // The --script form drives several commands over one connection.
    let out = heipa()
        .args(["client", "--addr", &server.addr, "--script", "ping; jobs; metrics"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ok version="), "{text}");
    assert!(text.contains(&format!("{job}:done")), "{text}");
    assert!(text.contains("cancelled=1"), "{text}");
}

#[test]
fn explicit_hier_flags_override_a_config_topology_key() {
    // `explicit flags always win`: a config `topology =` key must not
    // shadow an explicit --hier/--dist pair.
    let dir = tmpdir();
    let cfg = dir.join("topo.conf");
    std::fs::write(&cfg, "graph = sten_cop20k\ntopology = torus:4x4x4\nalgorithm = gpu-im\nseeds = 1\n")
        .unwrap();
    // Config alone: the torus (k=64).
    let out = heipa().args(["map", "--config", cfg.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("k=64"));
    // Explicit --hier/--dist: the 8-PE hierarchy wins over the config topology.
    let out = heipa()
        .args(["map", "--config", cfg.to_str().unwrap(), "--hier", "2:2:2", "--dist", "1:10:100"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=8"), "config topology shadowed explicit --hier: {text}");
    // Explicit --topology still wins over everything.
    let out = heipa()
        .args(["map", "--config", cfg.to_str().unwrap(), "--hier", "2:2:2", "--dist", "1:10:100", "--topology", "torus:2x2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("k=4"));
}
