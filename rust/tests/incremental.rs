//! Incremental-remapping integration: the patch → warm-remap → batch
//! pipeline exercised end to end, against from-scratch oracles.
//!
//! * random patch sequences must leave the session graph byte-identical
//!   to a from-scratch rebuild of the same edge set,
//! * a warm remap's reported objective must equal `J(C, D, Π)` recomputed
//!   from scratch on the patched graph, and
//! * over the wire, a provably intra-cluster patch must answer with
//!   `remap=warm hier_cache=hit` — the whole point of the subsystem.

use heipa::algo::Algorithm;
use heipa::cancel::CancelToken;
use heipa::coordinator::protocol::handle_command;
use heipa::coordinator::service::{Service, ServiceConfig};
use heipa::engine::{Engine, EngineConfig, MapSpec, RemapKind};
use heipa::graph::builder::from_edges;
use heipa::graph::{gen, CsrGraph};
use heipa::incremental::{fingerprint, GraphPatch};
use heipa::multilevel::{CoarseHierarchy, CoarsenConfig, HierarchyParams};
use heipa::par::Pool;
use heipa::partition::{comm_cost, is_balanced};
use heipa::topology::Machine;
use heipa::Vertex;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Deterministic split-mix step — property tests must not depend on
/// ambient entropy.
fn next(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// A plain-map mirror of the session graph: the from-scratch oracle the
/// patched CSR is checked against.
struct Mirror {
    vw: Vec<i64>,
    /// Undirected edges keyed `(min, max)`.
    edges: BTreeMap<(Vertex, Vertex), f64>,
}

impl Mirror {
    fn of(g: &CsrGraph) -> Mirror {
        let mut edges = BTreeMap::new();
        for u in 0..g.n() as Vertex {
            let (nbrs, ws) = g.neighbors_w(u);
            for (&v, &w) in nbrs.iter().zip(ws) {
                if u < v {
                    edges.insert((u, v), w);
                }
            }
        }
        Mirror { vw: g.vw.clone(), edges }
    }

    fn degree(&self, v: Vertex) -> usize {
        self.edges.keys().filter(|&&(a, b)| a == v || b == v).count()
    }

    fn rebuild(&self) -> CsrGraph {
        let edges: Vec<(Vertex, Vertex, f64)> =
            self.edges.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
        from_edges(self.vw.len(), &edges, Some(self.vw.clone()))
    }
}

/// Generate one valid random op against the mirror, apply it to the
/// mirror, and return its wire form. `None` if the drawn kind has no
/// valid move (e.g. `rv` with no isolated vertex).
fn random_op(m: &mut Mirror, state: &mut u64) -> Option<String> {
    let n = m.vw.len() as Vertex;
    match next(state) % 6 {
        0 => {
            // ae: a fresh non-edge, non-self pair.
            for _ in 0..32 {
                let u = (next(state) % n as u64) as Vertex;
                let v = (next(state) % n as u64) as Vertex;
                let key = (u.min(v), u.max(v));
                if u != v && !m.edges.contains_key(&key) {
                    let w = (1 + next(state) % 16) as f64 * 0.25;
                    m.edges.insert(key, w);
                    return Some(format!("ae:{u}:{v}:{w}"));
                }
            }
            None
        }
        1 => {
            // re: an existing edge.
            if m.edges.is_empty() {
                return None;
            }
            let i = (next(state) % m.edges.len() as u64) as usize;
            let &(u, v) = m.edges.keys().nth(i).unwrap();
            m.edges.remove(&(u, v));
            Some(format!("re:{u}:{v}"))
        }
        2 => {
            // ew: reweight an existing edge.
            if m.edges.is_empty() {
                return None;
            }
            let i = (next(state) % m.edges.len() as u64) as usize;
            let &(u, v) = m.edges.keys().nth(i).unwrap();
            let w = (1 + next(state) % 16) as f64 * 0.5;
            m.edges.insert((u, v), w);
            Some(format!("ew:{u}:{v}:{w}"))
        }
        3 => {
            // vw: reweight a vertex.
            let v = (next(state) % n as u64) as Vertex;
            let w = (next(state) % 9) as i64;
            m.vw[v as usize] = w;
            Some(format!("vw:{v}:{w}"))
        }
        4 => {
            // av: append an isolated vertex.
            let w = (1 + next(state) % 5) as i64;
            m.vw.push(w);
            Some(format!("av:{w}"))
        }
        _ => {
            // rv: drop an isolated vertex; every id above shifts down.
            let v = (0..n).find(|&v| m.degree(v) == 0)?;
            m.vw.remove(v as usize);
            let edges = std::mem::take(&mut m.edges);
            m.edges = edges
                .into_iter()
                .map(|((a, b), w)| {
                    let shift = |x: Vertex| if x > v { x - 1 } else { x };
                    ((shift(a), shift(b)), w)
                })
                .collect();
            Some(format!("rv:{v}"))
        }
    }
}

#[test]
fn random_patch_sequences_match_from_scratch_rebuild() {
    let mut g = gen::rgg(250, 0.1, 17);
    let mut mirror = Mirror::of(&g);
    assert_eq!(fingerprint(&g), fingerprint(&mirror.rebuild()), "mirror starts in sync");
    let mut state = 0x9e3779b97f4a7c15u64;
    for round in 0..8 {
        let mut ops = Vec::new();
        while ops.len() < 6 {
            if let Some(op) = random_op(&mut mirror, &mut state) {
                ops.push(op);
            }
        }
        let patch = GraphPatch::parse(&ops.join(",")).unwrap_or_else(|e| {
            panic!("round {round}: generated ops failed to parse ({e}): {ops:?}")
        });
        let applied = patch
            .apply(&g)
            .unwrap_or_else(|e| panic!("round {round}: apply failed ({e}): {ops:?}"));
        applied.graph.validate().unwrap();
        let rebuilt = mirror.rebuild();
        assert_eq!(applied.graph.xadj, rebuilt.xadj, "round {round}: offsets diverged: {ops:?}");
        assert_eq!(applied.graph.adj, rebuilt.adj, "round {round}: targets diverged: {ops:?}");
        assert_eq!(applied.graph.vw, rebuilt.vw, "round {round}: vertex weights diverged");
        assert_eq!(
            fingerprint(&applied.graph),
            fingerprint(&rebuilt),
            "round {round}: fingerprint diverged (edge weights?): {ops:?}"
        );
        g = applied.graph;
    }
}

#[test]
fn warm_remap_objective_matches_from_scratch_recompute() {
    let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..EngineConfig::default() });
    let g = Arc::new(gen::rgg(2_000, 0.05, 9));
    e.put_graph("sess", g.clone());
    let spec = MapSpec::named("sess")
        .hierarchy("2:2")
        .distance("1:10")
        .algo(Some(Algorithm::GpuIm))
        .seed(1)
        .return_mapping(true);
    let first = e.map(&spec).unwrap();
    assert_eq!(first.remap, None, "nothing to warm-start from on the first solve");
    // Wire a fresh edge between two currently non-adjacent vertices.
    let u = 0u32;
    let v = (1..g.n() as u32)
        .rev()
        .find(|&v| g.find_edge(u, v).is_none())
        .expect("rgg is sparse; some non-neighbor exists");
    e.patch_graph("sess", &GraphPatch::parse(&format!("ae:{u}:{v}:2.0")).unwrap()).unwrap();
    let warm = e.map(&spec).unwrap();
    assert_eq!(warm.remap, Some(RemapKind::Warm));
    // Oracle: recompute J(C, D, Π) from scratch on the patched graph.
    let patched = e.resolve_graph(&spec.graph).unwrap();
    let m = e.resolve_machine(&spec).unwrap();
    assert_eq!(patched.find_edge(u, v), Some(2.0), "patch landed in the session store");
    let oracle = comm_cost(&patched, &warm.mapping, &m);
    assert!(
        (warm.comm_cost - oracle).abs() <= 1e-6 * oracle.max(1.0),
        "warm J {} disagrees with from-scratch recompute {oracle}",
        warm.comm_cost
    );
    assert!(is_balanced(&patched, &warm.mapping, m.k(), 0.031));
    assert_eq!(e.warm_remaps(), 1);
    assert_eq!(e.cold_fallbacks(), 0);
}

/// Pull `key=` out of a wire reply.
fn field<'a>(reply: &'a str, key: &str) -> &'a str {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .unwrap_or_else(|| panic!("no {key}= in `{reply}`"))
}

#[test]
fn warm_path_reuses_cached_hierarchy_over_the_wire() {
    // One worker, one device thread: the engine's hierarchy build is
    // bit-identical to the external build below, so the intra-cluster
    // pair we pick is intra-cluster in the engine's cache too.
    let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
    let g = gen::rgg(2_000, 0.05, 3);
    let (k, eps) = (4usize, 0.03f64);
    svc.put_graph("sess", Arc::new(g.clone()));

    // Rebuild the hierarchy the gpu-im solver will cache (identical
    // params: CoarsenConfig::device() + HierarchyParams::device) and pick
    // a non-adjacent pair merged at level 1 — the patch then provably
    // keeps every coarse level, so the engine re-keys the cached
    // hierarchy instead of discarding it.
    let params = HierarchyParams::device(&g, k, eps, CoarsenConfig::device());
    let pool = Pool::new(1);
    let hier = CoarseHierarchy::build(
        &pool,
        Arc::new(g.clone()),
        &params.build,
        &params.cfg,
        &CancelToken::new(),
        None,
    )
    .unwrap();
    assert!(hier.levels() >= 2, "need a real hierarchy for level reuse");
    let map0 = hier.map(0);
    let mut pair = None;
    'outer: for u in 0..g.n() as Vertex {
        for v in (u + 1)..g.n() as Vertex {
            if map0[u as usize] == map0[v as usize] && g.find_edge(u, v).is_none() {
                pair = Some((u, v));
                break 'outer;
            }
        }
    }
    let (u, v) = pair.expect("some level-1 cluster holds a non-adjacent pair");

    let map_cmd =
        format!("map graph=sess algorithm=gpu-im hierarchy=2:2 distance=1:10 eps={eps} seed=1 mapping=1");
    let first = handle_command(&svc, &map_cmd);
    assert!(first.starts_with("ok id="), "{first}");
    assert!(!first.contains("remap="), "{first}");

    let patched = handle_command(&svc, &format!("graph patch name=sess ops=ae:{u}:{v}:1.0"));
    assert!(patched.starts_with("ok graph=sess"), "{patched}");
    assert!(patched.contains("version=2"), "{patched}");

    let second = handle_command(&svc, &map_cmd);
    assert!(second.contains(" remap=warm"), "warm path not taken: {second}");
    assert!(
        second.contains(" hier_cache=hit"),
        "intra-cluster patch must keep the cached hierarchy: {second}"
    );

    // Oracle-validate the reported objective against a from-scratch
    // recompute on the patched graph (reply carries j to 3 decimals).
    let patched_g = GraphPatch::parse(&format!("ae:{u}:{v}:1.0")).unwrap().apply(&g).unwrap().graph;
    let machine = Machine::hier("2:2", "1:10").unwrap();
    let mapping: Vec<u32> =
        field(&second, "mapping").split(',').map(|t| t.parse().unwrap()).collect();
    assert_eq!(mapping.len(), g.n());
    let oracle = comm_cost(&patched_g, &mapping, &machine);
    let j: f64 = field(&second, "j").parse().unwrap();
    assert!(
        (j - oracle).abs() <= 5e-3 * oracle.max(1.0),
        "wire j {j} disagrees with from-scratch recompute {oracle}"
    );

    let metrics = handle_command(&svc, "metrics");
    assert!(metrics.contains(" patches=1 "), "{metrics}");
    assert!(metrics.contains(" warm_remaps=1 "), "{metrics}");
    assert!(metrics.contains(" cold_fallbacks=0 "), "{metrics}");
}
