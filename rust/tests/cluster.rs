//! Cluster-tier integration: a real [`heipa::cluster::Router`] in front
//! of real [`Service`] nodes speaking the wire protocol over real TCP.
//!
//! Nodes are spawned as `MortalNode`s — the protocol dispatcher behind a
//! killable accept loop — so tests can simulate a node dying mid-job
//! (port closed, live connections reset) without leaving the process.

use heipa::cluster::{Health, Router, RouterConfig};
use heipa::coordinator::protocol::{self, ServeOptions};
use heipa::coordinator::service::{Service, ServiceConfig};
use heipa::fault::{FaultPlane, FaultPoint};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A coordinator node that can be killed: the real protocol dispatcher
/// ([`protocol::handle_command`]) behind a hand-rolled accept loop with
/// a stop flag. `kill` closes the listening port and resets every live
/// connection — the TCP signature of a `kill -9`d process.
struct MortalNode {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
}

impl MortalNode {
    fn spawn(svc: Arc<Service>) -> MortalNode {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let (stop2, conns2) = (stop.clone(), conns.clone());
        std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).unwrap();
                        conns2.lock().unwrap().push(stream.try_clone().unwrap());
                        let svc = svc.clone();
                        let stop = stop2.clone();
                        std::thread::spawn(move || {
                            let mut reader = BufReader::new(stream.try_clone().unwrap());
                            let mut writer = stream;
                            let mut line = String::new();
                            loop {
                                line.clear();
                                match reader.read_line(&mut line) {
                                    Ok(0) | Err(_) => return,
                                    Ok(_) => {}
                                }
                                if stop.load(Ordering::SeqCst) {
                                    return;
                                }
                                let reply = protocol::handle_command(&svc, line.trim_end());
                                if writeln!(writer, "{reply}").is_err() {
                                    return;
                                }
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => return,
                }
            }
            // Dropping the listener here closes the port.
        });
        MortalNode { addr, stop, conns }
    }

    fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        // Let the accept loop notice the flag and drop the port.
        std::thread::sleep(Duration::from_millis(30));
    }
}

fn node_service() -> Arc<Service> {
    Arc::new(Service::with_config(ServiceConfig { threads: 1, workers: 2, ..Default::default() }))
}

/// N mortal nodes plus a router over them.
fn fleet(n: usize, replication: usize, plane: Option<FaultPlane>) -> (Vec<MortalNode>, Router) {
    let nodes: Vec<MortalNode> = (0..n).map(|_| MortalNode::spawn(node_service())).collect();
    let addrs: Vec<String> = nodes.iter().map(|m| m.addr.to_string()).collect();
    let cfg = RouterConfig { replication, request_timeout_ms: 15_000, plane };
    (nodes, Router::new(&addrs, cfg))
}

/// One request → one reply straight to a node (bypassing the router).
fn ask(addr: SocketAddr, line: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    writeln!(s, "{line}").unwrap();
    let mut r = String::new();
    BufReader::new(s).read_line(&mut r).unwrap();
    r.trim_end().to_string()
}

const RING_PUT: &str =
    "graph put name=g csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6";
const ANON_JOB: &str =
    "instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3";

fn owners_of(router: &Router, name: &str) -> Vec<String> {
    let reply = router.handle_line(&format!("cluster route name={name}"));
    assert!(reply.starts_with("ok "), "{reply}");
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix("owners="))
        .unwrap()
        .split(',')
        .map(str::to_string)
        .collect()
}

#[test]
fn router_routes_jobs_translates_ids_and_aggregates_metrics() {
    let (_nodes, router) = fleet(2, 2, None);
    // Router-side job ids are dense (1, 2, …) regardless of which node
    // served the job or what id it used locally.
    for expect in 1..=2u64 {
        let submitted = router.handle_line(&format!("submit {ANON_JOB} seed={expect}"));
        assert_eq!(
            submitted, format!("ok job={expect} state=queued"),
            "router must hand out its own dense ids"
        );
        let waited = router.handle_line(&format!("wait job={expect}"));
        assert!(waited.starts_with(&format!("ok job={expect} ")), "{waited}");
        assert!(waited.contains("state=done"), "{waited}");
        let result = router.handle_line(&format!("result job={expect}"));
        assert!(result.starts_with(&format!("ok id={expect} ")), "{result}");
        assert!(result.contains(" j="), "{result}");
    }
    // The fleet-aggregated metrics line: node counters summed, router
    // counters appended.
    let metrics = router.handle_line("metrics");
    assert!(metrics.contains(" completed=2 "), "{metrics}");
    assert!(metrics.contains("per_algorithm=sharedmap-f:2"), "{metrics}");
    assert!(metrics.contains(" routed_jobs=2 failovers=0 nodes_up=2"), "{metrics}");
    let ping = router.handle_line("ping");
    assert!(ping.contains("nodes=2 nodes_up=2"), "{ping}");
    let listed = router.handle_line("cluster nodes");
    assert!(listed.starts_with("ok count=2 nodes="), "{listed}");
    assert_eq!(listed.matches("/up/").count(), 2, "{listed}");
}

#[test]
fn router_speaks_the_wire_over_tcp() {
    // The router behind the shared accept loop (`serve_lines`), exactly
    // as `serve_router` wires it — driven over a real client socket.
    let (_nodes, router) = fleet(2, 2, None);
    let router = Arc::new(router);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let router = router.clone();
        let handler: protocol::LineHandler = Arc::new(move |line| router.handle_line(line));
        std::thread::spawn(move || {
            let _ = protocol::serve_lines(listener, ServeOptions::default(), handler);
        });
    }
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut send = |line: &str| -> String {
        writeln!(writer, "{line}").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    };
    let ping = send("ping");
    assert!(ping.starts_with("ok version="), "{ping}");
    assert!(ping.contains("nodes=2"), "{ping}");
    assert_eq!(send(&format!("submit {ANON_JOB} seed=7")), "ok job=1 state=queued");
    assert!(send("wait job=1").contains("state=done"));
    assert!(send("bogus").starts_with("err code=parse"));
}

#[test]
fn session_graphs_pin_on_exactly_r_replicas() {
    let (nodes, router) = fleet(3, 2, None);
    let put = router.handle_line(RING_PUT);
    assert_eq!(put, "ok graph=g n=8 m=8 version=1");
    let owners = owners_of(&router, "g");
    assert_eq!(owners.len(), 2, "replication=2 → two ring owners: {owners:?}");
    // Exactly the two owners hold the graph — verified against each node
    // directly, behind the router's back.
    for node in &nodes {
        let held = ask(node.addr, "graph list");
        if owners.contains(&node.addr.to_string()) {
            assert_eq!(held, "ok count=1 graphs=g@v1", "owner {}", node.addr);
        } else {
            assert_eq!(held, "ok count=0", "non-owner {}", node.addr);
        }
    }
    // Session jobs and patches flow through the router; the patch lands
    // on every owner and bumps the router-side version.
    let mapped =
        router.handle_line("map graph=g algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3");
    assert!(mapped.starts_with("ok id="), "{mapped}");
    let patched = router.handle_line("graph patch name=g ops=ae:0:4:1.0");
    assert!(patched.contains("version=2"), "{patched}");
    for addr in &owners {
        let node = nodes.iter().find(|n| n.addr.to_string() == *addr).unwrap();
        assert_eq!(ask(node.addr, "graph list"), "ok count=1 graphs=g@v2");
    }
    assert_eq!(router.handle_line("graph del name=g"), "ok dropped=g");
    for node in &nodes {
        assert_eq!(ask(node.addr, "graph list"), "ok count=0");
    }
}

#[test]
fn blocking_map_fails_over_when_the_owner_dies() {
    let (nodes, router) = fleet(2, 1, None);
    assert_eq!(router.handle_line(RING_PUT), "ok graph=g n=8 m=8 version=1");
    let owners = owners_of(&router, "g");
    assert_eq!(owners.len(), 1);
    let owner = nodes.iter().find(|n| n.addr.to_string() == owners[0]).unwrap();
    let survivor = nodes.iter().find(|n| n.addr.to_string() != owners[0]).unwrap();
    assert_eq!(ask(survivor.addr, "graph list"), "ok count=0", "graph pinned on owner only");
    owner.kill();
    // The session job lands on the survivor: the router re-uploads the
    // graph from its retained copy and tags the reply.
    let mapped =
        router.handle_line("map graph=g algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3");
    assert!(mapped.starts_with("ok id="), "{mapped}");
    assert!(mapped.ends_with(" failover=1"), "{mapped}");
    assert_eq!(ask(survivor.addr, "graph list"), "ok count=1 graphs=g@v1", "graph re-uploaded");
    let metrics = router.handle_line("metrics");
    assert!(metrics.contains(" failovers=1 "), "{metrics}");
    let dead = router.nodes().iter().find(|n| n.addr() == owners[0]).unwrap();
    assert_eq!(dead.health(), Health::Down);
}

#[test]
fn async_job_rehomes_when_its_node_dies_mid_job() {
    let (nodes, router) = fleet(2, 1, None);
    assert_eq!(router.handle_line(RING_PUT), "ok graph=g n=8 m=8 version=1");
    let owners = owners_of(&router, "g");
    let owner = nodes.iter().find(|n| n.addr.to_string() == owners[0]).unwrap();
    // The job routes to the graph's owner and sleeps there…
    let submitted = router.handle_line(
        "submit graph=g algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3 opt.__sleep_ms=300",
    );
    assert_eq!(submitted, "ok job=1 state=queued");
    // …which dies mid-job. The wait hits the dead node, and the router
    // re-submits the retained line to the survivor (re-uploading the
    // graph) instead of surfacing the transport error.
    owner.kill();
    let waited = router.handle_line("wait job=1");
    assert!(waited.starts_with("ok job=1 "), "{waited}");
    assert!(waited.contains("state=done"), "{waited}");
    assert!(waited.ends_with(" failover=1"), "{waited}");
    let result = router.handle_line("result job=1");
    assert!(result.starts_with("ok id=1 "), "{result}");
    assert!(result.contains(" j="), "{result}");
    assert!(result.ends_with(" failover=1"), "{result}");
    let metrics = router.handle_line("metrics");
    assert!(metrics.contains(" failovers=1 "), "{metrics}");
    assert!(metrics.contains(" routed_jobs=1 "), "{metrics}");
    assert!(metrics.contains(" nodes_up=1"), "{metrics}");
}

#[test]
fn seeded_chaos_leaves_every_job_terminal() {
    // Severed links (route_dispatch) and lost probes (node_probe) at
    // high rates: every reply must still be terminal — `ok …` or a
    // typed `err code=…` — never a hang (the test completing is the
    // liveness assertion; socket timeouts bound every wait).
    let mut plane = FaultPlane::disarmed();
    plane.arm(FaultPoint::RouteDispatch, 0.35, 11);
    plane.arm(FaultPoint::NodeProbe, 0.5, 5);
    let (_nodes, router) = fleet(2, 2, Some(plane));
    let router = Arc::new(router);
    router.start_probes(Duration::from_millis(25));
    let terminal = |r: &str| r.starts_with("ok ") || r.starts_with("err code=");
    let mut accepted = Vec::new();
    for seed in 0..8u64 {
        let reply = router.handle_line(&format!("submit {ANON_JOB} seed={seed}"));
        assert!(terminal(&reply), "submit not terminal: {reply}");
        if let Some(id) = reply.split_whitespace().find_map(|t| t.strip_prefix("job=")) {
            accepted.push(id.parse::<u64>().unwrap());
        }
    }
    assert!(!accepted.is_empty(), "a 35% fault rate must not reject everything");
    for id in &accepted {
        let reply = router.handle_line(&format!("wait job={id}"));
        assert!(terminal(&reply), "wait not terminal: {reply}");
        if reply.starts_with("ok ") {
            assert!(reply.contains("state="), "{reply}");
        }
    }
    // The control plane stays coherent under the same chaos.
    let jobs = router.handle_line("jobs");
    assert!(terminal(&jobs), "{jobs}");
    let metrics = router.handle_line("metrics");
    assert!(metrics.starts_with("ok requests="), "{metrics}");
    assert!(metrics.contains(" routed_jobs="), "{metrics}");
}

#[test]
fn batches_route_as_a_unit_through_the_router() {
    let (_nodes, router) = fleet(2, 2, None);
    let jobs: Vec<String> = (1..=3)
        .map(|s| protocol::escape_value(&format!("{ANON_JOB} seed={s}")))
        .collect();
    let reply = router.handle_line(&format!("batch submit jobs={}", jobs.join(";")));
    assert!(reply.starts_with("ok batch=1 count=3 jobs=1,2,3"), "{reply}");
    let waited = router.handle_line("batch wait id=1");
    assert_eq!(waited, "ok batch=1 count=3 done=3 failed=0 cancelled=0 expired=0");
    // The three batched jobs are individually addressable by router id.
    for id in 1..=3u64 {
        let status = router.handle_line(&format!("status job={id}"));
        assert!(status.contains("state=done"), "{status}");
    }
    let metrics = router.handle_line("metrics");
    assert!(metrics.contains(" batches=1 "), "{metrics}");
    assert!(metrics.contains(" routed_jobs=3 "), "{metrics}");
}

#[test]
fn drain_fans_out_to_the_fleet() {
    let (nodes, router) = fleet(2, 2, None);
    assert_eq!(router.handle_line("drain timeout_ms=30000"), "ok drained=1");
    // Every node refuses new work afterwards — the drain really reached
    // them all.
    for node in &nodes {
        let refused = ask(node.addr, &format!("submit {ANON_JOB}"));
        assert!(refused.starts_with("err code=unavailable"), "{refused}");
    }
}
