//! Seeded chaos suite: the full solver matrix under the fault plane.
//!
//! Runs every registered solver with per-job fault planes (`__fault.*`
//! options) arming the solve, graph-store and job-pickup injection
//! points — plus `device_launch` for the PJRT offload path, whose
//! degradation chain must fall back to the cpu backend before switching
//! solvers — with retry + degradation enabled, and asserts the
//! self-healing contract:
//!
//! * every job reaches a terminal state — a valid mapping (possibly
//!   `degraded`) or a typed error, never a hang or a lost job;
//! * the engine worker pool survives (a clean job still completes
//!   afterwards);
//! * the fault metrics stay consistent: `retries == Σ (attempts − 1)`,
//!   `degraded_completions` matches the degraded outcomes, and every
//!   failed attempt is attributed to `faults_injected`.
//!
//! The suite also runs under a process-global `HEIPA_FAULTS` plane (the
//! CI chaos-smoke job arms kernel-launch and hierarchy-build faults on
//! top); the invariants are written to hold under both planes at once.
//! `chaos_report_for_fixed_seeds` additionally emits a per-job report to
//! `$HEIPA_CHAOS_REPORT` so CI can diff two isolated runs bit-for-bit.

use heipa::engine::{
    solver_by_name, solver_names, Engine, EngineConfig, GraphSource, JobHandle, MapSpec,
    RetryPolicy,
};
use heipa::partition::validate_mapping;
use heipa::topology::Machine;
use std::collections::BTreeMap;
use std::time::Duration;

const INSTANCE: &str = "wal_598a";
const HIERARCHY: &str = "2:2";
const DISTANCE: &str = "1:10";

/// Per-job plane: solve panics, graph-store and job-pickup errors, all
/// drawn from one reproducible seed.
fn fault_options(seed: u64) -> BTreeMap<String, String> {
    let mut o = BTreeMap::new();
    o.insert("__fault.solve".into(), "0.5".into());
    o.insert("__fault.graph_store".into(), "0.3".into());
    o.insert("__fault.job_pickup".into(), "0.2".into());
    o.insert("__fault.seed".into(), seed.to_string());
    o
}

fn chaos_engine(threads: usize, workers: usize) -> Engine {
    Engine::new(EngineConfig {
        threads,
        workers,
        retry: RetryPolicy { max_attempts: 3, base_backoff: Duration::from_millis(1) },
        ..EngineConfig::default()
    })
}

fn chaos_spec(algo: heipa::algo::Algorithm, fault_seed: u64) -> MapSpec {
    MapSpec::named(INSTANCE)
        .hierarchy(HIERARCHY)
        .distance(DISTANCE)
        .algo(Some(algo))
        .seed(1)
        .return_mapping(true)
        .options(fault_options(fault_seed))
}

/// Validate a completed outcome end to end: mapping shape, and the
/// independent quality oracle accepts it. The oracle runs under
/// [`heipa::fault::suppress`] so a process-global plane can neither kill
/// the verification nor have its decision streams advanced by it (the
/// report test depends on the latter for bit-for-bit reproducibility).
fn assert_outcome_valid(label: &str, out: &heipa::engine::MapOutcome) {
    assert!(!out.mapping.is_empty(), "{label}: no mapping returned");
    validate_mapping(&out.mapping, out.n, out.k).unwrap_or_else(|e| panic!("{label}: {e}"));
    heipa::fault::suppress(|| {
        let g = Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() })
            .resolve_graph(&GraphSource::Named(INSTANCE.into()))
            .expect("resolve instance");
        let m = Machine::resolve(None, HIERARCHY, DISTANCE).expect("machine");
        let q = heipa::metrics::mapping_quality(&g, &out.mapping, &m);
        assert!(q.comm_cost.is_finite() && q.comm_cost >= 0.0, "{label}: bad J {}", q.comm_cost);
        assert!(
            (q.comm_cost - out.comm_cost).abs() < 1e-6 * q.comm_cost.max(1.0),
            "{label}: outcome J {} != oracle J {}",
            out.comm_cost,
            q.comm_cost
        );
    });
}

#[test]
fn chaos_matrix_reaches_terminal_states_with_consistent_metrics() {
    // threads = 0: auto, honoring HEIPA_THREADS — CI's chaos-smoke runs
    // this matrix at 1/2/4 device threads.
    let e = chaos_engine(0, 2);
    let mut jobs: Vec<(String, JobHandle)> = Vec::new();
    for (i, name) in solver_names().into_iter().enumerate() {
        let algo = solver_by_name(name).expect("registered").algorithm();
        for round in 0..3u64 {
            let spec = chaos_spec(algo, 1000 + 17 * i as u64 + round);
            jobs.push((format!("{name}/r{round}"), e.submit(&spec).expect("submit")));
        }
    }

    let mut attempts_total = 0u64;
    let mut degraded_seen = 0u64;
    let mut failed_paths = 0u64;
    for (label, h) in &jobs {
        let result = h.wait();
        let st = h.status();
        assert!(st.state.is_terminal(), "{label}: non-terminal state {:?}", st.state);
        assert!(st.attempts >= 1 && st.attempts <= 3, "{label}: attempts {}", st.attempts);
        attempts_total += u64::from(st.attempts);
        match result {
            Ok(out) => {
                assert_outcome_valid(label, &out);
                assert_eq!(out.attempts, st.attempts, "{label}: attempt counts disagree");
                if out.degraded {
                    degraded_seen += 1;
                    assert_eq!(out.attempts, 3, "{label}: degraded before retries exhausted");
                }
            }
            Err(err) => {
                // Typed error: a terminal non-Done state with a reason.
                let msg = err.to_string();
                assert!(!msg.is_empty(), "{label}: empty error");
                assert!(st.error.is_some(), "{label}: terminal failure without detail");
                failed_paths += 1;
            }
        }
    }

    // Metrics consistency. Every requeue bumped exactly one attempt
    // counter past 1, so the retry counter is fully accounted for.
    assert_eq!(
        e.retries(),
        attempts_total - jobs.len() as u64,
        "retries != Σ(attempts-1)"
    );
    assert_eq!(e.degraded_completions(), degraded_seen);
    // Every failed attempt here is plane-injected (the solvers are sound
    // on this instance): each retry consumed one injected failure and
    // each degradation entry one more.
    assert!(
        e.faults_injected() >= e.retries() + degraded_seen + failed_paths,
        "injected {} < retries {} + degraded {} + failed {}",
        e.faults_injected(),
        e.retries(),
        degraded_seen,
        failed_paths
    );
    // With solve at p=0.5 across the whole matrix, silence means the
    // plane is not wired in.
    assert!(e.faults_injected() > 0, "no faults fired across the matrix");

    // The worker pool survived: a clean job (no per-job plane) completes.
    let clean = MapSpec::named(INSTANCE)
        .hierarchy(HIERARCHY)
        .distance(DISTANCE)
        .algo(Some(heipa::algo::Algorithm::SharedMapF))
        .seed(2)
        .return_mapping(true);
    let out = e.map(&clean).expect("engine workers died during chaos");
    assert_outcome_valid("clean-after-chaos", &out);
}

#[test]
fn same_fault_seed_reproduces_the_same_outcome() {
    // The per-job plane is keyed only by (options, attempt), so two
    // identical submits replay the identical fault sequence. A
    // process-global HEIPA_FAULTS plane has shared streams that advance
    // across runs — reproducibility across *processes* for that tier is
    // asserted by CI diffing two isolated chaos-report runs.
    if heipa::fault::global().armed_any() {
        return;
    }
    let run = || {
        let e = chaos_engine(1, 1);
        let algo = heipa::algo::Algorithm::SharedMapF;
        let h = e.submit(&chaos_spec(algo, 7)).expect("submit");
        let outcome = h.wait();
        let st = h.status();
        let fingerprint = match outcome {
            Ok(out) => format!(
                "{:?}:{}:{}:{}:{:?}",
                st.state,
                out.attempts,
                out.degraded,
                out.comm_cost.to_bits(),
                out.mapping
            ),
            Err(err) => format!("{:?}:{}:{}", st.state, st.attempts, err),
        };
        fingerprint
    };
    assert_eq!(run(), run(), "same seed must replay the same fault sequence");
}

#[test]
fn chaos_report_for_fixed_seeds() {
    // Serial engine (one worker, one device thread, zero backoff): the
    // whole run is deterministic for fixed seeds, including a global
    // HEIPA_FAULTS plane — jobs are submitted and awaited one at a time,
    // so global decision streams are consumed in a fixed order. CI runs
    // this test twice in isolated processes (`--exact`) with
    // HEIPA_CHAOS_REPORT set and diffs the two reports bit-for-bit.
    let e = Engine::new(EngineConfig {
        threads: 1,
        workers: 1,
        retry: RetryPolicy { max_attempts: 3, base_backoff: Duration::ZERO },
        ..EngineConfig::default()
    });
    let mut lines = Vec::new();
    for (i, name) in solver_names().into_iter().enumerate() {
        let algo = solver_by_name(name).expect("registered").algorithm();
        let h = e.submit(&chaos_spec(algo, 31 * (i as u64 + 1))).expect("submit");
        let _ = h.wait();
        let st = h.status();
        assert!(st.state.is_terminal(), "{name}: non-terminal");
        let line = match h.try_result() {
            Some(Ok(out)) => {
                assert_outcome_valid(name, &out);
                format!(
                    "solver={name} state={} attempts={} degraded={} j_bits={}",
                    st.state.name(),
                    st.attempts,
                    u8::from(out.degraded),
                    out.comm_cost.to_bits()
                )
            }
            Some(Err(err)) => format!(
                "solver={name} state={} attempts={} error={}",
                st.state.name(),
                st.attempts,
                err.to_string().replace(' ', "_")
            ),
            None => unreachable!("terminal job without result"),
        };
        lines.push(line);
    }
    lines.push(format!(
        "totals retries={} faults_injected={} degraded={}",
        e.retries(),
        e.faults_injected(),
        e.degraded_completions()
    ));
    if let Ok(path) = std::env::var("HEIPA_CHAOS_REPORT") {
        std::fs::write(&path, lines.join("\n") + "\n")
            .unwrap_or_else(|err| panic!("write {path}: {err}"));
    }
}

#[test]
fn device_launch_fault_degrades_to_cpu_backend() {
    // A flaky accelerator (`device_launch` at p=1) must not fail the job:
    // the retry fence absorbs the panics and the degradation chain drops
    // to the cpu backend *before* switching solvers, so the outcome is a
    // valid mapping that records the backend actually used.
    let e = chaos_engine(1, 1);
    let mut opts = BTreeMap::new();
    opts.insert("__fault.device_launch".into(), "1".into());
    opts.insert("__fault.seed".into(), "99".into());
    let spec = MapSpec::named(INSTANCE)
        .hierarchy(HIERARCHY)
        .distance(DISTANCE)
        .algo(Some(heipa::algo::Algorithm::GpuIm))
        .seed(1)
        .backend(heipa::engine::Backend::Device)
        .return_mapping(true)
        .options(opts);
    let h = e.submit(&spec).expect("submit");
    let out = h.wait().expect("device-launch chaos must degrade, not fail");
    assert_eq!(
        out.backend,
        heipa::engine::Backend::Cpu,
        "degradation must fall back to the cpu backend"
    );
    assert!(out.degraded, "p=1 device faults must exhaust retries into degradation");
    assert_eq!(out.attempts, 3, "degradation fires on the final attempt");
    assert_outcome_valid("device_launch", &out);
    assert!(e.faults_injected() > 0, "device_launch plane never fired");
}

#[test]
fn malformed_fault_spec_is_a_terminal_typed_error() {
    // A bad `__fault.*` option must fail the job (typed, terminal), not
    // wedge it or take the worker down.
    let e = chaos_engine(1, 1);
    let mut opts = BTreeMap::new();
    opts.insert("__fault.solve".into(), "not-a-probability".into());
    let spec = MapSpec::named(INSTANCE)
        .hierarchy(HIERARCHY)
        .distance(DISTANCE)
        .algo(Some(heipa::algo::Algorithm::SharedMapF))
        .options(opts);
    let h = e.submit(&spec).expect("submit");
    let err = h.wait().expect_err("malformed plane must fail the job");
    assert!(err.to_string().contains("__fault"), "untyped error: {err}");
    assert_eq!(h.status().state, heipa::engine::JobState::Failed);
    // Worker still alive.
    assert!(e
        .map(&MapSpec::named(INSTANCE).hierarchy(HIERARCHY).distance(DISTANCE))
        .is_ok());
}
