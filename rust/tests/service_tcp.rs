//! TCP front-end integration: drive the coordinator's real accept loop
//! (`protocol::serve_listener`) over real sockets — the async job API,
//! graph sessions, the legacy blocking `map`, and the connection cap.

use heipa::coordinator::protocol::{self, ServeOptions};
use heipa::coordinator::service::{Service, ServiceConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bind an ephemeral port and serve the real protocol loop on it.
fn spawn(svc: Arc<Service>, opts: ServeOptions) -> SocketAddr {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = protocol::serve_listener(svc, listener, opts);
    });
    addr
}

fn two_worker_service() -> Arc<Service> {
    Arc::new(Service::with_config(ServiceConfig { threads: 1, workers: 2, ..Default::default() }))
}

/// An interactive connection: send one line, read one reply.
struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        Conn { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// Pipelined helper: write all lines, then collect all replies.
fn roundtrip(addr: SocketAddr, lines_in: &[&str]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    for l in lines_in {
        writeln!(conn, "{l}").unwrap();
    }
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    BufReader::new(conn).lines().map(|l| l.unwrap()).collect()
}

fn job_id_of(reply: &str) -> u64 {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
        .unwrap_or_else(|| panic!("no job id in `{reply}`"))
}

#[test]
fn ping_map_metrics_over_tcp() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let replies = roundtrip(
        addr,
        &[
            "ping",
            "map instance=sten_cop20k algorithm=gpu-im hierarchy=2:2:2 distance=1:10:100 eps=0.03 seed=1",
            "metrics",
        ],
    );
    assert_eq!(replies.len(), 3, "replies: {replies:?}");
    assert!(replies[0].starts_with("ok version="), "{}", replies[0]);
    assert!(replies[0].contains("queue_depth="), "{}", replies[0]);
    assert!(replies[0].contains("graphs=0"), "{}", replies[0]);
    assert!(replies[1].starts_with("ok "), "{}", replies[1]);
    assert!(replies[1].contains("algorithm=gpu-im"));
    assert!(replies[1].contains(" j="));
    assert!(replies[2].contains("requests=1"));
    assert!(replies[2].contains("completed=1"));
    assert!(replies[2].contains("queue_depth="));
}

#[test]
fn protocol_errors_do_not_kill_connection() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let replies = roundtrip(addr, &["bogus", "map instance=missing_instance", "ping"]);
    assert_eq!(replies.len(), 3);
    assert!(replies[0].starts_with("err code=parse"), "{}", replies[0]);
    assert!(replies[1].starts_with("err "), "{}", replies[1]);
    // The error message survives escaping: unescape restores real text
    // with spaces (the old renderer flattened them to `_`).
    let msg = replies[1].split_once("message=").map(|(_, v)| v).unwrap();
    let text = protocol::unescape_value(msg);
    assert!(text.contains("missing_instance"), "{text}");
    assert!(text.contains(' '), "message lost its spaces: {text}");
    assert!(replies[2].starts_with("ok version="), "{}", replies[2]);
}

#[test]
fn mapping_payload_roundtrips() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let replies = roundtrip(
        addr,
        &["map instance=sten_cop20k algorithm=jet hierarchy=2:2 distance=1:10 eps=0.05 seed=2 mapping=1"],
    );
    let line = &replies[0];
    assert!(line.starts_with("ok "));
    let mapping_part = line.split("mapping=").nth(1).expect("mapping field");
    let ids: Vec<u32> = mapping_part.split(',').map(|t| t.parse().unwrap()).collect();
    let g = heipa::graph::gen::generate_by_name("sten_cop20k");
    assert_eq!(ids.len(), g.n());
    assert!(ids.iter().all(|&b| b < 4));
}

#[test]
fn submit_over_tcp_returns_before_the_solve_and_matches_blocking_map() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut conn = Conn::open(addr);
    let body = "instance=sten_cop20k algorithm=gpu-im hierarchy=2:2:2 distance=1:10:100 eps=0.03 seed=5 mapping=1";

    // Async path: submit → (immediate job id) → wait → result.
    let t0 = Instant::now();
    let submitted = conn.send(&format!("submit {body} opt.__sleep_ms=300"));
    let submit_latency = t0.elapsed();
    assert!(submitted.starts_with("ok job="), "{submitted}");
    assert!(submitted.contains("state=queued"), "{submitted}");
    // The solve sleeps ≥ 300ms; the submit reply must not have waited for it.
    assert!(
        submit_latency < Duration::from_millis(300),
        "submit blocked for {submit_latency:?} — not asynchronous"
    );
    let job = job_id_of(&submitted);
    let waited = conn.send(&format!("wait job={job}"));
    assert!(waited.contains("state=done"), "{waited}");
    let result = conn.send(&format!("result job={job}"));

    // Parity: the legacy blocking path must produce the identical outcome
    // fields (same spec, same seed — the sleep hook does not affect the
    // solve). Wall-clock fields (host_ms/device_ms) naturally vary per
    // run and are excluded.
    let blocking = conn.send(&format!("map {body}"));
    let fields = |s: &str| -> Vec<(String, String)> {
        s.split_whitespace()
            .filter_map(|t| t.split_once('='))
            .filter(|(k, _)| ["algorithm", "n", "k", "j", "imbalance", "polish_dj", "mapping"].contains(k))
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    };
    assert_eq!(
        fields(&result),
        fields(&blocking),
        "async result and blocking map disagree:\n  {result}\n  {blocking}"
    );
    assert!(!fields(&result).is_empty());
}

#[test]
fn cancel_over_tcp_stops_a_running_job() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut conn = Conn::open(addr);
    let submitted = conn.send(
        "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 opt.__sleep_ms=60000",
    );
    let job = job_id_of(&submitted);
    // Cancel from a *different* connection: job identity is server-side.
    let mut other = Conn::open(addr);
    let cancelled = other.send(&format!("cancel job={job}"));
    assert!(cancelled.starts_with("ok job="), "{cancelled}");
    let t0 = Instant::now();
    let waited = conn.send(&format!("wait job={job}"));
    assert!(t0.elapsed() < Duration::from_secs(10), "cancelled job still blocked the wait");
    assert!(waited.contains("state=cancelled"), "{waited}");
    let result = conn.send(&format!("result job={job}"));
    assert!(result.starts_with("err code=cancelled"), "{result}");
    // The cancelled counter is bumped when the job is retired (at worker
    // pop for a queued cancel) — poll briefly rather than race it.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let metrics = other.send("metrics");
        if metrics.contains("cancelled=1") {
            break;
        }
        assert!(Instant::now() < deadline, "cancelled never counted: {metrics}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn graph_sessions_survive_across_connections() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut a = Conn::open(addr);
    let put = a.send("graph put name=ring csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6");
    assert_eq!(put, "ok graph=ring n=8 m=8 version=1");
    drop(a); // the session graph outlives the uploading connection
    let mut b = Conn::open(addr);
    assert_eq!(b.send("graph list"), "ok count=1 graphs=ring@v1");
    let mapped = b.send("map graph=ring algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3");
    assert!(mapped.starts_with("ok id="), "{mapped}");
    assert!(mapped.contains("k=4"), "{mapped}");
    assert_eq!(b.send("graph del name=ring"), "ok dropped=ring");
}

#[test]
fn patch_then_remap_warm_over_tcp() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut conn = Conn::open(addr);
    let put = conn.send("graph put name=ring csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6");
    assert_eq!(put, "ok graph=ring n=8 m=8 version=1");
    // On an 8-ring the one-hop halo around a patched edge covers most of
    // the graph, so lift the region cap to keep the warm path open.
    let map_cmd = "map graph=ring algorithm=gpu-im hierarchy=2:2 distance=1:10 eps=0.3 seed=1 \
                   opt.remap.max_region_frac=1";
    let first = conn.send(map_cmd);
    assert!(first.starts_with("ok id="), "{first}");
    assert!(!first.contains("remap="), "first solve has nothing to warm-start from: {first}");
    let patched = conn.send("graph patch name=ring ops=ae:0:4:1.0");
    assert_eq!(patched, "ok graph=ring n=8 m=9 version=2 touched=2 ops=1");
    assert_eq!(conn.send("graph list"), "ok count=1 graphs=ring@v2");
    let second = conn.send(map_cmd);
    assert!(second.contains(" remap=warm"), "{second}");
    // Patch errors are typed and leave the session graph untouched.
    let bad = conn.send("graph patch name=ring ops=zz:1");
    assert!(bad.starts_with("err code=patch"), "{bad}");
    let missing = conn.send("graph patch name=nope ops=ae:0:1:1.0");
    assert!(missing.starts_with("err code=unknown_graph"), "{missing}");
    let metrics = conn.send("metrics");
    assert!(metrics.contains(" patches=1 "), "{metrics}");
    assert!(metrics.contains(" warm_remaps=1 "), "{metrics}");
}

#[test]
fn batch_submit_and_wait_over_tcp() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut conn = Conn::open(addr);
    let body = "instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 eps=0.3";
    let jobs: Vec<String> = (1..=3)
        .map(|s| protocol::escape_value(&format!("{body} seed={s}")))
        .collect();
    let reply = conn.send(&format!("batch submit jobs={}", jobs.join(";")));
    assert!(reply.starts_with("ok batch="), "{reply}");
    assert!(reply.contains("count=3"), "{reply}");
    let batch: u64 = reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix("batch=").and_then(|v| v.parse().ok()))
        .unwrap();
    // Waiting works from a different connection: batch identity is
    // server-side, like job identity.
    let mut other = Conn::open(addr);
    let waited = other.send(&format!("batch wait id={batch}"));
    assert_eq!(
        waited,
        format!("ok batch={batch} count=3 done=3 failed=0 cancelled=0 expired=0")
    );
    assert!(other.send("batch wait id=9999").starts_with("err code=unknown_batch"));
    let metrics = other.send("metrics");
    assert!(metrics.contains(" batches=1 "), "{metrics}");
    assert!(metrics.contains(" batched_jobs=3 "), "{metrics}");
}

#[test]
fn drain_finishes_in_flight_work_and_refuses_new_jobs() {
    let addr = spawn(two_worker_service(), ServeOptions::default());
    let mut conn = Conn::open(addr);
    let submitted = conn.send(
        "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 opt.__sleep_ms=300",
    );
    let job = job_id_of(&submitted);
    // Drain from a different connection: it blocks until the in-flight
    // job retires, then acknowledges.
    let mut other = Conn::open(addr);
    let drained = other.send("drain timeout_ms=30000");
    assert_eq!(drained, "ok drained=1");
    // The in-flight job finished normally rather than being dropped.
    let waited = conn.send(&format!("wait job={job}"));
    assert!(waited.contains("state=done"), "{waited}");
    // New work — async and blocking alike — is refused with a typed error.
    let refused = conn.send("submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10");
    assert!(refused.starts_with("err code=unavailable"), "{refused}");
    let refused = conn.send("map instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10");
    assert!(refused.starts_with("err code=unavailable"), "{refused}");
    // Reads still work on a drained node, and drain is idempotent.
    assert!(conn.send(&format!("result job={job}")).starts_with("ok "), "result after drain");
    assert_eq!(other.send("drain timeout_ms=1000"), "ok drained=1");
}

#[test]
fn oversize_lines_get_toobig_and_the_connection_survives() {
    let addr = spawn(
        two_worker_service(),
        ServeOptions { max_line_len: 64, ..ServeOptions::default() },
    );
    let mut conn = Conn::open(addr);
    // An oversize request — e.g. a huge inline `graph put csr=` payload —
    // is answered with `err code=toobig` and discarded; the same
    // connection keeps serving afterwards.
    let oversize = format!("graph put name=big csr=0,{}", "1,".repeat(200));
    let reply = conn.send(&oversize);
    assert!(reply.starts_with("err code=toobig"), "{reply}");
    assert!(conn.send("ping").starts_with("ok version="));
    // A line at the limit still parses normally (as a protocol error for
    // this garbage body, not a framing error).
    let at_limit = "x".repeat(64);
    let reply = conn.send(&at_limit);
    assert!(reply.starts_with("err code=parse"), "{reply}");
}

#[test]
fn connection_cap_rejects_with_busy_and_recovers() {
    let addr = spawn(two_worker_service(), ServeOptions { max_conns: 1, ..ServeOptions::default() });
    let mut first = Conn::open(addr);
    assert!(first.send("ping").starts_with("ok version="));
    // Second concurrent connection: one busy line, then closed.
    let over = TcpStream::connect(addr).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut lines = BufReader::new(over).lines();
    let busy = lines.next().unwrap().unwrap();
    assert!(busy.starts_with("err code=busy"), "{busy}");
    assert!(lines.next().is_none(), "over-cap connection must be closed");
    // Dropping the first connection frees the slot (poll briefly: the
    // server decrements when the handler thread exits). An over-cap
    // connection announces itself with an unsolicited busy line; an
    // accepted one stays silent until spoken to — probe with a short
    // read timeout before committing to a ping.
    drop(first);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "slot never freed");
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        if reader.read_line(&mut line).is_ok() && line.starts_with("err code=busy") {
            std::thread::sleep(Duration::from_millis(10));
            continue; // still over cap
        }
        // No busy line → the connection was accepted; it must serve.
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut writer = stream;
        writeln!(writer, "ping").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok version="), "{line}");
        break;
    }
}
