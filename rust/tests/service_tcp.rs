//! TCP front-end integration: drive the coordinator over a real socket.

use heipa::coordinator::protocol;
use heipa::coordinator::service::Service;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn spawn(svc: Arc<Service>) -> std::net::SocketAddr {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let svc = svc.clone();
            std::thread::spawn(move || {
                let reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    let reply = match protocol::parse_command(&line) {
                        Ok(protocol::Command::Ping) => "ok pong=1".to_string(),
                        Ok(protocol::Command::Metrics) => protocol::render_metrics(&svc.metrics()),
                        Ok(protocol::Command::Map(req)) => match svc.submit(req) {
                            Ok(resp) => protocol::render_response(&resp),
                            Err(e) => protocol::render_error(&e),
                        },
                        Err(e) => protocol::render_error(&e),
                    };
                    if writeln!(writer, "{reply}").is_err() {
                        break;
                    }
                }
            });
        }
    });
    addr
}

fn roundtrip(addr: std::net::SocketAddr, lines_in: &[&str]) -> Vec<String> {
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    for l in lines_in {
        writeln!(conn, "{l}").unwrap();
    }
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    BufReader::new(conn).lines().map(|l| l.unwrap()).collect()
}

#[test]
fn ping_map_metrics_over_tcp() {
    let svc = Arc::new(Service::start("artifacts".into(), 1));
    let addr = spawn(svc);
    let replies = roundtrip(
        addr,
        &[
            "ping",
            "map instance=sten_cop20k algorithm=gpu-im hierarchy=2:2:2 distance=1:10:100 eps=0.03 seed=1",
            "metrics",
        ],
    );
    assert_eq!(replies.len(), 3, "replies: {replies:?}");
    assert!(replies[0].contains("pong"));
    assert!(replies[1].starts_with("ok "), "{}", replies[1]);
    assert!(replies[1].contains("algorithm=gpu-im"));
    assert!(replies[1].contains(" j="));
    assert!(replies[2].contains("requests=1"));
}

#[test]
fn protocol_errors_do_not_kill_connection() {
    let svc = Arc::new(Service::start("artifacts".into(), 1));
    let addr = spawn(svc);
    let replies = roundtrip(addr, &["bogus", "map instance=missing_instance", "ping"]);
    assert_eq!(replies.len(), 3);
    assert!(replies[0].starts_with("err "));
    assert!(replies[1].starts_with("err "));
    assert!(replies[2].contains("pong"));
}

#[test]
fn mapping_payload_roundtrips() {
    let svc = Arc::new(Service::start("artifacts".into(), 1));
    let addr = spawn(svc);
    let replies = roundtrip(
        addr,
        &["map instance=sten_cop20k algorithm=jet hierarchy=2:2 distance=1:10 eps=0.05 seed=2 mapping=1"],
    );
    let line = &replies[0];
    assert!(line.starts_with("ok "));
    let mapping_part = line.split("mapping=").nth(1).expect("mapping field");
    let ids: Vec<u32> = mapping_part.split(',').map(|t| t.parse().unwrap()).collect();
    let g = heipa::graph::gen::generate_by_name("sten_cop20k");
    assert_eq!(ids.len(), g.n());
    assert!(ids.iter().all(|&b| b < 4));
}
