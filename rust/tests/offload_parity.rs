//! CPU-pool vs device-backend parity for the offloaded multilevel
//! kernels (ISSUE: real device execution path).
//!
//! Matching and contraction offload pure integer/rating math whose
//! device kernels reproduce the host formulas bit for bit, so their
//! results are asserted *exactly equal* at all three compiled graph
//! classes. The Jet candidate kernel computes gains by a dense
//! `conn · D` product whose f64 summation order differs from the host's
//! sparse scan (and its candidate set is a superset — every block
//! `b < k`, not only connected ones), so end-to-end mappings are
//! compared under a documented quality tolerance instead; the host
//! second filter re-evaluates every candidate either way, which keeps
//! the move list safe.
//!
//! Every test skips itself (with a note on stderr) when the AOT
//! artifacts are absent or the PJRT plugin cannot come up — run
//! `make artifacts` first; CI's `offload-smoke` job runs them for real.

use heipa::algo::Algorithm;
use heipa::coarsen::contract_cas::contract_cas;
use heipa::coarsen::match_par::preference_matching;
use heipa::coarsen::{matching_to_map, serial_hem};
use heipa::engine::{Backend, Engine, EngineConfig, MapSpec};
use heipa::graph::{gen, CsrGraph, EdgeList};
use heipa::par::{ledger, Pool};
use heipa::partition::validate_mapping;
use heipa::runtime::device;
use std::sync::Arc;

/// Activate the thread-local device session against the crate-root
/// artifacts, or report why the test is skipped.
fn try_device() -> Option<device::DeviceGuard> {
    let guard = device::activate("artifacts")?;
    if !device::graph_kernels_available() {
        eprintln!("skipping: graph-kernel artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(guard)
}

/// One graph per compiled class `(n_pad, m_pad)`, comfortably inside it.
fn class_graphs() -> Vec<Arc<CsrGraph>> {
    vec![
        Arc::new(gen::grid2d(30, 30, false)),   // 900 ≤ 1024
        Arc::new(gen::grid2d(60, 60, false)),   // 3600 ≤ 4096
        Arc::new(gen::grid2d(120, 120, false)), // 14400 ≤ 16384
    ]
}

#[test]
fn match_round_is_bit_identical_at_three_sizes() {
    let pool = Pool::new(1);
    for (i, g) in class_graphs().into_iter().enumerate() {
        let cpu = preference_matching(&g, &pool, i64::MAX, 7 + i as u64, 8);
        let Some(_guard) = try_device() else { return };
        let _scope = device::graph_scope(&g);
        let before = ledger::device_snapshot();
        let dev = preference_matching(&g, &pool, i64::MAX, 7 + i as u64, 8);
        let delta = ledger::device_snapshot().since(before);
        assert!(delta.device_launches > 0, "class {i}: device branch never engaged");
        assert_eq!(cpu, dev, "class {i}: matchings diverge");
    }
}

#[test]
fn match_round_respects_the_weight_cap_on_device() {
    let mut g = gen::grid2d(30, 30, false);
    for v in 0..g.n() {
        g.vw[v] = 1 + (v % 5) as i64;
    }
    let g = Arc::new(g);
    let pool = Pool::new(1);
    let cpu = preference_matching(&g, &pool, 6, 3, 8);
    let Some(_guard) = try_device() else { return };
    let _scope = device::graph_scope(&g);
    let dev = preference_matching(&g, &pool, 6, 3, 8);
    assert_eq!(cpu, dev, "weight-capped matchings diverge");
    for v in 0..g.n() {
        let m = dev[v] as usize;
        if m != v {
            assert!(g.vw[v] + g.vw[m] <= 6, "cap violated at {v}-{m}");
        }
    }
}

#[test]
fn contract_gather_is_bit_identical_at_three_sizes() {
    // One pool thread makes the CAS insert order (and thus the f64
    // fusion order) identical across backends, so every field — the
    // edge weights included — must match exactly.
    let pool = Pool::new(1);
    for (i, g) in class_graphs().into_iter().enumerate() {
        let mate = serial_hem(&g, i64::MAX, 11 + i as u64);
        let (map, nc) = matching_to_map(&mate);
        let el = EdgeList::build(&g);
        let cpu = contract_cas(&pool, &g, &el, &map, nc);
        let Some(_guard) = try_device() else { return };
        let _scope = device::graph_scope(&g);
        let before = ledger::device_snapshot();
        let dev = contract_cas(&pool, &g, &el, &map, nc);
        let delta = ledger::device_snapshot().since(before);
        assert!(delta.device_launches > 0, "class {i}: device branch never engaged");
        assert_eq!(cpu.xadj, dev.xadj, "class {i}");
        assert_eq!(cpu.adj, dev.adj, "class {i}");
        assert_eq!(cpu.vw, dev.vw, "class {i}");
        assert_eq!(
            cpu.ew.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            dev.ew.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            "class {i}: fused edge weights diverge"
        );
    }
}

fn engine(artifacts_dir: &str) -> Engine {
    Engine::new(EngineConfig {
        threads: 1,
        workers: 1,
        artifacts_dir: artifacts_dir.into(),
        ..EngineConfig::default()
    })
}

fn device_spec(g: Arc<CsrGraph>) -> MapSpec {
    MapSpec::in_memory(g)
        .hierarchy("2:2")
        .distance("1:10")
        .algo(Some(Algorithm::GpuIm))
        .seed(5)
        .return_mapping(true)
}

/// End-to-end `gpu_im` through PJRT: the device mapping must be valid
/// and its cost within 20% of the CPU pool's. The tolerance covers the
/// Jet kernel's dense-summation gain differences and its superset
/// candidate set (see module docs); matching and contraction are
/// bit-identical, so the hierarchies underneath agree exactly.
#[test]
fn gpu_im_device_backend_matches_cpu_quality() {
    {
        let Some(_guard) = try_device() else { return };
    }
    let g = Arc::new(gen::grid2d(60, 60, false));
    let e = engine("artifacts");
    let cpu = e.map(&device_spec(g.clone()).backend(Backend::Cpu)).unwrap();
    let dev = e.map(&device_spec(g.clone()).backend(Backend::Device)).unwrap();
    assert_eq!(dev.backend, Backend::Device, "device job fell back unexpectedly");
    assert_eq!(cpu.backend, Backend::Cpu);
    assert!(e.device_launches() > 0, "no PJRT launches recorded");
    validate_mapping(&dev.mapping, dev.n, dev.k).unwrap();
    let diff = (dev.comm_cost - cpu.comm_cost).abs();
    assert!(
        diff <= 0.2 * cpu.comm_cost,
        "device quality drifted: cpu {} vs device {}",
        cpu.comm_cost,
        dev.comm_cost
    );
}

/// The device graph store uploads a pinned session graph once: a repeat
/// job re-anchors the same `Arc`s (graph store + hierarchy cache), so
/// its bus traffic must shrink by at least one full finest-graph upload
/// (class `(4096, 32768)`: `m_pad·16 + n_pad·8` bytes) while still
/// launching kernels.
#[test]
fn pinned_graph_uploads_once_across_repeat_jobs() {
    {
        let Some(_guard) = try_device() else { return };
    }
    let e = engine("artifacts");
    let g = Arc::new(gen::grid2d(60, 60, false));
    e.put_graph("parity_g", g);
    let spec = MapSpec::named("parity_g")
        .hierarchy("2:2")
        .distance("1:10")
        .algo(Some(Algorithm::GpuIm))
        .seed(5)
        .backend(Backend::Device);
    let first = e.map(&spec).unwrap();
    assert_eq!(first.backend, Backend::Device);
    let (l1, h1) = (e.device_launches(), e.h2d_bytes());
    assert!(l1 > 0 && h1 > 0);
    let second = e.map(&spec).unwrap();
    assert_eq!(second.backend, Backend::Device);
    let (l2, h2) = (e.device_launches(), e.h2d_bytes());
    assert!(l2 > l1, "repeat job launched nothing");
    let finest_upload = (32_768 * 16 + 4_096 * 8) as u64;
    let (d1, d2) = (h1, h2 - h1);
    assert!(
        d2 + finest_upload <= d1,
        "repeat job re-uploaded the graph: first {d1} B, second {d2} B, upload {finest_upload} B"
    );
}

/// `backend=auto` without artifacts resolves quietly to the CPU pool:
/// same mapping quality, no degradation, no device traffic.
#[test]
fn auto_backend_falls_back_cleanly_without_artifacts() {
    let e = engine("definitely_missing_artifacts");
    let g = Arc::new(gen::grid2d(30, 30, false));
    let out = e.map(&device_spec(g).backend(Backend::Auto)).unwrap();
    assert_eq!(out.backend, Backend::Cpu, "auto must resolve to cpu without artifacts");
    assert!(!out.degraded, "clean fallback is not a degradation");
    validate_mapping(&out.mapping, out.n, out.k).unwrap();
    assert_eq!(e.device_launches(), 0);
    assert_eq!(e.h2d_bytes(), 0);
}
