//! Checked-device mode end to end: seeded known-bad kernels must be
//! flagged with the right kernel label, and the full solver stack must run
//! conflict-free at 1, 2 and 4 threads.
//!
//! The checker's registry is process-global, so every test takes
//! [`checker_lock`] to serialize against the others (including the clean
//! solves, which would otherwise observe a seeded test's conflicts).

#![cfg(feature = "device-check")]

use heipa::algo::gpu_im::{gpu_im, GpuImConfig};
use heipa::graph::{gen, EdgeList};
use heipa::partition::l_max;
use heipa::refine::jet_loop::{jet_refine, JetConfig};
use heipa::refine::Objective;
use heipa::par::{check, ledger, Pool, SharedMut};
use heipa::topology::Machine;
use std::sync::{Mutex, MutexGuard};

fn checker_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    // A seeded-kernel test that failed an assertion poisons the lock;
    // the serialized state itself is drained below, so keep going.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Flip the checker into collect mode and restore the previous mode (and
/// drain leftovers) on drop, so a failing test cannot leak panics into
/// the next one.
struct CollectMode {
    prev: bool,
}

impl CollectMode {
    fn new() -> Self {
        let prev = check::set_panic_on_conflict(false);
        check::take_conflicts();
        CollectMode { prev }
    }
}

impl Drop for CollectMode {
    fn drop(&mut self) {
        check::take_conflicts();
        check::set_panic_on_conflict(self.prev);
    }
}

#[test]
fn seeded_write_write_race_is_flagged() {
    let _guard = checker_lock();
    let _mode = CollectMode::new();
    let pool = Pool::new(2);
    // 20k units so the pool genuinely dispatches to workers (the inline
    // fallback only covers n < 2 * MIN_CHUNK); all units hammer slot 0.
    let n = 20_000;
    let mut buf = vec![0u32; 8];
    let ptr = SharedMut::new(&mut buf);
    let _k = ledger::kernel("tests:seeded_ww");
    pool.parallel_for(n, |i| {
        // SAFETY: deliberately violates the disjoint-writes contract (the
        // point of this test); u32 stores cannot produce invalid values.
        unsafe { ptr.write(0, i as u32) };
    });
    drop(_k);
    let conflicts = check::take_conflicts();
    assert!(!conflicts.is_empty(), "seeded write/write race not flagged");
    for c in &conflicts {
        assert_eq!(c.kernel, "tests:seeded_ww", "wrong kernel label: {c}");
        assert_eq!(c.kind, check::ConflictKind::WriteWrite, "wrong kind: {c}");
        assert_eq!(c.index, 0, "wrong element index: {c}");
        assert_ne!(c.units.0, c.units.1, "conflict must name two distinct units: {c}");
    }
}

#[test]
fn seeded_write_read_race_is_flagged() {
    let _guard = checker_lock();
    let _mode = CollectMode::new();
    let pool = Pool::new(2);
    let n = 20_000;
    let mut buf = vec![0u32; n];
    let ptr = SharedMut::new(&mut buf);
    let _k = ledger::kernel("tests:seeded_wr");
    pool.parallel_for(n, |i| {
        // SAFETY: in-bounds, and each unit writes only its own slot — the
        // *read* of the neighbor's freshly-written slot inside the same
        // superstep is the seeded contract violation.
        unsafe {
            ptr.write(i, i as u32);
            let _ = ptr.read((i + 1) % n);
        }
    });
    drop(_k);
    let conflicts = check::take_conflicts();
    assert!(!conflicts.is_empty(), "seeded write/read race not flagged");
    assert!(
        conflicts.iter().any(|c| c.kind == check::ConflictKind::ReadWrite),
        "expected a write/read conflict, got: {conflicts:?}"
    );
    for c in &conflicts {
        assert_eq!(c.kernel, "tests:seeded_wr", "wrong kernel label: {c}");
        assert_ne!(c.units.0, c.units.1, "conflict must name two distinct units: {c}");
    }
}

#[test]
fn conflicts_panic_by_default_with_label() {
    let _guard = checker_lock();
    check::take_conflicts();
    let result = std::panic::catch_unwind(|| {
        let pool = Pool::new(1);
        let mut buf = vec![0u32; 4];
        let ptr = SharedMut::new(&mut buf);
        let _k = ledger::kernel("tests:panicking_ww");
        pool.parallel_for(16_384, |i| {
            // SAFETY: deliberate write/write violation; see above.
            unsafe { ptr.write(1, i as u32) };
        });
    });
    let err = result.expect_err("checked mode must panic on a conflict");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(
        msg.contains("tests:panicking_ww") && msg.contains("write/write"),
        "panic message must carry the kernel label and kind: {msg:?}"
    );
    check::take_conflicts();
}

/// The real solver stack, end to end, must be conflict-free at every
/// thread count — including `threads = 1`, where the logical-unit tagging
/// still detects contract violations no interleaving could expose.
#[test]
fn full_solve_is_conflict_free_at_1_2_4_threads() {
    let _guard = checker_lock();
    check::take_conflicts();
    let g = gen::rgg(3_000, gen::rgg_paper_radius(3_000), 42);
    let m = Machine::hier("2:2", "1:10").unwrap();
    let k = m.k();
    let eps = 0.03;
    for threads in [1usize, 2, 4] {
        let pool = Pool::new(threads);
        let mapping = gpu_im(&pool, &g, &m, eps, 7, &GpuImConfig::default(), None);
        assert_eq!(mapping.len(), g.n(), "threads={threads}");
        assert_eq!(
            check::conflict_count(),
            0,
            "gpu_im raised conflicts at threads={threads}"
        );

        // Standalone Jet pass over a fresh edge list on the same graph.
        let el = EdgeList::build_par(&pool, &g);
        let mut part = mapping.clone();
        let lmax = l_max(g.total_vweight(), k, eps);
        jet_refine(&pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&m), &JetConfig::default());
        assert_eq!(
            check::conflict_count(),
            0,
            "jet_refine raised conflicts at threads={threads}"
        );
    }
}
