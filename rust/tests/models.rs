//! Machine-model subsystem tests: oracle/matrix parity across every
//! model, distance-function properties (symmetry, zero diagonal,
//! finiteness), schedule validity, and end-to-end mapping through the
//! engine for every spec scheme.

use heipa::algo::Algorithm;
use heipa::engine::{Engine, EngineConfig, MapSpec};
use heipa::partition::validate_mapping;
use heipa::topology::{DistanceOracle, Machine, MatrixModel};

/// One small machine per model family (k ≤ 64 so all-pairs sweeps are
/// cheap).
fn all_models() -> Vec<Machine> {
    let mut ms = vec![
        Machine::parse_spec("hier:4:4:2/1:10:100").unwrap(),
        Machine::parse_spec("torus:4x4x2").unwrap(),
        Machine::parse_spec("torus:8/2.5").unwrap(),
        Machine::parse_spec("mesh:6x5").unwrap(),
        Machine::parse_spec("fattree:3:2,4,4/1,5,20").unwrap(),
        Machine::parse_spec("dragonfly:4:2:3/1,2,5").unwrap(),
        Machine::parse_spec("hetero:4+8+4+1/1,10").unwrap(),
    ];
    ms.push(
        Machine::from_model(
            MatrixModel::from_text("4\n0 1 10 10\n1 0 10 10\n10 10 0 1\n10 10 1 0\n", "inline")
                .unwrap(),
        )
        .unwrap(),
    );
    ms
}

#[test]
fn oracle_backends_agree_on_all_pairs_for_every_model() {
    for m in all_models() {
        let k = m.k();
        let implicit = DistanceOracle::implicit(&m);
        let dense = DistanceOracle::dense(&m);
        let blocked = DistanceOracle::blocked(&m, 2); // tiny cap forces evictions
        for x in 0..k as u32 {
            for y in 0..k as u32 {
                let d = m.distance(x, y);
                assert_eq!(implicit.get(x, y), d, "{}: implicit ({x},{y})", m.label());
                assert_eq!(dense.get(x, y), d, "{}: dense ({x},{y})", m.label());
                assert_eq!(blocked.get(x, y), d, "{}: blocked ({x},{y})", m.label());
                assert_eq!(dense.row(x).get(y), d, "{}: dense row ({x},{y})", m.label());
                assert_eq!(blocked.row(x).get(y), d, "{}: blocked row ({x},{y})", m.label());
            }
        }
    }
}

#[test]
fn distances_are_symmetric_finite_and_zero_on_the_diagonal() {
    for m in all_models() {
        let k = m.k();
        for x in 0..k as u32 {
            assert_eq!(m.distance(x, x), 0.0, "{}: diag({x})", m.label());
            for y in 0..k as u32 {
                let d = m.distance(x, y);
                assert!(d.is_finite() && d >= 0.0, "{}: D[{x},{y}] = {d}", m.label());
                assert_eq!(d, m.distance(y, x), "{}: asymmetric at ({x},{y})", m.label());
                if x != y {
                    assert!(d > 0.0, "{}: distinct PEs at zero distance ({x},{y})", m.label());
                }
            }
        }
    }
}

#[test]
fn schedules_are_consistent_with_k() {
    for m in all_models() {
        let prod: usize = m.schedule().iter().map(|&a| a as usize).product();
        assert_eq!(prod, m.k(), "{}", m.label());
        assert!(m.schedule().iter().all(|&a| a >= 1), "{}", m.label());
        // Span bookkeeping matches the schedule prefix products.
        let mut span = 1usize;
        for level in 1..=m.levels() {
            assert_eq!(m.pes_per_block_at_level(level), span, "{} level {level}", m.label());
            span *= m.schedule()[level - 1] as usize;
        }
    }
}

#[test]
fn spec_strings_round_trip() {
    for m in all_models() {
        if m.spec_string().starts_with("file:") {
            continue; // inline matrix has no on-disk path to re-parse
        }
        let m2 = Machine::parse_spec(&m.spec_string())
            .unwrap_or_else(|e| panic!("{}: {e}", m.spec_string()));
        assert_eq!(m, m2);
        assert_eq!(m.k(), m2.k());
    }
}

#[test]
fn every_model_maps_end_to_end_through_the_engine() {
    let e = Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() });
    for m in all_models() {
        // Note the inline MatrixModel has no on-disk path to re-parse:
        // it works here because MapSpec::topology carries the validated
        // Machine itself (the tempfile test below covers `file:PATH`).
        for algo in [Algorithm::GpuHm, Algorithm::GpuIm, Algorithm::SharedMapF] {
            let spec = MapSpec::named("sten_cop20k").topology(&m).algo(Some(algo)).seed(1);
            let out =
                e.map(&spec).unwrap_or_else(|err| panic!("{} / {}: {err}", m.label(), algo.name()));
            assert_eq!(out.k, m.k(), "{} / {}", m.label(), algo.name());
            validate_mapping(&out.mapping, out.n, out.k)
                .unwrap_or_else(|err| panic!("{} / {}: {err}", m.label(), algo.name()));
            assert!(out.comm_cost > 0.0, "{} / {}", m.label(), algo.name());
            // Engine-reported J equals an independent oracle evaluation.
            let g = e
                .resolve_graph(&heipa::engine::GraphSource::Named("sten_cop20k".into()))
                .unwrap();
            let j = heipa::partition::comm_cost(&g, &out.mapping, &m);
            assert!(
                (j - out.comm_cost).abs() < 1e-6 * j.max(1.0),
                "{} / {}: {j} vs {}",
                m.label(),
                algo.name(),
                out.comm_cost
            );
        }
    }
}

#[test]
fn file_model_via_a_real_tempfile_maps_end_to_end() {
    let path = std::env::temp_dir().join(format!("heipa_models_{}.mat", std::process::id()));
    std::fs::write(&path, "4\n0 1 10 10\n1 0 10 10\n10 10 0 1\n10 10 1 0\n").unwrap();
    let e = Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() });
    let spec = MapSpec::named("sten_cop20k")
        .topology_spec(format!("file:{}", path.display()))
        .algo(Some(Algorithm::GpuIm))
        .seed(1);
    let out = e.map(&spec).unwrap();
    assert_eq!(out.k, 4);
    validate_mapping(&out.mapping, out.n, out.k).unwrap();
    std::fs::remove_file(&path).ok();
}

#[test]
fn mapping_prefers_cheap_links_on_a_torus() {
    // On a 2x2x2 torus, a good mapping of a torus-shaped task graph must
    // beat a random one substantially — i.e. the torus distances really
    // reach the solvers.
    let e = Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() });
    let g = std::sync::Arc::new(heipa::graph::gen::torus3d(16, 16, 4));
    let m = Machine::parse_spec("torus:2x2x2").unwrap();
    let out = e
        .map(&MapSpec::in_memory(g.clone()).topology(&m).algo(Some(Algorithm::GpuIm)).seed(1))
        .unwrap();
    let mut rng = heipa::rng::Rng::new(7);
    let random: Vec<u32> = (0..g.n()).map(|_| rng.below(m.k() as u64) as u32).collect();
    let j_rand = heipa::partition::comm_cost(&g, &random, &m);
    assert!(
        out.comm_cost < j_rand * 0.6,
        "torus mapping not better than random: {} vs {j_rand}",
        out.comm_cost
    );
}
