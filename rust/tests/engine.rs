//! Engine-level integration tests: the one-spec/one-job-API contract.
//!
//! * spec round-trips: kv config file → `MapSpec` → wire `MapRequest` →
//!   `MapSpec` without loss;
//! * polish parity: the library engine and the service produce the same
//!   polished `comm_cost` for the same spec (the CLI drives the very same
//!   `Engine::map`, covered by `tests/cli.rs`);
//! * job parity: `submit(..).wait()` reproduces `map(..)` field for
//!   field, and a cancelled multilevel job aborts mid-solve;
//! * registry: every solver name resolves and solves a smoke instance
//!   through the engine.

use heipa::algo::Algorithm;
use heipa::config::RunConfig;
use heipa::coordinator::service::Service;
use heipa::coordinator::MapRequest;
use heipa::engine::{
    solver_by_name, solver_names, Engine, EngineConfig, JobState, MapSpec, Refinement,
};
use heipa::partition::validate_mapping;

fn engine() -> Engine {
    Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() })
}

#[test]
fn kv_file_to_spec_to_wire_roundtrip() {
    let text = "graph = rgg15\nhierarchy = 4:8:2\ndistance = 1:10:100\neps = 0.05\n\
                algorithm = gpu-hm\nrefinement = strong\ncoarsening = cluster\npolish = 1\n\
                seeds = 9\nopt.adaptive = 0\n";
    let cfg = RunConfig::from_kv_text(text).unwrap();
    let spec = cfg.to_spec(cfg.graph.as_deref().unwrap());

    // Spec carries everything the file said.
    assert_eq!(spec.eps, 0.05);
    assert_eq!(spec.algorithm, Some(Algorithm::GpuHm));
    assert_eq!(spec.refinement, Refinement::Strong);
    assert_eq!(spec.coarsening, heipa::multilevel::SchemeKind::Cluster);
    assert!(spec.polish);
    assert_eq!(spec.primary_seed(), 9);
    assert_eq!(spec.opt_bool("adaptive"), Some(false));

    // Lower onto the wire and back: nothing is lost.
    let req = MapRequest::from_spec(&spec).unwrap();
    assert_eq!(req.instance, "rgg15");
    let spec2 = req.to_spec();
    assert_eq!(spec2, spec);

    // And the wire protocol parses to the same request (via both the
    // blocking `map` verb and the async `submit` verb).
    let line = "map instance=rgg15 algorithm=gpu-hm hierarchy=4:8:2 distance=1:10:100 \
                eps=0.05 seed=9 refinement=strong coarsening=cluster polish=1 mapping=1 \
                opt.adaptive=0";
    let heipa::coordinator::protocol::Command::Map { req: parsed, .. } =
        heipa::coordinator::protocol::parse_command(line).unwrap()
    else {
        panic!("expected map command");
    };
    assert_eq!(parsed, req);
    let heipa::coordinator::protocol::Command::Submit { req: parsed, .. } =
        heipa::coordinator::protocol::parse_command(&format!("submit{}", line.strip_prefix("map").unwrap()))
            .unwrap()
    else {
        panic!("expected submit command");
    };
    assert_eq!(parsed, req);
}

#[test]
fn library_and_service_polish_paths_agree() {
    // `heipa map --polish`, the library API and the TCP service all call
    // Engine::map on the same spec; assert the two in-process front-ends
    // produce the identical polished cost.
    let spec = MapSpec::named("sten_cont300")
        .hierarchy("2:2:2")
        .distance("1:10:100")
        .algo(Some(Algorithm::Jet))
        .seed(1)
        .polish(true)
        .return_mapping(true);

    let lib = engine().map(&spec).unwrap();

    let svc = Service::start("artifacts".into(), 1);
    let wire = svc.submit(MapRequest::from_spec(&spec).unwrap()).unwrap();

    assert_eq!(lib.algorithm, wire.outcome.algorithm);
    assert!(
        (lib.comm_cost - wire.outcome.comm_cost).abs() < 1e-9 * lib.comm_cost.max(1.0),
        "library J {} != service J {}",
        lib.comm_cost,
        wire.outcome.comm_cost
    );
    assert!(
        (lib.polish_improvement - wire.outcome.polish_improvement).abs() < 1e-9,
        "polish ΔJ diverged: {} vs {}",
        lib.polish_improvement,
        wire.outcome.polish_improvement
    );
    assert_eq!(lib.mapping, wire.outcome.mapping);
}

#[test]
fn every_registered_solver_name_solves_through_the_engine() {
    let e = engine();
    assert_eq!(solver_names().len(), Algorithm::all().len());
    for name in solver_names() {
        let algo = solver_by_name(name).expect("name resolves").algorithm();
        let spec = MapSpec::named("sten_cop20k")
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(algo));
        let out = e.map(&spec).unwrap_or_else(|err| panic!("{name}: {err}"));
        assert_eq!(out.algorithm.name(), name);
        validate_mapping(&out.mapping, out.n, out.k).unwrap_or_else(|err| panic!("{name}: {err}"));
        assert!(out.comm_cost > 0.0, "{name}");
    }
}

#[test]
fn auto_routing_picks_by_size_and_refinement_upgrades() {
    let e = engine();
    // Small instance → quality flavor.
    let small = e.map(&MapSpec::named("wal_598a").hierarchy("2:2").distance("1:10")).unwrap();
    assert_eq!(small.algorithm, Algorithm::GpuHmUltra);
    // Strong refinement upgrades a pinned fast flavor.
    let strong = e
        .map(
            &MapSpec::named("wal_598a")
                .hierarchy("2:2")
                .distance("1:10")
                .algo(Some(Algorithm::SharedMapF))
                .refinement(Refinement::Strong),
        )
        .unwrap();
    assert_eq!(strong.algorithm, Algorithm::SharedMapS);
}

#[test]
fn topology_spec_round_trips_through_config_and_wire() {
    // topology= key: kv config → spec → wire request → spec, lossless.
    let cfg = RunConfig::from_kv_text("graph = rgg15\ntopology = torus:4x4x4\nseeds = 3\n").unwrap();
    let spec = cfg.to_spec(cfg.graph.as_deref().unwrap());
    assert_eq!(spec.topology.as_deref(), Some("torus:4x4x4"));
    assert_eq!(spec.machine().unwrap().k(), 64);

    let req = MapRequest::from_spec(&spec).unwrap();
    assert_eq!(req.topology.as_deref(), Some("torus:4x4x4"));
    assert_eq!(req.to_spec(), spec);

    let line = "map instance=rgg15 topology=torus:4x4x4 seed=3 mapping=1";
    let heipa::coordinator::protocol::Command::Map { req: parsed, .. } =
        heipa::coordinator::protocol::parse_command(line).unwrap()
    else {
        panic!("expected map command");
    };
    assert_eq!(parsed.topology, req.topology);
}

#[test]
fn submit_wait_reproduces_the_blocking_map_exactly() {
    // The acceptance parity check, in-process: the async job path must
    // produce the very MapOutcome the old blocking path did.
    let e = engine();
    let spec = MapSpec::named("sten_cop20k")
        .hierarchy("2:2:2")
        .distance("1:10:100")
        .algo(Some(Algorithm::GpuIm))
        .seed(4)
        .return_mapping(true);
    let blocking = e.map(&spec).unwrap();
    let job = e.submit(&spec).unwrap();
    let async_out = job.wait().unwrap();
    assert_eq!(job.status().state, JobState::Done);
    assert_eq!(blocking.algorithm, async_out.algorithm);
    assert_eq!(blocking.n, async_out.n);
    assert_eq!(blocking.k, async_out.k);
    assert_eq!(blocking.seed, async_out.seed);
    assert_eq!(blocking.mapping, async_out.mapping, "same seed must yield the same mapping");
    assert!((blocking.comm_cost - async_out.comm_cost).abs() < 1e-9 * blocking.comm_cost.max(1.0));
    assert!((blocking.imbalance - async_out.imbalance).abs() < 1e-12);
}

#[test]
fn cancelling_a_running_multilevel_job_aborts_the_solve() {
    // A real multilevel solve (no sleep hook): repeatedly submit + cancel
    // mid-flight; a cancelled job must come back as Cancelled, never
    // hang, and the worker must stay usable. (The hard wall-clock bound
    // on cancellation latency is asserted with the synthetic slow solver
    // in the engine's unit tests; solver-level poll behavior is pinned by
    // the registry/jet_loop cancellation tests.)
    let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..EngineConfig::default() });
    let g = std::sync::Arc::new(heipa::graph::gen::rgg(
        20_000,
        heipa::graph::gen::rgg_paper_radius(20_000),
        3,
    ));
    let spec = MapSpec::in_memory(g)
        .hierarchy("4:8:2")
        .distance("1:10:100")
        .algo(Some(Algorithm::GpuIm));
    let mut saw_cancel = false;
    for _ in 0..4 {
        let job = e.submit(&spec).unwrap();
        while job.status().state == JobState::Queued {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        job.cancel();
        let t0 = std::time::Instant::now();
        let result = job.wait();
        assert!(t0.elapsed() < std::time::Duration::from_secs(30), "cancel hung");
        match job.status().state {
            JobState::Cancelled => {
                assert!(result.unwrap_err().to_string().contains("cancelled"));
                saw_cancel = true;
                break;
            }
            JobState::Done => continue, // solve won the race; try again
            other => panic!("unexpected terminal state {other:?}"),
        }
    }
    assert!(saw_cancel, "solve always beat the cancel — graph too small for this test");
    // Worker is still healthy.
    assert!(e.map(&MapSpec::named("wal_598a").hierarchy("2:2").distance("1:10")).is_ok());
}

#[test]
fn engine_maps_a_torus_machine_end_to_end() {
    // The acceptance path: topology spec → engine → gpu_hm/gpu_im →
    // metrics, all distances via the machine-model oracle.
    let e = engine();
    for algo in [Algorithm::GpuHm, Algorithm::GpuIm] {
        let spec = MapSpec::named("sten_cop20k")
            .topology_spec("torus:2x2x2")
            .algo(Some(algo))
            .seed(1);
        let out = e.map(&spec).unwrap();
        assert_eq!(out.k, 8);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        // Re-evaluate independently through the model.
        let g = e.resolve_graph(&heipa::engine::GraphSource::Named("sten_cop20k".into())).unwrap();
        let m = heipa::topology::Machine::parse_spec("torus:2x2x2").unwrap();
        let j = heipa::partition::comm_cost(&g, &out.mapping, &m);
        assert!((j - out.comm_cost).abs() < 1e-6 * j.max(1.0));
    }
}
