//! Integration tests: whole-pipeline invariants across modules, the
//! paper's qualitative claims on real (scaled) instances, and
//! property-style sweeps over seeds/hierarchies — all driven through the
//! engine front door.

use heipa::algo::Algorithm;
use heipa::engine::{Engine, EngineConfig, MapOutcome, MapSpec};
use heipa::graph::{gen, CsrGraph};
use heipa::partition::{comm_cost, edge_cut, is_balanced, l_max, validate_mapping};
use heipa::rng::Rng;
use heipa::topology::{Hierarchy, Machine};
use std::sync::Arc;

const EPS: f64 = 0.03;

fn engine() -> Engine {
    Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() })
}

/// One engine run with a pinned algorithm on an in-memory graph.
fn solve(e: &Engine, g: &Arc<CsrGraph>, algo: Algorithm, h: &Machine, eps: f64, seed: u64) -> MapOutcome {
    e.map(&MapSpec::in_memory(g.clone()).topology(h).algo(Some(algo)).eps(eps).seed(seed))
        .expect("engine map")
}

/// Feasibility: `max block weight <= L_max` (the paper's constraint; the
/// ratio-based `imbalance()` can exceed ε by ceiling effects).
fn feasible(g: &CsrGraph, m: &[u32], k: usize) -> bool {
    heipa::partition::max_block_weight(g, m, k) <= l_max(g.total_vweight(), k, EPS)
}

#[test]
fn every_algorithm_is_feasible_on_every_smoke_instance() {
    let e = engine();
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();
    for spec in gen::smoke_suite() {
        let g = Arc::new(spec.generate());
        for algo in [
            Algorithm::GpuHm,
            Algorithm::GpuIm,
            Algorithm::SharedMapF,
            Algorithm::IntMapF,
            Algorithm::Jet,
        ] {
            let r = solve(&e, &g, algo, &h, EPS, 1);
            validate_mapping(&r.mapping, g.n(), h.k())
                .unwrap_or_else(|err| panic!("{} on {}: {err}", algo.name(), spec.name));
            assert!(
                feasible(&g, &r.mapping, h.k()),
                "{} on {}: infeasible (imb {:.4})",
                algo.name(),
                spec.name,
                r.imbalance
            );
        }
    }
}

#[test]
fn paper_quality_ordering_on_mesh_family() {
    // The paper's headline quality shape: SharedMap-S best; GPU-HM-ultra
    // competitive (~+12%); Jet (edge-cut) clearly unfit (~+90%).
    let e = engine();
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();
    let mut j_sms = 0.0;
    let mut j_ultra = 0.0;
    let mut j_jet = 0.0;
    for name in ["sten_cop20k", "del15", "wal_598a"] {
        let g = Arc::new(gen::generate_by_name(name));
        j_sms += solve(&e, &g, Algorithm::SharedMapS, &h, EPS, 1).comm_cost;
        j_ultra += solve(&e, &g, Algorithm::GpuHmUltra, &h, EPS, 1).comm_cost;
        j_jet += solve(&e, &g, Algorithm::Jet, &h, EPS, 1).comm_cost;
    }
    assert!(j_ultra <= j_sms * 1.35, "ultra {j_ultra} vs sharedmap-s {j_sms}");
    assert!(j_jet > j_ultra * 1.15, "jet should be clearly worse: {j_jet} vs {j_ultra}");
}

#[test]
fn modeled_speed_ordering_holds() {
    // GPU-IM must be the fastest device algorithm; SharedMap-S the
    // slowest solver overall (paper Fig. 2 left).
    let e = engine();
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();
    let g = Arc::new(gen::generate_by_name("rgg15"));
    let im = solve(&e, &g, Algorithm::GpuIm, &h, EPS, 1);
    let hm_u = solve(&e, &g, Algorithm::GpuHmUltra, &h, EPS, 1);
    let sms = solve(&e, &g, Algorithm::SharedMapS, &h, EPS, 1);
    assert!(im.device_ms < hm_u.device_ms, "gpu-im {} !< gpu-hm-ultra {}", im.device_ms, hm_u.device_ms);
    assert!(im.device_ms < sms.device_ms / 20.0, "gpu-im {} not ≫ sharedmap-s {}", im.device_ms, sms.device_ms);
}

#[test]
fn seed_sweep_stability() {
    // Across seeds, quality varies but feasibility and rough quality hold.
    let e = engine();
    let h = Machine::hier("2:4:4", "1:10:100").unwrap();
    let g = Arc::new(gen::generate_by_name("wal_598a"));
    let spec = MapSpec::in_memory(g.clone())
        .topology(&h)
        .algo(Some(Algorithm::GpuIm))
        .eps(EPS)
        .seeds(vec![1, 2, 3, 4, 5]);
    let outcomes = e.map_all_seeds(&spec).unwrap();
    let mut costs = Vec::new();
    for r in outcomes {
        assert!(feasible(&g, &r.mapping, h.k()), "seed {} infeasible", r.seed);
        costs.push(r.comm_cost);
    }
    let max = costs.iter().cloned().fold(f64::MIN, f64::max);
    let min = costs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min < 1.6, "seed variance too high: {min}..{max}");
}

#[test]
fn hierarchy_sweep_cost_grows_with_machine_size() {
    // More islands with expensive links → higher total cost, and every
    // hierarchy stays feasible (exercises Eq. 2 across depths).
    let e = engine();
    let g = Arc::new(gen::generate_by_name("sten_cop20k"));
    let mut last = 0.0;
    for top in [1u32, 2, 4, 6] {
        let h = Machine::from(Hierarchy::new(vec![4, 8, top], vec![1.0, 10.0, 100.0]).unwrap());
        let r = solve(&e, &g, Algorithm::GpuHm, &h, EPS, 1);
        assert!(feasible(&g, &r.mapping, h.k()), "top={top} infeasible");
        if top > 1 {
            assert!(r.comm_cost > last * 0.9, "cost did not grow: {last} -> {}", r.comm_cost);
        }
        last = r.comm_cost;
    }
}

#[test]
fn mapping_objective_beats_cut_objective_under_heterogeneous_distances() {
    // The point of the whole paper: with D = 1:10:100, minimizing J
    // directly (GPU-IM) beats minimizing edge-cut (Jet) on J — even
    // though Jet's edge-cut is lower or comparable.
    let e = engine();
    let h = Machine::hier("4:8:2", "1:10:100").unwrap();
    let mut im_wins = 0;
    let names = ["sten_cop20k", "del15", "rgg15", "wal_598a"];
    for name in names {
        let g = Arc::new(gen::generate_by_name(name));
        let im = solve(&e, &g, Algorithm::GpuIm, &h, EPS, 1);
        let jet = solve(&e, &g, Algorithm::Jet, &h, EPS, 1);
        if im.comm_cost < jet.comm_cost {
            im_wins += 1;
        }
        // Sanity: Jet genuinely optimizes the cut.
        let cut_im = edge_cut(&g, &im.mapping);
        let cut_jet = edge_cut(&g, &jet.mapping);
        assert!(cut_jet < cut_im * 1.5, "{name}: jet's cut should be competitive");
    }
    assert!(im_wins >= 3, "gpu-im won on only {im_wins}/{} instances", names.len());
}

#[test]
fn two_phase_composition_matches_direct_evaluation() {
    // block_comm_matrix + comm_cost_blocks must equal comm_cost for any
    // mapping (ties partition/, topology/, algo::qap together).
    let e = engine();
    let h = Machine::hier("4:4", "1:10").unwrap();
    let g = Arc::new(gen::generate_by_name("wal_598a"));
    let r = solve(&e, &g, Algorithm::GpuHm, &h, EPS, 3);
    let k = h.k();
    let bmat = heipa::partition::block_comm_matrix(&g, &r.mapping, k);
    let identity: Vec<u32> = (0..k as u32).collect();
    let j_blocks = heipa::partition::comm_cost_blocks(&bmat, k, &identity, &h.oracle());
    assert!((j_blocks - r.comm_cost).abs() < 1e-6 * r.comm_cost.max(1.0));
}

#[test]
fn qap_polish_composes_with_any_algorithm() {
    // The engine's polish stage never hurts J and preserves balance
    // (host path; the device path is covered in runtime::offload tests).
    let e = engine();
    let h = Machine::hier("2:4:2", "1:10:100").unwrap();
    let k = h.k();
    let g = Arc::new(gen::generate_by_name("sten_cont300"));
    for algo in [Algorithm::Jet, Algorithm::GpuIm] {
        let base = MapSpec::in_memory(g.clone()).topology(&h).algo(Some(algo)).eps(EPS);
        let plain = e.map(&base.clone()).unwrap();
        let polished = e.map(&base.polish(true)).unwrap();
        assert!(
            polished.comm_cost <= plain.comm_cost + 1e-9,
            "{}: polish worsened J",
            algo.name()
        );
        assert!(polished.polish_improvement >= 0.0);
        let j_check = comm_cost(&g, &polished.mapping, &h);
        assert!((j_check - polished.comm_cost).abs() < 1e-6 * j_check.max(1.0));
        assert!(
            is_balanced(&g, &polished.mapping, k, EPS + 0.002)
                == is_balanced(&g, &plain.mapping, k, EPS + 0.002),
            "{}: polish changed balance",
            algo.name()
        );
    }
}

#[test]
fn metis_roundtrip_preserves_mapping_results() {
    // gen → write METIS → read → identical mapping for the same seed.
    let g = gen::generate_by_name("sten_cop20k");
    let dir = std::env::temp_dir().join("heipa_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.graph");
    heipa::graph::io::write_metis(&g, &path).unwrap();
    let g2 = heipa::graph::io::read_metis(&path).unwrap();
    assert_eq!(g.n(), g2.n());
    assert_eq!(g.m(), g2.m());
    let e = engine();
    let h = Machine::hier("2:2", "1:10").unwrap();
    let a = solve(&e, &Arc::new(g), Algorithm::GpuIm, &h, EPS, 7);
    let b = solve(&e, &Arc::new(g2), Algorithm::GpuIm, &h, EPS, 7);
    assert_eq!(a.mapping, b.mapping);
}

#[test]
fn named_and_path_sources_agree() {
    // The engine resolves registry names and METIS paths to the same
    // graph, so identical specs produce identical mappings.
    let e = engine();
    let dir = std::env::temp_dir().join("heipa_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("named_vs_path.graph");
    heipa::graph::io::write_metis(&gen::generate_by_name("wal_598a"), &path).unwrap();
    let base = MapSpec::named("wal_598a").hierarchy("2:2").distance("1:10").seed(4);
    let by_name = e.map(&base.clone()).unwrap();
    let mut by_path = base;
    by_path.graph = heipa::engine::GraphSource::Named(path.to_str().unwrap().to_string());
    let by_path = e.map(&by_path).unwrap();
    assert_eq!(by_name.mapping, by_path.mapping);
    assert_eq!(by_name.comm_cost, by_path.comm_cost);
}

#[test]
fn random_graph_fuzz_many_shapes() {
    // Property-style: random small graphs, random hierarchies — always
    // valid, feasible mappings.
    let e = engine();
    let mut rng = Rng::new(99);
    for trial in 0..8 {
        let n = 200 + rng.below_usize(800);
        let g = Arc::new(gen::rgg(n, 0.55 * ((n as f64).ln() / n as f64).sqrt() * 1.3, trial));
        let a1 = 1 + rng.below(3) as u32;
        let a2 = 1 + rng.below(4) as u32;
        let h = Machine::from(Hierarchy::new(vec![a1 + 1, a2 + 1], vec![1.0, 10.0]).unwrap());
        let r = solve(&e, &g, Algorithm::GpuIm, &h, 0.10, trial);
        validate_mapping(&r.mapping, g.n(), h.k()).unwrap();
        assert!(
            heipa::partition::max_block_weight(&g, &r.mapping, h.k())
                <= l_max(g.total_vweight(), h.k(), 0.10),
            "trial {trial} infeasible"
        );
    }
}
