//! The unified multilevel subsystem: pluggable coarsening schemes, the
//! coarse-graph hierarchy, and the uncoarsening driver.
//!
//! Before this module existed, the coarsen → initial → uncoarsen-refine
//! skeleton was duplicated four times — device-style in
//! [`crate::algo::jet`] and [`crate::algo::gpu_im`], serially in
//! [`crate::algo::intmap`] and [`crate::initial`]. Now every pipeline is
//! three calls:
//!
//! 1. [`CoarseHierarchy::build`] (or [`CoarseHierarchy::build_serial`])
//!    runs the configured [`CoarsenScheme`] level by level until the
//!    graph is below the target size or contraction stalls, contracting
//!    with the CAS-hash kernel (serial oracle for CPU baselines) and
//!    recording per-level stats, phase timing and the modeled H2D upload
//!    exactly once;
//! 2. the caller produces an initial partition/mapping of
//!    [`CoarseHierarchy::coarsest`];
//! 3. [`CoarseHierarchy::uncoarsen`] (or `uncoarsen_serial`) projects the
//!    solution level by level and hands each finer graph to the caller's
//!    refinement closure.
//!
//! Two schemes exist: [`MatchingScheme`] (preference matching + bounded
//! two-hop fallback — the paper's §4.2 coarsening) and [`ClusterScheme`]
//! (size-constrained label-propagation clustering, after Shared-Memory
//! Hierarchical Process Mapping), which keeps shrinking graphs whose
//! matchings stall — star-like and other irregular instances.
//! [`SchemeKind::Auto`] runs matching and falls back to clustering on any
//! level where matching stalls.
//!
//! A built [`CoarseHierarchy`] is independent of the initial-mapping and
//! refinement seeds, so the engine caches hierarchies for session graphs
//! (keyed by graph identity + [`CoarsenConfig`] + level cap + salt) and
//! repeat jobs skip the Coarsening/Contraction phases entirely.

pub mod hierarchy;
pub mod scheme;

pub use hierarchy::{BuildParams, CoarseHierarchy, HierarchyHandle, HierarchyParams};
pub use scheme::{scheme, ClusterScheme, CoarsenScheme, LevelStep, MatchingScheme};

use anyhow::{bail, Result};

/// A level whose contraction keeps more than this fraction of its
/// vertices has stalled; the hierarchy stops there.
pub const STALL_FRACTION: f64 = 0.96;

/// Matched-fraction target of the matching scheme: below it, the bounded
/// two-hop fallback passes run (paper §4.2 "Matching").
pub const TWOHOP_TARGET: f64 = 0.75;

/// Default base seed for device coarsening. Deliberately **not** the
/// per-job seed: one graph + one scheme then yield one hierarchy, so the
/// engine's hierarchy cache serves every seed of a `run_matrix` sweep and
/// every repeat job on a pinned session graph. Initial mapping and
/// refinement still consume the job seed.
pub const DEFAULT_COARSEN_SALT: u64 = 0x5eed_c0a7_5a17_0001;

/// Which coarsening scheme a pipeline runs — the `coarsening=` knob of
/// the spec, config files, the wire protocol and the CLI.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Preference matching + bounded two-hop fallback (paper §4.2).
    Matching,
    /// Size-constrained label-propagation clustering.
    Cluster,
    /// Matching first; any level where matching stalls is redone with
    /// clustering. The default: identical to `Matching` on well-behaved
    /// graphs, robust on irregular ones.
    #[default]
    Auto,
}

impl SchemeKind {
    pub fn name(self) -> &'static str {
        match self {
            SchemeKind::Matching => "matching",
            SchemeKind::Cluster => "cluster",
            SchemeKind::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "matching" | "match" => Ok(SchemeKind::Matching),
            "cluster" | "lp" => Ok(SchemeKind::Cluster),
            "auto" => Ok(SchemeKind::Auto),
            other => bail!("unknown coarsening scheme `{other}` (matching|cluster|auto)"),
        }
    }
}

/// Every knob of the coarsening stage, shared by all four multilevel
/// pipelines (the former per-algo `coarsest_factor`/`match_rounds`
/// duplicates collapsed into one place).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CoarsenConfig {
    /// Scheme selection (see [`SchemeKind`]).
    pub scheme: SchemeKind,
    /// Preference-matching rounds per level.
    pub match_rounds: usize,
    /// Label-propagation rounds per level (cluster scheme).
    pub cluster_rounds: usize,
    /// Upper bound on two-hop fallback passes per level; each pass runs
    /// only while the matched fraction is below [`TWOHOP_TARGET`] and the
    /// previous pass made progress.
    pub max_twohop_passes: usize,
    /// Coarsen until `coarsest_factor · k` vertices (paper: 8)…
    pub coarsest_factor: usize,
    /// …but never below this floor (64 for the device pipelines, 400 for
    /// the serial integrated mapper, the `coarsest_size` of the
    /// bisection substrate).
    pub coarsest_min: usize,
    /// Base seed of the per-level coarsening streams (mixed through
    /// [`crate::rng::level_seed`]). Device pipelines default to
    /// [`DEFAULT_COARSEN_SALT`] instead of the job seed so the engine's
    /// hierarchy cache can serve seed sweeps; serial baselines pass the
    /// job seed explicitly at build time.
    pub salt: u64,
}

impl Default for CoarsenConfig {
    fn default() -> Self {
        CoarsenConfig::device()
    }
}

impl CoarsenConfig {
    /// The device-pipeline flavor (GPU-IM / Jet).
    pub fn device() -> Self {
        CoarsenConfig {
            scheme: SchemeKind::Auto,
            match_rounds: 8,
            cluster_rounds: 6,
            max_twohop_passes: 2,
            coarsest_factor: 8,
            coarsest_min: 64,
            salt: DEFAULT_COARSEN_SALT,
        }
    }

    /// The serial-baseline flavor with an explicit coarsest-size floor.
    pub fn serial(coarsest_min: usize) -> Self {
        CoarsenConfig { coarsest_min, ..CoarsenConfig::device() }
    }

    /// The level cap for a `k`-way partition/mapping.
    pub fn coarsest_for(&self, k: usize) -> usize {
        (self.coarsest_factor * k).max(self.coarsest_min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_kind_round_trips() {
        for kind in [SchemeKind::Matching, SchemeKind::Cluster, SchemeKind::Auto] {
            assert_eq!(SchemeKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(SchemeKind::from_name("lp").unwrap(), SchemeKind::Cluster);
        assert!(SchemeKind::from_name("bogus").is_err());
        assert_eq!(SchemeKind::default(), SchemeKind::Auto);
    }

    #[test]
    fn coarsest_respects_factor_and_floor() {
        let cfg = CoarsenConfig::device();
        assert_eq!(cfg.coarsest_for(64), 512);
        assert_eq!(cfg.coarsest_for(2), 64, "floor dominates for tiny k");
        assert_eq!(CoarsenConfig::serial(400).coarsest_for(8), 400);
    }
}
