//! Pluggable coarsening schemes.
//!
//! A [`CoarsenScheme`] turns one graph into a cluster map `V → [n_c]`
//! that the hierarchy builder contracts along. Two implementations:
//!
//! * [`MatchingScheme`] — the paper's §4.2 coarsening: device preference
//!   matching with the `expansion*²` rating, then bounded two-hop
//!   fallback passes (leaves/twins/relatives) while the matched fraction
//!   stays below [`super::TWOHOP_TARGET`];
//! * [`ClusterScheme`] — size-constrained label-propagation clustering
//!   for graphs where matchings stall (stars, hubs, highly irregular
//!   degree distributions): clusters may hold more than two vertices, so
//!   one level can shrink a star to a point where a matching removes at
//!   most half of it.
//!
//! Both run **device-style** (pool kernels computing per-vertex decisions,
//! a deterministic host pass applying them — the same split the two-hop
//! fallback always had) and expose a **serial oracle** (`step_serial`)
//! for the CPU baselines, which must stay pool-free.

use super::{CoarsenConfig, SchemeKind, TWOHOP_TARGET};
use crate::coarsen::{
    match_par::preference_matching, matched_fraction, matching_to_map, serial_hem,
    twohop::twohop_matching, Matching,
};
use crate::graph::{CsrGraph, EdgeList};
use crate::par::Pool;
use crate::refine::ConnBuf;
use crate::rng::{edge_noise, hash_u64};
use crate::{VWeight, Vertex};
use std::sync::atomic::{AtomicU32, Ordering};

/// The product of one coarsening level.
pub struct LevelStep {
    /// Cluster map `V → [nc]`.
    pub map: Vec<Vertex>,
    /// Number of coarse vertices.
    pub nc: usize,
    /// Fraction of vertices in non-singleton clusters, after every
    /// fallback pass ran (recorded into the phase breakdown).
    pub matched_fraction: f64,
    /// Wall milliseconds of the *serial host* passes inside this step
    /// (two-hop fallback, cluster apply sweep). The device timeline
    /// stalls on them, so the hierarchy builder charges this as device
    /// time on top of the ledger — the `timed_cpu!` accounting the old
    /// inline pipelines had.
    pub host_cpu_ms: f64,
}

/// One coarsening scheme. Implementations produce cluster maps only; the
/// hierarchy builder owns contraction (CAS-hash kernel or serial oracle),
/// stall detection and level bookkeeping.
pub trait CoarsenScheme: Sync {
    fn kind(&self) -> SchemeKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Device-style step: pool kernels plus a deterministic host pass.
    /// `el` is the extended CSR edge list of `g` (unused by the current
    /// schemes but part of the contract — contraction-adjacent kernels
    /// are edge-parallel).
    fn step(
        &self,
        pool: &Pool,
        g: &CsrGraph,
        el: &EdgeList,
        lmax: VWeight,
        seed: u64,
        cfg: &CoarsenConfig,
    ) -> LevelStep;

    /// Serial oracle step for the CPU baselines: no pool, no edge list.
    fn step_serial(&self, g: &CsrGraph, lmax: VWeight, seed: u64, cfg: &CoarsenConfig) -> LevelStep;
}

/// Preference matching + bounded two-hop fallback (paper §4.2).
pub struct MatchingScheme;

/// Size-constrained label-propagation clustering.
pub struct ClusterScheme;

/// The scheme singletons.
pub static MATCHING: MatchingScheme = MatchingScheme;
pub static CLUSTER: ClusterScheme = ClusterScheme;

/// The scheme for a concrete kind. `Auto` resolves to [`MatchingScheme`]
/// as its first choice; the per-level stall fallback to [`ClusterScheme`]
/// lives in the hierarchy builder.
pub fn scheme(kind: SchemeKind) -> &'static dyn CoarsenScheme {
    match kind {
        SchemeKind::Cluster => &CLUSTER,
        SchemeKind::Matching | SchemeKind::Auto => &MATCHING,
    }
}

/// Iterate the two-hop fallback (bounded) and lower the matching to a
/// cluster map. Each pass runs only while the matched fraction is below
/// [`TWOHOP_TARGET`] and the previous pass still matched someone — the
/// old pipelines ran at most one pass even when it left the matching
/// far short of the target.
fn finish_matching(
    g: &CsrGraph,
    mut mate: Matching,
    lmax: VWeight,
    cfg: &CoarsenConfig,
) -> LevelStep {
    let host_start = std::time::Instant::now();
    let mut frac = matched_fraction(&mate);
    let mut passes = 0;
    while frac < TWOHOP_TARGET && passes < cfg.max_twohop_passes {
        if twohop_matching(g, &mut mate, lmax) == 0 {
            break;
        }
        frac = matched_fraction(&mate);
        passes += 1;
    }
    let host_cpu_ms = host_start.elapsed().as_secs_f64() * 1e3;
    let (map, nc) = matching_to_map(&mate);
    LevelStep { map, nc, matched_fraction: frac, host_cpu_ms }
}

impl CoarsenScheme for MatchingScheme {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Matching
    }

    fn step(
        &self,
        pool: &Pool,
        g: &CsrGraph,
        _el: &EdgeList,
        lmax: VWeight,
        seed: u64,
        cfg: &CoarsenConfig,
    ) -> LevelStep {
        let mate = preference_matching(g, pool, lmax, seed, cfg.match_rounds);
        finish_matching(g, mate, lmax, cfg)
    }

    fn step_serial(&self, g: &CsrGraph, lmax: VWeight, seed: u64, cfg: &CoarsenConfig) -> LevelStep {
        let mate = serial_hem(g, lmax, seed);
        finish_matching(g, mate, lmax, cfg)
    }
}

const NO_MOVE: u32 = u32::MAX;

/// Aggregate a vertex's edge weight per neighboring cluster label and
/// visit each `(label, total)` pair once. Low-degree vertices use the
/// allocation-light [`ConnBuf`] linear scan; past its stack capacity —
/// hubs can see up to `deg` *distinct* labels, which would make the scan
/// O(deg²) on exactly the irregular graphs the cluster scheme targets —
/// the pairs are sorted by label and merged in O(deg log deg).
fn for_each_label_weight(
    g: &CsrGraph,
    labels: &[Vertex],
    v: usize,
    mut visit: impl FnMut(Vertex, f64),
) {
    let (nbrs, ws) = g.neighbors_w(v as Vertex);
    if nbrs.len() <= ConnBuf::STACK {
        let mut conn = ConnBuf::new();
        for (&u, &w) in nbrs.iter().zip(ws) {
            conn.add(labels[u as usize], w);
        }
        conn.for_each(visit);
        return;
    }
    let mut pairs: Vec<(Vertex, f64)> =
        nbrs.iter().zip(ws).map(|(&u, &w)| (labels[u as usize], w)).collect();
    pairs.sort_unstable_by_key(|&(l, _)| l);
    let mut i = 0;
    while i < pairs.len() {
        let label = pairs[i].0;
        let mut total = 0.0;
        while i < pairs.len() && pairs[i].0 == label {
            total += pairs[i].1;
            i += 1;
        }
        visit(label, total);
    }
}

/// Size-constrained label propagation, shared by the device and serial
/// entry points (the device variant runs the per-vertex label-choice
/// kernel on the pool; the apply pass is a deterministic host sweep in
/// vertex order either way, so results are identical across thread
/// counts).
///
/// Each round, half the vertices (a per-round hash parity, preventing
/// symmetric label swaps) pick the neighboring cluster they are most
/// strongly connected to — provided joining keeps the cluster below
/// `lmax` and beats their connection to their current cluster.
fn cluster_core(
    g: &CsrGraph,
    lmax: VWeight,
    seed: u64,
    rounds: usize,
    pool: Option<&Pool>,
) -> LevelStep {
    let n = g.n();
    if n == 0 {
        return LevelStep { map: Vec::new(), nc: 0, matched_fraction: 0.0, host_cpu_ms: 0.0 };
    }
    let mut host_cpu = std::time::Duration::ZERO;
    let mut labels: Vec<Vertex> = (0..n as Vertex).collect();
    let mut cw: Vec<VWeight> = g.vw.clone();
    let desired: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(NO_MOVE)).collect();

    for round in 0..rounds.max(1) {
        let rseed = hash_u64(seed ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        {
            let labels = &labels;
            let cw = &cw;
            let desired = &desired;
            let choose = move |v: usize| {
                // relaxed: `desired[v]` is owned by unit `v` during this
                // kernel; the host reads it after the barrier.
                desired[v].store(NO_MOVE, Ordering::Relaxed);
                // Parity gate: only half the vertices move per round, so
                // two singletons can never swap labels within one round.
                if hash_u64(rseed ^ v as u64) & 1 != 0 {
                    return;
                }
                let own_label = labels[v];
                let mut own = 0.0f64;
                let mut best: Option<(f64, Vertex)> = None;
                for_each_label_weight(g, labels, v, |label, w| {
                    if label == own_label {
                        own = w;
                        return;
                    }
                    // Capacity pre-check against last round's weights;
                    // re-checked exactly in the apply pass.
                    if cw[label as usize] + g.vw[v] > lmax {
                        return;
                    }
                    let r = w + 1e-12 * edge_noise(v as Vertex, label, rseed);
                    if best.map(|(br, bl)| r > br || (r == br && label < bl)).unwrap_or(true) {
                        best = Some((r, label));
                    }
                });
                if let Some((r, label)) = best {
                    if r > own {
                        // relaxed: unit-owned slot (see above).
                        desired[v].store(label, Ordering::Relaxed);
                    }
                }
            };
            let _k = crate::par::ledger::kernel("multilevel/scheme:lp_choose");
            match pool {
                Some(p) => p.parallel_for(n, choose),
                None => (0..n).for_each(choose),
            }
        }
        // Apply in vertex order with exact running cluster weights —
        // deterministic under any pool size. A serial host pass: its
        // wall time is charged to the device timeline by the builder.
        let apply_start = std::time::Instant::now();
        let mut moved = 0usize;
        for v in 0..n {
            // relaxed: host-side read after the kernel barrier.
            let target = desired[v].load(Ordering::Relaxed);
            if target == NO_MOVE || target == labels[v] {
                continue;
            }
            if cw[target as usize] + g.vw[v] > lmax {
                continue;
            }
            cw[labels[v] as usize] -= g.vw[v];
            cw[target as usize] += g.vw[v];
            labels[v] = target;
            moved += 1;
        }
        host_cpu += apply_start.elapsed();
        if moved == 0 {
            break;
        }
    }

    // Dense relabel in vertex order + cluster sizes.
    let relabel_start = std::time::Instant::now();
    let mut remap = vec![u32::MAX; n];
    let mut map = vec![0 as Vertex; n];
    let mut nc = 0u32;
    for v in 0..n {
        let l = labels[v] as usize;
        if remap[l] == u32::MAX {
            remap[l] = nc;
            nc += 1;
        }
        map[v] = remap[l];
    }
    let mut size = vec![0u32; nc as usize];
    for &c in &map {
        size[c as usize] += 1;
    }
    let grouped = map.iter().filter(|&&c| size[c as usize] >= 2).count();
    host_cpu += relabel_start.elapsed();
    LevelStep {
        map,
        nc: nc as usize,
        matched_fraction: grouped as f64 / n as f64,
        host_cpu_ms: host_cpu.as_secs_f64() * 1e3,
    }
}

impl CoarsenScheme for ClusterScheme {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Cluster
    }

    fn step(
        &self,
        pool: &Pool,
        g: &CsrGraph,
        _el: &EdgeList,
        lmax: VWeight,
        seed: u64,
        cfg: &CoarsenConfig,
    ) -> LevelStep {
        cluster_core(g, lmax, seed, cfg.cluster_rounds, Some(pool))
    }

    fn step_serial(&self, g: &CsrGraph, lmax: VWeight, seed: u64, cfg: &CoarsenConfig) -> LevelStep {
        cluster_core(g, lmax, seed, cfg.cluster_rounds, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn check_map(step: &LevelStep, n: usize) {
        assert_eq!(step.map.len(), n);
        let mut seen = vec![false; step.nc];
        for &c in &step.map {
            seen[c as usize] = true;
        }
        assert!(seen.into_iter().all(|s| s), "cluster map not surjective");
        assert!((0.0..=1.0).contains(&step.matched_fraction));
    }

    /// A forest of stars: preference matching pairs at most (hub, one
    /// leaf) per star, so the matched fraction stays low without the
    /// two-hop / cluster machinery.
    fn star_forest(stars: u32, leaves: u32) -> CsrGraph {
        let n = stars * (leaves + 1);
        let mut b = GraphBuilder::new(n as usize);
        for s in 0..stars {
            let hub = s * (leaves + 1);
            for i in 1..=leaves {
                b.add_edge(hub, hub + i, 1.0);
            }
        }
        b.build()
    }

    #[test]
    fn matching_step_device_matches_serial_shape() {
        let g = gen::grid2d(24, 24, false);
        let cfg = CoarsenConfig::device();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let dev = MATCHING.step(&pool, &g, &el, i64::MAX, 7, &cfg);
        check_map(&dev, g.n());
        let ser = MATCHING.step_serial(&g, i64::MAX, 7, &cfg);
        check_map(&ser, g.n());
        assert!(dev.matched_fraction > 0.6);
        assert!(ser.matched_fraction > 0.6);
    }

    #[test]
    fn bounded_twohop_fallback_iterates_until_target_or_dry() {
        let g = star_forest(8, 9);
        let pool = Pool::new(1);
        let el = EdgeList::build(&g);
        let none = CoarsenConfig { max_twohop_passes: 0, ..CoarsenConfig::device() };
        let some = CoarsenConfig { max_twohop_passes: 2, ..CoarsenConfig::device() };
        let bare = MATCHING.step(&pool, &g, &el, i64::MAX, 3, &none);
        let full = MATCHING.step(&pool, &g, &el, i64::MAX, 3, &some);
        assert!(
            full.matched_fraction > bare.matched_fraction,
            "fallback passes must raise the matched fraction ({} vs {})",
            full.matched_fraction,
            bare.matched_fraction
        );
        assert!(full.nc < bare.nc);
        check_map(&full, g.n());
    }

    #[test]
    fn cluster_step_deterministic_across_thread_counts() {
        let g = gen::rgg(1_500, 0.06, 9);
        let cfg = CoarsenConfig::device();
        let el = EdgeList::build(&g);
        let one = CLUSTER.step(&Pool::new(1), &g, &el, i64::MAX, 5, &cfg);
        let four = CLUSTER.step(&Pool::new(4), &g, &el, i64::MAX, 5, &cfg);
        assert_eq!(one.map, four.map);
        assert_eq!(one.nc, four.nc);
        let serial = CLUSTER.step_serial(&g, i64::MAX, 5, &cfg);
        assert_eq!(one.map, serial.map, "serial oracle diverges from the device step");
        check_map(&one, g.n());
    }

    #[test]
    fn cluster_respects_weight_cap() {
        let mut g = gen::grid2d(8, 8, false);
        for v in 0..g.n() {
            g.vw[v] = 1 + (v % 4) as i64;
        }
        let cap = 6;
        let step = CLUSTER.step_serial(&g, cap, 11, &CoarsenConfig::device());
        let mut cw = vec![0i64; step.nc];
        for v in 0..g.n() {
            cw[step.map[v] as usize] += g.vw[v];
        }
        // Singletons heavier than the cap are allowed (they never moved);
        // multi-vertex clusters must respect it.
        let mut size = vec![0u32; step.nc];
        for &c in &step.map {
            size[c as usize] += 1;
        }
        for c in 0..step.nc {
            if size[c] >= 2 {
                assert!(cw[c] <= cap, "cluster {c} weight {} over cap", cw[c]);
            }
        }
    }

    #[test]
    fn cluster_shrinks_star_forest_where_matching_stalls() {
        let g = star_forest(10, 12);
        let cfg = CoarsenConfig::device();
        let matching = MATCHING.step_serial(&g, i64::MAX, 3, &CoarsenConfig {
            max_twohop_passes: 0,
            ..cfg.clone()
        });
        let cluster = CLUSTER.step_serial(&g, i64::MAX, 3, &cfg);
        assert!(
            cluster.nc < matching.nc,
            "cluster {} should out-shrink stalled matching {}",
            cluster.nc,
            matching.nc
        );
        check_map(&cluster, g.n());
    }
}
