//! The coarse-graph hierarchy: the one place the multilevel loop lives.
//!
//! [`CoarseHierarchy::build`] (device kernels + CAS-hash contraction)
//! and [`CoarseHierarchy::build_serial`] (CPU-baseline oracles) run the
//! configured [`super::CoarsenScheme`] level by level, with stall
//! detection ([`super::STALL_FRACTION`]), per-level cancellation
//! boundaries, phase timing and the modeled H2D upload charged exactly
//! once per build. [`CoarseHierarchy::uncoarsen`] /
//! [`CoarseHierarchy::uncoarsen_serial`] drive projection + per-level
//! refinement over the caller's closure (which shares one
//! [`crate::refine::RefineWorkspace`] across every level).
//!
//! A hierarchy is a pure function of `(graph, CoarsenConfig, BuildParams)`
//! — it never sees the job seed — so the engine caches instances per
//! session graph and repeat jobs skip straight to initial mapping.

use super::scheme::{LevelStep, CLUSTER};
use super::{CoarsenConfig, CoarsenScheme, SchemeKind, STALL_FRACTION};
use crate::cancel::CancelToken;
use crate::coarsen::{contract_cas::contract_cas, contract_serial};
use crate::graph::{CsrGraph, EdgeList};
use crate::metrics::{Phase, PhaseBreakdown};
use crate::par::Pool;
use crate::{Block, VWeight, Vertex};
use std::sync::Arc;

/// What to build: the level cap, the pair/cluster weight cap, and the
/// base seed of the coarsening streams.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BuildParams {
    /// Stop once the coarsest graph has at most this many vertices.
    pub coarsest: usize,
    /// Maximum matched-pair / cluster weight (`L_max`).
    pub lmax: VWeight,
    /// Base seed, mixed per level via [`crate::rng::level_seed`].
    pub seed: u64,
}

/// Everything the engine needs to build — or find in its cache — the
/// hierarchy a solver is about to consume. Equality over the full
/// parameter set is the cache key (together with graph identity).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HierarchyParams {
    pub cfg: CoarsenConfig,
    pub build: BuildParams,
}

impl HierarchyParams {
    /// The parameters of a device pipeline mapping `g` onto `k` PEs with
    /// imbalance `eps` — exactly what `gpu_im`/`jet_partition` build when
    /// handed no prebuilt hierarchy.
    pub fn device(g: &CsrGraph, k: usize, eps: f64, cfg: CoarsenConfig) -> HierarchyParams {
        let lmax = crate::partition::l_max(g.total_vweight(), k, eps);
        let build = BuildParams { coarsest: cfg.coarsest_for(k), lmax, seed: cfg.salt };
        HierarchyParams { cfg, build }
    }
}

/// A hierarchy as handed to a solver: the instance plus whether it came
/// out of the engine cache (`cached` jobs must not re-account the build's
/// phase times — an earlier job already paid them).
#[derive(Clone)]
pub struct HierarchyHandle {
    pub hier: Arc<CoarseHierarchy>,
    pub cached: bool,
}

/// The multilevel hierarchy: `graphs[0]` is the input graph, each
/// `maps[i]` contracts `graphs[i]` onto `graphs[i + 1]`.
pub struct CoarseHierarchy {
    graphs: Vec<Arc<CsrGraph>>,
    /// Extended CSR edge lists, parallel to `graphs` (device builds
    /// only; empty for serial builds).
    edge_lists: Vec<EdgeList>,
    maps: Vec<Vec<Vertex>>,
    matched: Vec<f64>,
    stalled: bool,
    scheme: SchemeKind,
    params: BuildParams,
    phases: PhaseBreakdown,
}

/// Time `$e` into `$pb` under `$ph`, or just run it when no breakdown is
/// being collected.
macro_rules! timed_opt {
    ($phases:expr, $ph:expr, $e:expr) => {
        match $phases.as_deref_mut() {
            Some(p) => p.time($ph, || $e),
            None => $e,
        }
    };
}

/// One level of the configured scheme, with the `Auto` stall fallback:
/// when matching barely shrinks the graph, the level is redone with the
/// cluster scheme before the builder gives up on it.
fn run_level(
    scheme_kind: SchemeKind,
    pool: &Pool,
    g: &CsrGraph,
    el: &EdgeList,
    lmax: VWeight,
    seed: u64,
    cfg: &CoarsenConfig,
) -> LevelStep {
    let first: &dyn CoarsenScheme = super::scheme(scheme_kind);
    let step = first.step(pool, g, el, lmax, seed, cfg);
    if scheme_kind == SchemeKind::Auto && level_stalled(step.nc, g.n()) {
        return CLUSTER.step(pool, g, el, lmax, seed, cfg);
    }
    step
}

fn run_level_serial(
    scheme_kind: SchemeKind,
    g: &CsrGraph,
    lmax: VWeight,
    seed: u64,
    cfg: &CoarsenConfig,
) -> LevelStep {
    let first: &dyn CoarsenScheme = super::scheme(scheme_kind);
    let step = first.step_serial(g, lmax, seed, cfg);
    if scheme_kind == SchemeKind::Auto && level_stalled(step.nc, g.n()) {
        return CLUSTER.step_serial(g, lmax, seed, cfg);
    }
    step
}

fn level_stalled(nc: usize, n: usize) -> bool {
    nc as f64 > n as f64 * STALL_FRACTION
}

impl CoarseHierarchy {
    /// Build with device kernels (preference matching / cluster LP +
    /// CAS-hash contraction). Charges the modeled H2D upload of the
    /// input graph once, times every level into both `phases` (when
    /// given) and the hierarchy's own breakdown (served to later cache
    /// hits for inspection, never re-merged), and polls `cancel` at
    /// every level boundary — `None` means the build was cancelled.
    pub fn build(
        pool: &Pool,
        g: Arc<CsrGraph>,
        params: &BuildParams,
        cfg: &CoarsenConfig,
        cancel: &CancelToken,
        mut phases: Option<&mut PhaseBreakdown>,
    ) -> Option<CoarseHierarchy> {
        let mut pb = PhaseBreakdown::default();
        let first_el = pb.time(Phase::Misc, || {
            // Modeled H2D upload of the CSR graph (xadj + adj + weights);
            // paid once per hierarchy, not once per job.
            crate::par::ledger::charge(3, (g.n() + 2 * g.num_directed()) as u64);
            EdgeList::build_par(pool, &g)
        });
        let mut graphs = vec![g];
        let mut edge_lists = vec![first_el];
        let mut maps: Vec<Vec<Vertex>> = Vec::new();
        let mut matched: Vec<f64> = Vec::new();
        let mut stalled = false;
        let mut level = 0u64;
        while graphs.last().unwrap().n() > params.coarsest {
            // Level cancellation boundary: the engine discards the job's
            // result, so the partial build is simply dropped.
            if cancel.is_cancelled() {
                if let Some(ph) = phases.as_deref_mut() {
                    ph.merge(&pb);
                }
                return None;
            }
            // Fault plane: `hierarchy_build` (global plane, one check per
            // level; panics into the engine's per-job fence).
            if crate::fault::fire_global(crate::fault::FaultPoint::HierarchyBuild) {
                panic!("{}", crate::fault::failure(crate::fault::FaultPoint::HierarchyBuild));
            }
            let cur = graphs.last().unwrap().clone();
            // Anchor this level's graph for the device session: the first
            // kernel launch against it uploads the CSR arrays once; the
            // scope keeps them resident across the matching rounds and the
            // contraction gather of this level.
            let _scope = crate::runtime::device::graph_scope(&cur);
            let lseed = crate::rng::level_seed(params.seed, level);
            let next = {
                let el = edge_lists.last().unwrap();
                let step = pb.time(Phase::Coarsening, || {
                    run_level(cfg.scheme, pool, &cur, el, params.lmax, lseed, cfg)
                });
                // The step's serial host passes (two-hop fallback, cluster
                // apply sweep) stall the device timeline: charge their wall
                // time as device time, like the old `timed_cpu!` blocks
                // (the ledger only sees the pool kernels).
                pb.add(
                    Phase::Coarsening,
                    crate::par::cost::Measurement {
                        device_ms: step.host_cpu_ms,
                        host_ms: 0.0,
                        ledger: Default::default(),
                    },
                );
                if level_stalled(step.nc, cur.n()) {
                    None
                } else {
                    let coarse = pb.time(Phase::Contraction, || {
                        contract_cas(pool, &cur, el, &step.map, step.nc)
                    });
                    let coarse_el = pb.time(Phase::Misc, || EdgeList::build_par(pool, &coarse));
                    Some((step, coarse, coarse_el))
                }
            };
            let Some((step, coarse, coarse_el)) = next else {
                stalled = true;
                break;
            };
            pb.record_matched_fraction(step.matched_fraction);
            matched.push(step.matched_fraction);
            maps.push(step.map);
            graphs.push(Arc::new(coarse));
            edge_lists.push(coarse_el);
            level += 1;
        }
        if let Some(ph) = phases.as_deref_mut() {
            ph.merge(&pb);
        }
        Some(CoarseHierarchy {
            graphs,
            edge_lists,
            maps,
            matched,
            stalled,
            scheme: cfg.scheme,
            params: params.clone(),
            phases: pb,
        })
    }

    /// Resolve the hierarchy a device pipeline runs on: `prebuilt` (the
    /// engine's cache) when supplied — asserted to belong to `g` — or an
    /// inline build parked in `owned`. `None` means the build was
    /// cancelled. This is the one place the pipelines derive
    /// `BuildParams` from a [`CoarsenConfig`], so the engine's cache key
    /// ([`HierarchyParams::device`]) can never diverge from what an
    /// inline build produces.
    #[allow(clippy::too_many_arguments)]
    pub fn resolve<'a>(
        prebuilt: Option<&'a CoarseHierarchy>,
        owned: &'a mut Option<CoarseHierarchy>,
        pool: &Pool,
        g: &CsrGraph,
        k: usize,
        lmax: VWeight,
        cfg: &CoarsenConfig,
        cancel: &CancelToken,
        phases: Option<&mut PhaseBreakdown>,
    ) -> Option<&'a CoarseHierarchy> {
        if let Some(h) = prebuilt {
            debug_assert_eq!(h.finest().n(), g.n(), "prebuilt hierarchy for a different graph");
            return Some(h);
        }
        let params = BuildParams { coarsest: cfg.coarsest_for(k), lmax, seed: cfg.salt };
        *owned = Some(Self::build(pool, Arc::new(g.clone()), &params, cfg, cancel, phases)?);
        owned.as_ref()
    }

    /// Build with the serial oracles (CPU baselines): no pool, no edge
    /// lists, no device-ledger charges. `None` means cancelled.
    pub fn build_serial(
        g: &CsrGraph,
        params: &BuildParams,
        cfg: &CoarsenConfig,
        cancel: &CancelToken,
    ) -> Option<CoarseHierarchy> {
        let mut graphs = vec![Arc::new(g.clone())];
        let mut maps: Vec<Vec<Vertex>> = Vec::new();
        let mut matched: Vec<f64> = Vec::new();
        let mut stalled = false;
        let mut level = 0u64;
        while graphs.last().unwrap().n() > params.coarsest {
            if cancel.is_cancelled() {
                return None;
            }
            // Fault plane: `hierarchy_build`, per level (see `build`).
            if crate::fault::fire_global(crate::fault::FaultPoint::HierarchyBuild) {
                panic!("{}", crate::fault::failure(crate::fault::FaultPoint::HierarchyBuild));
            }
            let cur = graphs.last().unwrap().clone();
            let lseed = crate::rng::level_seed(params.seed, level);
            let step = run_level_serial(cfg.scheme, &cur, params.lmax, lseed, cfg);
            if level_stalled(step.nc, cur.n()) {
                stalled = true;
                break;
            }
            let coarse = contract_serial(&cur, &step.map, step.nc);
            matched.push(step.matched_fraction);
            maps.push(step.map);
            graphs.push(Arc::new(coarse));
            level += 1;
        }
        Some(CoarseHierarchy {
            graphs,
            edge_lists: Vec::new(),
            maps,
            matched,
            stalled,
            scheme: cfg.scheme,
            params: params.clone(),
            phases: PhaseBreakdown::default(),
        })
    }

    /// Number of coarsening levels (contractions).
    pub fn levels(&self) -> usize {
        self.maps.len()
    }

    /// The input graph.
    pub fn finest(&self) -> &CsrGraph {
        &self.graphs[0]
    }

    /// The coarsest graph (equal to [`CoarseHierarchy::finest`] when no
    /// level was built).
    pub fn coarsest(&self) -> &CsrGraph {
        self.graphs.last().unwrap()
    }

    /// The coarsest graph's edge list. Panics on serial builds.
    pub fn coarsest_el(&self) -> &EdgeList {
        self.edge_lists.last().expect("edge lists exist on device-built hierarchies")
    }

    /// The graph at `level` (0 = finest, `levels()` = coarsest).
    pub fn graph(&self, level: usize) -> &CsrGraph {
        &self.graphs[level]
    }

    /// The shared handle to the graph at `level` — the identity the
    /// device graph store keys its uploads on. Pass this to
    /// [`crate::runtime::device::graph_scope`] to anchor the level for a
    /// device session: because the hierarchy (and the engine cache above
    /// it) owns the `Arc` for its whole lifetime, repeat jobs, seed
    /// sweeps and warm remaps on the same session graph re-anchor the
    /// *same* allocation and hit the device-resident copy instead of
    /// re-uploading.
    pub fn graph_arc(&self, level: usize) -> &Arc<CsrGraph> {
        &self.graphs[level]
    }

    /// The contraction map from `level` onto `level + 1`.
    pub fn map(&self, level: usize) -> &[Vertex] {
        &self.maps[level]
    }

    /// Whether this hierarchy was built with device kernels (and thus
    /// carries edge lists).
    pub fn is_device(&self) -> bool {
        !self.edge_lists.is_empty()
    }

    /// True when the last attempted level barely shrank and the builder
    /// stopped early.
    pub fn stalled(&self) -> bool {
        self.stalled
    }

    pub fn scheme(&self) -> SchemeKind {
        self.scheme
    }

    pub fn params(&self) -> &BuildParams {
        &self.params
    }

    /// The build's own phase breakdown (Coarsening / Contraction / Misc
    /// and per-level matched fractions). Jobs that triggered the build
    /// merge it into their outcome; cache hits do not.
    pub fn phases(&self) -> &PhaseBreakdown {
        &self.phases
    }

    /// Final matched fraction per level, finest first.
    pub fn matched_fractions(&self) -> &[f64] {
        &self.matched
    }

    /// Check the structural invariants every hierarchy must satisfy:
    /// each level strictly shrinks, each map is a surjection onto the
    /// coarser vertex set, and contraction preserves total vertex weight.
    pub fn validate(&self) -> Result<(), String> {
        for lev in 0..self.maps.len() {
            let fine = &self.graphs[lev];
            let coarse = &self.graphs[lev + 1];
            let map = &self.maps[lev];
            if map.len() != fine.n() {
                return Err(format!("level {lev}: map len {} != n {}", map.len(), fine.n()));
            }
            if coarse.n() >= fine.n() {
                return Err(format!(
                    "level {lev}: does not strictly shrink ({} -> {})",
                    fine.n(),
                    coarse.n()
                ));
            }
            let mut seen = vec![false; coarse.n()];
            for (v, &c) in map.iter().enumerate() {
                let Some(slot) = seen.get_mut(c as usize) else {
                    return Err(format!("level {lev}: map[{v}] = {c} out of range {}", coarse.n()));
                };
                *slot = true;
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("level {lev}: map not surjective onto [{}]", coarse.n()));
            }
            if fine.total_vweight() != coarse.total_vweight() {
                return Err(format!(
                    "level {lev}: vertex weight not preserved ({} -> {})",
                    fine.total_vweight(),
                    coarse.total_vweight()
                ));
            }
        }
        if self.is_device() && self.edge_lists.len() != self.graphs.len() {
            return Err("edge lists not parallel to graphs".into());
        }
        Ok(())
    }

    /// Device-style uncoarsening: refine the coarsest solution, then for
    /// every finer level project it down (parallel kernel, timed as
    /// Uncontraction) and refine again (timed as Refine + Rebalance).
    /// `refine(level, graph, edge_list, part)` receives the graph index
    /// (`levels()` for the coarsest, 0 for the finest) and is expected to
    /// check its own cancellation token — projection always completes so
    /// cancelled runs still return a structurally valid assignment.
    pub fn uncoarsen(
        &self,
        pool: &Pool,
        mut part: Vec<Block>,
        mut phases: Option<&mut PhaseBreakdown>,
        mut refine: impl FnMut(usize, &CsrGraph, &EdgeList, &mut Vec<Block>),
    ) -> Vec<Block> {
        assert!(self.is_device(), "uncoarsen() needs a device-built hierarchy");
        debug_assert_eq!(part.len(), self.coarsest().n());
        let coarsest_level = self.maps.len();
        timed_opt!(phases, Phase::RefineRebalance, {
            // Anchor each level's graph so the refinement kernels reuse
            // the device-resident copy from the build (or upload once).
            let _scope = crate::runtime::device::graph_scope(self.graph_arc(coarsest_level));
            refine(coarsest_level, self.coarsest(), self.coarsest_el(), &mut part)
        });
        for lev in (0..coarsest_level).rev() {
            let fine = &self.graphs[lev];
            let map = &self.maps[lev];
            let mut fine_part = vec![0 as Block; fine.n()];
            timed_opt!(phases, Phase::Uncontraction, {
                let fp = crate::par::SharedMut::new(&mut fine_part);
                let _k = crate::par::ledger::kernel("multilevel/hierarchy:project");
                // SAFETY: unit `v` writes only slot `v`; `part`/`map` are
                // read-only in this kernel.
                pool.parallel_for(fine.n(), |v| unsafe {
                    fp.write(v, part[map[v] as usize]);
                });
            });
            timed_opt!(phases, Phase::RefineRebalance, {
                let _scope = crate::runtime::device::graph_scope(self.graph_arc(lev));
                refine(lev, fine, &self.edge_lists[lev], &mut fine_part)
            });
            part = fine_part;
        }
        part
    }

    /// Serial uncoarsening for the CPU baselines: identical contract,
    /// minus the pool, the edge lists and the phase timing.
    pub fn uncoarsen_serial(
        &self,
        mut part: Vec<Block>,
        mut refine: impl FnMut(usize, &CsrGraph, &mut Vec<Block>),
    ) -> Vec<Block> {
        debug_assert_eq!(part.len(), self.coarsest().n());
        let coarsest_level = self.maps.len();
        refine(coarsest_level, self.coarsest(), &mut part);
        for lev in (0..coarsest_level).rev() {
            let fine = &self.graphs[lev];
            let map = &self.maps[lev];
            let mut fine_part = vec![0 as Block; fine.n()];
            for v in 0..fine.n() {
                fine_part[v] = part[map[v] as usize];
            }
            refine(lev, fine, &mut fine_part);
            part = fine_part;
        }
        part
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn params(coarsest: usize) -> BuildParams {
        BuildParams { coarsest, lmax: i64::MAX, seed: 42 }
    }

    #[test]
    fn device_build_validates_and_reaches_target() {
        let g = Arc::new(gen::rgg(3_000, 0.05, 4));
        let pool = Pool::new(2);
        let cfg = CoarsenConfig::device();
        let h = CoarseHierarchy::build(&pool, g.clone(), &params(200), &cfg, &CancelToken::new(), None)
            .unwrap();
        h.validate().unwrap();
        assert!(h.levels() >= 1);
        assert!(h.is_device());
        assert!(h.coarsest().n() <= 200 || h.stalled());
        assert_eq!(h.finest().n(), g.n());
        assert_eq!(h.matched_fractions().len(), h.levels());
        // The builder's breakdown covers the build phases.
        assert!(h.phases().device_ms(Phase::Coarsening) > 0.0);
        assert!(h.phases().device_ms(Phase::Contraction) > 0.0);
    }

    #[test]
    fn serial_build_validates() {
        let g = gen::grid2d(40, 40, false);
        let cfg = CoarsenConfig::serial(160);
        let h = CoarseHierarchy::build_serial(&g, &params(160), &cfg, &CancelToken::new()).unwrap();
        h.validate().unwrap();
        assert!(!h.is_device());
        assert!(h.levels() >= 1);
    }

    #[test]
    fn build_is_deterministic() {
        let g = Arc::new(gen::rgg(2_000, 0.05, 8));
        let cfg = CoarsenConfig::device();
        let pool = Pool::new(1);
        let a = CoarseHierarchy::build(&pool, g.clone(), &params(100), &cfg, &CancelToken::new(), None)
            .unwrap();
        let b = CoarseHierarchy::build(&pool, g.clone(), &params(100), &cfg, &CancelToken::new(), None)
            .unwrap();
        assert_eq!(a.levels(), b.levels());
        for lev in 0..a.levels() {
            assert_eq!(a.map(lev), b.map(lev), "level {lev} maps diverge");
            assert_eq!(a.graph(lev + 1).xadj, b.graph(lev + 1).xadj);
        }
    }

    #[test]
    fn cancelled_build_returns_none() {
        let g = Arc::new(gen::grid2d(40, 40, false));
        let cancelled = CancelToken::new();
        cancelled.cancel();
        let pool = Pool::new(1);
        assert!(CoarseHierarchy::build(
            &pool,
            g,
            &params(64),
            &CoarsenConfig::device(),
            &cancelled,
            None
        )
        .is_none());
    }

    #[test]
    fn tiny_graph_builds_zero_levels() {
        let g = Arc::new(gen::grid2d(4, 4, false));
        let pool = Pool::new(1);
        let h = CoarseHierarchy::build(
            &pool,
            g.clone(),
            &params(64),
            &CoarsenConfig::device(),
            &CancelToken::new(),
            None,
        )
        .unwrap();
        assert_eq!(h.levels(), 0);
        assert_eq!(h.coarsest().n(), g.n());
        // Uncoarsening degenerates to one refine call on the input graph.
        let out = h.uncoarsen(&pool, vec![0; g.n()], None, |lev, gl, _el, part| {
            assert_eq!(lev, 0);
            assert_eq!(gl.n(), part.len());
        });
        assert_eq!(out.len(), g.n());
    }

    #[test]
    fn uncoarsen_projects_through_every_level() {
        let g = Arc::new(gen::grid2d(30, 30, false));
        let pool = Pool::new(2);
        let h = CoarseHierarchy::build(
            &pool,
            g.clone(),
            &params(64),
            &CoarsenConfig::device(),
            &CancelToken::new(),
            None,
        )
        .unwrap();
        // Label the coarsest graph by parity; projection must carry the
        // labels down exactly along the composed maps.
        let part: Vec<Block> = (0..h.coarsest().n() as Block).map(|c| c % 2).collect();
        let mut calls = 0usize;
        let out = h.uncoarsen(&pool, part.clone(), None, |_lev, _g, _el, _p| calls += 1);
        assert_eq!(calls, h.levels() + 1);
        // Compose the maps manually.
        let mut expect: Vec<Block> = part;
        for lev in (0..h.levels()).rev() {
            let map = h.map(lev);
            let next: Vec<Block> = (0..h.graph(lev).n()).map(|v| expect[map[v] as usize]).collect();
            expect = next;
        }
        assert_eq!(out, expect);
        // Serial driver agrees (device hierarchy still projects fine).
        let ser = h.uncoarsen_serial(
            (0..h.coarsest().n() as Block).map(|c| c % 2).collect(),
            |_lev, _g, _p| {},
        );
        assert_eq!(ser, expect);
    }
}
