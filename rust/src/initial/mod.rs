//! Serial multilevel graph partitioning ("kaffpa-lite").
//!
//! The substrate every mapping algorithm builds on: SharedMap-like and
//! IntMap-like baselines call it directly; GPU-HM and GPU-IM use it for
//! the coarsest graphs (the paper keeps initial partitioning on the CPU —
//! §4.2 "Initial Partitioning").
//!
//! Pipeline: serial coarsening through the unified multilevel subsystem
//! → greedy graph growing (multiple tries) → FM refinement during
//! uncoarsening; k-way via recursive bisection with proportional target
//! weights.

use crate::graph::CsrGraph;
use crate::multilevel::{BuildParams, CoarsenConfig, CoarseHierarchy};
use crate::refine::fm2::{fm2_refine, Fm2Config};
use crate::rng::Rng;
use crate::{Block, VWeight, Vertex};

/// Multilevel bisection configuration.
#[derive(Clone, Debug)]
pub struct MlConfig {
    /// Coarsening stage; `coarsen.coarsest_min` is the stop size (the
    /// former `coarsest_size`).
    pub coarsen: CoarsenConfig,
    /// Initial-partition attempts (keep the best).
    pub tries: usize,
    /// FM passes per level.
    pub fm_passes: usize,
    /// Stall limit within an FM pass.
    pub fm_stall: usize,
}

impl Default for MlConfig {
    fn default() -> Self {
        MlConfig { coarsen: CoarsenConfig::serial(160), tries: 4, fm_passes: 3, fm_stall: 400 }
    }
}

impl MlConfig {
    /// The "fast" flavor (fewer tries/passes) used by -F baselines.
    pub fn fast() -> Self {
        MlConfig { coarsen: CoarsenConfig::serial(160), tries: 2, fm_passes: 1, fm_stall: 150 }
    }

    /// The "strong" flavor used by -S baselines. Mirrors Kaffpa-strong's
    /// effort profile (many initial tries, deep FM) — the quality/runtime
    /// anchor of the paper's comparison.
    pub fn strong() -> Self {
        MlConfig { coarsen: CoarsenConfig::serial(100), tries: 16, fm_passes: 8, fm_stall: 1500 }
    }
}

/// Multilevel bisection of `g` into blocks {0, 1} with target weight
/// fraction `frac0` for block 0 and imbalance `eps` per side.
pub fn bisect_multilevel(g: &CsrGraph, frac0: f64, eps: f64, seed: u64, cfg: &MlConfig) -> Vec<Block> {
    let total = g.total_vweight();
    let max0 = (((1.0 + eps) * total as f64) * frac0).ceil() as VWeight;
    let max1 = (((1.0 + eps) * total as f64) * (1.0 - frac0)).ceil() as VWeight;

    // Coarsening; cap pair weight so the coarsest graph stays bisectable.
    let cap = (total as f64 * frac0.min(1.0 - frac0) * (1.0 + eps)).ceil() as VWeight;
    let params =
        BuildParams { coarsest: cfg.coarsen.coarsest_min, lmax: cap.max(1), seed };
    let hier = CoarseHierarchy::build_serial(g, &params, &cfg.coarsen, &Default::default())
        .expect("bisection build has no cancel token");

    // Initial bisection on the coarsest graph (best of `tries`).
    let coarsest = hier.coarsest();
    let mut best_part: Option<(f64, Vec<Block>)> = None;
    let mut rng = Rng::new(seed ^ 0x9e37);
    for t in 0..cfg.tries.max(1) {
        let mut part = greedy_growing(coarsest, max0, max1, &mut rng);
        fm2_refine(
            coarsest,
            &mut part,
            &Fm2Config { max0, max1, passes: cfg.fm_passes + 2, stall_limit: cfg.fm_stall },
        );
        let cut = crate::partition::edge_cut(coarsest, &part);
        if best_part.as_ref().map(|(bc, _)| cut < *bc).unwrap_or(true) {
            best_part = Some((cut, part));
        }
        let _ = t;
    }
    let part = best_part.unwrap().1;

    // Uncoarsening with FM refinement (the coarsest level was already
    // FM-refined inside the tries loop above).
    let coarsest_level = hier.levels();
    hier.uncoarsen_serial(part, |lev, fine, fine_part| {
        if lev == coarsest_level {
            return;
        }
        fm2_refine(
            fine,
            fine_part,
            &Fm2Config { max0, max1, passes: cfg.fm_passes, stall_limit: cfg.fm_stall },
        );
    })
}

/// Greedy graph growing: grow block 0 from a random seed vertex by max
/// connectivity until it reaches its target weight. Handles disconnected
/// graphs by reseeding.
fn greedy_growing(g: &CsrGraph, max0: VWeight, _max1: VWeight, rng: &mut Rng) -> Vec<Block> {
    use crate::refine::OrdF64;
    use std::collections::BinaryHeap;
    let n = g.n();
    let total = g.total_vweight();
    // Target: half of total (respecting max0).
    let target = (total / 2).min(max0);
    let mut part = vec![1 as Block; n];
    if n == 0 {
        return part;
    }
    let mut in0 = vec![false; n];
    let mut conn = vec![0.0f64; n];
    let mut heap: BinaryHeap<(OrdF64, Vertex)> = BinaryHeap::new();
    let mut w0 = 0 as VWeight;
    let mut seeded = vec![false; n];

    while w0 < target {
        let v = match heap.pop() {
            Some((OrdF64(c), v)) if !in0[v as usize] && c == conn[v as usize] => v,
            Some(_) => continue, // stale
            None => {
                // Reseed from an unreached vertex.
                let mut v = rng.below_usize(n);
                let mut guard = 0;
                while (in0[v] || seeded[v]) && guard < 4 * n {
                    v = (v + 1) % n;
                    guard += 1;
                }
                if guard >= 4 * n {
                    break;
                }
                seeded[v] = true;
                v as Vertex
            }
        };
        let vi = v as usize;
        if w0 + g.vw[vi] > max0 {
            // Skip too-heavy vertex; try others.
            if heap.is_empty() {
                break;
            }
            continue;
        }
        in0[vi] = true;
        part[vi] = 0;
        w0 += g.vw[vi];
        let (nbrs, ws) = g.neighbors_w(v);
        for (&u, &w) in nbrs.iter().zip(ws) {
            let ui = u as usize;
            if !in0[ui] {
                conn[ui] += w;
                heap.push((OrdF64(conn[ui]), u));
            }
        }
    }
    part
}

/// Recursive-bisection k-way partition with per-level imbalance adjustment
/// `ε_cut = (1+ε)^(1/⌈log₂ k⌉) − 1` so the final k-way partition is
/// ε-balanced.
pub fn recursive_kway(g: &CsrGraph, k: usize, eps: f64, seed: u64, cfg: &MlConfig) -> Vec<Block> {
    assert!(k >= 1);
    let mut part = vec![0 as Block; g.n()];
    if k == 1 || g.n() == 0 {
        return part;
    }
    let depth = (k as f64).log2().ceil().max(1.0);
    let eps_cut = (1.0 + eps).powf(1.0 / depth) - 1.0;
    rb_rec(g, &(0..g.n() as Vertex).collect::<Vec<_>>(), k, eps_cut, seed, cfg, 0, &mut part);
    part
}

fn rb_rec(
    g: &CsrGraph,
    vertices: &[Vertex],
    k: usize,
    eps: f64,
    seed: u64,
    cfg: &MlConfig,
    block_off: Block,
    out: &mut [Block],
) {
    if k == 1 {
        for &v in vertices {
            out[v as usize] = block_off;
        }
        return;
    }
    // Build the induced subgraph over `vertices`.
    let sub = induce(g, vertices);
    let k0 = k / 2;
    let k1 = k - k0;
    let frac0 = k0 as f64 / k as f64;
    let part2 = bisect_multilevel(&sub, frac0, eps, seed, cfg);
    let mut side0: Vec<Vertex> = Vec::new();
    let mut side1: Vec<Vertex> = Vec::new();
    for (i, &v) in vertices.iter().enumerate() {
        if part2[i] == 0 {
            side0.push(v);
        } else {
            side1.push(v);
        }
    }
    rb_rec(g, &side0, k0, eps, seed.wrapping_add(1), cfg, block_off, out);
    rb_rec(g, &side1, k1, eps, seed.wrapping_add(2), cfg, block_off + k0 as Block, out);
}

/// Induce the subgraph over an arbitrary vertex subset (serial; the
/// device-side Algorithm 1 lives in [`crate::graph::subgraph`]).
fn induce(g: &CsrGraph, vertices: &[Vertex]) -> CsrGraph {
    let mut local = vec![u32::MAX; g.n()];
    for (i, &v) in vertices.iter().enumerate() {
        local[v as usize] = i as u32;
    }
    let mut xadj = vec![0u32; vertices.len() + 1];
    let mut adj = Vec::new();
    let mut ew = Vec::new();
    let mut vw = Vec::with_capacity(vertices.len());
    for (i, &v) in vertices.iter().enumerate() {
        vw.push(g.vw[v as usize]);
        let (nbrs, ws) = g.neighbors_w(v);
        for (&u, &w) in nbrs.iter().zip(ws) {
            let lu = local[u as usize];
            if lu != u32::MAX {
                adj.push(lu);
                ew.push(w);
            }
        }
        xadj[i + 1] = adj.len() as u32;
    }
    CsrGraph { xadj, adj, ew, vw }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{edge_cut, is_balanced};

    #[test]
    fn bisection_balanced_and_low_cut() {
        let g = gen::grid2d(20, 20, false);
        let part = bisect_multilevel(&g, 0.5, 0.03, 1, &MlConfig::default());
        assert!(is_balanced(&g, &part, 2, 0.04));
        // A 20x20 grid has optimal bisection cut 20; allow slack.
        let cut = edge_cut(&g, &part);
        assert!(cut <= 40.0, "cut {cut}");
    }

    #[test]
    fn unbalanced_target_fraction() {
        let g = gen::grid2d(16, 16, false);
        let part = bisect_multilevel(&g, 0.25, 0.05, 2, &MlConfig::default());
        let w0: i64 = (0..g.n()).filter(|&v| part[v] == 0).map(|v| g.vw[v]).sum();
        let frac = w0 as f64 / g.total_vweight() as f64;
        assert!(frac > 0.15 && frac < 0.35, "frac0={frac}");
    }

    #[test]
    fn kway_covers_all_blocks_and_balances() {
        let g = gen::rgg(3_000, 0.05, 4);
        for k in [3, 4, 7] {
            let part = recursive_kway(&g, k, 0.05, 5, &MlConfig::fast());
            let mut seen = vec![false; k];
            for &b in &part {
                seen[b as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "k={k}: empty block");
            assert!(is_balanced(&g, &part, k, 0.08), "k={k} imbalanced");
        }
    }

    #[test]
    fn k1_is_trivial() {
        let g = gen::grid2d(5, 5, false);
        let part = recursive_kway(&g, 1, 0.03, 1, &MlConfig::default());
        assert!(part.iter().all(|&b| b == 0));
    }

    #[test]
    fn handles_disconnected_graphs() {
        // road_like can be disconnected.
        let g = gen::road_like(40, 40, 9);
        let part = recursive_kway(&g, 4, 0.10, 3, &MlConfig::fast());
        assert!(is_balanced(&g, &part, 4, 0.15));
    }

    #[test]
    fn strong_config_not_worse_than_fast() {
        let g = gen::grid2d(24, 24, false);
        let fast = recursive_kway(&g, 8, 0.03, 7, &MlConfig::fast());
        let strong = recursive_kway(&g, 8, 0.03, 7, &MlConfig::strong());
        assert!(edge_cut(&g, &strong) <= edge_cut(&g, &fast) * 1.15);
    }
}
