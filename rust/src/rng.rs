//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry a small, fast,
//! well-understood generator: SplitMix64 for seeding and xoshiro256++ for
//! the stream. Determinism matters: the paper runs every algorithm with
//! five fixed seeds and averages, and the parallel kernels add
//! *deterministic* noise `η` to edge ratings to break ties reproducibly.

/// SplitMix64 step — used to derive stream seeds from a single `u64`.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

/// Deterministic per-edge noise `η({u, v})` in `[0, 1)`, symmetric in the
/// endpoints and independent of traversal direction. Used by the matching
/// ratings to break ties without a global RNG (paper §4.2 Matching).
#[inline]
pub fn edge_noise(u: u32, v: u32, seed: u64) -> f64 {
    let (a, b) = if u < v { (u, v) } else { (v, u) };
    let mut h = seed ^ ((a as u64) << 32 | b as u64);
    let x = splitmix64(&mut h);
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Deterministic per-vertex hash, used by twin detection and CAS probing.
#[inline]
pub fn hash_u64(x: u64) -> u64 {
    let mut h = x;
    splitmix64(&mut h)
}

/// Per-level stream seed for multilevel coarsening.
///
/// The pipelines used to salt ad hoc — `seed ^ (level << 32)` in the
/// device algorithms, `seed ^ (level << 24)` in the serial ones — which
/// collides across `(seed, level)` pairs that differ only in the shifted
/// bit (e.g. `(s ^ 1 << 24, 0)` and `(s, 1)` fed the serial matcher the
/// same stream). The seed is mixed through SplitMix64 *before* the level
/// is folded in, so structured seed relationships no longer line up with
/// level offsets.
#[inline]
pub fn level_seed(seed: u64, level: u64) -> u64 {
    let mut s = seed;
    let mixed = splitmix64(&mut s);
    let mut t = mixed ^ level;
    splitmix64(&mut t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
    }

    #[test]
    fn edge_noise_symmetric() {
        for (u, v) in [(0u32, 1u32), (5, 900), (123, 77)] {
            assert_eq!(edge_noise(u, v, 42), edge_noise(v, u, 42));
            assert!(edge_noise(u, v, 42) < 1.0);
        }
    }

    #[test]
    fn edge_noise_seed_sensitive() {
        assert_ne!(edge_noise(1, 2, 1), edge_noise(1, 2, 2));
    }

    #[test]
    fn level_seed_has_no_structured_collisions() {
        // Regression for the old `seed ^ (level << K)` salting: the pairs
        // (s, 1) and (s ^ (1 << 24), 0) collided under the serial scheme,
        // and (s, 1) / (s ^ (1 << 32), 0) under the device scheme.
        use std::collections::HashSet;
        let base = 0x0123_4567_89ab_cdefu64;
        for shift in [16u32, 24, 32] {
            assert_ne!(
                level_seed(base, 1),
                level_seed(base ^ (1 << shift), 0),
                "shift {shift} collision survived the rework"
            );
        }
        // Broad sweep: every (seed, level) pair in a practical range gets
        // its own stream.
        let mut seen = HashSet::new();
        for s in 0..64u64 {
            for level in 0..64u64 {
                assert!(
                    seen.insert(level_seed(base.wrapping_add(s), level)),
                    "collision at seed offset {s}, level {level}"
                );
            }
        }
    }
}
