//! # HeiPa-RS — GPU-Accelerated Process Mapping, reproduced in Rust + JAX + Pallas
//!
//! Reproduction of *GPU-Accelerated Algorithms for Process Mapping*
//! (Samoldekin, Schulz, Woydt; CS.DC 2025).
//!
//! ## The front door: [`engine`]
//!
//! Every way of running a mapping — library call, `heipa` CLI, the TCP
//! coordinator, the benchmark harness — is one [`engine::MapSpec`] handed
//! to one [`engine::Engine`]:
//!
//! ```no_run
//! use heipa::engine::{Engine, MapSpec};
//!
//! let engine = Engine::with_defaults();
//! let spec = MapSpec::named("rgg15").hierarchy("4:8:2").distance("1:10:100").polish(true);
//! let outcome = engine.map(&spec)?;
//! println!("J = {:.0}, imbalance = {:.4}", outcome.comm_cost, outcome.imbalance);
//! # anyhow::Ok(())
//! ```
//!
//! The engine owns the worker pool, the PJRT runtime and a bounded graph
//! cache once; solvers are looked up in a name-indexed registry
//! ([`engine::solver_by_name`]), and every run returns the same
//! [`engine::MapOutcome`] (mapping, `J`, imbalance, host/device time,
//! phase breakdown, polish improvement).
//!
//! ## What's underneath
//!
//! * the **hierarchical process mapping problem (HPMP)** model: task graphs,
//!   machine hierarchies `H = a_1 : … : a_ℓ` with distances
//!   `D = d_1 : … : d_ℓ`, and the communication-cost objective
//!   `J(C, D, Π) = Σ_{ij} C_ij · D_{Π(i)Π(j)}`;
//! * **GPU-HM** ([`algo::gpu_hm`]): hierarchical multisection driven by a
//!   reimplementation of the Jet GPU partitioner (paper Alg. 1 + 2);
//! * **GPU-IM** ([`algo::gpu_im`]): integrated mapping inside the multilevel
//!   pipeline (paper Alg. 3–6);
//! * the CPU baselines the paper compares against
//!   ([`algo::sharedmap`], [`algo::intmap`], [`algo::jet`]);
//! * the **unified multilevel subsystem** ([`multilevel`]): pluggable
//!   coarsening schemes (matching / size-constrained cluster LP), one
//!   [`multilevel::CoarseHierarchy`] shared by every pipeline, and an
//!   engine-level hierarchy cache so repeat jobs on a session graph skip
//!   coarsening entirely;
//! * a bulk-synchronous data-parallel execution substrate ([`par`]) standing
//!   in for Kokkos/CUDA, with a calibrated GPU cost model;
//! * a PJRT runtime ([`runtime`]) that executes AOT-compiled JAX/Pallas
//!   kernels (QAP swap scoring, J evaluation) from the Rust hot path;
//! * a mapping-as-a-service coordinator ([`coordinator`]) — the engine's
//!   asynchronous job API (`submit`/`status`/`wait`/`result`/`cancel`,
//!   graph-as-resource sessions) behind a line-oriented TCP protocol —
//!   and the benchmark harness ([`harness`]) regenerating every paper
//!   table/figure;
//! * an **incremental-remapping subsystem** ([`incremental`]): graph
//!   patches on pinned session graphs, warm-start region refinement
//!   (`remap=warm`) reusing untouched hierarchy-cache levels, and
//!   batched job submission that packs small same-machine jobs into one
//!   worker pass;
//! * a deterministic **fault-injection plane** ([`fault`]) threaded
//!   through kernel launch, hierarchy build, graph IO, job pickup and the
//!   wire, driving the engine's self-healing pipeline (retry with capped
//!   exponential backoff, then graceful degradation down a solver
//!   fallback chain);
//! * a **cluster tier** ([`cluster`]): a router coordinator speaking the
//!   same wire protocol in front of N engine processes, with
//!   consistent-hash session routing, replication, health probes,
//!   backpressure-aware dispatch and mid-job failover (`failover=1`).
//!
//! The engine itself is **job-oriented**: [`engine::Engine::submit`]
//! enqueues a spec on a bounded priority queue served by a pool of
//! engine workers and returns a [`engine::JobHandle`] immediately;
//! [`engine::Engine::map`] is simply `submit(..)` + `wait()`. In-flight
//! jobs are cancellable through a [`cancel::CancelToken`] polled at
//! coarsening-level and Jet-round boundaries.
//!
//! See `DESIGN.md` for the hardware-substitution notes and the experiment
//! index, and `examples/quickstart.rs` for the five-line end-to-end usage.

pub mod algo;
pub mod cancel;
pub mod cluster;
pub mod coarsen;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod fault;
pub mod graph;
pub mod harness;
pub mod incremental;
pub mod initial;
pub mod metrics;
pub mod multilevel;
pub mod par;
pub mod partition;
pub mod refine;
pub mod rng;
pub mod runtime;
pub mod topology;

/// Vertex index type. Graphs in this crate are bounded by `u32` vertices
/// (the paper's largest instance, europe_osm, has 50.9 M < 2^32).
pub type Vertex = u32;
/// Block / PE index type.
pub type Block = u32;
/// Vertex weights are integral (exact balance arithmetic).
pub type VWeight = i64;
/// Edge weights / communication volumes are floating point.
pub type EWeight = f64;
