//! Line protocol for the TCP front-end.
//!
//! Requests are single lines of space-separated `key=value` tokens:
//!
//! ```text
//! map instance=rgg15 algorithm=gpu-im hierarchy=4:8:2 distance=1:10:100 eps=0.03 seed=1 polish=1
//! map instance=del15 algorithm=auto refinement=strong opt.adaptive=0 mapping=1
//! map instance=rgg15 topology=torus:4x4x4 seed=2
//! metrics
//! ping
//! ```
//!
//! Responses are single lines: `ok key=value …` or `err message=…`.

use super::{MapReply, MapRequest, ServiceMetrics};
use crate::algo::Algorithm;
use crate::engine::Refinement;
use anyhow::{bail, Result};

/// Parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Map(MapRequest),
    Metrics,
    Ping,
}

/// Parse one request line.
pub fn parse_command(line: &str) -> Result<Command> {
    let mut tokens = line.split_whitespace();
    let verb = tokens.next().unwrap_or("");
    match verb {
        "ping" => Ok(Command::Ping),
        "metrics" => Ok(Command::Metrics),
        "map" => {
            let mut req = MapRequest::default();
            for tok in tokens {
                let Some((k, v)) = tok.split_once('=') else {
                    bail!("bad token `{tok}` (expected key=value)");
                };
                match k {
                    "instance" => req.instance = v.to_string(),
                    "algorithm" => {
                        req.algorithm = if v == "auto" {
                            None
                        } else {
                            Some(
                                Algorithm::from_name(v)
                                    .ok_or_else(|| anyhow::anyhow!("unknown algorithm {v}"))?,
                            )
                        }
                    }
                    "hierarchy" => req.hierarchy = v.to_string(),
                    "distance" => req.distance = v.to_string(),
                    "topology" => req.topology = Some(v.to_string()),
                    "eps" => req.eps = v.parse()?,
                    "seed" => req.seed = v.parse()?,
                    "refinement" => req.refinement = Refinement::from_name(v)?,
                    "polish" => req.polish = v == "1" || v == "true",
                    "mapping" => req.return_mapping = v == "1" || v == "true",
                    other => {
                        if let Some(opt) = other.strip_prefix("opt.") {
                            req.options.insert(opt.to_string(), v.to_string());
                        } else {
                            bail!("unknown key `{other}`");
                        }
                    }
                }
            }
            if req.instance.is_empty() {
                bail!("map requires instance=…");
            }
            Ok(Command::Map(req))
        }
        "" => bail!("empty command"),
        other => bail!("unknown verb `{other}`"),
    }
}

/// Render a map reply line.
pub fn render_response(r: &MapReply) -> String {
    let o = &r.outcome;
    let mut s = format!(
        "ok id={} algorithm={} n={} k={} j={:.3} imbalance={:.5} host_ms={:.3} device_ms={:.3} polish_dj={:.3}",
        r.id, o.algorithm.name(), o.n, o.k, o.comm_cost, o.imbalance, o.host_ms, o.device_ms,
        o.polish_improvement
    );
    if !o.mapping.is_empty() {
        s.push_str(" mapping=");
        let parts: Vec<String> = o.mapping.iter().map(|b| b.to_string()).collect();
        s.push_str(&parts.join(","));
    }
    s
}

/// Render a metrics line.
pub fn render_metrics(m: &ServiceMetrics) -> String {
    let per: Vec<String> = m.per_algorithm.iter().map(|(k, v)| format!("{k}:{v}")).collect();
    format!(
        "ok requests={} failures={} host_ms={:.1} device_ms={:.1} per_algorithm={}",
        m.requests,
        m.failures,
        m.total_host_ms,
        m.total_device_ms,
        per.join(";")
    )
}

/// Render an error line.
pub fn render_error(e: &anyhow::Error) -> String {
    format!("err message={}", format!("{e}").replace(['\n', ' '], "_"))
}

/// Serve the protocol over TCP (one thread per connection) until the
/// process exits. Binds `addr` and prints the bound address.
pub fn serve_tcp(service: std::sync::Arc<super::service::Service>, addr: &str) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind(addr)?;
    println!("heipa coordinator listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let svc = service.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().ok();
            let reader = BufReader::new(stream.try_clone().expect("clone stream"));
            let mut writer = stream;
            for line in reader.lines() {
                let Ok(line) = line else { break };
                let reply = match parse_command(&line) {
                    Ok(Command::Ping) => "ok pong=1".to_string(),
                    Ok(Command::Metrics) => render_metrics(&svc.metrics()),
                    Ok(Command::Map(req)) => match svc.submit(req) {
                        Ok(resp) => render_response(&resp),
                        Err(e) => render_error(&e),
                    },
                    Err(e) => render_error(&e),
                };
                if writer.write_all(reply.as_bytes()).and_then(|_| writer.write_all(b"\n")).is_err() {
                    break;
                }
            }
            let _ = peer;
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_map_command() {
        let cmd = parse_command(
            "map instance=rgg15 algorithm=gpu-im hierarchy=4:8:2 distance=1:10:100 eps=0.05 seed=7 polish=1",
        )
        .unwrap();
        let Command::Map(req) = cmd else { panic!() };
        assert_eq!(req.instance, "rgg15");
        assert_eq!(req.algorithm, Some(Algorithm::GpuIm));
        assert_eq!(req.eps, 0.05);
        assert!(req.polish);
    }

    #[test]
    fn auto_algorithm_unpins() {
        let Command::Map(req) = parse_command("map instance=x algorithm=auto").unwrap() else {
            panic!()
        };
        assert_eq!(req.algorithm, None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_command("").is_err());
        assert!(parse_command("frob instance=x").is_err());
        assert!(parse_command("map").is_err());
        assert!(parse_command("map instance=x bad").is_err());
        assert!(parse_command("map instance=x algorithm=nope").is_err());
        assert!(parse_command("map instance=x refinement=nope").is_err());
    }

    #[test]
    fn parses_topology_key() {
        let Command::Map(req) = parse_command("map instance=x topology=torus:4x4x4").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.topology.as_deref(), Some("torus:4x4x4"));
        assert_eq!(req.to_spec().machine().unwrap().k(), 64);
    }

    #[test]
    fn parses_refinement_and_solver_options() {
        let Command::Map(req) =
            parse_command("map instance=x refinement=strong opt.adaptive=0").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.refinement, Refinement::Strong);
        assert_eq!(req.options.get("adaptive").map(String::as_str), Some("0"));
    }

    #[test]
    fn response_rendering_roundtrips_keys() {
        let r = MapReply {
            id: 3,
            outcome: crate::engine::MapOutcome {
                algorithm: Algorithm::GpuHm,
                n: 10,
                k: 4,
                seed: 1,
                mapping: vec![0, 1, 2, 3],
                comm_cost: 123.5,
                imbalance: 0.01,
                host_ms: 5.0,
                device_ms: 0.2,
                phases: None,
                polish_improvement: 1.0,
            },
        };
        let line = render_response(&r);
        assert!(line.starts_with("ok id=3 algorithm=gpu-hm"));
        assert!(line.contains("mapping=0,1,2,3"));
    }
}
