//! Line protocol for the TCP front-end — the asynchronous job API.
//!
//! Requests are single lines of space-separated `key=value` tokens:
//!
//! ```text
//! submit instance=rgg15 algorithm=gpu-im hierarchy=4:8:2 distance=1:10:100 seed=1
//! submit graph=mesh topology=torus:4x4x4 priority=5 deadline_ms=60000
//! status job=3
//! wait job=3 timeout_ms=5000
//! result job=3
//! cancel job=3
//! jobs
//! graph put name=mesh path=/data/mesh.graph
//! graph put name=tri csr=0,2,4,6/1,2,0,2,0,1
//! graph list
//! graph del name=mesh
//! graph patch name=mesh ops=ae:0:9:1.0,re:3:4
//! batch submit jobs=<job>;<job>        # each <job> a percent-escaped submit body
//! batch wait id=7 timeout_ms=5000
//! map instance=rgg15 polish=1          # legacy blocking path (submit+wait+result)
//! metrics
//! ping                                 # ok version=… queue_depth=… in_flight=… graphs=…
//! drain timeout_ms=5000                # stop admitting, finish in-flight → ok drained=1
//! cluster nodes                        # node table (routers: the fleet; nodes: self)
//! cluster route name=mesh              # which node(s) own a session graph
//! ```
//!
//! Responses are single lines. `submit` replies `ok job=<id> state=queued`
//! **before the solve runs**; `map`/`result` reply the full outcome
//! (`ok id=… algorithm=… j=…`, plus `degraded=1` / `attempts=N` when the
//! self-healing pipeline retried or fell back — see [`crate::fault`]);
//! errors are `err code=<code> message=…` with the message
//! percent-escaped ([`escape_value`]) so clients can recover the real
//! text — including its spaces — via [`unescape_value`]. Error codes:
//! `parse` (malformed request line), `toobig` (request line longer than
//! [`ServeOptions::max_line_len`]), `busy` (bounded job queue or
//! connection limit), `unknown_job`, `unknown_graph`, `unknown_batch`,
//! `not_done`, `timeout`, `failed`, `cancelled`, `expired`, `patch`
//! (a [`crate::incremental::GraphPatch`] that does not apply),
//! `unavailable`.
//!
//! `graph patch` applies an incremental edit to a pinned session graph
//! (bumping its version, shown as `name@vN` in `graph list`); the next
//! `map`/`submit` over that session warm-starts from the previous
//! mapping and replies with `remap=warm` (or `remap=cold` when the
//! engine fell back to a full solve). `batch submit` admits several
//! jobs as one all-or-nothing unit that engine workers may drain into
//! a single worker-pool pass.
//!
//! Submits accept `max_attempts=`/`backoff_ms=` to override the
//! service's retry policy per job, and `backend=cpu|device|auto` to pick
//! the kernel execution backend ([`crate::engine::Backend`]); replies
//! carry ` backend=device` only when the device backend actually ran, so
//! cpu replies stay byte-compatible. The cluster router forwards these
//! lines verbatim — backend selection needs nothing router-side.

use super::service::{JobOptions, Service};
use super::{MapReply, MapRequest, ServiceMetrics};
use crate::algo::Algorithm;
use crate::engine::{Backend, JobState, JobStatus, Refinement, SubmitError};
use crate::incremental::PatchError;
use crate::fault::{self, FaultPoint};
use crate::multilevel::SchemeKind;
use crate::graph::CsrGraph;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Per-submit wire options (`priority=`, `deadline_ms=`,
/// `max_attempts=`, `backoff_ms=`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireSubmitOpts {
    pub priority: i32,
    pub deadline_ms: Option<u64>,
    /// Total execution attempts (retry policy override).
    pub max_attempts: Option<u32>,
    /// Base retry backoff in ms (doubles per attempt, capped).
    pub backoff_ms: Option<u64>,
}

/// Parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// Legacy blocking path: submit + wait + result in one round trip.
    Map { req: MapRequest, opts: WireSubmitOpts },
    /// Async submit: replies `ok job=<id>` immediately.
    Submit { req: MapRequest, opts: WireSubmitOpts },
    Status { job: u64 },
    Wait { job: u64, timeout_ms: Option<u64> },
    JobResult { job: u64 },
    Cancel { job: u64 },
    Jobs,
    GraphPut { name: String, path: Option<String>, csr: Option<String> },
    GraphList,
    GraphDrop { name: String },
    /// Apply an incremental edit to a pinned session graph
    /// (`ops=` uses the [`crate::incremental::GraphPatch`] grammar).
    GraphPatch { name: String, ops: String },
    /// Submit several jobs as one batch unit (all-or-nothing admission;
    /// the first job's submit options apply to the whole batch).
    BatchSubmit { reqs: Vec<MapRequest>, opts: WireSubmitOpts },
    /// Block until every job of a batch reaches a terminal state.
    BatchWait { id: u64, timeout_ms: Option<u64> },
    Metrics,
    /// Cheap typed health probe:
    /// `ok version=<crate> queue_depth=<d> in_flight=<f> graphs=<g>`.
    Ping,
    /// Graceful drain: stop admitting (new submits get
    /// `err code=unavailable`), finish queued + in-flight work, then
    /// reply `ok drained=1` (or `err code=timeout` past `timeout_ms`).
    Drain { timeout_ms: Option<u64> },
    /// The node table. A plain `serve` node answers for itself
    /// (`ok count=1 nodes=self/up/<qd>/<if>`); the cluster router
    /// answers with one `addr/health/queue_depth/in_flight` entry per
    /// downstream node.
    ClusterNodes,
    /// Which node(s) own a session graph. A plain node answers
    /// `owners=self` when it pins the graph; the router answers with
    /// the ring's replica set.
    ClusterRoute { name: String },
}

/// Parse the shared `key=value` body of `map`/`submit`.
fn parse_job_body<'a>(
    tokens: impl Iterator<Item = &'a str>,
) -> Result<(MapRequest, WireSubmitOpts)> {
    let mut req = MapRequest::default();
    let mut opts = WireSubmitOpts::default();
    for tok in tokens {
        let Some((k, v)) = tok.split_once('=') else {
            bail!("bad token `{tok}` (expected key=value)");
        };
        match k {
            // `graph=` is the session-graph alias of `instance=`: both
            // resolve through the engine's graph store (pinned tier
            // first), so `graph put name=X …; submit graph=X …` works.
            "instance" | "graph" => req.instance = v.to_string(),
            "algorithm" => {
                req.algorithm = if v == "auto" {
                    None
                } else {
                    Some(
                        Algorithm::from_name(v)
                            .ok_or_else(|| anyhow::anyhow!("unknown algorithm {v}"))?,
                    )
                }
            }
            "hierarchy" => req.hierarchy = v.to_string(),
            "distance" => req.distance = v.to_string(),
            "topology" => req.topology = Some(v.to_string()),
            "eps" => req.eps = v.parse()?,
            "seed" => req.seed = v.parse()?,
            "refinement" => req.refinement = Refinement::from_name(v)?,
            "coarsening" => req.coarsening = SchemeKind::from_name(v)?,
            "polish" => req.polish = v == "1" || v == "true",
            "backend" => req.backend = Backend::from_name(v)?,
            "mapping" => req.return_mapping = v == "1" || v == "true",
            "priority" => opts.priority = v.parse().context("priority")?,
            "deadline_ms" => opts.deadline_ms = Some(v.parse().context("deadline_ms")?),
            "max_attempts" => opts.max_attempts = Some(v.parse().context("max_attempts")?),
            "backoff_ms" => opts.backoff_ms = Some(v.parse().context("backoff_ms")?),
            other => {
                if let Some(opt) = other.strip_prefix("opt.") {
                    req.options.insert(opt.to_string(), v.to_string());
                } else {
                    bail!("unknown key `{other}`");
                }
            }
        }
    }
    if req.instance.is_empty() {
        bail!("missing instance=… (or graph=…)");
    }
    Ok((req, opts))
}

/// Parse a `job=<id>` argument list (plus optional extra keys handled by
/// the caller via the returned map).
fn parse_kv_args<'a>(
    tokens: impl Iterator<Item = &'a str>,
) -> Result<std::collections::BTreeMap<&'a str, &'a str>> {
    let mut out = std::collections::BTreeMap::new();
    for tok in tokens {
        let Some((k, v)) = tok.split_once('=') else {
            bail!("bad token `{tok}` (expected key=value)");
        };
        out.insert(k, v);
    }
    Ok(out)
}

fn require_job(kv: &std::collections::BTreeMap<&str, &str>) -> Result<u64> {
    kv.get("job").context("missing job=<id>")?.parse().context("job id")
}

/// Parse one request line.
pub fn parse_command(line: &str) -> Result<Command> {
    let mut tokens = line.split_whitespace();
    let verb = tokens.next().unwrap_or("");
    match verb {
        "ping" => Ok(Command::Ping),
        "metrics" => Ok(Command::Metrics),
        "jobs" => Ok(Command::Jobs),
        "drain" => {
            let kv = parse_kv_args(tokens)?;
            let timeout_ms = match kv.get("timeout_ms") {
                Some(v) => Some(v.parse().context("timeout_ms")?),
                None => None,
            };
            Ok(Command::Drain { timeout_ms })
        }
        "cluster" => {
            let sub = tokens.next().unwrap_or("");
            match sub {
                "nodes" => Ok(Command::ClusterNodes),
                "route" => {
                    let kv = parse_kv_args(tokens)?;
                    let name = kv.get("name").context("cluster route needs name=…")?.to_string();
                    Ok(Command::ClusterRoute { name })
                }
                other => bail!("unknown cluster subcommand `{other}` (nodes|route)"),
            }
        }
        "map" => {
            let (req, opts) = parse_job_body(tokens)?;
            Ok(Command::Map { req, opts })
        }
        "submit" => {
            let (req, opts) = parse_job_body(tokens)?;
            Ok(Command::Submit { req, opts })
        }
        "status" => Ok(Command::Status { job: require_job(&parse_kv_args(tokens)?)? }),
        "wait" => {
            let kv = parse_kv_args(tokens)?;
            let timeout_ms = match kv.get("timeout_ms") {
                Some(v) => Some(v.parse().context("timeout_ms")?),
                None => None,
            };
            Ok(Command::Wait { job: require_job(&kv)?, timeout_ms })
        }
        "result" => Ok(Command::JobResult { job: require_job(&parse_kv_args(tokens)?)? }),
        "cancel" => Ok(Command::Cancel { job: require_job(&parse_kv_args(tokens)?)? }),
        "graph" => {
            let sub = tokens.next().unwrap_or("");
            match sub {
                "put" => {
                    let kv = parse_kv_args(tokens)?;
                    let name = kv.get("name").context("graph put needs name=…")?.to_string();
                    let path = kv.get("path").map(|s| s.to_string());
                    let csr = kv.get("csr").map(|s| s.to_string());
                    if path.is_some() == csr.is_some() {
                        bail!("graph put needs exactly one of path=… or csr=…");
                    }
                    Ok(Command::GraphPut { name, path, csr })
                }
                "list" => Ok(Command::GraphList),
                "del" | "drop" => {
                    let kv = parse_kv_args(tokens)?;
                    let name = kv.get("name").context("graph del needs name=…")?.to_string();
                    Ok(Command::GraphDrop { name })
                }
                "patch" => {
                    let kv = parse_kv_args(tokens)?;
                    let name = kv.get("name").context("graph patch needs name=…")?.to_string();
                    let ops = kv.get("ops").context("graph patch needs ops=…")?.to_string();
                    Ok(Command::GraphPatch { name, ops })
                }
                other => bail!("unknown graph subcommand `{other}` (put|list|del|patch)"),
            }
        }
        "batch" => {
            let sub = tokens.next().unwrap_or("");
            match sub {
                "submit" => {
                    let kv = parse_kv_args(tokens)?;
                    let jobs = kv.get("jobs").context("batch submit needs jobs=…")?;
                    let mut reqs = Vec::new();
                    let mut opts = None;
                    for (i, part) in jobs.split(';').enumerate() {
                        if part.is_empty() {
                            continue;
                        }
                        let body = unescape_value(part);
                        let (req, o) = parse_job_body(body.split_whitespace())
                            .with_context(|| format!("batch job #{}", i + 1))?;
                        if opts.is_none() {
                            opts = Some(o);
                        }
                        reqs.push(req);
                    }
                    if reqs.is_empty() {
                        bail!("batch submit needs at least one job");
                    }
                    Ok(Command::BatchSubmit { reqs, opts: opts.unwrap_or_default() })
                }
                "wait" => {
                    let kv = parse_kv_args(tokens)?;
                    let id =
                        kv.get("id").context("missing id=<batch>")?.parse().context("batch id")?;
                    let timeout_ms = match kv.get("timeout_ms") {
                        Some(v) => Some(v.parse().context("timeout_ms")?),
                        None => None,
                    };
                    Ok(Command::BatchWait { id, timeout_ms })
                }
                other => bail!("unknown batch subcommand `{other}` (submit|wait)"),
            }
        }
        "" => bail!("empty command"),
        other => bail!("unknown verb `{other}`"),
    }
}

/// Parse an inline CSR upload: `<xadj>/<adjncy>[/<eweights>[/<vweights>]]`,
/// each a comma-separated list. The adjacency must already be symmetric
/// (validated before the graph is pinned).
pub fn parse_inline_csr(text: &str) -> Result<CsrGraph> {
    fn list<T: std::str::FromStr>(part: &str, what: &str) -> Result<Vec<T>> {
        if part.is_empty() {
            return Ok(Vec::new());
        }
        part.split(',')
            .map(|t| t.parse::<T>().map_err(|_| anyhow::anyhow!("bad {what} entry `{t}`")))
            .collect()
    }
    let parts: Vec<&str> = text.split('/').collect();
    if !(2..=4).contains(&parts.len()) {
        bail!("csr wants xadj/adjncy[/eweights[/vweights]], got {} part(s)", parts.len());
    }
    let xadj: Vec<u32> = list(parts[0], "xadj")?;
    let adj: Vec<crate::Vertex> = list(parts[1], "adjncy")?;
    if xadj.is_empty() {
        bail!("xadj must have n+1 entries");
    }
    let n = xadj.len() - 1;
    let ew: Vec<crate::EWeight> = match parts.get(2) {
        Some(p) if !p.is_empty() => list(p, "eweight")?,
        _ => vec![1.0; adj.len()],
    };
    let vw: Vec<crate::VWeight> = match parts.get(3) {
        Some(p) if !p.is_empty() => list(p, "vweight")?,
        _ => vec![1; n],
    };
    if ew.len() != adj.len() {
        bail!("eweights length {} != adjncy length {}", ew.len(), adj.len());
    }
    if vw.len() != n {
        bail!("vweights length {} != n {}", vw.len(), n);
    }
    let g = CsrGraph { xadj, adj, ew, vw };
    g.validate().map_err(anyhow::Error::msg)?;
    Ok(g)
}

/// Percent-escape a wire value: space, newline, CR and `%` itself, so
/// error messages survive the space-separated key=value framing intact.
pub fn escape_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '%' => out.push_str("%25"),
            ' ' => out.push_str("%20"),
            '\n' => out.push_str("%0A"),
            '\r' => out.push_str("%0D"),
            _ => out.push(c),
        }
    }
    out
}

/// Reverse [`escape_value`]. Unrecognized `%` sequences pass through
/// unchanged, so unescaping is total.
pub fn unescape_value(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 3 <= bytes.len() {
            let replaced = match (bytes[i + 1], bytes[i + 2]) {
                (b'2', b'5') => Some('%'),
                (b'2', b'0') => Some(' '),
                (b'0', b'A') => Some('\n'),
                (b'0', b'D') => Some('\r'),
                _ => None,
            };
            if let Some(c) = replaced {
                out.push(c);
                i += 3;
                continue;
            }
        }
        let c = s[i..].chars().next().expect("in-bounds char");
        out.push(c);
        i += c.len_utf8();
    }
    out
}

/// Render a map/result reply line.
pub fn render_response(r: &MapReply) -> String {
    let o = &r.outcome;
    let mut s = format!(
        "ok id={} algorithm={} n={} k={} j={:.3} imbalance={:.5} host_ms={:.3} device_ms={:.3} polish_dj={:.3}",
        r.id, o.algorithm.name(), o.n, o.k, o.comm_cost, o.imbalance, o.host_ms, o.device_ms,
        o.polish_improvement
    );
    if let Some(cached) = o.hierarchy_cache {
        s.push_str(if cached { " hier_cache=hit" } else { " hier_cache=miss" });
    }
    if o.degraded {
        s.push_str(" degraded=1");
    }
    if o.attempts > 1 {
        s.push_str(&format!(" attempts={}", o.attempts));
    }
    if let Some(kind) = o.remap {
        s.push_str(&format!(" remap={}", kind.name()));
    }
    // Only non-default backends render, keeping cpu replies
    // byte-compatible with the pre-offload wire format. `auto` never
    // appears: the outcome carries the backend actually used.
    if o.backend == Backend::Device {
        s.push_str(" backend=device");
    }
    if !o.mapping.is_empty() {
        s.push_str(" mapping=");
        let parts: Vec<String> = o.mapping.iter().map(|b| b.to_string()).collect();
        s.push_str(&parts.join(","));
    }
    s
}

/// Render a metrics line.
pub fn render_metrics(m: &ServiceMetrics) -> String {
    let per: Vec<String> = m.per_algorithm.iter().map(|(k, v)| format!("{k}:{v}")).collect();
    format!(
        "ok requests={} failures={} completed={} cancelled={} deadline_missed={} \
         busy_rejections={} hier_hits={} hier_misses={} retries={} faults_injected={} \
         degraded={} patches={} graphs_replaced={} warm_remaps={} cold_fallbacks={} \
         batches={} batched_jobs={} device_launches={} h2d_bytes={} d2h_bytes={} \
         backend_fallbacks={} queue_depth={} in_flight={} \
         host_ms={:.1} device_ms={:.1} per_algorithm={}",
        m.requests,
        m.failures,
        m.completed,
        m.cancelled,
        m.deadline_missed,
        m.busy_rejections,
        m.hierarchy_cache_hits,
        m.hierarchy_cache_misses,
        m.retries,
        m.faults_injected,
        m.degraded_completions,
        m.patches_applied,
        m.graphs_replaced,
        m.warm_remaps,
        m.cold_fallbacks,
        m.batches,
        m.batched_jobs,
        m.device_launches,
        m.h2d_bytes,
        m.d2h_bytes,
        m.backend_fallbacks,
        m.queue_depth,
        m.in_flight,
        m.total_host_ms,
        m.total_device_ms,
        per.join(";")
    )
}

/// Render an error with an explicit machine-readable code.
pub fn render_err(code: &str, msg: &str) -> String {
    format!("err code={code} message={}", escape_value(msg))
}

/// Render a request-level error line (`code=parse`).
pub fn render_error(e: &anyhow::Error) -> String {
    render_err("parse", &format!("{e:#}"))
}

/// Render a job status line:
/// `ok job=<id> state=<state> [attempts=…] [error=…]`.
pub fn render_job_status(st: &JobStatus) -> String {
    let mut s = format!("ok job={} state={}", st.id, st.state.name());
    if st.attempts > 1 {
        s.push_str(&format!(" attempts={}", st.attempts));
    }
    if let Some(e) = &st.error {
        s.push_str(" error=");
        s.push_str(&escape_value(e));
    }
    s
}

fn unknown_job(job: u64) -> String {
    render_err("unknown_job", &format!("no job with id {job}"))
}

/// The terminal-but-not-done states render as coded errors.
fn render_job_error(st: &JobStatus) -> String {
    let code = match st.state {
        JobState::Failed => "failed",
        JobState::Cancelled => "cancelled",
        JobState::Expired => "expired",
        _ => "failed",
    };
    render_err(code, st.error.as_deref().unwrap_or("job did not complete"))
}

/// Execute one parsed command against the service. Every front-end — the
/// TCP accept loop, tests and the e2e example — goes through this one
/// dispatcher, so the wire semantics cannot drift between them.
pub fn dispatch(svc: &Service, cmd: Command) -> String {
    match cmd {
        Command::Ping => format!(
            "ok version={} queue_depth={} in_flight={} graphs={}",
            env!("CARGO_PKG_VERSION"),
            svc.engine().queue_depth(),
            svc.engine().in_flight(),
            svc.graph_entries().len(),
        ),
        Command::Drain { timeout_ms } => {
            svc.start_drain();
            let deadline = timeout_ms
                .map(|ms| std::time::Instant::now() + std::time::Duration::from_millis(ms));
            loop {
                if svc.drained() {
                    return "ok drained=1".to_string();
                }
                if deadline.is_some_and(|d| std::time::Instant::now() >= d) {
                    return render_err(
                        "timeout",
                        &format!(
                            "drain still has work in flight after {}ms",
                            timeout_ms.unwrap_or(0)
                        ),
                    );
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
        Command::ClusterNodes => format!(
            "ok count=1 nodes=self/up/{}/{}",
            svc.engine().queue_depth(),
            svc.engine().in_flight(),
        ),
        Command::ClusterRoute { name } => {
            if svc.graph_names().iter().any(|n| n == &name) {
                format!("ok graph={name} owners=self")
            } else {
                render_err("unknown_graph", &format!("no pinned graph named {name}"))
            }
        }
        Command::Metrics => render_metrics(&svc.metrics()),
        Command::Map { req, opts } => {
            // The wire never blocks on queue admission — a full queue is
            // `err code=busy` for `map` exactly as for `submit` (only
            // in-process callers opt into blocking submits). The
            // connection then blocks on the *solve*, which is the legacy
            // `map` contract.
            let jopts = JobOptions {
                priority: opts.priority,
                deadline_ms: opts.deadline_ms,
                block_when_full: false,
                max_attempts: opts.max_attempts,
                backoff_ms: opts.backoff_ms,
            };
            match svc.submit_async(&req, jopts) {
                Err(e @ SubmitError::Busy { .. }) => render_err("busy", &e.to_string()),
                Err(e) => render_err("unavailable", &e.to_string()),
                Ok(h) => match h.wait() {
                    Ok(outcome) => render_response(&MapReply { id: h.id().0, outcome }),
                    Err(_) => render_job_error(&h.status()),
                },
            }
        }
        Command::Submit { req, opts } => {
            let jopts = JobOptions {
                priority: opts.priority,
                deadline_ms: opts.deadline_ms,
                block_when_full: false,
                max_attempts: opts.max_attempts,
                backoff_ms: opts.backoff_ms,
            };
            match svc.submit_async(&req, jopts) {
                Ok(h) => format!("ok job={} state=queued", h.id()),
                Err(e @ SubmitError::Busy { .. }) => render_err("busy", &e.to_string()),
                Err(e) => render_err("unavailable", &e.to_string()),
            }
        }
        Command::Status { job } => match svc.job(job) {
            Some(h) => render_job_status(&h.status()),
            None => unknown_job(job),
        },
        Command::Wait { job, timeout_ms } => match svc.job(job) {
            None => unknown_job(job),
            Some(h) => match timeout_ms {
                None => {
                    let _ = h.wait();
                    render_job_status(&h.status())
                }
                Some(ms) => match h.wait_timeout(std::time::Duration::from_millis(ms)) {
                    Some(_) => render_job_status(&h.status()),
                    None => render_err("timeout", &format!("job {job} still pending after {ms}ms")),
                },
            },
        },
        Command::JobResult { job } => match svc.job(job) {
            None => unknown_job(job),
            Some(h) => match h.try_result() {
                None => render_err(
                    "not_done",
                    &format!("job {job} is {}", h.status().state.name()),
                ),
                Some(Ok(outcome)) => render_response(&MapReply { id: job, outcome }),
                Some(Err(_)) => render_job_error(&h.status()),
            },
        },
        Command::Cancel { job } => match svc.cancel(job) {
            Some(st) => format!("ok job={job} cancelled=1 state={}", st.state.name()),
            None => unknown_job(job),
        },
        Command::Jobs => {
            let js = svc.jobs();
            if js.is_empty() {
                "ok count=0".to_string()
            } else {
                let list: Vec<String> =
                    js.iter().map(|s| format!("{}:{}", s.id, s.state.name())).collect();
                format!("ok count={} jobs={}", js.len(), list.join(","))
            }
        }
        Command::GraphPut { name, path, csr } => {
            let built: Result<CsrGraph> = match (&path, &csr) {
                (Some(p), _) => crate::graph::io::read_metis(std::path::Path::new(p))
                    .with_context(|| format!("read {p}")),
                (_, Some(c)) => parse_inline_csr(c),
                _ => Err(anyhow::anyhow!("graph put needs path=… or csr=…")),
            };
            match built {
                Ok(g) => {
                    let (n, m, version, replaced) = svc.put_graph(&name, Arc::new(g));
                    let mut s = format!("ok graph={name} n={n} m={m} version={version}");
                    if replaced {
                        s.push_str(" replaced=1");
                    }
                    s
                }
                Err(e) => render_error(&e),
            }
        }
        Command::GraphList => {
            let entries = svc.graph_entries();
            if entries.is_empty() {
                "ok count=0".to_string()
            } else {
                let list: Vec<String> =
                    entries.iter().map(|(name, v)| format!("{name}@v{v}")).collect();
                format!("ok count={} graphs={}", entries.len(), list.join(","))
            }
        }
        Command::GraphDrop { name } => {
            if svc.drop_graph(&name) {
                format!("ok dropped={name}")
            } else {
                render_err("unknown_graph", &format!("no pinned graph named {name}"))
            }
        }
        Command::GraphPatch { name, ops } => match crate::incremental::GraphPatch::parse(&ops) {
            Err(e) => render_err("patch", &e),
            Ok(p) => match svc.patch_graph(&name, &p) {
                Ok(s) => format!(
                    "ok graph={name} n={} m={} version={} touched={} ops={}",
                    s.n, s.m, s.version, s.touched, s.ops
                ),
                Err(PatchError::UnknownGraph(_)) => {
                    render_err("unknown_graph", &format!("no pinned graph named {name}"))
                }
                Err(PatchError::Invalid(msg)) => render_err("patch", &msg),
            },
        },
        Command::BatchSubmit { reqs, opts } => {
            let jopts = JobOptions {
                priority: opts.priority,
                deadline_ms: opts.deadline_ms,
                block_when_full: false,
                max_attempts: opts.max_attempts,
                backoff_ms: opts.backoff_ms,
            };
            match svc.submit_engine_batch(&reqs, jopts) {
                Ok((batch, handles)) => {
                    let ids: Vec<String> = handles.iter().map(|h| h.id().to_string()).collect();
                    format!("ok batch={batch} count={} jobs={}", handles.len(), ids.join(","))
                }
                Err(e @ SubmitError::Busy { .. }) => render_err("busy", &e.to_string()),
                Err(e) => render_err("unavailable", &e.to_string()),
            }
        }
        Command::BatchWait { id, timeout_ms } => match svc.batch_jobs(id) {
            None => render_err("unknown_batch", &format!("no batch with id {id}")),
            Some(jobs) => {
                let deadline = timeout_ms
                    .map(|ms| std::time::Instant::now() + std::time::Duration::from_millis(ms));
                let mut states = Vec::with_capacity(jobs.len());
                for j in &jobs {
                    // A job evicted from the retention window finished
                    // long ago; it just drops out of the tally.
                    let Some(h) = svc.job(*j) else { continue };
                    match deadline {
                        None => {
                            let _ = h.wait();
                        }
                        Some(d) => {
                            let left = d.saturating_duration_since(std::time::Instant::now());
                            if h.wait_timeout(left).is_none() {
                                return render_err(
                                    "timeout",
                                    &format!(
                                        "batch {id} still has pending jobs after {}ms",
                                        timeout_ms.unwrap_or(0)
                                    ),
                                );
                            }
                        }
                    }
                    states.push(h.status().state);
                }
                let count = |s: JobState| states.iter().filter(|&&x| x == s).count();
                format!(
                    "ok batch={id} count={} done={} failed={} cancelled={} expired={}",
                    jobs.len(),
                    count(JobState::Done),
                    count(JobState::Failed),
                    count(JobState::Cancelled),
                    count(JobState::Expired),
                )
            }
        },
    }
}

/// Parse + dispatch one request line, always producing one reply line.
pub fn handle_command(svc: &Service, line: &str) -> String {
    match parse_command(line) {
        Ok(cmd) => dispatch(svc, cmd),
        Err(e) => render_error(&e),
    }
}

/// TCP accept-loop options.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent connection cap; connections past it receive one
    /// `err code=busy` line and are closed instead of spawning a thread.
    pub max_conns: usize,
    /// Per-connection socket read/write timeout in ms; a connection that
    /// stays silent (or cannot be written to) this long is closed. `0`
    /// disables the timeout.
    pub read_timeout_ms: u64,
    /// Longest accepted request line in bytes. An oversize line is
    /// answered with `err code=toobig` and discarded through its
    /// terminating newline; the connection stays usable.
    pub max_line_len: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_conns: 64, read_timeout_ms: 120_000, max_line_len: 4 << 20 }
    }
}

/// One framed request line, or why there isn't one.
enum WireLine {
    Line(String),
    /// The line overran [`ServeOptions::max_line_len`]; its bytes were
    /// discarded through the terminating newline.
    TooLong,
    Eof,
}

/// Read one `\n`-terminated line of at most `max_len` bytes (exclusive
/// of the terminator; a trailing `\r` is stripped). Unlike
/// `BufRead::read_line`, an oversize line cannot balloon memory: its
/// bytes are dropped as they stream in and `TooLong` is reported once
/// the newline (or EOF) arrives.
fn read_bounded_line<R: std::io::BufRead>(
    reader: &mut R,
    max_len: usize,
) -> std::io::Result<WireLine> {
    let mut buf: Vec<u8> = Vec::new();
    let mut overflowed = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF; a trailing unterminated line is still served.
            return Ok(match (overflowed, buf.is_empty()) {
                (true, _) => WireLine::TooLong,
                (false, true) => WireLine::Eof,
                (false, false) => WireLine::Line(String::from_utf8_lossy(&buf).into_owned()),
            });
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            let fits = !overflowed && buf.len() + pos <= max_len;
            if fits {
                buf.extend_from_slice(&chunk[..pos]);
            }
            reader.consume(pos + 1);
            return Ok(if fits {
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                WireLine::Line(String::from_utf8_lossy(&buf).into_owned())
            } else {
                WireLine::TooLong
            });
        }
        let len = chunk.len();
        if !overflowed && buf.len() + len <= max_len {
            buf.extend_from_slice(chunk);
        } else {
            overflowed = true;
            buf.clear();
        }
        reader.consume(len);
    }
}

/// Decrements the live-connection gauge even when the handler panics.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A per-line request handler: one request line in, one reply line out.
/// [`serve_listener`] binds it to a [`Service`]; the cluster router
/// ([`crate::cluster`]) binds it to its forwarding table.
pub type LineHandler = Arc<dyn Fn(&str) -> String + Send + Sync>;

/// Serve a line protocol on an already-bound listener until the process
/// exits. Connections are thin command shells — one named thread each,
/// bounded by [`ServeOptions::max_conns`] — and every line goes through
/// `handler`.
pub fn serve_lines(
    listener: std::net::TcpListener,
    opts: ServeOptions,
    handler: LineHandler,
) -> Result<()> {
    use std::io::BufReader;
    let cap = opts.max_conns.max(1);
    let max_len = opts.max_line_len.max(1);
    let timeout = (opts.read_timeout_ms > 0)
        .then(|| std::time::Duration::from_millis(opts.read_timeout_ms));
    let active = Arc::new(AtomicUsize::new(0));
    let mut conn_seq = 0u64;
    for stream in listener.incoming() {
        let mut stream = stream?;
        if active.load(Ordering::SeqCst) >= cap {
            let _ = writeln!(stream, "{}", render_err("busy", &format!("connection limit {cap} reached")));
            continue; // dropping the stream closes it
        }
        active.fetch_add(1, Ordering::SeqCst);
        let guard = ConnGuard(active.clone());
        let handler = handler.clone();
        conn_seq += 1;
        let _ = std::thread::Builder::new().name(format!("heipa-conn-{conn_seq}")).spawn(move || {
            let _guard = guard;
            // A connection that stalls mid-line (or mid-write) is closed
            // once the socket timeout fires; `read_bounded_line` surfaces
            // the timeout as an Err and the loop below drops the stream.
            let _ = stream.set_read_timeout(timeout);
            let _ = stream.set_write_timeout(timeout);
            let Ok(read_half) = stream.try_clone() else { return };
            let mut reader = BufReader::new(read_half);
            let mut writer = stream;
            loop {
                // Fault plane: `wire_read`/`wire_write` model a flaky
                // transport — the connection drops; jobs already
                // submitted keep running and remain queryable on the
                // client's next connection.
                if fault::fire_global(FaultPoint::WireRead) {
                    break;
                }
                let reply = match read_bounded_line(&mut reader, max_len) {
                    Err(_) | Ok(WireLine::Eof) => break, // timeout, reset or clean EOF
                    Ok(WireLine::TooLong) => {
                        render_err("toobig", &format!("request line exceeds {max_len} bytes"))
                    }
                    Ok(WireLine::Line(line)) => handler(&line),
                };
                if fault::fire_global(FaultPoint::WireWrite) {
                    break;
                }
                if writer.write_all(reply.as_bytes()).and_then(|_| writer.write_all(b"\n")).is_err()
                {
                    break;
                }
            }
        });
    }
    Ok(())
}

/// Serve the job protocol on an already-bound listener until the
/// process exits: [`serve_lines`] with every line dispatched through
/// [`handle_command`] against `service`.
pub fn serve_listener(
    service: Arc<Service>,
    listener: std::net::TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    serve_lines(listener, opts, Arc::new(move |line| handle_command(&service, line)))
}

/// Bind `addr`, print the bound address, and serve forever.
pub fn serve_tcp(service: Arc<Service>, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)?;
    println!("heipa coordinator listening on {}", listener.local_addr()?);
    serve_listener(service, listener, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ServiceConfig;

    #[test]
    fn parses_map_command() {
        let cmd = parse_command(
            "map instance=rgg15 algorithm=gpu-im hierarchy=4:8:2 distance=1:10:100 eps=0.05 seed=7 polish=1",
        )
        .unwrap();
        let Command::Map { req, opts } = cmd else { panic!() };
        assert_eq!(req.instance, "rgg15");
        assert_eq!(req.algorithm, Some(Algorithm::GpuIm));
        assert_eq!(req.eps, 0.05);
        assert!(req.polish);
        assert_eq!(opts, WireSubmitOpts::default());
    }

    #[test]
    fn parses_submit_with_job_options_and_graph_alias() {
        let Command::Submit { req, opts } = parse_command(
            "submit graph=mesh topology=torus:4x4 priority=5 deadline_ms=2500 \
             max_attempts=3 backoff_ms=50 opt.adaptive=0",
        )
        .unwrap() else {
            panic!()
        };
        assert_eq!(req.instance, "mesh");
        assert_eq!(req.topology.as_deref(), Some("torus:4x4"));
        assert_eq!(opts.priority, 5);
        assert_eq!(opts.deadline_ms, Some(2500));
        assert_eq!(opts.max_attempts, Some(3));
        assert_eq!(opts.backoff_ms, Some(50));
        assert_eq!(req.options.get("adaptive").map(String::as_str), Some("0"));
        // Absent retry keys stay None so the service default applies.
        let Command::Submit { opts, .. } = parse_command("submit graph=mesh").unwrap() else {
            panic!()
        };
        assert_eq!(opts.max_attempts, None);
        assert_eq!(opts.backoff_ms, None);
        assert!(parse_command("submit graph=mesh max_attempts=lots").is_err());
    }

    #[test]
    fn parses_job_commands() {
        assert_eq!(parse_command("status job=3").unwrap(), Command::Status { job: 3 });
        assert_eq!(
            parse_command("wait job=4 timeout_ms=100").unwrap(),
            Command::Wait { job: 4, timeout_ms: Some(100) }
        );
        assert_eq!(parse_command("wait job=4").unwrap(), Command::Wait { job: 4, timeout_ms: None });
        assert_eq!(parse_command("result job=5").unwrap(), Command::JobResult { job: 5 });
        assert_eq!(parse_command("cancel job=6").unwrap(), Command::Cancel { job: 6 });
        assert_eq!(parse_command("jobs").unwrap(), Command::Jobs);
        assert!(parse_command("status").is_err(), "job= is required");
        assert!(parse_command("wait job=x").is_err());
    }

    #[test]
    fn parses_graph_session_commands() {
        assert_eq!(
            parse_command("graph put name=m path=/tmp/m.graph").unwrap(),
            Command::GraphPut { name: "m".into(), path: Some("/tmp/m.graph".into()), csr: None }
        );
        assert_eq!(
            parse_command("graph put name=t csr=0,1/0").unwrap(),
            Command::GraphPut { name: "t".into(), path: None, csr: Some("0,1/0".into()) }
        );
        assert_eq!(parse_command("graph list").unwrap(), Command::GraphList);
        assert_eq!(parse_command("graph del name=m").unwrap(), Command::GraphDrop { name: "m".into() });
        assert!(parse_command("graph put name=m").is_err(), "path xor csr required");
        assert!(parse_command("graph put name=m path=a csr=b").is_err());
        assert!(parse_command("graph frob").is_err());
    }

    #[test]
    fn auto_algorithm_unpins() {
        let Command::Map { req, .. } = parse_command("map instance=x algorithm=auto").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.algorithm, None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_command("").is_err());
        assert!(parse_command("frob instance=x").is_err());
        assert!(parse_command("map").is_err());
        assert!(parse_command("submit").is_err());
        assert!(parse_command("map instance=x bad").is_err());
        assert!(parse_command("map instance=x algorithm=nope").is_err());
        assert!(parse_command("map instance=x refinement=nope").is_err());
        assert!(parse_command("submit instance=x priority=high").is_err());
    }

    #[test]
    fn parses_topology_key() {
        let Command::Map { req, .. } = parse_command("map instance=x topology=torus:4x4x4").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.topology.as_deref(), Some("torus:4x4x4"));
        assert_eq!(req.to_spec().machine().unwrap().k(), 64);
    }

    #[test]
    fn parses_coarsening_key() {
        let Command::Map { req, .. } =
            parse_command("map instance=x coarsening=cluster").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.coarsening, SchemeKind::Cluster);
        assert_eq!(req.to_spec().coarsening, SchemeKind::Cluster);
        assert!(parse_command("map instance=x coarsening=bogus").is_err());
        // Default when absent.
        let Command::Map { req, .. } = parse_command("map instance=x").unwrap() else { panic!() };
        assert_eq!(req.coarsening, SchemeKind::Auto);
    }

    #[test]
    fn parses_refinement_and_solver_options() {
        let Command::Map { req, .. } =
            parse_command("map instance=x refinement=strong opt.adaptive=0").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.refinement, Refinement::Strong);
        assert_eq!(req.options.get("adaptive").map(String::as_str), Some("0"));
    }

    #[test]
    fn error_messages_round_trip_through_escaping() {
        // Regression: render_error used to replace every space with `_`,
        // mangling messages beyond recovery.
        let original = "instance `no such thing` is neither\na registry name (100% sure)";
        let line = render_err("parse", original);
        assert!(line.starts_with("err code=parse message="), "{line}");
        let value = line.split_once("message=").unwrap().1;
        assert!(!value.contains(' ') && !value.contains('\n'), "raw separators leaked: {line}");
        assert_eq!(unescape_value(value), original);
        // Escaping is idempotent through one round trip, including `%`.
        assert_eq!(unescape_value(&escape_value("a%20b c")), "a%20b c");
        // Unknown escapes pass through.
        assert_eq!(unescape_value("x%zz"), "x%zz");
    }

    #[test]
    fn inline_csr_parses_and_validates() {
        // Triangle: 3 vertices, each adjacent to the other two.
        let g = parse_inline_csr("0,2,4,6/1,2,0,2,0,1").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        // Weighted variant.
        let g = parse_inline_csr("0,1,2/1,0/2.5,2.5/3,4").unwrap();
        assert_eq!(g.vw, vec![3, 4]);
        assert_eq!(g.ew, vec![2.5, 2.5]);
        // Asymmetric adjacency is rejected by validation.
        assert!(parse_inline_csr("0,1,1/1").is_err());
        // Length mismatches are rejected.
        assert!(parse_inline_csr("0,2,4,6/1,2,0,2,0,1/1.0").is_err());
        assert!(parse_inline_csr("").is_err());
    }

    #[test]
    fn response_rendering_roundtrips_keys() {
        let r = MapReply {
            id: 3,
            outcome: crate::engine::MapOutcome {
                algorithm: Algorithm::GpuHm,
                n: 10,
                k: 4,
                seed: 1,
                mapping: vec![0, 1, 2, 3],
                comm_cost: 123.5,
                imbalance: 0.01,
                host_ms: 5.0,
                device_ms: 0.2,
                phases: None,
                polish_improvement: 1.0,
                hierarchy_cache: Some(true),
                degraded: false,
                attempts: 1,
                remap: None,
                backend: Backend::Cpu,
            },
        };
        let line = render_response(&r);
        assert!(line.starts_with("ok id=3 algorithm=gpu-hm"));
        assert!(line.contains(" hier_cache=hit"));
        assert!(line.contains("mapping=0,1,2,3"));
        // First-try, non-degraded cpu outcomes stay byte-compatible with
        // the pre-retry, pre-offload wire format.
        assert!(
            !line.contains("degraded")
                && !line.contains("attempts")
                && !line.contains("remap")
                && !line.contains("backend"),
            "{line}"
        );
        let mut r = r;
        r.outcome.degraded = true;
        r.outcome.attempts = 3;
        r.outcome.remap = Some(crate::engine::RemapKind::Warm);
        let line = render_response(&r);
        assert!(line.contains(" degraded=1"), "{line}");
        assert!(line.contains(" attempts=3"), "{line}");
        assert!(line.contains(" remap=warm"), "{line}");
        r.outcome.remap = Some(crate::engine::RemapKind::Cold);
        assert!(render_response(&r).contains(" remap=cold"));
        r.outcome.backend = Backend::Device;
        assert!(render_response(&r).contains(" backend=device"));
    }

    #[test]
    fn parses_backend_key() {
        let Command::Map { req, .. } = parse_command("map instance=x backend=device").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.backend, Backend::Device);
        let Command::Submit { req, .. } = parse_command("submit instance=x backend=auto").unwrap()
        else {
            panic!()
        };
        assert_eq!(req.backend, Backend::Auto);
        // Default when absent; bogus values are parse errors.
        let Command::Map { req, .. } = parse_command("map instance=x").unwrap() else { panic!() };
        assert_eq!(req.backend, Backend::Cpu);
        assert!(parse_command("map instance=x backend=tpu").is_err());
    }

    fn quick_service() -> Service {
        Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() })
    }

    #[test]
    fn dispatcher_drives_the_full_job_lifecycle() {
        let svc = quick_service();
        let reply = handle_command(
            &svc,
            "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 seed=1",
        );
        assert!(reply.starts_with("ok job="), "{reply}");
        let job: u64 = reply
            .split_whitespace()
            .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
            .expect("job id");
        let wait = handle_command(&svc, &format!("wait job={job}"));
        assert!(wait.contains("state=done"), "{wait}");
        let result = handle_command(&svc, &format!("result job={job}"));
        assert!(result.starts_with("ok id="), "{result}");
        assert!(result.contains(" j="), "{result}");
        let jobs = handle_command(&svc, "jobs");
        assert!(jobs.contains(&format!("{job}:done")), "{jobs}");
        // Unknown ids are coded errors.
        assert!(handle_command(&svc, "status job=999").starts_with("err code=unknown_job"));
        assert!(handle_command(&svc, "result job=999").starts_with("err code=unknown_job"));
        assert!(handle_command(&svc, "cancel job=999").starts_with("err code=unknown_job"));
    }

    #[test]
    fn dispatcher_submit_returns_before_the_solve_and_cancel_works() {
        let svc = quick_service();
        let reply = handle_command(
            &svc,
            "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 opt.__sleep_ms=60000",
        );
        let job: u64 = reply
            .split_whitespace()
            .find_map(|t| t.strip_prefix("job=").and_then(|v| v.parse().ok()))
            .unwrap_or_else(|| panic!("no job id in {reply}"));
        let status = handle_command(&svc, &format!("status job={job}"));
        assert!(
            status.contains("state=queued") || status.contains("state=running"),
            "submit blocked until completion: {status}"
        );
        // result before completion → not_done.
        let early = handle_command(&svc, &format!("result job={job}"));
        assert!(early.starts_with("err code=not_done"), "{early}");
        // A bounded wait times out while the job sleeps.
        let t = handle_command(&svc, &format!("wait job={job} timeout_ms=50"));
        assert!(t.starts_with("err code=timeout"), "{t}");
        let c = handle_command(&svc, &format!("cancel job={job}"));
        assert!(c.starts_with("ok job="), "{c}");
        let w = handle_command(&svc, &format!("wait job={job}"));
        assert!(w.contains("state=cancelled"), "{w}");
        let r = handle_command(&svc, &format!("result job={job}"));
        assert!(r.starts_with("err code=cancelled"), "{r}");
    }

    #[test]
    fn dispatcher_reports_busy_with_a_distinct_code() {
        let svc = Service::with_config(ServiceConfig {
            threads: 1,
            workers: 1,
            queue_cap: 1,
            ..Default::default()
        });
        let slow = "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 opt.__sleep_ms=3000";
        let first = handle_command(&svc, slow);
        assert!(first.starts_with("ok job="), "{first}");
        // Wait for the worker to pick the first job up, then fill the queue.
        while svc.engine().queue_depth() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let second = handle_command(&svc, slow);
        assert!(second.starts_with("ok job="), "{second}");
        let third = handle_command(&svc, slow);
        assert!(third.starts_with("err code=busy"), "{third}");
        assert!(svc.metrics().busy_rejections >= 1);
        // Cancel the backlog so the test exits promptly.
        for id in 1..=2u64 {
            handle_command(&svc, &format!("cancel job={id}"));
            handle_command(&svc, &format!("wait job={id}"));
        }
    }

    #[test]
    fn bounded_reader_frames_lines_and_survives_oversize() {
        use std::io::Cursor;
        let mut r = Cursor::new(b"ping\r\nmetrics\n".to_vec());
        let WireLine::Line(l) = read_bounded_line(&mut r, 16).unwrap() else { panic!() };
        assert_eq!(l, "ping");
        let WireLine::Line(l) = read_bounded_line(&mut r, 16).unwrap() else { panic!() };
        assert_eq!(l, "metrics");
        assert!(matches!(read_bounded_line(&mut r, 16).unwrap(), WireLine::Eof));

        // Oversize line: reported once, discarded through its newline;
        // the connection stays usable for the next request. A tiny
        // BufReader forces the chunked overflow path.
        let mut big = vec![b'x'; 100];
        big.push(b'\n');
        big.extend_from_slice(b"ping\n");
        let mut r = std::io::BufReader::with_capacity(3, Cursor::new(big));
        assert!(matches!(read_bounded_line(&mut r, 8).unwrap(), WireLine::TooLong));
        let WireLine::Line(l) = read_bounded_line(&mut r, 8).unwrap() else { panic!() };
        assert_eq!(l, "ping");

        // A line of exactly max_len bytes fits.
        let mut r = Cursor::new(b"12345678\n".to_vec());
        let WireLine::Line(l) = read_bounded_line(&mut r, 8).unwrap() else { panic!() };
        assert_eq!(l, "12345678");

        // Unterminated trailing lines are served; oversize ones are not.
        let mut r = Cursor::new(b"tail".to_vec());
        let WireLine::Line(l) = read_bounded_line(&mut r, 8).unwrap() else { panic!() };
        assert_eq!(l, "tail");
        let mut r = Cursor::new(vec![b'y'; 50]);
        assert!(matches!(read_bounded_line(&mut r, 8).unwrap(), WireLine::TooLong));
    }

    /// Every reply is `ok …` or `err code=<known>` — no panics, no
    /// unframed text — for any input line.
    fn assert_typed(reply: &str, line: &str) {
        const CODES: &[&str] = &[
            "parse", "toobig", "busy", "unknown_job", "unknown_graph", "unknown_batch",
            "not_done", "timeout", "failed", "cancelled", "expired", "patch", "unavailable",
        ];
        if reply == "ok" || reply.starts_with("ok ") {
            return;
        }
        let code = reply
            .strip_prefix("err code=")
            .and_then(|r| r.split_whitespace().next())
            .unwrap_or_else(|| panic!("unframed reply for {line:?}: {reply}"));
        assert!(CODES.contains(&code), "unknown code `{code}` for {line:?}: {reply}");
    }

    #[test]
    fn garbage_lines_always_get_a_typed_reply() {
        let svc = quick_service();
        // Seeded fuzz over protocol fragments: verbs, half-formed keys,
        // broken escapes, overflowing numbers, binary-ish noise.
        const FRAGS: &[&str] = &[
            "map", "submit", "status", "wait", "result", "cancel", "graph", "put", "del",
            "jobs", "metrics", "ping", "instance=", "graph=", "job=", "csr=", "name=",
            "algorithm=gpu-im", "algorithm=", "hierarchy=2:2", "deadline_ms=",
            "max_attempts=", "backoff_ms=", "opt.", "=", "=x", "%", "%2", "%25", "%zz",
            "0,2,4/1,0,1", "/", ",", ":", "\t", "\u{1F4A5}", "-1",
            "18446744073709551616", "priority=high", "job=0x10",
            "patch", "batch", "ops=", "ops=ae:0:1:1.0", "ops=zz", "id=", "jobs=", ";",
            "jobs=instance%3Dx", "ae:0:1", "rv:",
            "drain", "cluster", "nodes", "route", "timeout_ms=5",
        ];
        let mut state = 0xC0FFEE_u64;
        for _ in 0..500 {
            let parts = 1 + (crate::rng::splitmix64(&mut state) % 6) as usize;
            let line: Vec<&str> = (0..parts)
                .map(|_| FRAGS[(crate::rng::splitmix64(&mut state) % FRAGS.len() as u64) as usize])
                .collect();
            let line = line.join(" ");
            assert_typed(&handle_command(&svc, &line), &line);
        }
    }

    #[test]
    fn truncated_commands_always_get_a_typed_reply() {
        let svc = quick_service();
        // Every split point of a valid upload — both halves of a csr
        // payload cut mid-token included — must yield a framed reply.
        let full = "graph put name=tri csr=0,2,4,6/1,2,0,2,0,1";
        for cut in 0..=full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            assert_typed(&handle_command(&svc, &full[..cut]), &full[..cut]);
            assert_typed(&handle_command(&svc, &full[cut..]), &full[cut..]);
        }
        // The intact command still works afterwards.
        assert!(handle_command(&svc, full).starts_with("ok graph=tri"));
    }

    #[test]
    fn dispatcher_graph_sessions_upload_once_map_many() {
        let svc = quick_service();
        // An 8-cycle uploaded inline.
        let put = handle_command(
            &svc,
            "graph put name=ring csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6",
        );
        assert_eq!(put, "ok graph=ring n=8 m=8 version=1");
        assert_eq!(handle_command(&svc, "graph list"), "ok count=1 graphs=ring@v1");
        // Two jobs over the same pinned graph, different machines.
        for (hier, dist, k) in [("2:2", "1:10", 4), ("4", "1", 4)] {
            let reply = handle_command(
                &svc,
                &format!("map graph=ring algorithm=sharedmap-f hierarchy={hier} distance={dist} eps=0.3"),
            );
            assert!(reply.starts_with("ok id="), "{hier}: {reply}");
            assert!(reply.contains(&format!("k={k}")), "{hier}: {reply}");
        }
        assert_eq!(handle_command(&svc, "graph del name=ring"), "ok dropped=ring");
        assert!(handle_command(&svc, "graph del name=ring").starts_with("err code=unknown_graph"));
        assert_eq!(handle_command(&svc, "graph list"), "ok count=0");
    }

    #[test]
    fn parses_batch_and_patch_commands() {
        assert_eq!(
            parse_command("graph patch name=m ops=ae:0:5:2.0,re:1:2").unwrap(),
            Command::GraphPatch { name: "m".into(), ops: "ae:0:5:2.0,re:1:2".into() }
        );
        assert!(parse_command("graph patch name=m").is_err(), "ops= required");
        assert!(parse_command("graph patch ops=ae:0:1:1").is_err(), "name= required");
        let line = format!(
            "batch submit jobs={};{}",
            escape_value("graph=g hierarchy=2:2 distance=1:10 priority=3"),
            escape_value("graph=g hierarchy=2:2 distance=1:10 seed=2"),
        );
        let Command::BatchSubmit { reqs, opts } = parse_command(&line).unwrap() else { panic!() };
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[1].seed, 2);
        assert_eq!(opts.priority, 3, "first job's options apply to the batch");
        assert_eq!(
            parse_command("batch wait id=7 timeout_ms=100").unwrap(),
            Command::BatchWait { id: 7, timeout_ms: Some(100) }
        );
        assert_eq!(
            parse_command("batch wait id=7").unwrap(),
            Command::BatchWait { id: 7, timeout_ms: None }
        );
        assert!(parse_command("batch submit").is_err());
        assert!(parse_command("batch submit jobs=;").is_err(), "empty batch");
        assert!(parse_command("batch submit jobs=nokv").is_err(), "jobs must be key=value");
        assert!(parse_command("batch wait").is_err());
        assert!(parse_command("batch frob").is_err());
    }

    #[test]
    fn ping_reports_typed_health_fields() {
        let svc = quick_service();
        let reply = handle_command(&svc, "ping");
        assert_eq!(
            reply,
            format!("ok version={} queue_depth=0 in_flight=0 graphs=0", env!("CARGO_PKG_VERSION"))
        );
        handle_command(&svc, "graph put name=t csr=0,2,4,6/1,2,0,2,0,1");
        assert!(handle_command(&svc, "ping").ends_with(" graphs=1"));
    }

    #[test]
    fn parses_drain_and_cluster_commands() {
        assert_eq!(parse_command("drain").unwrap(), Command::Drain { timeout_ms: None });
        assert_eq!(
            parse_command("drain timeout_ms=250").unwrap(),
            Command::Drain { timeout_ms: Some(250) }
        );
        assert!(parse_command("drain timeout_ms=x").is_err());
        assert_eq!(parse_command("cluster nodes").unwrap(), Command::ClusterNodes);
        assert_eq!(
            parse_command("cluster route name=m").unwrap(),
            Command::ClusterRoute { name: "m".into() }
        );
        assert!(parse_command("cluster route").is_err(), "name= required");
        assert!(parse_command("cluster frob").is_err());
    }

    #[test]
    fn dispatcher_drains_and_refuses_new_work() {
        let svc = quick_service();
        assert_eq!(handle_command(&svc, "drain timeout_ms=2000"), "ok drained=1");
        let refused = handle_command(
            &svc,
            "submit instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10",
        );
        assert!(refused.starts_with("err code=unavailable"), "{refused}");
        // Idempotent: a second drain of an already-drained service is ok.
        assert_eq!(handle_command(&svc, "drain timeout_ms=2000"), "ok drained=1");
    }

    #[test]
    fn cluster_verbs_on_a_plain_node_answer_for_self() {
        let svc = quick_service();
        assert_eq!(handle_command(&svc, "cluster nodes"), "ok count=1 nodes=self/up/0/0");
        assert!(
            handle_command(&svc, "cluster route name=m").starts_with("err code=unknown_graph")
        );
        handle_command(&svc, "graph put name=m csr=0,2,4,6/1,2,0,2,0,1");
        assert_eq!(handle_command(&svc, "cluster route name=m"), "ok graph=m owners=self");
    }

    #[test]
    fn dispatcher_patches_and_warm_remaps_over_the_wire() {
        let svc = quick_service();
        let put = handle_command(
            &svc,
            "graph put name=ring csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6",
        );
        assert_eq!(put, "ok graph=ring n=8 m=8 version=1");
        // Pin gpu-im (the warm path needs a solver with cacheable
        // hierarchy params) and lift the region cap — on an 8-vertex ring
        // the one-hop halo of any edge covers most of the graph.
        let map_cmd = "map graph=ring algorithm=gpu-im hierarchy=2:2 distance=1:10 \
                       eps=0.3 seed=1 opt.remap.max_region_frac=1";
        let first = handle_command(&svc, map_cmd);
        assert!(first.starts_with("ok id="), "{first}");
        assert!(!first.contains("remap="), "{first}");
        let patch = handle_command(&svc, "graph patch name=ring ops=ae:0:4:1.0");
        assert_eq!(patch, "ok graph=ring n=8 m=9 version=2 touched=2 ops=1");
        assert_eq!(handle_command(&svc, "graph list"), "ok count=1 graphs=ring@v2");
        let second = handle_command(&svc, map_cmd);
        assert!(second.contains(" remap=warm"), "{second}");
        // Re-putting over the live session replaces it.
        let reput = handle_command(
            &svc,
            "graph put name=ring csr=0,2,4,6,8,10,12,14,16/1,7,0,2,1,3,2,4,3,5,4,6,5,7,0,6",
        );
        assert_eq!(reput, "ok graph=ring n=8 m=8 version=3 replaced=1");
        // Bad ops and unknown names are typed errors.
        assert!(handle_command(&svc, "graph patch name=ring ops=zz").starts_with("err code=patch"));
        assert!(handle_command(&svc, "graph patch name=nope ops=re:0:1")
            .starts_with("err code=unknown_graph"));
        // Structurally inapplicable patches (removing a missing edge) too.
        assert!(handle_command(&svc, "graph patch name=ring ops=re:2:6")
            .starts_with("err code=patch"));
        let metrics = handle_command(&svc, "metrics");
        assert!(metrics.contains(" patches=1 "), "{metrics}");
        assert!(metrics.contains(" graphs_replaced=1 "), "{metrics}");
        assert!(metrics.contains(" warm_remaps=1 "), "{metrics}");
    }

    #[test]
    fn dispatcher_batch_submit_and_wait() {
        let svc = quick_service();
        let body = |seed: u64| {
            escape_value(&format!(
                "instance=wal_598a algorithm=sharedmap-f hierarchy=2:2 distance=1:10 seed={seed}"
            ))
        };
        let reply = handle_command(&svc, &format!("batch submit jobs={};{}", body(1), body(2)));
        assert!(reply.starts_with("ok batch="), "{reply}");
        assert!(reply.contains("count=2"), "{reply}");
        let batch: u64 = reply
            .split_whitespace()
            .find_map(|t| t.strip_prefix("batch=").and_then(|v| v.parse().ok()))
            .expect("batch id");
        let wait = handle_command(&svc, &format!("batch wait id={batch}"));
        assert_eq!(
            wait,
            format!("ok batch={batch} count=2 done=2 failed=0 cancelled=0 expired=0")
        );
        assert!(handle_command(&svc, "batch wait id=99").starts_with("err code=unknown_batch"));
        let m = svc.metrics();
        assert_eq!((m.batches, m.batched_jobs, m.requests), (1, 2, 2));
    }
}
