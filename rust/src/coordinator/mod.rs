//! Process-mapping-as-a-service: the L3 coordinator.
//!
//! A deployment of this library is a long-running *mapping service*: HPC
//! schedulers submit task graphs and machine hierarchies and receive
//! vertex → PE mappings. The coordinator owns
//!
//! * a **router** that picks an algorithm per request (quality-optimal
//!   GPU-HM-ultra for small graphs, throughput-optimal GPU-IM for large
//!   ones) unless the client pins one,
//! * a single-consumer **job queue** feeding a worker thread that owns the
//!   device pool and the PJRT [`crate::runtime::Runtime`] (one client per
//!   device, mirroring the paper's one-GPU setup),
//! * an optional **QAP polish** stage that refines the block → PE
//!   assignment with the offloaded all-pairs swap kernel, and
//! * service **metrics** (requests, per-algorithm counts, device time).
//!
//! Front-ends: an in-process handle ([`service::Service::submit`]) and a
//! line-oriented TCP protocol ([`protocol`], `heipa serve`).

pub mod protocol;
pub mod service;

use crate::algo::Algorithm;

/// A mapping request.
#[derive(Clone, Debug, PartialEq)]
pub struct MapRequest {
    /// Instance registry name (`rgg15`, …) or a path to a METIS file.
    pub instance: String,
    /// Pinned algorithm, or `None` for router choice.
    pub algorithm: Option<Algorithm>,
    pub hierarchy: String,
    pub distance: String,
    pub eps: f64,
    pub seed: u64,
    /// Run the offloaded QAP polish stage after mapping.
    pub polish: bool,
    /// Return the full mapping vector in the response.
    pub return_mapping: bool,
}

impl Default for MapRequest {
    fn default() -> Self {
        MapRequest {
            instance: String::new(),
            algorithm: None,
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            polish: false,
            return_mapping: false,
        }
    }
}

/// A mapping response.
#[derive(Clone, Debug)]
pub struct MapResponse {
    pub id: u64,
    pub algorithm: Algorithm,
    pub n: usize,
    pub k: usize,
    pub comm_cost: f64,
    pub imbalance: f64,
    pub host_ms: f64,
    pub device_ms: f64,
    /// J improvement from the polish stage (0 when disabled).
    pub polish_improvement: f64,
    /// The mapping, when requested.
    pub mapping: Option<Vec<crate::Block>>,
}

/// Router policy: which algorithm serves a request that did not pin one.
/// Small graphs get the quality flavor, large ones the throughput flavor
/// (threshold = the suite's size-class boundary).
pub fn route(n: usize, pinned: Option<Algorithm>) -> Algorithm {
    if let Some(a) = pinned {
        return a;
    }
    if n <= 60_000 {
        Algorithm::GpuHmUltra
    } else {
        Algorithm::GpuIm
    }
}

/// Service metrics snapshot.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    pub requests: u64,
    pub failures: u64,
    pub total_host_ms: f64,
    pub total_device_ms: f64,
    pub per_algorithm: std::collections::BTreeMap<&'static str, u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_prefers_quality_for_small() {
        assert_eq!(route(10_000, None), Algorithm::GpuHmUltra);
        assert_eq!(route(1_000_000, None), Algorithm::GpuIm);
        assert_eq!(route(10, Some(Algorithm::IntMapS)), Algorithm::IntMapS);
    }
}
