//! Process-mapping-as-a-service: the L3 coordinator.
//!
//! A deployment of this library is a long-running *mapping service*: HPC
//! schedulers submit task graphs and machine hierarchies and receive
//! vertex → PE mappings. The coordinator is a thin shell around one
//! asynchronous [`crate::engine::Engine`]:
//!
//! * the engine's **job API** — `submit` returns a job id immediately,
//!   jobs run on the engine's worker pool behind a bounded priority
//!   queue, and clients `status`/`wait`/`result`/`cancel` by id,
//! * **graph-as-resource sessions** — `graph put` pins a task graph
//!   server-side (`Arc<CsrGraph>` shared across jobs, workers and
//!   connections) for the upload-once/map-many pattern; `graph patch`
//!   edits the pinned graph in place (bumping its session version and
//!   arming warm-start incremental remapping — see
//!   [`crate::incremental`]) and `batch submit` admits several jobs as
//!   one all-or-nothing unit,
//! * the wire-level [`MapRequest`], which lowers into the engine's
//!   [`MapSpec`] (routing, refinement upgrade and the QAP polish stage all
//!   happen inside the engine, identically to every other front-end), and
//! * service **metrics** (requests, per-algorithm counts, queue depth,
//!   in-flight/cancelled/deadline-missed counters, device time) kept in
//!   atomics — a panicked job cannot poison them.
//!
//! Front-ends: an in-process handle ([`service::Service`]) and a
//! line-oriented TCP protocol ([`protocol`], `heipa serve` / `heipa
//! client`) with a bounded connection pool. A fleet of these processes
//! scales horizontally behind the [`crate::cluster`] router, which
//! speaks the same protocol and needs nothing from a node beyond the
//! typed `ping`, `drain` and `cluster …` verbs every coordinator
//! answers for itself.

pub mod protocol;
pub mod service;

use crate::algo::Algorithm;
use crate::engine::{Backend, GraphSource, MapOutcome, MapSpec, Refinement};
use crate::multilevel::SchemeKind;
use anyhow::{bail, Result};

pub use crate::engine::route;

/// A mapping request — the wire-level form of a [`MapSpec`]. One seed per
/// request; clients fan seeds out as separate requests.
#[derive(Clone, Debug, PartialEq)]
pub struct MapRequest {
    /// Instance registry name (`rgg15`, …) or a path to a METIS file.
    pub instance: String,
    /// Pinned algorithm, or `None` for router choice.
    pub algorithm: Option<Algorithm>,
    pub hierarchy: String,
    pub distance: String,
    /// Machine-model spec (`topology=torus:4x4x4` on the wire); overrides
    /// `hierarchy`/`distance` when set.
    pub topology: Option<String>,
    pub eps: f64,
    pub seed: u64,
    pub refinement: Refinement,
    /// Multilevel coarsening scheme (`coarsening=matching|cluster|auto`
    /// on the wire).
    pub coarsening: SchemeKind,
    /// Run the QAP polish stage after mapping.
    pub polish: bool,
    /// Execution backend for the hot kernels (`backend=cpu|device|auto`
    /// on the wire; the reply carries the backend actually used).
    pub backend: Backend,
    /// Return the full mapping vector in the reply.
    pub return_mapping: bool,
    /// Solver-specific options (`opt.NAME=value` on the wire).
    pub options: std::collections::BTreeMap<String, String>,
}

impl Default for MapRequest {
    fn default() -> Self {
        MapRequest {
            instance: String::new(),
            algorithm: None,
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            topology: None,
            eps: 0.03,
            seed: 1,
            refinement: Refinement::Standard,
            coarsening: SchemeKind::Auto,
            polish: false,
            backend: Backend::Cpu,
            return_mapping: false,
            options: std::collections::BTreeMap::new(),
        }
    }
}

impl MapRequest {
    /// Lower into the engine's spec.
    pub fn to_spec(&self) -> MapSpec {
        let mut spec = MapSpec::named(self.instance.clone())
            .hierarchy(self.hierarchy.clone())
            .distance(self.distance.clone())
            .eps(self.eps)
            .seed(self.seed)
            .algo(self.algorithm)
            .refinement(self.refinement)
            .coarsening(self.coarsening)
            .polish(self.polish)
            .backend(self.backend)
            .return_mapping(self.return_mapping)
            .options(self.options.clone());
        spec.topology = self.topology.clone();
        spec
    }

    /// Lift a spec onto the wire. Fails for in-memory graphs and for
    /// machines whose spec string does not round-trip on another host
    /// (e.g. an in-memory `MatrixModel` — the line protocol cannot carry
    /// either); multi-seed specs lower to their primary seed.
    pub fn from_spec(spec: &MapSpec) -> Result<MapRequest> {
        let GraphSource::Named(instance) = &spec.graph else {
            bail!("in-memory graphs cannot be sent over the wire");
        };
        if let Some(m) = spec.cached_machine() {
            if !m.spec_round_trips() {
                bail!(
                    "machine `{}` cannot be sent over the wire (its spec string does not \
                     round-trip on another host; write it to a file and use file:PATH)",
                    m.label()
                );
            }
        }
        Ok(MapRequest {
            instance: instance.clone(),
            algorithm: spec.algorithm,
            hierarchy: spec.hierarchy.clone(),
            distance: spec.distance.clone(),
            topology: spec.topology.clone(),
            eps: spec.eps,
            seed: spec.primary_seed(),
            refinement: spec.refinement,
            coarsening: spec.coarsening,
            polish: spec.polish,
            backend: spec.backend,
            return_mapping: spec.return_mapping,
            options: spec.options.clone(),
        })
    }
}

/// A service reply: the request id plus the engine's unified outcome.
/// `outcome.mapping` is empty unless the request set `return_mapping`.
#[derive(Clone, Debug)]
pub struct MapReply {
    pub id: u64,
    pub outcome: MapOutcome,
}

/// Service metrics snapshot. Counters are cumulative since service
/// start; `queue_depth` and `in_flight` are point-in-time gauges.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    /// Jobs accepted (blocking `map` and async `submit` alike).
    pub requests: u64,
    /// Jobs that reached `Failed` (bad spec, solver error or panic).
    pub failures: u64,
    /// Jobs that reached `Done`.
    pub completed: u64,
    /// Jobs that reached `Cancelled`.
    pub cancelled: u64,
    /// Jobs that reached `Expired` (per-job deadline missed).
    pub deadline_missed: u64,
    /// Submits rejected because the bounded job queue was full.
    pub busy_rejections: u64,
    /// Jobs whose multilevel hierarchy came from the engine's hierarchy
    /// cache (cumulative).
    pub hierarchy_cache_hits: u64,
    /// Jobs that built (and cached) their multilevel hierarchy
    /// (cumulative).
    pub hierarchy_cache_misses: u64,
    /// Failed attempts re-queued for retry (cumulative).
    pub retries: u64,
    /// Failures attributed to the fault plane (`HEIPA_FAULTS` /
    /// `opt.__fault.*`), cumulative across attempts.
    pub faults_injected: u64,
    /// Jobs that completed through the degradation fallback chain (their
    /// outcomes carry `degraded=1` on the wire).
    pub degraded_completions: u64,
    /// Graph patches applied to pinned session graphs (cumulative).
    pub patches_applied: u64,
    /// Session re-puts that replaced an existing pinned graph.
    pub graphs_replaced: u64,
    /// Jobs answered by warm-start region refinement after a patch
    /// (`remap=warm` on the wire).
    pub warm_remaps: u64,
    /// Patched sessions that fell back to a full cold solve
    /// (`remap=cold` on the wire).
    pub cold_fallbacks: u64,
    /// Engine batches admitted via `batch submit` (cumulative).
    pub batches: u64,
    /// Jobs submitted through those batches (cumulative).
    pub batched_jobs: u64,
    /// PJRT kernel launches issued by the device backend (cumulative;
    /// includes the QAP polish offload).
    pub device_launches: u64,
    /// Bytes uploaded host→device (cumulative). Stays flat across repeat
    /// jobs over a pinned session graph — the upload-once contract.
    pub h2d_bytes: u64,
    /// Bytes downloaded device→host (cumulative).
    pub d2h_bytes: u64,
    /// `backend=device` jobs that fell back to the CPU pool because the
    /// runtime or an artifact was missing (cumulative).
    pub backend_fallbacks: u64,
    /// Jobs currently waiting in the queue (gauge).
    pub queue_depth: usize,
    /// Jobs currently being solved (gauge).
    pub in_flight: usize,
    pub total_host_ms: f64,
    pub total_device_ms: f64,
    pub per_algorithm: std::collections::BTreeMap<&'static str, u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_prefers_quality_for_small() {
        assert_eq!(route(10_000, None), Algorithm::GpuHmUltra);
        assert_eq!(route(1_000_000, None), Algorithm::GpuIm);
        assert_eq!(route(10, Some(Algorithm::IntMapS)), Algorithm::IntMapS);
    }

    #[test]
    fn request_spec_roundtrip() {
        let mut req = MapRequest {
            instance: "rgg15".into(),
            algorithm: Some(Algorithm::GpuIm),
            hierarchy: "4:8:2".into(),
            distance: "1:10:100".into(),
            topology: Some("torus:4x4".into()),
            eps: 0.05,
            seed: 9,
            refinement: Refinement::Strong,
            coarsening: SchemeKind::Cluster,
            polish: true,
            backend: Backend::Auto,
            return_mapping: true,
            options: std::collections::BTreeMap::new(),
        };
        req.options.insert("adaptive".into(), "0".into());
        let spec = req.to_spec();
        assert_eq!(spec.primary_seed(), 9);
        assert_eq!(MapRequest::from_spec(&spec).unwrap(), req);
    }

    #[test]
    fn in_memory_specs_do_not_lower() {
        let g = std::sync::Arc::new(crate::graph::gen::grid2d(4, 4, false));
        assert!(MapRequest::from_spec(&MapSpec::in_memory(g)).is_err());
    }

    #[test]
    fn non_round_trippable_machines_do_not_lower() {
        // An in-memory matrix model's `file:inline` spec would resolve to
        // a different (or missing) machine on the server — reject it.
        let model = crate::topology::MatrixModel::from_text("2\n0 1\n1 0", "inline").unwrap();
        let m = crate::topology::Machine::from_model(model).unwrap();
        let spec = MapSpec::named("rgg15").topology(&m);
        let err = MapRequest::from_spec(&spec).unwrap_err();
        assert!(err.to_string().contains("round-trip"), "{err}");
        // Parse-able specs still lower fine.
        let t = crate::topology::Machine::parse_spec("torus:4x4").unwrap();
        assert!(MapRequest::from_spec(&MapSpec::named("rgg15").topology(&t)).is_ok());
    }
}
