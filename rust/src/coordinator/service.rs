//! The coordinator service: wire-level job bookkeeping around one
//! asynchronous [`crate::engine::Engine`]. Graph caching (pinned session
//! tier + bounded LRU), algorithm routing, the worker pool and the
//! optional device-offloaded QAP polish all happen inside the engine —
//! the service tracks job handles for the wire protocol and keeps
//! metrics.
//!
//! Metrics live in atomics (plus one poison-recovering mutex for the
//! per-algorithm map), so a panicking job can never take the whole
//! service down with a poisoned lock — regression-tested with the
//! fault plane's `solve` injection point (`opt.__fault.solve`).

use super::{MapReply, MapRequest, ServiceMetrics};
use crate::engine::{
    Engine, EngineConfig, JobHandle, JobState, JobStatus, MapOutcome, RetryPolicy, SubmitError,
    SubmitOpts,
};
use crate::graph::CsrGraph;
use crate::incremental::{GraphPatch, PatchError, PatchSummary};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Artifact directory for the PJRT offload kernels; the service still
    /// maps (host polish only) when the runtime cannot come up.
    pub artifacts_dir: String,
    /// Device worker threads per engine worker (0 = auto).
    pub threads: usize,
    /// Graph cache entry cap — bounds worker memory for long-lived
    /// `serve` processes.
    pub graph_cache_cap: usize,
    /// Engine workers draining the job queue (jobs on different workers
    /// overlap).
    pub workers: usize,
    /// Bounded job-queue capacity; non-blocking submits past it are
    /// rejected with `err code=busy`.
    pub queue_cap: usize,
    /// Finished jobs retained for `status`/`result` lookups; the oldest
    /// finished jobs are evicted beyond this.
    pub job_retention: usize,
    /// Default retry policy for jobs that did not set per-job
    /// `max_attempts`/`backoff_ms` (see [`JobOptions`]).
    pub retry: RetryPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifacts_dir: "artifacts".into(),
            threads: 0,
            graph_cache_cap: 64,
            workers: 1,
            queue_cap: 256,
            job_retention: 1024,
            retry: RetryPolicy::default(),
        }
    }
}

/// Per-submit options on the wire (`priority=`, `deadline_ms=`,
/// `max_attempts=`, `backoff_ms=`).
#[derive(Clone, Copy, Debug, Default)]
pub struct JobOptions {
    /// Higher runs first; FIFO within a class.
    pub priority: i32,
    /// Reject or abort the job this many ms after submit.
    pub deadline_ms: Option<u64>,
    /// Block on a full queue instead of failing with `Busy` (in-process
    /// callers only; the wire front-end never blocks).
    pub block_when_full: bool,
    /// Total execution attempts; overrides the service default. When only
    /// one of `max_attempts`/`backoff_ms` is set, the other half comes
    /// from the service's [`ServiceConfig::retry`].
    pub max_attempts: Option<u32>,
    /// Base retry backoff in ms (doubles per attempt, capped).
    pub backoff_ms: Option<u64>,
}

/// Lock-free counters + one poison-recovering map. `f64` totals are
/// stored as bit patterns and updated with a CAS loop.
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    failures: AtomicU64,
    completed: AtomicU64,
    cancelled: AtomicU64,
    deadline_missed: AtomicU64,
    busy_rejections: AtomicU64,
    host_ms_bits: AtomicU64,
    device_ms_bits: AtomicU64,
    per_algorithm: Mutex<BTreeMap<&'static str, u64>>,
}

fn add_f64(cell: &AtomicU64, v: f64) {
    // relaxed: commutative f64 accumulation via CAS loop on a statistics
    // counter; readers only need an approximate snapshot.
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// The completion hook run by whichever engine worker retires a job.
fn completion_hook(counters: &Arc<Counters>) -> crate::engine::job::CompletionHook {
    let c = counters.clone();
    Arc::new(move |st: &JobStatus, out: Option<&MapOutcome>| {
        // relaxed: every arm bumps a monotone statistics counter, read
        // approximately by `metrics()`.
        match st.state {
            JobState::Done => {
                c.completed.fetch_add(1, Ordering::Relaxed);
                if let Some(o) = out {
                    add_f64(&c.host_ms_bits, o.host_ms);
                    add_f64(&c.device_ms_bits, o.device_ms);
                    let mut per = c.per_algorithm.lock().unwrap_or_else(PoisonError::into_inner);
                    *per.entry(o.algorithm.name()).or_insert(0) += 1;
                }
            }
            JobState::Failed => {
                // relaxed: statistics counter.
                c.failures.fetch_add(1, Ordering::Relaxed);
            }
            JobState::Cancelled => {
                // relaxed: statistics counter.
                c.cancelled.fetch_add(1, Ordering::Relaxed);
            }
            JobState::Expired => {
                // relaxed: statistics counter.
                c.deadline_missed.fetch_add(1, Ordering::Relaxed);
            }
            JobState::Queued | JobState::Running => {}
        }
    })
}

/// Handles of every live (and recently finished) job, in submit order.
#[derive(Default)]
struct JobRegistry {
    order: VecDeque<u64>,
    map: HashMap<u64, JobHandle>,
}

/// Wire-visible batches: batch id → job ids, bounded to the most recent
/// [`BATCH_RETENTION`] batches (evicted batches answer `unknown_batch`;
/// their jobs stay individually queryable under job retention).
#[derive(Default)]
struct BatchRegistry {
    seq: u64,
    order: VecDeque<u64>,
    map: HashMap<u64, Vec<u64>>,
}

const BATCH_RETENTION: usize = 256;

/// Handle to a running coordinator service.
pub struct Service {
    engine: Engine,
    jobs: Mutex<JobRegistry>,
    batches: Mutex<BatchRegistry>,
    counters: Arc<Counters>,
    retention: usize,
    /// Service-default retry policy (base for per-job overrides).
    retry: RetryPolicy,
    /// Set by `drain`: admissions refuse with [`SubmitError::Draining`]
    /// while in-flight work runs to completion.
    draining: AtomicBool,
}

impl Service {
    /// Convenience: one engine worker, default caps.
    pub fn start(artifacts_dir: String, threads: usize) -> Service {
        Service::with_config(ServiceConfig { artifacts_dir, threads, ..ServiceConfig::default() })
    }

    /// Start the engine worker pool behind the job queue.
    pub fn with_config(cfg: ServiceConfig) -> Service {
        let engine = Engine::new(EngineConfig {
            threads: cfg.threads,
            artifacts_dir: cfg.artifacts_dir,
            graph_cache_cap: cfg.graph_cache_cap,
            workers: cfg.workers,
            queue_cap: cfg.queue_cap,
            retry: cfg.retry,
            ..EngineConfig::default()
        });
        Service {
            engine,
            jobs: Mutex::new(JobRegistry::default()),
            batches: Mutex::new(BatchRegistry::default()),
            counters: Arc::new(Counters::default()),
            retention: cfg.job_retention.max(1),
            retry: cfg.retry,
            draining: AtomicBool::new(false),
        }
    }

    /// The engine behind this service (graph sessions, gauges).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn registry(&self) -> std::sync::MutexGuard<'_, JobRegistry> {
        self.jobs.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn register(&self, h: JobHandle) {
        let mut r = self.registry();
        r.order.push_back(h.id().0);
        r.map.insert(h.id().0, h);
        while r.map.len() > self.retention {
            // Evict the oldest *finished* job; never drop a live handle.
            let Some(pos) =
                r.order.iter().position(|id| r.map.get(id).is_none_or(|h| h.is_finished()))
            else {
                break;
            };
            if let Some(id) = r.order.remove(pos) {
                r.map.remove(&id);
            }
        }
    }

    /// Submit asynchronously: returns the job handle as soon as the job
    /// is queued. `Err(Busy)` when the bounded queue is full (and
    /// `opts.block_when_full` is off).
    /// Lower wire-level [`JobOptions`] into engine [`SubmitOpts`], wiring
    /// in the metrics completion hook. Per-job retry override: either
    /// wire key fills in the other half from the service default; neither
    /// set → engine default applies.
    fn lower_opts(&self, opts: JobOptions) -> SubmitOpts {
        let retry = match (opts.max_attempts, opts.backoff_ms) {
            (None, None) => None,
            (attempts, backoff) => Some(RetryPolicy {
                max_attempts: attempts.unwrap_or(self.retry.max_attempts).max(1),
                base_backoff: backoff
                    .map_or(self.retry.base_backoff, Duration::from_millis),
            }),
        };
        SubmitOpts {
            priority: opts.priority,
            deadline: opts.deadline_ms.map(Duration::from_millis),
            block_when_full: opts.block_when_full,
            on_complete: Some(completion_hook(&self.counters)),
            retry,
        }
    }

    pub fn submit_async(
        &self,
        request: &MapRequest,
        opts: JobOptions,
    ) -> std::result::Result<JobHandle, SubmitError> {
        if self.is_draining() {
            return Err(SubmitError::Draining);
        }
        let submit = self.lower_opts(opts);
        match self.engine.submit_opts(&request.to_spec(), submit) {
            Ok(h) => {
                // relaxed: statistics counter.
                self.counters.requests.fetch_add(1, Ordering::Relaxed);
                self.register(h.clone());
                Ok(h)
            }
            Err(e) => {
                if matches!(e, SubmitError::Busy { .. }) {
                    // relaxed: statistics counter.
                    self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Submit a request and wait for the reply — the pre-job-API blocking
    /// path, now `submit_async` + `wait` (blocking on queue space, never
    /// on `Busy`).
    pub fn submit(&self, request: MapRequest) -> Result<MapReply> {
        let h = self
            .submit_async(&request, JobOptions { block_when_full: true, ..JobOptions::default() })
            .map_err(anyhow::Error::from)?;
        let outcome = h.wait()?;
        Ok(MapReply { id: h.id().0, outcome })
    }

    /// Submit a batch; every job is enqueued before the first wait, so
    /// with multiple engine workers the batch overlaps. Replies come back
    /// in request order even when jobs finish out of order, and one
    /// failing request does not fail the rest.
    pub fn submit_batch(&self, requests: Vec<MapRequest>) -> Vec<Result<MapReply>> {
        let handles: Vec<std::result::Result<JobHandle, SubmitError>> = requests
            .iter()
            .map(|r| {
                self.submit_async(r, JobOptions { block_when_full: true, ..JobOptions::default() })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                let h = h.map_err(anyhow::Error::from)?;
                Ok(MapReply { id: h.id().0, outcome: h.wait()? })
            })
            .collect()
    }

    /// Submit several requests as one engine batch (`batch submit`):
    /// admission is all-or-nothing, and a worker popping one of the jobs
    /// drains its compatible small siblings into the same worker-pool
    /// pass. Returns the wire-visible batch id plus the job handles in
    /// request order.
    pub fn submit_engine_batch(
        &self,
        requests: &[MapRequest],
        opts: JobOptions,
    ) -> std::result::Result<(u64, Vec<JobHandle>), SubmitError> {
        if self.is_draining() {
            return Err(SubmitError::Draining);
        }
        let submit = self.lower_opts(opts);
        let specs: Vec<_> = requests.iter().map(|r| r.to_spec()).collect();
        match self.engine.submit_batch(&specs, submit) {
            Ok(handles) => {
                // relaxed: statistics counter.
                self.counters.requests.fetch_add(handles.len() as u64, Ordering::Relaxed);
                for h in &handles {
                    self.register(h.clone());
                }
                let ids: Vec<u64> = handles.iter().map(|h| h.id().0).collect();
                let mut b = self.batches.lock().unwrap_or_else(PoisonError::into_inner);
                b.seq += 1;
                let id = b.seq;
                b.order.push_back(id);
                b.map.insert(id, ids);
                while b.map.len() > BATCH_RETENTION {
                    if let Some(old) = b.order.pop_front() {
                        b.map.remove(&old);
                    }
                }
                Ok((id, handles))
            }
            Err(e) => {
                if matches!(e, SubmitError::Busy { .. }) {
                    // relaxed: statistics counter.
                    self.counters.busy_rejections.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Job ids of a wire batch, in request order; `None` for unknown (or
    /// retention-evicted) batch ids.
    pub fn batch_jobs(&self, id: u64) -> Option<Vec<u64>> {
        self.batches.lock().unwrap_or_else(PoisonError::into_inner).map.get(&id).cloned()
    }

    /// Look up a job by wire id.
    pub fn job(&self, id: u64) -> Option<JobHandle> {
        self.registry().map.get(&id).cloned()
    }

    /// Cancel by wire id; `None` for unknown jobs.
    pub fn cancel(&self, id: u64) -> Option<JobStatus> {
        let h = self.job(id)?;
        h.cancel();
        Some(h.status())
    }

    /// Status of every tracked job, in submit order.
    pub fn jobs(&self) -> Vec<JobStatus> {
        let r = self.registry();
        r.order.iter().filter_map(|id| r.map.get(id).map(|h| h.status())).collect()
    }

    /// Pin a session graph (`graph put`); returns (n, m, version,
    /// replaced). Re-putting an existing name atomically replaces the
    /// session: its version bumps, stale cached hierarchies and any
    /// stored warm-start mapping are dropped, while in-flight jobs keep
    /// mapping the `Arc` they already resolved.
    pub fn put_graph(&self, name: &str, g: Arc<CsrGraph>) -> (usize, usize, u64, bool) {
        let nm = (g.n(), g.m());
        let (version, replaced) = self.engine.put_graph(name, g);
        (nm.0, nm.1, version, replaced)
    }

    /// Apply a [`GraphPatch`] to a pinned session graph (`graph patch`).
    pub fn patch_graph(
        &self,
        name: &str,
        patch: &GraphPatch,
    ) -> std::result::Result<PatchSummary, PatchError> {
        self.engine.patch_graph(name, patch)
    }

    /// Pinned session graphs with their patch versions, sorted by name.
    pub fn graph_entries(&self) -> Vec<(String, u64)> {
        self.engine.graph_entries()
    }

    /// Names of the pinned session graphs, sorted.
    pub fn graph_names(&self) -> Vec<String> {
        self.engine.graph_names()
    }

    /// Drop a pinned session graph; false when unknown.
    pub fn drop_graph(&self, name: &str) -> bool {
        self.engine.drop_graph(name)
    }

    /// Start draining (`drain` wire command): every subsequent admission
    /// refuses with [`SubmitError::Draining`]; queued and in-flight jobs
    /// run to completion. Idempotent.
    pub fn start_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Whether the drain has completed: drain requested *and* neither
    /// queued nor in-flight work remains.
    pub fn drained(&self) -> bool {
        self.is_draining() && self.engine.queue_depth() == 0 && self.engine.in_flight() == 0
    }

    pub fn metrics(&self) -> ServiceMetrics {
        let c = &self.counters;
        // relaxed: every load below is an approximate statistics snapshot;
        // exactness across counters is not promised to callers.
        ServiceMetrics {
            requests: c.requests.load(Ordering::Relaxed),
            failures: c.failures.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            deadline_missed: c.deadline_missed.load(Ordering::Relaxed),
            busy_rejections: c.busy_rejections.load(Ordering::Relaxed),
            hierarchy_cache_hits: self.engine.hierarchy_cache_hits(),
            hierarchy_cache_misses: self.engine.hierarchy_cache_misses(),
            retries: self.engine.retries(),
            faults_injected: self.engine.faults_injected(),
            degraded_completions: self.engine.degraded_completions(),
            patches_applied: self.engine.patches_applied(),
            graphs_replaced: self.engine.graphs_replaced(),
            warm_remaps: self.engine.warm_remaps(),
            cold_fallbacks: self.engine.cold_fallbacks(),
            batches: self.engine.batches(),
            batched_jobs: self.engine.batched_jobs(),
            device_launches: self.engine.device_launches(),
            h2d_bytes: self.engine.h2d_bytes(),
            d2h_bytes: self.engine.d2h_bytes(),
            backend_fallbacks: self.engine.backend_fallbacks(),
            queue_depth: self.engine.queue_depth(),
            in_flight: self.engine.in_flight(),
            // relaxed: same approximate-snapshot rationale as above.
            total_host_ms: f64::from_bits(c.host_ms_bits.load(Ordering::Relaxed)),
            total_device_ms: f64::from_bits(c.device_ms_bits.load(Ordering::Relaxed)),
            per_algorithm: c.per_algorithm.lock().unwrap_or_else(PoisonError::into_inner).clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Algorithm;

    fn small_request(instance: &str) -> MapRequest {
        MapRequest {
            instance: instance.into(),
            algorithm: Some(Algorithm::GpuIm),
            hierarchy: "2:2:2".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            ..MapRequest::default()
        }
    }

    /// Completion hooks for jobs cancelled/expired *while queued* fire
    /// when a worker pops (or a full-queue purge evicts) them — poll
    /// briefly instead of racing that retirement.
    fn await_metric(svc: &Service, what: &str, f: impl Fn(&ServiceMetrics) -> bool) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !f(&svc.metrics()) {
            assert!(std::time::Instant::now() < deadline, "metric `{what}` never converged");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    /// A fast request with the cancellable sleep test hook.
    fn sleepy_request(ms: u64) -> MapRequest {
        let mut req = MapRequest {
            instance: "wal_598a".into(),
            algorithm: Some(Algorithm::SharedMapF),
            hierarchy: "2:2".into(),
            distance: "1:10".into(),
            ..MapRequest::default()
        };
        req.options.insert("__sleep_ms".into(), ms.to_string());
        req
    }

    #[test]
    fn submits_and_maps() {
        let svc = Service::start("artifacts".into(), 1);
        let resp = svc.submit(small_request("sten_cop20k")).unwrap();
        assert_eq!(resp.outcome.k, 8);
        assert!(resp.outcome.comm_cost > 0.0);
        assert!(resp.outcome.imbalance <= 0.032);
        assert!(resp.outcome.mapping.is_empty(), "mapping withheld unless requested");
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.failures, 0);
    }

    #[test]
    fn batch_and_cache_reuse() {
        let svc = Service::start("artifacts".into(), 1);
        let reqs = vec![small_request("wal_598a"), small_request("wal_598a")];
        let out = svc.submit_batch(reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Second run hits the engine's graph cache → not slower by graph
        // gen; just check both returned consistent sizes.
        let (a, b) = (out[0].as_ref().unwrap(), out[1].as_ref().unwrap());
        assert_eq!(a.outcome.n, b.outcome.n);
    }

    #[test]
    fn batch_replies_in_request_order_despite_out_of_order_finish() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 2, ..Default::default() });
        // First request sleeps; the second finishes well before it.
        let reqs = vec![sleepy_request(400), sleepy_request(0), sleepy_request(0)];
        let out = svc.submit_batch(reqs);
        assert_eq!(out.len(), 3);
        let ids: Vec<u64> = out.iter().map(|r| r.as_ref().unwrap().id).collect();
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "replies out of request order: {ids:?}");
    }

    #[test]
    fn batch_survives_a_mid_batch_invalid_request() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 2, ..Default::default() });
        let reqs =
            vec![small_request("wal_598a"), small_request("no_such_instance"), small_request("wal_598a")];
        let out = svc.submit_batch(reqs);
        assert!(out[0].is_ok(), "{:?}", out[0].as_ref().err());
        assert!(out[1].is_err());
        assert!(out[2].is_ok(), "{:?}", out[2].as_ref().err());
        let m = svc.metrics();
        assert_eq!(m.failures, 1);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn async_submit_cancel_and_metrics() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let h = svc
            .submit_async(&sleepy_request(60_000), JobOptions::default())
            .unwrap();
        assert!(!h.is_finished(), "submit_async must return before the solve");
        assert!(svc.job(h.id().0).is_some());
        let st = svc.cancel(h.id().0).unwrap();
        assert!(matches!(st.state, JobState::Cancelled | JobState::Running));
        assert!(h.wait().is_err());
        assert_eq!(h.status().state, JobState::Cancelled);
        await_metric(&svc, "cancelled", |m| m.cancelled == 1);
        assert!(svc.cancel(999_999).is_none(), "unknown job id");
    }

    #[test]
    fn deadline_miss_is_counted() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let blocker = svc.submit_async(&sleepy_request(300), JobOptions::default()).unwrap();
        let late = svc
            .submit_async(
                &sleepy_request(0),
                JobOptions { deadline_ms: Some(30), ..JobOptions::default() },
            )
            .unwrap();
        assert!(late.wait().unwrap_err().to_string().contains("deadline"));
        blocker.wait().unwrap();
        await_metric(&svc, "deadline_missed", |m| m.deadline_missed == 1);
    }

    #[test]
    fn panicking_job_does_not_poison_metrics_or_kill_the_service() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let mut bad = sleepy_request(0);
        // The solve panics (injected) on every attempt; the self-healing
        // pipeline degrades the job to a fallback solver instead of
        // failing it.
        bad.options.insert("__fault.solve".into(), "1".into());
        let reply = svc.submit(bad).unwrap();
        assert!(reply.outcome.degraded, "all-attempts fault must degrade");
        // Regression: metrics() used to .lock().unwrap() a mutex the
        // panicked attempt had poisoned, taking the service down with it.
        let m = svc.metrics();
        assert_eq!(m.failures, 0, "degraded completions are not failures");
        assert_eq!(m.completed, 1);
        assert_eq!(m.degraded_completions, 1);
        assert_eq!(m.faults_injected, 1);
        // And the same worker keeps serving, organically.
        let ok = svc.submit(small_request("wal_598a")).unwrap();
        assert!(ok.outcome.comm_cost > 0.0);
        assert!(!ok.outcome.degraded);
        assert_eq!(svc.metrics().completed, 2);
    }

    #[test]
    fn per_job_retry_options_override_the_service_default() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let mut flaky = sleepy_request(0);
        flaky.options.insert("__fault.solve".into(), "1".into());
        let h = svc
            .submit_async(
                &flaky,
                JobOptions {
                    max_attempts: Some(3),
                    backoff_ms: Some(1),
                    block_when_full: true,
                    ..JobOptions::default()
                },
            )
            .unwrap();
        let out = h.wait().unwrap();
        assert!(out.degraded);
        assert_eq!(out.attempts, 3);
        assert_eq!(h.status().attempts, 3);
        let m = svc.metrics();
        assert_eq!(m.retries, 2);
        assert_eq!(m.faults_injected, 3);
        assert_eq!(m.degraded_completions, 1);
    }

    #[test]
    fn polish_never_worsens() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cont300");
        req.polish = true;
        req.algorithm = Some(Algorithm::Jet); // edge-cut partition benefits from re-mapping
        let resp = svc.submit(req.clone()).unwrap();
        req.polish = false;
        let base = svc.submit(req).unwrap();
        assert!(resp.outcome.comm_cost <= base.outcome.comm_cost + 1e-6);
        assert!(resp.outcome.polish_improvement >= 0.0);
    }

    #[test]
    fn maps_onto_a_topology_spec_machine() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cop20k");
        req.topology = Some("torus:2x2x2".into());
        let resp = svc.submit(req).unwrap();
        assert_eq!(resp.outcome.k, 8);
        assert!(resp.outcome.comm_cost > 0.0);
        // A bad spec fails the request, not the worker.
        let mut bad = small_request("sten_cop20k");
        bad.topology = Some("torus:0x2".into());
        assert!(svc.submit(bad).is_err());
        assert_eq!(svc.metrics().failures, 1);
    }

    #[test]
    fn unknown_instance_fails_cleanly() {
        let svc = Service::start("artifacts".into(), 1);
        let out = svc.submit(small_request("no_such_instance"));
        assert!(out.is_err());
        assert_eq!(svc.metrics().failures, 1);
    }

    #[test]
    fn returns_mapping_when_asked() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cop20k");
        req.return_mapping = true;
        let resp = svc.submit(req).unwrap();
        let out = &resp.outcome;
        assert_eq!(out.mapping.len(), out.n);
        assert!(out.mapping.iter().all(|&pe| (pe as usize) < out.k));
    }

    #[test]
    fn session_graphs_are_shared_across_jobs() {
        let svc = Service::start("artifacts".into(), 1);
        let g = Arc::new(crate::graph::gen::grid2d(16, 16, false));
        let (n, m, version, replaced) = svc.put_graph("sess", g.clone());
        assert_eq!((n, m, version, replaced), (g.n(), g.m(), 1, false));
        assert_eq!(svc.graph_names(), vec!["sess".to_string()]);
        let mut req = small_request("sess");
        req.algorithm = Some(Algorithm::SharedMapF);
        req.hierarchy = "2:2".into();
        req.distance = "1:10".into();
        let a = svc.submit(req.clone()).unwrap();
        let b = svc.submit(req.clone()).unwrap();
        assert_eq!(a.outcome.n, g.n());
        assert_eq!(b.outcome.n, g.n());
        assert!(svc.drop_graph("sess"));
        assert!(svc.submit(req).is_err(), "dropped session graph must not resolve");
    }

    #[test]
    fn second_submit_on_a_pinned_graph_reports_a_hierarchy_cache_hit() {
        let svc = Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let g = Arc::new(crate::graph::gen::rgg(2_000, 0.05, 7));
        svc.put_graph("sess", g);
        let mut req = small_request("sess");
        req.hierarchy = "2:2".into();
        req.distance = "1:10".into();
        let first = svc.submit(req.clone()).unwrap();
        assert!(first.outcome.hierarchy_cache == Some(false), "first job builds");
        let m = svc.metrics();
        assert_eq!((m.hierarchy_cache_hits, m.hierarchy_cache_misses), (0, 1));
        req.seed = 2;
        let second = svc.submit(req).unwrap();
        assert_eq!(second.outcome.hierarchy_cache, Some(true), "repeat job must hit");
        let m = svc.metrics();
        assert_eq!(m.hierarchy_cache_hits, 1);
        assert_eq!(m.hierarchy_cache_misses, 1);
    }

    #[test]
    fn engine_batches_run_all_jobs_and_count() {
        let svc =
            Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let reqs: Vec<MapRequest> = (1..=3)
            .map(|s| {
                let mut r = sleepy_request(0);
                r.seed = s;
                r
            })
            .collect();
        let (batch, handles) = svc.submit_engine_batch(&reqs, JobOptions::default()).unwrap();
        assert_eq!(handles.len(), 3);
        assert_eq!(svc.batch_jobs(batch).unwrap().len(), 3);
        for h in &handles {
            h.wait().unwrap();
        }
        await_metric(&svc, "completed", |m| m.completed == 3);
        let m = svc.metrics();
        assert_eq!((m.batches, m.batched_jobs, m.requests), (1, 3, 3));
        assert!(svc.batch_jobs(999).is_none());
    }

    #[test]
    fn incremental_metrics_reconcile_with_job_counts() {
        let svc =
            Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let g = Arc::new(crate::graph::gen::rgg(2_000, 0.05, 7));
        let (_, _, version, replaced) = svc.put_graph("sess", g.clone());
        assert_eq!((version, replaced), (1, false));
        let mut req = small_request("sess");
        req.hierarchy = "2:2".into();
        req.distance = "1:10".into();
        let first = svc.submit(req.clone()).unwrap();
        assert_eq!(first.outcome.remap, None);
        // Edge-only patch between provably non-adjacent endpoints.
        let u = 0u32;
        let v = (1..g.n() as u32).rev().find(|&v| g.find_edge(u, v).is_none()).unwrap();
        let patch = GraphPatch::parse(&format!("ae:{u}:{v}:1.5")).unwrap();
        assert_eq!(svc.patch_graph("sess", &patch).unwrap().version, 2);
        let second = svc.submit(req.clone()).unwrap();
        assert_eq!(second.outcome.remap, Some(crate::engine::RemapKind::Warm));
        // Re-putting the graph replaces the session and clears warm state.
        let (_, _, version, replaced) = svc.put_graph("sess", g);
        assert_eq!((version, replaced), (3, true));
        let third = svc.submit(req).unwrap();
        assert_eq!(third.outcome.remap, None, "replacement cleared the stored mapping");
        await_metric(&svc, "completed", |m| m.completed == 3);
        let m = svc.metrics();
        assert_eq!(
            (m.patches_applied, m.warm_remaps, m.cold_fallbacks, m.graphs_replaced),
            (1, 1, 0, 1)
        );
        // Every warm or cold remap is a completed job.
        assert!(m.warm_remaps + m.cold_fallbacks <= m.completed);
    }

    #[test]
    fn device_metrics_reconcile_with_engine_counters() {
        // A bogus artifact dir forces every device job down the cpu
        // fallback; the wire metrics must mirror the engine's counters.
        let svc = Service::with_config(ServiceConfig {
            threads: 1,
            workers: 1,
            artifacts_dir: "definitely_missing_artifacts".into(),
            ..Default::default()
        });
        let mut req = small_request("wal_598a");
        req.hierarchy = "2:2".into();
        req.distance = "1:10".into();
        req.backend = crate::engine::Backend::Device;
        let reply = svc.submit(req.clone()).unwrap();
        assert_eq!(reply.outcome.backend, crate::engine::Backend::Cpu);
        assert!(!reply.outcome.degraded, "a backend fallback is not degradation");
        let m = svc.metrics();
        assert_eq!(m.backend_fallbacks, svc.engine().backend_fallbacks());
        assert_eq!(m.backend_fallbacks, 1);
        assert_eq!(
            (m.device_launches, m.h2d_bytes, m.d2h_bytes),
            (
                svc.engine().device_launches(),
                svc.engine().h2d_bytes(),
                svc.engine().d2h_bytes()
            )
        );
        assert_eq!(m.device_launches, 0, "nothing launched without artifacts");
        // And the wire line carries the new keys.
        let line = super::super::protocol::render_metrics(&m);
        for key in ["device_launches=0", "h2d_bytes=0", "d2h_bytes=0", "backend_fallbacks=1"] {
            assert!(line.contains(key), "missing {key}: {line}");
        }
    }

    #[test]
    fn drain_refuses_new_work_and_finishes_in_flight() {
        let svc =
            Service::with_config(ServiceConfig { threads: 1, workers: 1, ..Default::default() });
        let h = svc.submit_async(&sleepy_request(100), JobOptions::default()).unwrap();
        svc.start_drain();
        assert!(svc.is_draining());
        assert!(matches!(
            svc.submit_async(&sleepy_request(0), JobOptions::default()),
            Err(SubmitError::Draining)
        ));
        assert!(matches!(
            svc.submit_engine_batch(&[sleepy_request(0)], JobOptions::default()),
            Err(SubmitError::Draining)
        ));
        h.wait().unwrap();
        // The in-flight gauge can lag wait() by a beat; poll briefly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !svc.drained() {
            assert!(std::time::Instant::now() < deadline, "drain never completed");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    #[test]
    fn worker_cache_stays_bounded() {
        let svc = Service::with_config(ServiceConfig {
            threads: 1,
            graph_cache_cap: 1,
            ..ServiceConfig::default()
        });
        for name in ["sten_cop20k", "wal_598a", "sten_cont300"] {
            svc.submit(small_request(name)).unwrap();
        }
        assert_eq!(svc.engine().cached_graphs(), 1);
        assert_eq!(svc.metrics().failures, 0);
    }
}
