//! The coordinator worker: a job queue in front of one
//! [`crate::engine::Engine`]. Graph caching (bounded LRU), algorithm
//! routing and the optional device-offloaded QAP polish all happen inside
//! the engine — the worker only assigns ids and keeps metrics.

use super::{MapReply, MapRequest, ServiceMetrics};
use crate::engine::{Engine, EngineConfig};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Artifact directory for the PJRT offload kernels; the service still
    /// maps (host polish only) when the runtime cannot come up.
    pub artifacts_dir: String,
    /// Device worker threads (0 = auto).
    pub threads: usize,
    /// Graph cache entry cap — bounds worker memory for long-lived
    /// `serve` processes.
    pub graph_cache_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { artifacts_dir: "artifacts".into(), threads: 0, graph_cache_cap: 64 }
    }
}

/// Handle to a running coordinator worker.
pub struct Service {
    tx: mpsc::Sender<Job>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<ServiceMetrics>>,
}

struct Job {
    id: u64,
    request: MapRequest,
    reply: mpsc::Sender<Result<MapReply>>,
}

impl Service {
    /// Convenience: spawn with default cache cap.
    pub fn start(artifacts_dir: String, threads: usize) -> Service {
        Service::with_config(ServiceConfig { artifacts_dir, threads, ..ServiceConfig::default() })
    }

    /// Spawn the worker thread owning the engine.
    pub fn with_config(cfg: ServiceConfig) -> Service {
        let (tx, rx) = mpsc::channel::<Job>();
        let metrics = Arc::new(Mutex::new(ServiceMetrics::default()));
        let metrics_worker = metrics.clone();
        std::thread::spawn(move || {
            let engine = Engine::new(EngineConfig {
                threads: cfg.threads,
                artifacts_dir: cfg.artifacts_dir,
                graph_cache_cap: cfg.graph_cache_cap,
            });
            while let Ok(job) = rx.recv() {
                let out = engine
                    .map(&job.request.to_spec())
                    .map(|outcome| MapReply { id: job.id, outcome });
                {
                    let mut m = metrics_worker.lock().unwrap();
                    m.requests += 1;
                    match &out {
                        Ok(r) => {
                            m.total_host_ms += r.outcome.host_ms;
                            m.total_device_ms += r.outcome.device_ms;
                            *m.per_algorithm.entry(r.outcome.algorithm.name()).or_insert(0) += 1;
                        }
                        Err(_) => m.failures += 1,
                    }
                }
                let _ = job.reply.send(out);
            }
        });
        Service { tx, next_id: AtomicU64::new(1), metrics }
    }

    /// Submit a request and wait for the reply.
    pub fn submit(&self, request: MapRequest) -> Result<MapReply> {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job { id, request, reply })
            .map_err(|_| anyhow::anyhow!("service worker terminated"))?;
        rx.recv().context("service worker dropped the reply")?
    }

    /// Submit a batch; replies come back in request order.
    pub fn submit_batch(&self, requests: Vec<MapRequest>) -> Vec<Result<MapReply>> {
        let channels: Vec<_> = requests
            .into_iter()
            .map(|request| {
                let (reply, rx) = mpsc::channel();
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let sent = self.tx.send(Job { id, request, reply });
                (rx, sent)
            })
            .collect();
        channels
            .into_iter()
            .map(|(rx, sent)| {
                sent.map_err(|_| anyhow::anyhow!("service worker terminated"))?;
                rx.recv().context("service worker dropped the reply")?
            })
            .collect()
    }

    pub fn metrics(&self) -> ServiceMetrics {
        self.metrics.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Algorithm;

    fn small_request(instance: &str) -> MapRequest {
        MapRequest {
            instance: instance.into(),
            algorithm: Some(Algorithm::GpuIm),
            hierarchy: "2:2:2".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            ..MapRequest::default()
        }
    }

    #[test]
    fn submits_and_maps() {
        let svc = Service::start("artifacts".into(), 1);
        let resp = svc.submit(small_request("sten_cop20k")).unwrap();
        assert_eq!(resp.outcome.k, 8);
        assert!(resp.outcome.comm_cost > 0.0);
        assert!(resp.outcome.imbalance <= 0.032);
        assert!(resp.outcome.mapping.is_empty(), "mapping withheld unless requested");
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failures, 0);
    }

    #[test]
    fn batch_and_cache_reuse() {
        let svc = Service::start("artifacts".into(), 1);
        let reqs = vec![small_request("wal_598a"), small_request("wal_598a")];
        let out = svc.submit_batch(reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Second run hits the engine's graph cache → not slower by graph
        // gen; just check both returned consistent sizes.
        let (a, b) = (out[0].as_ref().unwrap(), out[1].as_ref().unwrap());
        assert_eq!(a.outcome.n, b.outcome.n);
    }

    #[test]
    fn polish_never_worsens() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cont300");
        req.polish = true;
        req.algorithm = Some(Algorithm::Jet); // edge-cut partition benefits from re-mapping
        let resp = svc.submit(req.clone()).unwrap();
        req.polish = false;
        let base = svc.submit(req).unwrap();
        assert!(resp.outcome.comm_cost <= base.outcome.comm_cost + 1e-6);
        assert!(resp.outcome.polish_improvement >= 0.0);
    }

    #[test]
    fn maps_onto_a_topology_spec_machine() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cop20k");
        req.topology = Some("torus:2x2x2".into());
        let resp = svc.submit(req).unwrap();
        assert_eq!(resp.outcome.k, 8);
        assert!(resp.outcome.comm_cost > 0.0);
        // A bad spec fails the request, not the worker.
        let mut bad = small_request("sten_cop20k");
        bad.topology = Some("torus:0x2".into());
        assert!(svc.submit(bad).is_err());
        assert_eq!(svc.metrics().failures, 1);
    }

    #[test]
    fn unknown_instance_fails_cleanly() {
        let svc = Service::start("artifacts".into(), 1);
        let out = svc.submit(small_request("no_such_instance"));
        assert!(out.is_err());
        assert_eq!(svc.metrics().failures, 1);
    }

    #[test]
    fn returns_mapping_when_asked() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cop20k");
        req.return_mapping = true;
        let resp = svc.submit(req).unwrap();
        let out = &resp.outcome;
        assert_eq!(out.mapping.len(), out.n);
        assert!(out.mapping.iter().all(|&pe| (pe as usize) < out.k));
    }

    #[test]
    fn worker_cache_stays_bounded() {
        let svc = Service::with_config(ServiceConfig {
            threads: 1,
            graph_cache_cap: 1,
            ..ServiceConfig::default()
        });
        for name in ["sten_cop20k", "wal_598a", "sten_cont300"] {
            svc.submit(small_request(name)).unwrap();
        }
        // No way to observe the worker's cache directly; the bound is
        // enforced by engine::cache (unit-tested there). This just proves
        // a cap-1 service keeps serving correctly.
        assert_eq!(svc.metrics().failures, 0);
    }
}
