//! The coordinator worker: job queue, graph cache, algorithm execution,
//! optional device-offloaded QAP polish.

use super::{route, MapRequest, MapResponse, ServiceMetrics};
use crate::algo::{qap, run_algorithm};
use crate::graph::{gen, io, CsrGraph};
use crate::par::Pool;
use crate::partition::{block_comm_matrix, comm_cost_blocks};
use crate::runtime::{offload, Runtime};
use crate::topology::Hierarchy;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Handle to a running coordinator worker.
pub struct Service {
    tx: mpsc::Sender<Job>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<ServiceMetrics>>,
}

struct Job {
    id: u64,
    request: MapRequest,
    reply: mpsc::Sender<Result<MapResponse>>,
}

impl Service {
    /// Spawn the worker thread. `artifacts_dir` enables the polish stage;
    /// if the runtime cannot come up the service still maps (no polish).
    pub fn start(artifacts_dir: String, threads: usize) -> Service {
        let (tx, rx) = mpsc::channel::<Job>();
        let metrics = Arc::new(Mutex::new(ServiceMetrics::default()));
        let metrics_worker = metrics.clone();
        std::thread::spawn(move || {
            let pool = if threads == 0 { Pool::default() } else { Pool::new(threads) };
            let runtime = Runtime::new(&artifacts_dir).ok();
            let mut graph_cache: HashMap<String, Arc<CsrGraph>> = HashMap::new();
            while let Ok(job) = rx.recv() {
                let out = handle(&pool, runtime.as_ref(), &mut graph_cache, job.id, &job.request);
                {
                    let mut m = metrics_worker.lock().unwrap();
                    m.requests += 1;
                    match &out {
                        Ok(r) => {
                            m.total_host_ms += r.host_ms;
                            m.total_device_ms += r.device_ms;
                            *m.per_algorithm.entry(r.algorithm.name()).or_insert(0) += 1;
                        }
                        Err(_) => m.failures += 1,
                    }
                }
                let _ = job.reply.send(out);
            }
        });
        Service { tx, next_id: AtomicU64::new(1), metrics }
    }

    /// Submit a request and wait for the response.
    pub fn submit(&self, request: MapRequest) -> Result<MapResponse> {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job { id, request, reply })
            .map_err(|_| anyhow::anyhow!("service worker terminated"))?;
        rx.recv().context("service worker dropped the reply")?
    }

    /// Submit a batch; responses come back in request order.
    pub fn submit_batch(&self, requests: Vec<MapRequest>) -> Vec<Result<MapResponse>> {
        let channels: Vec<_> = requests
            .into_iter()
            .map(|request| {
                let (reply, rx) = mpsc::channel();
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let sent = self.tx.send(Job { id, request, reply });
                (rx, sent)
            })
            .collect();
        channels
            .into_iter()
            .map(|(rx, sent)| {
                sent.map_err(|_| anyhow::anyhow!("service worker terminated"))?;
                rx.recv().context("service worker dropped the reply")?
            })
            .collect()
    }

    pub fn metrics(&self) -> ServiceMetrics {
        self.metrics.lock().unwrap().clone()
    }
}

/// Resolve an instance: registry name first, then METIS path.
fn resolve_graph(cache: &mut HashMap<String, Arc<CsrGraph>>, instance: &str) -> Result<Arc<CsrGraph>> {
    if let Some(g) = cache.get(instance) {
        return Ok(g.clone());
    }
    let g = if gen::instance_by_name(instance).is_some() {
        gen::generate_by_name(instance)
    } else {
        io::read_metis(Path::new(instance))
            .with_context(|| format!("instance `{instance}` is neither a registry name nor a readable METIS file"))?
    };
    let g = Arc::new(g);
    cache.insert(instance.to_string(), g.clone());
    Ok(g)
}

fn handle(
    pool: &Pool,
    runtime: Option<&Runtime>,
    cache: &mut HashMap<String, Arc<CsrGraph>>,
    id: u64,
    req: &MapRequest,
) -> Result<MapResponse> {
    let g = resolve_graph(cache, &req.instance)?;
    let h = Hierarchy::parse(&req.hierarchy, &req.distance)?;
    let algo = route(g.n(), req.algorithm);
    let mut result = run_algorithm(algo, pool, &g, &h, req.eps, req.seed);

    // Optional QAP polish: re-map blocks to PEs with the offloaded
    // all-pairs swap kernel (falls back to the host kernel without PJRT).
    let mut polish_improvement = 0.0;
    if req.polish {
        let k = h.k();
        let bmat = block_comm_matrix(&g, &result.mapping, k);
        let mut sigma: Vec<crate::Block> = (0..k as crate::Block).collect();
        let before = comm_cost_blocks(&bmat, k, &sigma, &h);
        match runtime {
            Some(rt) if rt.available(&format!("qap_step_k{}", offload::qap_kernel_size(k)?)) => {
                offload::swap_refine_offload(rt, &bmat, k, &h, &mut sigma, 20)?;
            }
            _ => {
                qap::swap_refine(&bmat, k, &mut sigma, &h, 20);
            }
        }
        let after = comm_cost_blocks(&bmat, k, &sigma, &h);
        if after < before {
            polish_improvement = before - after;
            for pe in result.mapping.iter_mut() {
                *pe = sigma[*pe as usize];
            }
            result.comm_cost -= polish_improvement;
        }
    }

    Ok(MapResponse {
        id,
        algorithm: algo,
        n: g.n(),
        k: h.k(),
        comm_cost: result.comm_cost,
        imbalance: result.imbalance,
        host_ms: result.host_ms,
        device_ms: result.device_ms,
        polish_improvement,
        mapping: if req.return_mapping { Some(result.mapping) } else { None },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Algorithm;

    fn small_request(instance: &str) -> MapRequest {
        MapRequest {
            instance: instance.into(),
            algorithm: Some(Algorithm::GpuIm),
            hierarchy: "2:2:2".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seed: 1,
            polish: false,
            return_mapping: false,
        }
    }

    #[test]
    fn submits_and_maps() {
        let svc = Service::start("artifacts".into(), 1);
        let resp = svc.submit(small_request("sten_cop20k")).unwrap();
        assert_eq!(resp.k, 8);
        assert!(resp.comm_cost > 0.0);
        assert!(resp.imbalance <= 0.032);
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failures, 0);
    }

    #[test]
    fn batch_and_cache_reuse() {
        let svc = Service::start("artifacts".into(), 1);
        let reqs = vec![small_request("wal_598a"), small_request("wal_598a")];
        let out = svc.submit_batch(reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Second run hits the graph cache → not slower by graph gen; just
        // check both returned consistent sizes.
        let (a, b) = (out[0].as_ref().unwrap(), out[1].as_ref().unwrap());
        assert_eq!(a.n, b.n);
    }

    #[test]
    fn polish_never_worsens() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cont300");
        req.polish = true;
        req.algorithm = Some(Algorithm::Jet); // edge-cut partition benefits from re-mapping
        let resp = svc.submit(req.clone()).unwrap();
        req.polish = false;
        let base = svc.submit(req).unwrap();
        assert!(resp.comm_cost <= base.comm_cost + 1e-6);
        assert!(resp.polish_improvement >= 0.0);
    }

    #[test]
    fn unknown_instance_fails_cleanly() {
        let svc = Service::start("artifacts".into(), 1);
        let out = svc.submit(small_request("no_such_instance"));
        assert!(out.is_err());
        assert_eq!(svc.metrics().failures, 1);
    }

    #[test]
    fn returns_mapping_when_asked() {
        let svc = Service::start("artifacts".into(), 1);
        let mut req = small_request("sten_cop20k");
        req.return_mapping = true;
        let resp = svc.submit(req).unwrap();
        let m = resp.mapping.unwrap();
        assert_eq!(m.len(), resp.n);
        assert!(m.iter().all(|&pe| (pe as usize) < resp.k));
    }
}
