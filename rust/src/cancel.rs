//! Cooperative cancellation for long-running solves.
//!
//! A [`CancelToken`] is a cheaply-clonable flag (plus an optional
//! deadline) handed to every solver through
//! [`crate::engine::Solver::solve`]. Solvers are required to poll it at
//! **coarsening-level boundaries** and **Jet refinement round
//! boundaries** — the two places a multilevel pipeline can stop without
//! leaving a partially-written mapping — so a cancelled job returns
//! within one level / one round rather than running to completion.
//!
//! Cancellation is cooperative and lossy by design: a cancelled solver
//! returns *some* structurally valid assignment (often all-zeros or the
//! best mapping found so far) and the engine discards it, marking the
//! job `Cancelled` (or `Expired` when the deadline tripped) instead of
//! `Done`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared cancellation flag + optional deadline.
///
/// Clones share the flag: cancelling any clone cancels them all. The
/// deadline is carried by value, so tokens derived from the same submit
/// observe the same cutoff instant.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that is not cancelled and never expires.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A fresh token that expires `after` from now.
    pub fn with_deadline(after: Duration) -> CancelToken {
        CancelToken { flag: Arc::new(AtomicBool::new(false)), deadline: Some(Instant::now() + after) }
    }

    /// Request cancellation (idempotent; visible to every clone).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// True once [`CancelToken::cancel`] was called on any clone.
    pub fn cancel_requested(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }

    /// True once the deadline (if any) has passed.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Time left until the deadline (`None` when no deadline is set;
    /// zero once it has passed). Lets waiters bound their sleeps so a
    /// queued job expires on time even when no worker touches it.
    pub fn deadline_remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// The poll solvers call at coarsening-level and Jet-round
    /// boundaries: explicit cancellation *or* an expired deadline.
    pub fn is_cancelled(&self) -> bool {
        self.cancel_requested() || self.deadline_exceeded()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_flag() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(!a.is_cancelled() && !b.is_cancelled());
        b.cancel();
        assert!(a.is_cancelled());
        assert!(a.cancel_requested());
        assert!(!a.deadline_exceeded());
    }

    #[test]
    fn deadline_trips_without_explicit_cancel() {
        let t = CancelToken::with_deadline(Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.is_cancelled());
        assert!(t.deadline_exceeded());
        assert!(!t.cancel_requested());
        let far = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!far.is_cancelled());
        assert!(far.deadline_remaining().unwrap() > Duration::from_secs(3500));
        assert_eq!(t.deadline_remaining(), Some(Duration::ZERO));
        assert_eq!(CancelToken::new().deadline_remaining(), None);
    }
}
