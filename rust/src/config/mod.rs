//! Run configuration: presets plus a tiny `key = value` config-file format
//! (the offline crate set has no serde/toml, so the parser is hand-rolled).
//!
//! A [`RunConfig`] lowers into the engine API: [`RunConfig::to_spec`]
//! produces the [`MapSpec`] and [`RunConfig::engine_config`] the
//! [`EngineConfig`], so `heipa map --config FILE` and library callers go
//! through exactly the same path as hand-built specs.

use crate::algo::Algorithm;
use crate::engine::{Backend, EngineConfig, MapSpec, Refinement, RetryPolicy};
use crate::multilevel::SchemeKind;
use crate::topology::{Hierarchy, Machine};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A full experiment/run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Task graph: instance registry name or METIS path (`graph = rgg15`).
    /// Optional because the CLI may supply it via `--graph`.
    pub graph: Option<String>,
    /// Machine hierarchy, e.g. `4:8:6`.
    pub hierarchy: String,
    /// Distance vector, e.g. `1:10:100`.
    pub distance: String,
    /// Machine-model spec (`topology = torus:4x4x4`); overrides
    /// `hierarchy`/`distance` when set.
    pub topology: Option<String>,
    /// Imbalance ε.
    pub eps: f64,
    /// Algorithm to run; `None` = auto-route (`algorithm = auto`).
    pub algorithm: Option<Algorithm>,
    /// Refinement flavor (`refinement = standard|strong`).
    pub refinement: Refinement,
    /// Multilevel coarsening scheme
    /// (`coarsening = matching|cluster|auto`).
    pub coarsening: SchemeKind,
    /// Run the QAP polish stage (`polish = 1`).
    pub polish: bool,
    /// Kernel execution backend (`backend = cpu|device|auto`).
    pub backend: Backend,
    /// Seeds (the paper averages over five).
    pub seeds: Vec<u64>,
    /// Device worker threads (0 = auto).
    pub threads: usize,
    /// Engine workers draining the job queue (`workers = 2`).
    pub workers: usize,
    /// Bounded job-queue capacity (`queue_cap = 256`).
    pub queue_cap: usize,
    /// Total execution attempts per job (`max_attempts = 3`; 1 = no
    /// retry). Lowered into [`EngineConfig::retry`].
    pub max_attempts: u32,
    /// Base retry backoff in ms (`backoff_ms = 100`; doubles per
    /// attempt, capped at [`crate::engine::RetryPolicy::MAX_BACKOFF`]).
    pub backoff_ms: u64,
    /// Artifact directory for the PJRT offload kernels.
    pub artifacts_dir: String,
    /// Solver-specific options (`opt.NAME = value`).
    pub options: BTreeMap<String, String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            graph: None,
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            topology: None,
            eps: 0.03,
            algorithm: Some(Algorithm::GpuIm),
            refinement: Refinement::Standard,
            coarsening: SchemeKind::Auto,
            polish: false,
            backend: Backend::Cpu,
            seeds: vec![1, 2, 3, 4, 5],
            threads: 0,
            workers: 1,
            queue_cap: 256,
            max_attempts: RetryPolicy::default().max_attempts,
            backoff_ms: RetryPolicy::default().base_backoff.as_millis() as u64,
            artifacts_dir: "artifacts".into(),
            options: BTreeMap::new(),
        }
    }
}

impl RunConfig {
    pub fn parse_hierarchy(&self) -> Result<Hierarchy> {
        Hierarchy::parse(&self.hierarchy, &self.distance)
    }

    /// Resolve the machine model: the `topology` key when present, the
    /// `hierarchy`/`distance` pair otherwise.
    pub fn machine(&self) -> Result<Machine> {
        Machine::resolve(self.topology.as_deref(), &self.hierarchy, &self.distance)
    }

    /// Lower into a [`MapSpec`] for `graph` (a registry name or METIS
    /// path — typically `self.graph` or a CLI override).
    pub fn to_spec(&self, graph: &str) -> MapSpec {
        let mut spec = MapSpec::named(graph)
            .hierarchy(self.hierarchy.clone())
            .distance(self.distance.clone())
            .eps(self.eps)
            .seeds(self.seeds.clone())
            .algo(self.algorithm)
            .refinement(self.refinement)
            .coarsening(self.coarsening)
            .polish(self.polish)
            .backend(self.backend)
            .options(self.options.clone());
        spec.topology = self.topology.clone();
        spec
    }

    /// Engine construction parameters carried by this config.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            threads: self.threads,
            artifacts_dir: self.artifacts_dir.clone(),
            workers: self.workers,
            queue_cap: self.queue_cap,
            retry: RetryPolicy {
                max_attempts: self.max_attempts.max(1),
                base_backoff: std::time::Duration::from_millis(self.backoff_ms),
            },
            ..EngineConfig::default()
        }
    }

    /// Load from a `key = value` file (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Self::from_kv_text(&text)
    }

    /// Parse the `key = value` format.
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut cfg = RunConfig::default();
        let kv = parse_kv(text)?;
        for (key, value) in kv {
            match key.as_str() {
                "graph" => cfg.graph = Some(value),
                "hierarchy" => cfg.hierarchy = value,
                "distance" => cfg.distance = value,
                "topology" => cfg.topology = Some(value),
                "eps" => cfg.eps = value.parse().context("eps")?,
                "algorithm" => {
                    cfg.algorithm = if value == "auto" {
                        None
                    } else {
                        Some(
                            Algorithm::from_name(&value)
                                .with_context(|| format!("unknown algorithm {value}"))?,
                        )
                    }
                }
                "refinement" => cfg.refinement = Refinement::from_name(&value)?,
                "coarsening" => cfg.coarsening = SchemeKind::from_name(&value)?,
                "polish" => cfg.polish = parse_bool(&value).context("polish")?,
                "backend" => cfg.backend = Backend::from_name(&value)?,
                "seeds" => {
                    cfg.seeds = value
                        .split(',')
                        .map(|s| s.trim().parse::<u64>().map_err(Into::into))
                        .collect::<Result<_>>()?
                }
                "threads" => cfg.threads = value.parse().context("threads")?,
                "workers" => cfg.workers = value.parse().context("workers")?,
                "queue_cap" => cfg.queue_cap = value.parse().context("queue_cap")?,
                "max_attempts" => cfg.max_attempts = value.parse().context("max_attempts")?,
                "backoff_ms" => cfg.backoff_ms = value.parse().context("backoff_ms")?,
                "artifacts_dir" => cfg.artifacts_dir = value,
                other => {
                    if let Some(opt) = other.strip_prefix("opt.") {
                        cfg.options.insert(opt.to_string(), value);
                    } else {
                        bail!("unknown config key `{other}`");
                    }
                }
            }
        }
        if cfg.seeds.is_empty() {
            bail!("seeds must not be empty");
        }
        // Validate the machine description; hierarchy/distance stay
        // individually well-formed even when topology overrides them.
        cfg.parse_hierarchy()?;
        cfg.machine()?;
        Ok(cfg)
    }
}

/// Strict boolean: only `0/1/true/false` are accepted — this parser
/// rejects typos instead of coercing them.
fn parse_bool(value: &str) -> Result<bool> {
    match value {
        "1" | "true" => Ok(true),
        "0" | "false" => Ok(false),
        other => bail!("expected 0/1/true/false, got `{other}`"),
    }
}

/// Parse `key = value` lines into an ordered map.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got `{raw}`", lineno + 1);
        };
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.eps, 0.03);
        assert_eq!(cfg.parse_hierarchy().unwrap().k(), 192);
        assert_eq!(cfg.seeds.len(), 5);
        assert_eq!(cfg.algorithm, Some(Algorithm::GpuIm));
    }

    #[test]
    fn parses_kv_text() {
        let cfg = RunConfig::from_kv_text(
            "hierarchy = 4:8:2\n# comment\ndistance = 1:10:100\neps = 0.05\nalgorithm = gpu-hm\nseeds = 7,8\n",
        )
        .unwrap();
        assert_eq!(cfg.parse_hierarchy().unwrap().k(), 64);
        assert_eq!(cfg.eps, 0.05);
        assert_eq!(cfg.algorithm, Some(Algorithm::GpuHm));
        assert_eq!(cfg.seeds, vec![7, 8]);
    }

    #[test]
    fn parses_engine_keys_and_lowers_to_spec() {
        let cfg = RunConfig::from_kv_text(
            "graph = rgg15\nhierarchy = 4:8:2\ndistance = 1:10:100\nalgorithm = auto\n\
             refinement = strong\npolish = 1\nopt.adaptive = 0\nseeds = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.graph.as_deref(), Some("rgg15"));
        assert_eq!(cfg.algorithm, None);
        assert_eq!(cfg.refinement, Refinement::Strong);
        assert!(cfg.polish);
        let spec = cfg.to_spec(cfg.graph.as_deref().unwrap());
        assert_eq!(spec.primary_seed(), 3);
        assert_eq!(spec.opt_bool("adaptive"), Some(false));
        assert!(spec.polish);
        assert_eq!(spec.algorithm, None);
    }

    #[test]
    fn topology_key_lowers_to_spec() {
        let cfg = RunConfig::from_kv_text("graph = rgg15\ntopology = torus:4x4x4\n").unwrap();
        assert_eq!(cfg.machine().unwrap().k(), 64);
        let spec = cfg.to_spec("rgg15");
        assert_eq!(spec.topology.as_deref(), Some("torus:4x4x4"));
        assert_eq!(spec.machine().unwrap().k(), 64);
        // Bad specs are rejected at config load.
        assert!(RunConfig::from_kv_text("topology = torus:0x4").is_err());
        assert!(RunConfig::from_kv_text("topology = bogus").is_err());
    }

    #[test]
    fn engine_worker_keys_reach_the_engine_config() {
        let cfg = RunConfig::from_kv_text("workers = 4\nqueue_cap = 32\nthreads = 2\n").unwrap();
        let ecfg = cfg.engine_config();
        assert_eq!(ecfg.workers, 4);
        assert_eq!(ecfg.queue_cap, 32);
        assert_eq!(ecfg.threads, 2);
        assert!(RunConfig::from_kv_text("workers = lots").is_err());
    }

    #[test]
    fn retry_keys_reach_the_engine_config() {
        let cfg = RunConfig::from_kv_text("max_attempts = 3\nbackoff_ms = 250\n").unwrap();
        let ecfg = cfg.engine_config();
        assert_eq!(ecfg.retry.max_attempts, 3);
        assert_eq!(ecfg.retry.base_backoff, std::time::Duration::from_millis(250));
        // Defaults: one attempt (no retry), and `max_attempts = 0` is
        // clamped to 1 rather than producing an unrunnable job.
        assert_eq!(RunConfig::default().engine_config().retry, RetryPolicy::default());
        let zero = RunConfig::from_kv_text("max_attempts = 0\n").unwrap();
        assert_eq!(zero.engine_config().retry.max_attempts, 1);
        assert!(RunConfig::from_kv_text("backoff_ms = soon").is_err());
    }

    #[test]
    fn coarsening_key_lowers_to_spec() {
        let cfg = RunConfig::from_kv_text("graph = rgg15\ncoarsening = cluster\n").unwrap();
        assert_eq!(cfg.coarsening, SchemeKind::Cluster);
        assert_eq!(cfg.to_spec("rgg15").coarsening, SchemeKind::Cluster);
        assert_eq!(RunConfig::default().coarsening, SchemeKind::Auto);
        assert!(RunConfig::from_kv_text("coarsening = frob").is_err());
    }

    #[test]
    fn backend_key_lowers_to_spec() {
        let cfg = RunConfig::from_kv_text("graph = rgg15\nbackend = device\n").unwrap();
        assert_eq!(cfg.backend, Backend::Device);
        assert_eq!(cfg.to_spec("rgg15").backend, Backend::Device);
        assert_eq!(RunConfig::default().backend, Backend::Cpu);
        assert!(RunConfig::from_kv_text("backend = tpu").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(RunConfig::from_kv_text("frobnicate = 3").is_err());
        assert!(RunConfig::from_kv_text("eps = banana").is_err());
        assert!(RunConfig::from_kv_text("algorithm = nope").is_err());
        assert!(RunConfig::from_kv_text("hierarchy = 4:8\ndistance = 1:10:100").is_err());
        assert!(RunConfig::from_kv_text("seeds = ").is_err());
        assert!(RunConfig::from_kv_text("polish = yes").is_err(), "polish must be strict");
    }

    #[test]
    fn kv_parser_ignores_comments() {
        let kv = parse_kv("a = 1 # trailing\n\n# full line\nb=2").unwrap();
        assert_eq!(kv.get("a").unwrap(), "1");
        assert_eq!(kv.get("b").unwrap(), "2");
    }
}
