//! Run configuration: presets plus a tiny `key = value` config-file format
//! (the offline crate set has no serde/toml, so the parser is hand-rolled).

use crate::algo::Algorithm;
use crate::topology::Hierarchy;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A full experiment/run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Machine hierarchy, e.g. `4:8:6`.
    pub hierarchy: String,
    /// Distance vector, e.g. `1:10:100`.
    pub distance: String,
    /// Imbalance ε.
    pub eps: f64,
    /// Algorithm to run.
    pub algorithm: Algorithm,
    /// Seeds (the paper averages over five).
    pub seeds: Vec<u64>,
    /// Device worker threads (0 = auto).
    pub threads: usize,
    /// Artifact directory for the PJRT offload kernels.
    pub artifacts_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            algorithm: Algorithm::GpuIm,
            seeds: vec![1, 2, 3, 4, 5],
            threads: 0,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl RunConfig {
    pub fn parse_hierarchy(&self) -> Result<Hierarchy> {
        Hierarchy::parse(&self.hierarchy, &self.distance)
    }

    /// Load from a `key = value` file (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Self::from_kv_text(&text)
    }

    /// Parse the `key = value` format.
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut cfg = RunConfig::default();
        let kv = parse_kv(text)?;
        for (key, value) in kv {
            match key.as_str() {
                "hierarchy" => cfg.hierarchy = value,
                "distance" => cfg.distance = value,
                "eps" => cfg.eps = value.parse().context("eps")?,
                "algorithm" => {
                    cfg.algorithm = Algorithm::from_name(&value)
                        .with_context(|| format!("unknown algorithm {value}"))?
                }
                "seeds" => {
                    cfg.seeds = value
                        .split(',')
                        .map(|s| s.trim().parse::<u64>().map_err(Into::into))
                        .collect::<Result<_>>()?
                }
                "threads" => cfg.threads = value.parse().context("threads")?,
                "artifacts_dir" => cfg.artifacts_dir = value,
                other => bail!("unknown config key `{other}`"),
            }
        }
        cfg.parse_hierarchy()?; // validate
        Ok(cfg)
    }
}

/// Parse `key = value` lines into an ordered map.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got `{raw}`", lineno + 1);
        };
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.eps, 0.03);
        assert_eq!(cfg.parse_hierarchy().unwrap().k(), 192);
        assert_eq!(cfg.seeds.len(), 5);
    }

    #[test]
    fn parses_kv_text() {
        let cfg = RunConfig::from_kv_text(
            "hierarchy = 4:8:2\n# comment\ndistance = 1:10:100\neps = 0.05\nalgorithm = gpu-hm\nseeds = 7,8\n",
        )
        .unwrap();
        assert_eq!(cfg.parse_hierarchy().unwrap().k(), 64);
        assert_eq!(cfg.eps, 0.05);
        assert_eq!(cfg.algorithm, Algorithm::GpuHm);
        assert_eq!(cfg.seeds, vec![7, 8]);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(RunConfig::from_kv_text("frobnicate = 3").is_err());
        assert!(RunConfig::from_kv_text("eps = banana").is_err());
        assert!(RunConfig::from_kv_text("algorithm = nope").is_err());
        assert!(RunConfig::from_kv_text("hierarchy = 4:8\ndistance = 1:10:100").is_err());
    }

    #[test]
    fn kv_parser_ignores_comments() {
        let kv = parse_kv("a = 1 # trailing\n\n# full line\nb=2").unwrap();
        assert_eq!(kv.get("a").unwrap(), "1");
        assert_eq!(kv.get("b").unwrap(), "2");
    }
}
