//! GPU cost model — the hardware-substitution half of the reproduction.
//!
//! The paper measures wall-clock on an RTX 4090 (16 384 CUDA cores,
//! 2.5 GHz boost). This host has one CPU core and no GPU, so absolute
//! GPU times are *modeled* from the kernel-launch ledger:
//!
//! ```text
//! t_device = launches · T_LAUNCH + work_items / THROUGHPUT
//! ```
//!
//! The two constants are calibrated against the paper's published absolute
//! phase times (Table 2: cop20k_A = 42.1 ms total, europe_osm = 320.6 ms
//! total for GPU-IM on the 4:8:6 hierarchy):
//!
//! * `T_LAUNCH` = 6 µs — typical CUDA kernel launch + sync latency; the
//!   paper's small-graph runtimes are launch-dominated (cop20k_A spends
//!   42 ms over a pipeline of a few thousand kernels).
//! * `THROUGHPUT` = 3 000 items/µs — effective irregular-workload
//!   throughput; europe_osm (≈108 M directed edges, tens of edge-parallel
//!   sweeps) lands at a few hundred ms.
//!
//! The model deliberately ignores per-item cost variation; the paper's
//! claims we reproduce are *relative* (speedup ratios, phase shares), and
//! those depend on launch counts and item counts, which we measure exactly.
//! Host wall-clock is always reported alongside the modeled time.

use super::ledger::Snapshot;

/// Modeled CUDA kernel launch + synchronization latency (µs).
pub const T_LAUNCH_US: f64 = 6.0;
/// Modeled effective device throughput (work items / µs).
pub const THROUGHPUT_ITEMS_PER_US: f64 = 3_000.0;

/// Modeled serial-CPU throughput (items/µs) for the speedup denominator of
/// CPU baselines when converting their measured work into modeled time on
/// the paper's Xeon w5-3435X. Wall-clock is used for CPU baselines by
/// default; this constant only feeds sanity checks.
pub const CPU_THROUGHPUT_ITEMS_PER_US: f64 = 150.0;

/// Modeled device time in microseconds for a ledger delta.
pub fn device_time_us(delta: Snapshot) -> f64 {
    delta.launches as f64 * T_LAUNCH_US + delta.work_items as f64 / THROUGHPUT_ITEMS_PER_US
}

/// Modeled device time in milliseconds.
pub fn device_time_ms(delta: Snapshot) -> f64 {
    device_time_us(delta) / 1_000.0
}

/// A scoped device timer: captures the ledger on construction and reports
/// modeled device time + host wall time on [`DeviceTimer::stop`].
pub struct DeviceTimer {
    start_ledger: Snapshot,
    start_wall: std::time::Instant,
}

/// What a [`DeviceTimer`] measured.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Modeled GPU time (ms) from the cost model.
    pub device_ms: f64,
    /// Wall-clock on this host (ms).
    pub host_ms: f64,
    /// Ledger delta (launches, work items).
    pub ledger: Snapshot,
}

impl Default for DeviceTimer {
    fn default() -> Self {
        Self::start()
    }
}

impl DeviceTimer {
    pub fn start() -> Self {
        DeviceTimer { start_ledger: super::ledger::snapshot(), start_wall: std::time::Instant::now() }
    }

    pub fn stop(&self) -> Measurement {
        let delta = super::ledger::snapshot().since(self.start_ledger);
        Measurement {
            device_ms: device_time_ms(delta),
            host_ms: self.start_wall.elapsed().as_secs_f64() * 1_000.0,
            ledger: delta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_dominated_vs_throughput_dominated() {
        // Many empty launches: launch term dominates.
        let many_launches = Snapshot { launches: 1_000, work_items: 1_000 };
        // One huge kernel: throughput term dominates.
        let big_kernel = Snapshot { launches: 1, work_items: 100_000_000 };
        let t1 = device_time_us(many_launches);
        let t2 = device_time_us(big_kernel);
        assert!(t1 > 0.9 * 1_000.0 * T_LAUNCH_US);
        assert!(t2 > 0.9 * 100_000_000.0 / THROUGHPUT_ITEMS_PER_US);
    }

    #[test]
    fn timer_measures_pool_work() {
        let pool = crate::par::Pool::new(1);
        let t = DeviceTimer::start();
        pool.parallel_for(30_000, |_| {});
        let m = t.stop();
        assert_eq!(m.ledger.launches, 1);
        assert_eq!(m.ledger.work_items, 30_000);
        assert!(m.device_ms > 0.0);
        assert!(m.host_ms >= 0.0);
    }

    #[test]
    fn calibration_ballpark_table2() {
        // europe_osm-scale GPU-IM: ~5k launches, ~1.5G items should land
        // within the same order of magnitude as the paper's 320 ms.
        let osm = Snapshot { launches: 5_000, work_items: 900_000_000 };
        let ms = device_time_ms(osm);
        assert!(ms > 100.0 && ms < 1_000.0, "modeled {ms} ms");
    }
}
