//! Kernel-launch ledger.
//!
//! Every [`super::Pool`] primitive records one (or two, for scans) kernel
//! launches and the number of flat work items. The ledger is the input to
//! the GPU cost model ([`super::cost`]): the paper's algorithms are
//! sequences of bulk-synchronous device kernels, so `(launches, items)`
//! fully determines the modeled device time.

use std::sync::atomic::{AtomicU64, Ordering};

static LAUNCHES: AtomicU64 = AtomicU64::new(0);
static WORK_ITEMS: AtomicU64 = AtomicU64::new(0);

/// A snapshot of the ledger counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub launches: u64,
    pub work_items: u64,
}

impl Snapshot {
    /// Counters accumulated since `earlier`.
    pub fn since(self, earlier: Snapshot) -> Snapshot {
        Snapshot {
            launches: self.launches - earlier.launches,
            work_items: self.work_items - earlier.work_items,
        }
    }
}

#[inline]
pub(crate) fn record_launch(items: u64) {
    LAUNCHES.fetch_add(1, Ordering::Relaxed);
    WORK_ITEMS.fetch_add(items, Ordering::Relaxed);
}

/// Charge device work that happens outside the pool primitives — e.g.
/// modeled host↔device transfers (one "launch" = one copy, items = words
/// moved). Used by the pipelines to account the paper's "Misc" phase.
#[inline]
pub fn charge(launches: u64, items: u64) {
    LAUNCHES.fetch_add(launches, Ordering::Relaxed);
    WORK_ITEMS.fetch_add(items, Ordering::Relaxed);
}

/// Read the current counters.
pub fn snapshot() -> Snapshot {
    Snapshot {
        launches: LAUNCHES.load(Ordering::Relaxed),
        work_items: WORK_ITEMS.load(Ordering::Relaxed),
    }
}

/// Reset both counters to zero (tests / per-experiment accounting).
pub fn reset() {
    LAUNCHES.store(0, Ordering::Relaxed);
    WORK_ITEMS.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::Pool;

    #[test]
    fn records_launches_and_items() {
        let pool = Pool::new(1);
        let before = snapshot();
        pool.parallel_for(100, |_| {});
        pool.reduce_sum_u64(50, |_| 1);
        let delta = snapshot().since(before);
        assert_eq!(delta.launches, 2);
        assert_eq!(delta.work_items, 150);
    }

    #[test]
    fn scan_counts_two_launches() {
        let pool = Pool::new(1);
        let before = snapshot();
        let _ = pool.scan_exclusive(10, |_| 1);
        let delta = snapshot().since(before);
        assert_eq!(delta.launches, 2);
        assert_eq!(delta.work_items, 20);
    }
}
