//! Kernel-launch ledger.
//!
//! Every [`super::Pool`] primitive records one (or two, for scans) kernel
//! launches and the number of flat work items. The ledger is the input to
//! the GPU cost model ([`super::cost`]): the paper's algorithms are
//! sequences of bulk-synchronous device kernels, so `(launches, items)`
//! fully determines the modeled device time.
//!
//! The ledger also carries the **kernel label scope**: launch sites open a
//! [`KernelScope`] naming the kernel (`"coarsen/match_par:prefs"`, …), and
//! diagnostics — in particular the `device-check` race checker — read
//! [`current_kernel`] to attribute a launch to its site.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

// relaxed: the ledger counters are independent monotonic tallies; readers
// only consume them after the kernel barrier (or tolerate small skew in
// live snapshots), so no cross-location ordering is required.
static LAUNCHES: AtomicU64 = AtomicU64::new(0);
static WORK_ITEMS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Stack of kernel labels opened on this (submitting) thread. A stack,
    /// not a cell, so nested launches (which run inline) restore the outer
    /// label on drop.
    static KERNEL_STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

/// RAII guard naming every launch issued while it is alive; created by
/// [`kernel`], popped on drop.
pub struct KernelScope(());

impl Drop for KernelScope {
    fn drop(&mut self) {
        KERNEL_STACK.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// Open a label scope for the kernels launched until the guard drops:
///
/// ```ignore
/// let _k = ledger::kernel("coarsen/match_par:prefs");
/// pool.parallel_for(n, |v| { ... });
/// ```
///
/// Labels are `&'static str` by design — launch sites are static program
/// points, and the checker must not allocate per launch.
#[must_use = "the label is popped when the guard drops"]
pub fn kernel(label: &'static str) -> KernelScope {
    KERNEL_STACK.with(|s| s.borrow_mut().push(label));
    KernelScope(())
}

/// The innermost kernel label on this thread, if any launch site named one.
pub fn current_kernel() -> Option<&'static str> {
    KERNEL_STACK.with(|s| s.borrow().last().copied())
}

/// A snapshot of the ledger counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub launches: u64,
    pub work_items: u64,
}

impl Snapshot {
    /// Counters accumulated since `earlier`.
    pub fn since(self, earlier: Snapshot) -> Snapshot {
        Snapshot {
            launches: self.launches - earlier.launches,
            work_items: self.work_items - earlier.work_items,
        }
    }
}

#[inline]
pub(crate) fn record_launch(items: u64) {
    // relaxed: independent statistics counters (see the statics above).
    LAUNCHES.fetch_add(1, Ordering::Relaxed);
    WORK_ITEMS.fetch_add(items, Ordering::Relaxed);
}

/// Charge device work that happens outside the pool primitives — e.g.
/// modeled host↔device transfers (one "launch" = one copy, items = words
/// moved). Used by the pipelines to account the paper's "Misc" phase.
#[inline]
pub fn charge(launches: u64, items: u64) {
    // relaxed: independent statistics counters (see the statics above).
    LAUNCHES.fetch_add(launches, Ordering::Relaxed);
    WORK_ITEMS.fetch_add(items, Ordering::Relaxed);
}

/// Read the current counters.
pub fn snapshot() -> Snapshot {
    // relaxed: live snapshots tolerate skew between the two counters;
    // per-experiment accounting reads after the kernel barrier anyway.
    Snapshot {
        launches: LAUNCHES.load(Ordering::Relaxed),
        work_items: WORK_ITEMS.load(Ordering::Relaxed),
    }
}

/// Reset both counters to zero (tests / per-experiment accounting).
pub fn reset() {
    // relaxed: callers reset between experiments, never inside a kernel.
    LAUNCHES.store(0, Ordering::Relaxed);
    WORK_ITEMS.store(0, Ordering::Relaxed);
}

thread_local! {
    /// Real-device counters for the PJRT execution path
    /// ([`crate::runtime::device`]). Thread-local — the device session
    /// itself is thread-local — so the engine can diff them around one
    /// job on its worker thread without cross-job interference, then
    /// fold the delta into its process-wide metrics.
    static DEVICE_LAUNCHES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    static H2D_BYTES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    static D2H_BYTES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// A snapshot of this thread's real-device counters (PJRT launches and
/// host↔device traffic in bytes — *measured*, unlike the modeled
/// [`charge`] tallies).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeviceSnapshot {
    pub device_launches: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

impl DeviceSnapshot {
    /// Counters accumulated since `earlier`.
    pub fn since(self, earlier: DeviceSnapshot) -> DeviceSnapshot {
        DeviceSnapshot {
            device_launches: self.device_launches - earlier.device_launches,
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
        }
    }
}

/// Record one real PJRT execution with its upload/download volume.
#[inline]
pub fn charge_device(h2d_bytes: u64, d2h_bytes: u64) {
    DEVICE_LAUNCHES.with(|c| c.set(c.get() + 1));
    H2D_BYTES.with(|c| c.set(c.get() + h2d_bytes));
    D2H_BYTES.with(|c| c.set(c.get() + d2h_bytes));
}

/// Record a host→device upload that happens outside an execution (e.g.
/// building a device-resident graph).
#[inline]
pub fn charge_h2d(bytes: u64) {
    H2D_BYTES.with(|c| c.set(c.get() + bytes));
}

/// Read this thread's real-device counters.
pub fn device_snapshot() -> DeviceSnapshot {
    DeviceSnapshot {
        device_launches: DEVICE_LAUNCHES.with(|c| c.get()),
        h2d_bytes: H2D_BYTES.with(|c| c.get()),
        d2h_bytes: D2H_BYTES.with(|c| c.get()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::Pool;

    #[test]
    fn records_launches_and_items() {
        let pool = Pool::new(1);
        let before = snapshot();
        pool.parallel_for(100, |_| {});
        pool.reduce_sum_u64(50, |_| 1);
        let delta = snapshot().since(before);
        assert_eq!(delta.launches, 2);
        assert_eq!(delta.work_items, 150);
    }

    #[test]
    fn kernel_labels_nest_and_restore() {
        assert_eq!(current_kernel(), None);
        {
            let _outer = kernel("outer");
            assert_eq!(current_kernel(), Some("outer"));
            {
                let _inner = kernel("inner");
                assert_eq!(current_kernel(), Some("inner"));
            }
            assert_eq!(current_kernel(), Some("outer"));
        }
        assert_eq!(current_kernel(), None);
    }

    #[test]
    fn scan_counts_two_launches() {
        let pool = Pool::new(1);
        let before = snapshot();
        let _ = pool.scan_exclusive(10, |_| 1);
        let delta = snapshot().since(before);
        assert_eq!(delta.launches, 2);
        assert_eq!(delta.work_items, 20);
    }
}
