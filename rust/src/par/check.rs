//! Checked-device mode: a shadow access log that validates the BSP
//! disjointness contract at every kernel barrier.
//!
//! Compiled only under `feature = "device-check"`. Every kernel launch
//! opens a *launch epoch*; while it is open, [`super::SharedMut`] and
//! [`super::AtomicList`] record each access tagged with the **logical work
//! unit** that performed it (the `parallel_for` index, the reduce worker
//! slot, the scan block id — not the OS thread). When the launch's barrier
//! completes, the log is validated:
//!
//! - **write/write** — no location may be written non-atomically by two
//!   distinct logical units within one superstep;
//! - **write/read** — no unit may read a location another unit wrote (or
//!   atomically appended) within the same superstep; reads of data written
//!   by *earlier* kernels are fine, that is what the barrier is for.
//!
//! Tagging by logical index makes the check *interleaving-independent*:
//! two units that would collide are flagged even when the scheduler happens
//! to run them on the same thread — including at `threads == 1`, where no
//! data race can physically occur but the contract violation is still a
//! bug on a real device. Atomic appends never conflict with each other.
//!
//! Conflicts panic by default, naming the kernel label (see
//! [`super::ledger::kernel`]), the buffer, the element index, and the two
//! logical units. Tests call [`set_panic_on_conflict`] +
//! [`take_conflicts`] to assert on diagnostics instead.

use super::ledger;
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Logical unit ids at or above this base denote *internal* pool units
/// (reduce worker slots, scan blocks) rather than user work-item indices;
/// the offset only disambiguates diagnostics — conflict detection treats
/// all unit ids uniformly.
pub const INTERNAL_UNIT_BASE: u64 = 1 << 62;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
    /// Atomically-published write (e.g. an [`super::AtomicList`] append):
    /// never conflicts with other atomic writes, still conflicts with a
    /// same-superstep non-atomic read or write by another unit.
    AtomicWrite,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConflictKind {
    /// Two distinct logical units wrote one location in one superstep.
    WriteWrite,
    /// A location written this superstep was read non-atomically by a
    /// different logical unit in the same superstep.
    ReadWrite,
}

impl std::fmt::Display for ConflictKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConflictKind::WriteWrite => write!(f, "write/write"),
            ConflictKind::ReadWrite => write!(f, "write/read"),
        }
    }
}

/// One validated contract violation, as reported at a kernel barrier.
#[derive(Clone, Debug)]
pub struct Conflict {
    /// Label of the launch site ([`ledger::kernel`]), or `"<unlabeled>"`.
    pub kernel: &'static str,
    pub kind: ConflictKind,
    /// Base address of the shadowed buffer (identifies *which* buffer).
    pub base: usize,
    /// Element index within that buffer.
    pub index: usize,
    /// The two conflicting logical unit ids (writer first for
    /// [`ConflictKind::ReadWrite`]).
    pub units: (u64, u64),
}

impl std::fmt::Display for Conflict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "device-check: {} conflict in kernel `{}` at buffer {:#x} index {}: logical units {} and {}",
            self.kind,
            self.kernel,
            self.base,
            self.index,
            fmt_unit(self.units.0),
            fmt_unit(self.units.1),
        )
    }
}

fn fmt_unit(u: u64) -> String {
    if u >= INTERNAL_UNIT_BASE {
        format!("internal#{}", u - INTERNAL_UNIT_BASE)
    } else {
        u.to_string()
    }
}

#[derive(Clone, Copy)]
struct Access {
    base: usize,
    index: usize,
    unit: u64,
    kind: AccessKind,
}

struct LaunchLog {
    label: &'static str,
    accesses: Vec<Access>,
}

struct Registry {
    /// Open launches by id. A map (not a single slot) because independent
    /// pools on different host threads may have kernels in flight at once.
    open: Mutex<HashMap<u64, LaunchLog>>,
    next_id: AtomicU64,
    conflicts: Mutex<Vec<Conflict>>,
    panic_on_conflict: AtomicBool,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        open: Mutex::new(HashMap::new()),
        next_id: AtomicU64::new(1),
        conflicts: Mutex::new(Vec::new()),
        panic_on_conflict: AtomicBool::new(true),
    })
}

thread_local! {
    /// Launch id the current thread is executing inside (0 = host code).
    static CURRENT_LAUNCH: Cell<u64> = const { Cell::new(0) };
    /// Logical unit id the current thread is executing on behalf of.
    static CURRENT_UNIT: Cell<u64> = const { Cell::new(0) };
}

/// Is checked mode on? Compiled-in by the feature, it defaults to
/// **enabled** and can be switched off with `HEIPA_DEVICE_CHECK=0`
/// (the harness reports the state; any other value, or unset, keeps it on).
pub fn enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var("HEIPA_DEVICE_CHECK").map_or(true, |v| v != "0"))
}

/// Route conflicts to [`take_conflicts`] instead of panicking (tests).
/// Returns the previous setting.
pub fn set_panic_on_conflict(panic: bool) -> bool {
    // relaxed: a test-harness toggle flipped outside any kernel; the value
    // is only consulted at barriers, which fully synchronize via mutexes.
    registry().panic_on_conflict.swap(panic, Ordering::Relaxed)
}

/// Drain the conflicts recorded since the last call.
pub fn take_conflicts() -> Vec<Conflict> {
    std::mem::take(&mut *lock(&registry().conflicts))
}

/// Number of conflicts currently recorded (not yet drained).
pub fn conflict_count() -> usize {
    lock(&registry().conflicts).len()
}

/// Poison-tolerant lock: checker state stays consistent across the panics
/// the checker itself throws (straight-line updates only under the lock).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Open a launch epoch; returns its id (0 when checking is disabled).
/// Captures the submitting thread's kernel label.
pub(super) fn begin_launch() -> u64 {
    if !enabled() {
        return 0;
    }
    let reg = registry();
    // relaxed: the id is a unique ticket; the registry mutex below is the
    // synchronization point for the log itself.
    let id = reg.next_id.fetch_add(1, Ordering::Relaxed);
    let label = ledger::current_kernel().unwrap_or("<unlabeled>");
    lock(&reg.open).insert(id, LaunchLog { label, accesses: Vec::new() });
    id
}

/// Close a launch epoch and validate its access log; called on the
/// submitting thread after the pool barrier, so every worker's accesses
/// are already published (the barrier's mutex orders them). Panics on the
/// first conflict unless [`set_panic_on_conflict`]`(false)`.
pub(super) fn end_launch(id: u64) {
    if id == 0 {
        return;
    }
    let reg = registry();
    let Some(log) = lock(&reg.open).remove(&id) else { return };
    let conflicts = validate(&log);
    if conflicts.is_empty() {
        return;
    }
    let first = conflicts[0].clone();
    let n = conflicts.len();
    lock(&reg.conflicts).extend(conflicts);
    // relaxed: see set_panic_on_conflict.
    if reg.panic_on_conflict.load(Ordering::Relaxed) {
        panic!("{first}{}", if n > 1 { format!(" (+{} more)", n - 1) } else { String::new() });
    }
}

/// Per-location summary accumulated while scanning a launch's access log.
#[derive(Default)]
struct LocState {
    writer: Option<u64>,
    atomic_writer: Option<u64>,
    reader: Option<u64>,
    reported: bool,
}

fn validate(log: &LaunchLog) -> Vec<Conflict> {
    // Cap the report per launch: one seeded race in an n-sized kernel
    // would otherwise produce n conflicts.
    const MAX_CONFLICTS: usize = 16;
    let mut locs: HashMap<(usize, usize), LocState> = HashMap::new();
    let mut out = Vec::new();
    for a in &log.accesses {
        if out.len() >= MAX_CONFLICTS {
            break;
        }
        let st = locs.entry((a.base, a.index)).or_default();
        if st.reported {
            continue;
        }
        let mut conflict = None;
        match a.kind {
            AccessKind::Write => {
                if let Some(w) = st.writer.or(st.atomic_writer) {
                    if w != a.unit {
                        conflict = Some((ConflictKind::WriteWrite, (w, a.unit)));
                    }
                }
                if conflict.is_none() {
                    if let Some(r) = st.reader {
                        if r != a.unit {
                            conflict = Some((ConflictKind::ReadWrite, (a.unit, r)));
                        }
                    }
                }
                st.writer.get_or_insert(a.unit);
            }
            AccessKind::AtomicWrite => {
                if let Some(w) = st.writer {
                    if w != a.unit {
                        conflict = Some((ConflictKind::WriteWrite, (w, a.unit)));
                    }
                }
                if conflict.is_none() {
                    if let Some(r) = st.reader {
                        if r != a.unit {
                            conflict = Some((ConflictKind::ReadWrite, (a.unit, r)));
                        }
                    }
                }
                st.atomic_writer.get_or_insert(a.unit);
            }
            AccessKind::Read => {
                if let Some(w) = st.writer.or(st.atomic_writer) {
                    if w != a.unit {
                        conflict = Some((ConflictKind::ReadWrite, (w, a.unit)));
                    }
                }
                st.reader.get_or_insert(a.unit);
            }
        }
        if let Some((kind, units)) = conflict {
            st.reported = true;
            out.push(Conflict {
                kernel: log.label,
                kind,
                base: a.base,
                index: a.index,
                units,
            });
        }
    }
    out
}

/// RAII guard marking the current thread as executing inside launch `id`;
/// restores the previous launch/unit on drop (nested inline launches).
pub(super) struct EnterGuard {
    prev_launch: u64,
    prev_unit: u64,
}

pub(super) fn enter(id: u64) -> EnterGuard {
    let prev_launch = CURRENT_LAUNCH.with(|c| c.replace(id));
    let prev_unit = CURRENT_UNIT.with(|c| c.replace(0));
    EnterGuard { prev_launch, prev_unit }
}

impl Drop for EnterGuard {
    fn drop(&mut self) {
        CURRENT_LAUNCH.with(|c| c.set(self.prev_launch));
        CURRENT_UNIT.with(|c| c.set(self.prev_unit));
    }
}

/// Tag subsequent accesses on this thread with logical unit `u`.
#[inline]
pub(super) fn set_unit(u: u64) {
    CURRENT_UNIT.with(|c| c.set(u));
}

/// Record one element access against the current launch (no-op in host
/// code, i.e. outside any launch epoch on this thread).
#[inline]
pub(super) fn record(base: usize, index: usize, kind: AccessKind) {
    let id = CURRENT_LAUNCH.with(|c| c.get());
    if id == 0 {
        return;
    }
    let unit = CURRENT_UNIT.with(|c| c.get());
    let mut open = lock(&registry().open);
    if let Some(log) = open.get_mut(&id) {
        log.accesses.push(Access { base, index, unit, kind });
    }
}

/// Record a contiguous range of accesses (e.g. a `SharedMut::slice` claim).
#[inline]
pub(super) fn record_range(base: usize, start: usize, len: usize, kind: AccessKind) {
    let id = CURRENT_LAUNCH.with(|c| c.get());
    if id == 0 {
        return;
    }
    let unit = CURRENT_UNIT.with(|c| c.get());
    let mut open = lock(&registry().open);
    if let Some(log) = open.get_mut(&id) {
        log.accesses.extend((start..start + len).map(|index| Access { base, index, unit, kind }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(accesses: Vec<Access>) -> LaunchLog {
        LaunchLog { label: "test:kernel", accesses }
    }

    fn acc(index: usize, unit: u64, kind: AccessKind) -> Access {
        Access { base: 0x1000, index, unit, kind }
    }

    #[test]
    fn disjoint_writes_are_clean() {
        let log = log_with((0..100).map(|i| acc(i, i as u64, AccessKind::Write)).collect());
        assert!(validate(&log).is_empty());
    }

    #[test]
    fn same_unit_write_then_read_is_clean() {
        let log = log_with(vec![
            acc(3, 7, AccessKind::Write),
            acc(3, 7, AccessKind::Read),
        ]);
        assert!(validate(&log).is_empty());
    }

    #[test]
    fn write_write_flagged_once_per_location() {
        let log = log_with(vec![
            acc(5, 1, AccessKind::Write),
            acc(5, 2, AccessKind::Write),
            acc(5, 3, AccessKind::Write),
        ]);
        let c = validate(&log);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].kind, ConflictKind::WriteWrite);
        assert_eq!(c[0].units, (1, 2));
        assert_eq!(c[0].kernel, "test:kernel");
        assert_eq!(c[0].index, 5);
    }

    #[test]
    fn cross_unit_read_of_written_slot_flagged() {
        let log = log_with(vec![
            acc(9, 4, AccessKind::Write),
            acc(9, 6, AccessKind::Read),
        ]);
        let c = validate(&log);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].kind, ConflictKind::ReadWrite);
        assert_eq!(c[0].units, (4, 6));
    }

    #[test]
    fn read_then_other_unit_write_flagged() {
        // Order in the log is arbitrary (interleaving-independent): the
        // read may be recorded before the write and must still be flagged.
        let log = log_with(vec![
            acc(2, 6, AccessKind::Read),
            acc(2, 4, AccessKind::Write),
        ]);
        let c = validate(&log);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].kind, ConflictKind::ReadWrite);
        assert_eq!(c[0].units, (4, 6), "writer reported first");
    }

    #[test]
    fn atomic_appends_do_not_conflict_with_each_other() {
        let log = log_with(vec![
            acc(0, 1, AccessKind::AtomicWrite),
            acc(0, 2, AccessKind::AtomicWrite),
            acc(1, 3, AccessKind::AtomicWrite),
        ]);
        assert!(validate(&log).is_empty());
    }

    #[test]
    fn atomic_write_vs_plain_access_conflicts() {
        let log = log_with(vec![
            acc(0, 1, AccessKind::AtomicWrite),
            acc(0, 2, AccessKind::Read),
        ]);
        let c = validate(&log);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].kind, ConflictKind::ReadWrite);

        let log = log_with(vec![
            acc(4, 1, AccessKind::Write),
            acc(4, 2, AccessKind::AtomicWrite),
        ]);
        let c = validate(&log);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].kind, ConflictKind::WriteWrite);
    }

    #[test]
    fn conflict_report_is_capped() {
        let mut accesses = Vec::new();
        for i in 0..1000 {
            accesses.push(acc(i, 1, AccessKind::Write));
            accesses.push(acc(i, 2, AccessKind::Write));
        }
        let c = validate(&log_with(accesses));
        assert!(!c.is_empty() && c.len() <= 16);
    }
}
