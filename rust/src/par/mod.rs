//! Bulk-synchronous data-parallel execution substrate (the "device").
//!
//! The paper implements its kernels with Kokkos' three primitives —
//! `parallel_for`, `parallel_reduce`, `parallel_scan` — on a CUDA GPU
//! (§3.3). This environment has no GPU, so the same primitives are
//! provided over a CPU worker pool (crossbeam scoped threads). Algorithms
//! upstack are written *exactly* as the paper's kernels: flat loops over
//! vertices or over the extended-CSR edge list, atomic CAS insertion,
//! atomically-appended move lists, and prefix-sum based compaction.
//!
//! Every launch is recorded in a [`ledger`], from which the calibrated
//! GPU cost model ([`cost`]) estimates what the kernel sequence would cost
//! on the paper's RTX 4090 — see DESIGN.md §1 for the substitution
//! rationale. Wall-clock on this host and modeled device time are reported
//! side by side by the benchmark harness.

pub mod cost;
pub mod ledger;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// A worker pool executing bulk-synchronous parallel primitives.
///
/// `threads == 1` executes inline (no spawn overhead); this is the default
/// on the single-core evaluation host. The execution *semantics* (one
/// logical work unit per index, barriers between kernels) are identical
/// for any thread count, and the test suite runs key kernels at 1, 2 and 4
/// threads to check determinism-insensitivity.
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
}

impl Default for Pool {
    fn default() -> Self {
        Self::new(default_threads())
    }
}

/// Thread count from `HEIPA_THREADS`, else available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HEIPA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        Pool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `parallel_for`: execute `f(i)` for all `i in 0..n`.
    ///
    /// One kernel launch; `n` work items are charged to the ledger.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        ledger::record_launch(n as u64);
        if self.threads == 1 || n < 2 * MIN_CHUNK {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        crossbeam_utils::thread::scope(|s| {
            for _ in 0..self.threads {
                s.spawn(|_| loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        f(i);
                    }
                });
            }
        })
        .expect("worker panicked in parallel_for");
    }

    /// `parallel_reduce` with an associative combiner:
    /// `R = combine(f(0), f(1), …, f(n-1))` starting from `identity`.
    pub fn parallel_reduce<T, F, C>(&self, n: usize, identity: T, f: F, combine: C) -> T
    where
        T: Send + Clone,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync + Send,
    {
        ledger::record_launch(n as u64);
        if self.threads == 1 || n < 2 * MIN_CHUNK {
            let mut acc = identity;
            for i in 0..n {
                acc = combine(acc, f(i));
            }
            return acc;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        let partials: Vec<T> = crossbeam_utils::thread::scope(|s| {
            let handles: Vec<_> = (0..self.threads)
                .map(|_| {
                    let identity = identity.clone();
                    let next = &next;
                    let f = &f;
                    let combine = &combine;
                    s.spawn(move |_| {
                        let mut acc = identity;
                        loop {
                            let start = next.fetch_add(chunk, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let end = (start + chunk).min(n);
                            for i in start..end {
                                acc = combine(acc, f(i));
                            }
                        }
                        acc
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
        .expect("worker panicked in parallel_reduce");
        partials.into_iter().fold(identity, |a, b| combine(a, b))
    }

    /// Convenience: `Σ f(i)` over `u64`.
    pub fn reduce_sum_u64<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> u64 + Sync,
    {
        self.parallel_reduce(n, 0u64, f, |a, b| a + b)
    }

    /// Convenience: `Σ f(i)` over `f64`.
    pub fn reduce_sum_f64<F>(&self, n: usize, f: F) -> f64
    where
        F: Fn(usize) -> f64 + Sync,
    {
        self.parallel_reduce(n, 0f64, f, |a, b| a + b)
    }

    /// `parallel_scan`: exclusive prefix sum of `f(i)`; returns a vector of
    /// length `n + 1` whose last element is the total (Kokkos semantics
    /// plus the total, which every call site in the paper needs anyway).
    pub fn scan_exclusive<F>(&self, n: usize, f: F) -> Vec<u64>
    where
        F: Fn(usize) -> u64 + Sync,
    {
        // Two-pass blocked scan: 2 launches, 2n work items.
        ledger::record_launch(n as u64);
        ledger::record_launch(n as u64);
        let mut out = vec![0u64; n + 1];
        if self.threads == 1 || n < 2 * MIN_CHUNK {
            let mut acc = 0u64;
            for i in 0..n {
                out[i] = acc;
                acc += f(i);
            }
            out[n] = acc;
            return out;
        }
        let nblocks = self.threads * 4;
        let block = n.div_ceil(nblocks);
        let mut block_sums = vec![0u64; nblocks];
        // Pass 1: per-block sums.
        {
            let bs = &mut block_sums;
            crossbeam_utils::thread::scope(|s| {
                for (b, slot) in bs.iter_mut().enumerate() {
                    let f = &f;
                    s.spawn(move |_| {
                        let start = b * block;
                        let end = ((b + 1) * block).min(n);
                        let mut acc = 0u64;
                        for i in start..end.max(start) {
                            acc += f(i);
                        }
                        *slot = acc;
                    });
                }
            })
            .expect("worker panicked in scan pass 1");
        }
        // Serial scan of block sums.
        let mut block_off = vec![0u64; nblocks + 1];
        for b in 0..nblocks {
            block_off[b + 1] = block_off[b] + block_sums[b];
        }
        // Pass 2: per-block exclusive scan into the output.
        {
            let out_ptr = SendPtr::new(&mut out);
            let out_ref = &out_ptr;
            crossbeam_utils::thread::scope(|s| {
                for b in 0..nblocks {
                    let f = &f;
                    let off = block_off[b];
                    s.spawn(move |_| {
                        let start = b * block;
                        let end = ((b + 1) * block).min(n);
                        let mut acc = off;
                        for i in start..end.max(start) {
                            // SAFETY: disjoint index ranges per block.
                            unsafe { out_ref.write(i, acc) };
                            acc += f(i);
                        }
                    });
                }
            })
            .expect("worker panicked in scan pass 2");
        }
        out[n] = block_off[nblocks];
        out
    }
}

const MIN_CHUNK: usize = 4096;

fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(MIN_CHUNK / 4, 1 << 16).max(1)
}

/// A shared mutable pointer for device-kernel-style *disjoint-index*
/// writes: many work units write non-overlapping slots of one output
/// array (the GPU programming model). The caller must guarantee
/// disjointness; helpers are `unsafe` to keep that contract visible.
pub struct SharedMut<T>(*mut T);
unsafe impl<T> Send for SharedMut<T> {}
unsafe impl<T> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(data: &mut [T]) -> Self {
        SharedMut(data.as_mut_ptr())
    }

    /// Write `val` to slot `i`.
    ///
    /// # Safety
    /// No two concurrent work units may write the same `i`, and `i` must
    /// be in bounds of the source slice.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }

    /// Exclusive sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// Ranges handed to concurrent work units must be pairwise disjoint
    /// and in bounds.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

type SendPtr<T> = SharedMut<T>;

/// An atomically-appended list, as used for the move lists `X` and `M` in
/// paper Alg. 4/5 ("inserted via an atomically incremented index").
pub struct AtomicList {
    data: Vec<AtomicU64>,
    len: AtomicUsize,
}

impl AtomicList {
    pub fn with_capacity(cap: usize) -> Self {
        let mut data = Vec::with_capacity(cap);
        data.resize_with(cap, || AtomicU64::new(0));
        AtomicList { data, len: AtomicUsize::new(0) }
    }

    /// Append `x`; returns its slot index.
    #[inline]
    pub fn push(&self, x: u64) -> usize {
        let i = self.len.fetch_add(1, Ordering::Relaxed);
        self.data[i].store(x, Ordering::Relaxed);
        i
    }

    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed).min(self.data.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the contents into a `Vec` (barrier between kernels).
    pub fn to_vec(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.data[i].load(Ordering::Relaxed)).collect()
    }

    pub fn reset(&self) {
        self.len.store(0, Ordering::Relaxed);
    }
}

/// Atomic `f64` add via CAS on the bit pattern (device-style atomic_add).
#[inline]
pub fn atomic_f64_add(cell: &AtomicU64, add: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match cell.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools() -> Vec<Pool> {
        vec![Pool::new(1), Pool::new(2), Pool::new(4)]
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        for pool in pools() {
            let n = 10_000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={}", pool.threads());
        }
    }

    #[test]
    fn reduce_matches_serial() {
        for pool in pools() {
            let n = 50_000;
            let total = pool.reduce_sum_u64(n, |i| i as u64);
            assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
        }
    }

    #[test]
    fn reduce_f64_close() {
        for pool in pools() {
            let n = 10_000;
            let total = pool.reduce_sum_f64(n, |i| (i as f64).sqrt());
            let serial: f64 = (0..n).map(|i| (i as f64).sqrt()).sum();
            assert!((total - serial).abs() < 1e-6 * serial.abs());
        }
    }

    #[test]
    fn scan_matches_serial() {
        for pool in pools() {
            let n = 30_000;
            let xs: Vec<u64> = (0..n).map(|i| (i % 7) as u64).collect();
            let scan = pool.scan_exclusive(n, |i| xs[i]);
            let mut acc = 0;
            for i in 0..n {
                assert_eq!(scan[i], acc, "i={} threads={}", i, pool.threads());
                acc += xs[i];
            }
            assert_eq!(scan[n], acc);
        }
    }

    #[test]
    fn scan_empty_and_tiny() {
        let pool = Pool::new(2);
        assert_eq!(pool.scan_exclusive(0, |_| 1), vec![0]);
        assert_eq!(pool.scan_exclusive(1, |_| 5), vec![0, 5]);
    }

    #[test]
    fn atomic_list_collects_everything() {
        for pool in pools() {
            let list = AtomicList::with_capacity(10_000);
            pool.parallel_for(10_000, |i| {
                if i % 3 == 0 {
                    list.push(i as u64);
                }
            });
            let mut v = list.to_vec();
            v.sort_unstable();
            let expect: Vec<u64> = (0..10_000).filter(|i| i % 3 == 0).map(|i| i as u64).collect();
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn atomic_f64_add_accumulates() {
        let pool = Pool::new(4);
        let cell = AtomicU64::new(0f64.to_bits());
        pool.parallel_for(10_000, |_| atomic_f64_add(&cell, 0.5));
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 5_000.0);
    }
}
