//! Bulk-synchronous data-parallel execution substrate (the "device").
//!
//! The paper implements its kernels with Kokkos' three primitives —
//! `parallel_for`, `parallel_reduce`, `parallel_scan` — on a CUDA GPU
//! (§3.3). This environment has no GPU, so the same primitives are
//! provided over a CPU worker pool. Algorithms upstack are written
//! *exactly* as the paper's kernels: flat loops over vertices or over the
//! extended-CSR edge list, atomic CAS insertion, atomically-appended move
//! lists, and prefix-sum based compaction.
//!
//! The pool is **persistent**: workers are spawned once when the [`Pool`]
//! is created and parked on a condvar between kernels, so a launch costs
//! one wake + one barrier instead of an OS `clone`/`join` pair. A mapping
//! run issues thousands of kernels, so steady-state launch overhead is the
//! CPU analogue of the paper's CUDA launch latency — see [`cost`]. The
//! execution semantics are unchanged: one logical work unit per index and
//! a full barrier between kernels (BSP).
//!
//! Every launch is recorded in a [`ledger`], from which the calibrated
//! GPU cost model ([`cost`]) estimates what the kernel sequence would cost
//! on the paper's RTX 4090 — see DESIGN.md §1 for the substitution
//! rationale. Wall-clock on this host and modeled device time are reported
//! side by side by the benchmark harness.

//!
//! A **checked-device mode** (`feature = "device-check"`, module `check`)
//! adds a shadow access log to [`SharedMut`], [`AtomicList`] and the
//! reduce/scan scratch buffers, and validates the BSP disjointness
//! contract at every kernel barrier — see `check` for the conflict rules.

#[cfg(feature = "device-check")]
pub mod check;
pub mod cost;
pub mod ledger;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Is the checked-device shadow log active in this build and run?
/// Always `false` without `feature = "device-check"`; with it, defaults to
/// `true` unless `HEIPA_DEVICE_CHECK=0` (see `check::enabled`).
pub fn device_check_active() -> bool {
    #[cfg(feature = "device-check")]
    {
        check::enabled()
    }
    #[cfg(not(feature = "device-check"))]
    {
        false
    }
}

/// A worker pool executing bulk-synchronous parallel primitives.
///
/// `threads == 1` executes inline (no workers are spawned); this is the
/// default on the single-core evaluation host. For `threads > 1`,
/// `threads - 1` long-lived workers are spawned once and woken per kernel;
/// the submitting thread acts as worker 0. The execution *semantics* (one
/// logical work unit per index, barriers between kernels) are identical
/// for any thread count, and the test suite runs key kernels at 1, 2 and 4
/// threads to check determinism-insensitivity.
///
/// `Pool` is cheap to clone (clones share the same workers) and the
/// workers are joined when the last clone is dropped. [`crate::engine::Engine`]
/// owns one pool for the process lifetime, so every solver run reuses the
/// same warm workers.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    workers: Option<Arc<WorkerSet>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Self::new(default_threads())
    }
}

/// Thread count from `HEIPA_THREADS`, else available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HEIPA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

thread_local! {
    /// Set for the lifetime of pool worker threads (and while a submitter
    /// executes its inline share of a kernel): nested launches from inside
    /// a kernel body run serially instead of deadlocking on the barrier.
    static IN_KERNEL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

#[inline]
fn in_kernel() -> bool {
    IN_KERNEL.with(|c| c.get())
}

/// Fault-plane hook for kernel launches (process-global plane only,
/// `kernel_launch` point). Deliberately never fires from inside a
/// kernel: a nested launch runs inline on a pool *worker* thread, where
/// a panic would unwind past the barrier and wedge the whole pool —
/// firing only on the submitting thread keeps the failure inside the
/// engine's per-job panic fence.
#[inline]
fn launch_fault_check() {
    use crate::fault::{self, FaultPoint};
    if !in_kernel() && fault::fire_global(FaultPoint::KernelLaunch) {
        panic!("{}", fault::failure(FaultPoint::KernelLaunch));
    }
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let workers =
            if threads > 1 { Some(Arc::new(WorkerSet::spawn(threads - 1))) } else { None };
        Pool { threads, workers }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The worker set, when this launch should fan out (`None` ⇒ run the
    /// kernel inline: single-threaded pool, tiny `n`, or a nested launch
    /// from inside another kernel).
    #[inline]
    fn dispatchable(&self, n: usize) -> Option<&WorkerSet> {
        if n < 2 * MIN_CHUNK || in_kernel() {
            return None;
        }
        self.workers.as_deref()
    }

    /// `parallel_for`: execute `f(i)` for all `i in 0..n`.
    ///
    /// One kernel launch; `n` work items are charged to the ledger.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        ledger::record_launch(n as u64);
        launch_fault_check();
        #[cfg(feature = "device-check")]
        let launch = check::begin_launch();
        let Some(ws) = self.dispatchable(n) else {
            {
                #[cfg(feature = "device-check")]
                let _chk = check::enter(launch);
                for i in 0..n {
                    #[cfg(feature = "device-check")]
                    check::set_unit(i as u64);
                    f(i);
                }
            }
            #[cfg(feature = "device-check")]
            check::end_launch(launch);
            return;
        };
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        ws.run(&|_w| {
            #[cfg(feature = "device-check")]
            let _chk = check::enter(launch);
            loop {
                // relaxed: chunk-claim ticket; each index is processed by
                // exactly one claimant and the pool barrier (mutex/condvar)
                // publishes all results to the submitter.
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    #[cfg(feature = "device-check")]
                    check::set_unit(i as u64);
                    f(i);
                }
            }
        });
        #[cfg(feature = "device-check")]
        check::end_launch(launch);
    }

    /// `parallel_reduce` with an associative combiner:
    /// `R = combine(f(0), f(1), …, f(n-1))` starting from `identity`.
    pub fn parallel_reduce<T, F, C>(&self, n: usize, identity: T, f: F, combine: C) -> T
    where
        T: Send + Clone,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync + Send,
    {
        ledger::record_launch(n as u64);
        launch_fault_check();
        #[cfg(feature = "device-check")]
        let launch = check::begin_launch();
        let Some(ws) = self.dispatchable(n) else {
            let mut acc = identity;
            {
                #[cfg(feature = "device-check")]
                let _chk = check::enter(launch);
                for i in 0..n {
                    #[cfg(feature = "device-check")]
                    check::set_unit(i as u64);
                    acc = combine(acc, f(i));
                }
            }
            #[cfg(feature = "device-check")]
            check::end_launch(launch);
            return acc;
        };
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        // Per-worker accumulators, seeded on the submitting thread so `T`
        // only needs `Send` (each worker exclusively owns its slot).
        let mut partials: Vec<Option<T>> =
            (0..self.threads).map(|_| Some(identity.clone())).collect();
        {
            let pp = SharedMut::new(&mut partials);
            let f = &f;
            let combine = &combine;
            ws.run(&move |w| {
                #[cfg(feature = "device-check")]
                let _chk = check::enter(launch);
                // The scratch slot is claimed under an *internal* unit id
                // (one per worker) so the checker validates the partials
                // buffer too: a duplicate worker id would be flagged.
                #[cfg(feature = "device-check")]
                check::set_unit(check::INTERNAL_UNIT_BASE + w as u64);
                // SAFETY: worker ids are distinct, so slots are disjoint.
                let slot = unsafe { pp.slice(w, 1) };
                let mut acc = slot[0].take().expect("partial seeded");
                loop {
                    // relaxed: chunk-claim ticket (see parallel_for).
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        #[cfg(feature = "device-check")]
                        check::set_unit(i as u64);
                        acc = combine(acc, f(i));
                    }
                }
                slot[0] = Some(acc);
            });
        }
        #[cfg(feature = "device-check")]
        check::end_launch(launch);
        partials.into_iter().flatten().fold(identity, |a, b| combine(a, b))
    }

    /// Convenience: `Σ f(i)` over `u64`.
    pub fn reduce_sum_u64<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> u64 + Sync,
    {
        self.parallel_reduce(n, 0u64, f, |a, b| a + b)
    }

    /// Convenience: `Σ f(i)` over `f64`.
    pub fn reduce_sum_f64<F>(&self, n: usize, f: F) -> f64
    where
        F: Fn(usize) -> f64 + Sync,
    {
        self.parallel_reduce(n, 0f64, f, |a, b| a + b)
    }

    /// `parallel_scan`: exclusive prefix sum of `f(i)`; returns a vector of
    /// length `n + 1` whose last element is the total (Kokkos semantics
    /// plus the total, which every call site in the paper needs anyway).
    pub fn scan_exclusive<F>(&self, n: usize, f: F) -> Vec<u64>
    where
        F: Fn(usize) -> u64 + Sync,
    {
        // Two-pass blocked scan: 2 launches, 2n work items.
        ledger::record_launch(n as u64);
        ledger::record_launch(n as u64);
        launch_fault_check();
        let mut out = vec![0u64; n + 1];
        let ws = match self.dispatchable(n) {
            Some(ws) => ws,
            None => {
                #[cfg(feature = "device-check")]
                let launch = check::begin_launch();
                {
                    #[cfg(feature = "device-check")]
                    let _chk = check::enter(launch);
                    let mut acc = 0u64;
                    for i in 0..n {
                        #[cfg(feature = "device-check")]
                        check::set_unit(i as u64);
                        out[i] = acc;
                        acc += f(i);
                    }
                    out[n] = acc;
                }
                #[cfg(feature = "device-check")]
                check::end_launch(launch);
                return out;
            }
        };
        let nblocks = self.threads * 4;
        let block = n.div_ceil(nblocks);
        let mut block_sums = vec![0u64; nblocks];
        // Pass 1: per-block sums (blocks claimed via an atomic counter).
        {
            #[cfg(feature = "device-check")]
            let launch = check::begin_launch();
            let bs = SharedMut::new(&mut block_sums);
            let next = AtomicUsize::new(0);
            let f = &f;
            ws.run(&move |_w| {
                #[cfg(feature = "device-check")]
                let _chk = check::enter(launch);
                loop {
                    // relaxed: block-claim ticket (see parallel_for).
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= nblocks {
                        break;
                    }
                    // The block id is the logical unit of a scan pass: the
                    // scratch write below must be unique per block.
                    #[cfg(feature = "device-check")]
                    check::set_unit(b as u64);
                    let start = b * block;
                    let end = ((b + 1) * block).min(n);
                    let mut acc = 0u64;
                    for i in start..end {
                        acc += f(i);
                    }
                    // SAFETY: one work unit per block index.
                    unsafe { bs.write(b, acc) };
                }
            });
            #[cfg(feature = "device-check")]
            check::end_launch(launch);
        }
        // Serial scan of the block sums.
        let mut block_off = vec![0u64; nblocks + 1];
        for b in 0..nblocks {
            block_off[b + 1] = block_off[b] + block_sums[b];
        }
        // Pass 2: per-block exclusive scan into the output.
        {
            #[cfg(feature = "device-check")]
            let launch = check::begin_launch();
            let op = SharedMut::new(&mut out);
            let next = AtomicUsize::new(0);
            let f = &f;
            let off = &block_off;
            ws.run(&move |_w| {
                #[cfg(feature = "device-check")]
                let _chk = check::enter(launch);
                loop {
                    // relaxed: block-claim ticket (see parallel_for).
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= nblocks {
                        break;
                    }
                    #[cfg(feature = "device-check")]
                    check::set_unit(b as u64);
                    let start = b * block;
                    let end = ((b + 1) * block).min(n);
                    let mut acc = off[b];
                    for i in start..end {
                        // SAFETY: disjoint index ranges per block.
                        unsafe { op.write(i, acc) };
                        acc += f(i);
                    }
                }
            });
            #[cfg(feature = "device-check")]
            check::end_launch(launch);
        }
        out[n] = block_off[nblocks];
        out
    }
}

const MIN_CHUNK: usize = 4096;

fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(MIN_CHUNK / 4, 1 << 16).max(1)
}

/// The long-lived workers behind a multi-threaded [`Pool`].
///
/// A kernel launch publishes a type-erased job under the state mutex,
/// bumps the epoch and wakes every worker; each worker runs the job
/// exactly once (the job body loops over an atomic work counter), then
/// decrements `active`. The submitter executes the job inline as worker 0
/// and blocks on `done_cv` until `active` returns to zero — that barrier
/// is what makes the lifetime erasure of the borrowed closure sound.
struct WorkerSet {
    shared: Arc<Shared>,
    spawned: usize,
    /// Serializes kernel launches from different host threads.
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct Shared {
    state: Mutex<JobState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

struct JobState {
    epoch: u64,
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Spawned workers still running the current epoch's job.
    active: usize,
    panicked: bool,
    shutdown: bool,
}

/// Lock ignoring poisoning: the pool's mutexes only guard launch
/// serialization and barrier counters maintained by straight-line code, so
/// a panic that unwound through [`WorkerSet::run`] leaves them in a valid
/// state — treating poison as fatal would permanently brick the
/// process-lifetime pool after one caught kernel panic.
fn lock_pool<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait_pool<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

impl WorkerSet {
    fn spawn(spawned: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..=spawned)
            .map(|id| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("heipa-worker-{id}"))
                    .spawn(move || worker_loop(sh, id))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerSet { shared, spawned, submit: Mutex::new(()), handles }
    }

    /// Execute `per_worker(w)` once for every worker id `w in 0..threads`
    /// (0 runs inline on the calling thread) and barrier until all are done.
    fn run(&self, per_worker: &(dyn Fn(usize) + Sync)) {
        let _serial = lock_pool(&self.submit);
        // SAFETY: the completion guard below blocks this frame until every
        // worker has finished running `per_worker`, so the erased lifetime
        // is never outlived.
        let job: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                per_worker,
            )
        };
        {
            let mut st = lock_pool(&self.shared.state);
            st.job = Some(job);
            st.active = self.spawned;
            st.panicked = false;
            st.epoch = st.epoch.wrapping_add(1);
        }
        self.shared.work_cv.notify_all();
        let guard = CompletionGuard { shared: &self.shared };
        // The submitter is worker 0; nested launches inside `per_worker`
        // fall back to inline execution via the thread-local flag.
        IN_KERNEL.with(|c| c.set(true));
        let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| per_worker(0)));
        IN_KERNEL.with(|c| c.set(false));
        drop(guard); // barrier: wait for the spawned workers
        let mut st = lock_pool(&self.shared.state);
        st.job = None;
        let worker_panicked = std::mem::replace(&mut st.panicked, false);
        drop(st);
        if let Err(payload) = inline {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker panicked in pool kernel");
        }
    }
}

/// Waits for all spawned workers to finish the current job — also on the
/// unwind path, so a panicking submitter cannot free state the workers
/// still reference.
struct CompletionGuard<'a> {
    shared: &'a Shared,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_pool(&self.shared.state);
        while st.active != 0 {
            st = wait_pool(&self.shared.done_cv, st);
        }
    }
}

impl Drop for WorkerSet {
    fn drop(&mut self) {
        {
            let mut st = lock_pool(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    IN_KERNEL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_pool(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = wait_pool(&shared.work_cv, st);
            }
            seen = st.epoch;
            st.job.expect("epoch bumped without a job")
        };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(id))).is_ok();
        let mut st = lock_pool(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            drop(st);
            shared.done_cv.notify_all();
        }
    }
}

/// A shared mutable pointer for device-kernel-style *disjoint-index*
/// writes: many work units write non-overlapping slots of one output
/// array (the GPU programming model). The caller must guarantee
/// disjointness; helpers are `unsafe` to keep that contract visible.
///
/// # The checked-mode contract
///
/// The contract every caller must uphold, per kernel launch:
///
/// 1. every index is in bounds of the source slice (debug builds assert
///    this unconditionally);
/// 2. no location is written by two distinct **logical work units**
///    (`parallel_for` indices, not threads) within one launch;
/// 3. no unit reads a location another unit wrote in the same launch —
///    data written by a previous kernel is safe, the barrier orders it.
///
/// Under `feature = "device-check"` (module `check`) every `read`,
/// `write` and `slice` is recorded in a shadow log tagged with the logical
/// unit, and the pool validates rules 2–3 at the kernel barrier,
/// reporting the kernel label and the two conflicting unit indices. The
/// check is interleaving-independent and works at any thread count,
/// including 1. Instances are per-launch temporaries; in debug builds,
/// `slice` additionally asserts that claimed ranges never overlap over
/// the instance's lifetime.
pub struct SharedMut<T> {
    ptr: *mut T,
    len: usize,
    /// Ranges handed out by `slice` (debug builds): overlap is a contract
    /// violation caught eagerly at claim time.
    #[cfg(debug_assertions)]
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: SharedMut is a plain pointer+length pair; it performs no access
// on its own, and every dereference goes through the `unsafe` methods
// below whose documented disjointness contract is exactly what makes
// cross-thread use sound. (Checked-device mode verifies that contract.)
unsafe impl<T> Send for SharedMut<T> {}
// SAFETY: as above — `&SharedMut` exposes only the `unsafe` accessors.
unsafe impl<T> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(data: &mut [T]) -> Self {
        SharedMut {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            #[cfg(debug_assertions)]
            claims: Mutex::new(Vec::new()),
        }
    }

    /// Write `val` to slot `i`.
    ///
    /// # Safety
    /// No two concurrent work units may write the same `i`, and `i` must
    /// be in bounds of the source slice.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        debug_assert!(i < self.len, "SharedMut::write out of bounds: index {i}, len {}", self.len);
        #[cfg(feature = "device-check")]
        check::record(self.ptr as usize, i, check::AccessKind::Write);
        *self.ptr.add(i) = val;
    }

    /// Read slot `i`.
    ///
    /// # Safety
    /// `i` must be in bounds and no other work unit may be writing slot
    /// `i` concurrently.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len, "SharedMut::read out of bounds: index {i}, len {}", self.len);
        #[cfg(feature = "device-check")]
        check::record(self.ptr as usize, i, check::AccessKind::Read);
        *self.ptr.add(i)
    }

    /// Exclusive sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// Ranges handed to concurrent work units must be pairwise disjoint
    /// and in bounds.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(
            start.checked_add(len).is_some_and(|end| end <= self.len),
            "SharedMut::slice out of bounds: [{start}, {start}+{len}), len {}",
            self.len
        );
        #[cfg(debug_assertions)]
        {
            let mut claims = self.claims.lock().unwrap_or_else(|e| e.into_inner());
            for &(s, l) in claims.iter() {
                assert!(
                    start >= s + l || s >= start + len,
                    "SharedMut::slice overlap: [{start}, {}) intersects prior claim [{s}, {})",
                    start + len,
                    s + l
                );
            }
            claims.push((start, len));
        }
        // The claim is conservatively logged as a write of the whole range
        // (slices are handed out for writing).
        #[cfg(feature = "device-check")]
        check::record_range(self.ptr as usize, start, len, check::AccessKind::Write);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// An atomically-appended list, as used for the move lists `X` and `M` in
/// paper Alg. 4/5 ("inserted via an atomically incremented index").
///
/// Appends beyond capacity are *saturating*: the element is dropped and
/// the [`AtomicList::overflowed`] flag is raised instead of indexing out
/// of bounds. Fallible callers (e.g. the delta conn-table update) check
/// the flag after the kernel barrier and fall back to an exact rebuild.
pub struct AtomicList {
    data: Vec<AtomicU64>,
    len: AtomicUsize,
    overflow: AtomicBool,
}

impl AtomicList {
    pub fn with_capacity(cap: usize) -> Self {
        let mut data = Vec::with_capacity(cap);
        data.resize_with(cap, || AtomicU64::new(0));
        AtomicList { data, len: AtomicUsize::new(0), overflow: AtomicBool::new(false) }
    }

    /// Append `x`; returns its claimed slot index. Past-capacity appends
    /// are dropped and raise [`AtomicList::overflowed`].
    #[inline]
    pub fn push(&self, x: u64) -> usize {
        // relaxed: `fetch_add` makes slot claims unique without any
        // cross-location ordering; readers consume slots only after the
        // kernel barrier, which is the publication point. The overflow
        // flag is likewise only read after the barrier.
        let i = self.len.fetch_add(1, Ordering::Relaxed);
        if let Some(slot) = self.data.get(i) {
            // Checked mode logs the append as an *atomic* write: appends
            // never conflict with each other, but a same-superstep
            // non-atomic read or write of the slot by another unit does.
            #[cfg(feature = "device-check")]
            check::record(self.data.as_ptr() as usize, i, check::AccessKind::AtomicWrite);
            slot.store(x, Ordering::Relaxed);
        } else {
            // relaxed: sticky flag, read host-side after the barrier.
            self.overflow.store(true, Ordering::Relaxed);
        }
        i
    }

    /// Number of retained elements (≤ capacity).
    pub fn len(&self) -> usize {
        // relaxed: meta-reads are either host-side (after the barrier) or
        // intentionally approximate mid-kernel.
        self.len.load(Ordering::Relaxed).min(self.data.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Did any append get dropped since the last [`AtomicList::reset`]?
    pub fn overflowed(&self) -> bool {
        // relaxed: read host-side after the kernel barrier.
        self.overflow.load(Ordering::Relaxed)
    }

    /// Element `i` (must be `< len()`; call between kernels only).
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        #[cfg(feature = "device-check")]
        check::record(self.data.as_ptr() as usize, i, check::AccessKind::Read);
        // relaxed: slots are published by the kernel barrier; a `get`
        // racing an in-superstep `push` is a contract violation that
        // checked mode flags as write/read.
        self.data[i].load(Ordering::Relaxed)
    }

    /// Snapshot the contents into a `Vec` (barrier between kernels).
    pub fn to_vec(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    pub fn reset(&self) {
        // relaxed: reset happens host-side between kernels.
        self.len.store(0, Ordering::Relaxed);
        self.overflow.store(false, Ordering::Relaxed);
    }
}

/// Atomic `f64` add via CAS on the bit pattern (device-style atomic_add).
#[inline]
pub fn atomic_f64_add(cell: &AtomicU64, add: f64) {
    // relaxed: the accumulated value is only read after the kernel
    // barrier; the CAS loop itself needs no ordering beyond atomicity of
    // each exchange (the retry re-reads the latest value).
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match cell.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools() -> Vec<Pool> {
        vec![Pool::new(1), Pool::new(2), Pool::new(4)]
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: multi-thread pools over dispatch-sized n are too slow under the interpreter
    fn parallel_for_covers_all_indices() {
        for pool in pools() {
            let n = 10_000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={}", pool.threads());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized reduction, too slow
    fn reduce_matches_serial() {
        for pool in pools() {
            let n = 50_000;
            let total = pool.reduce_sum_u64(n, |i| i as u64);
            assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized reduction, too slow
    fn reduce_f64_close() {
        for pool in pools() {
            let n = 10_000;
            let total = pool.reduce_sum_f64(n, |i| (i as f64).sqrt());
            let serial: f64 = (0..n).map(|i| (i as f64).sqrt()).sum();
            assert!((total - serial).abs() < 1e-6 * serial.abs());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized scan, too slow
    fn scan_matches_serial() {
        for pool in pools() {
            let n = 30_000;
            let xs: Vec<u64> = (0..n).map(|i| (i % 7) as u64).collect();
            let scan = pool.scan_exclusive(n, |i| xs[i]);
            let mut acc = 0;
            for i in 0..n {
                assert_eq!(scan[i], acc, "i={} threads={}", i, pool.threads());
                acc += xs[i];
            }
            assert_eq!(scan[n], acc);
        }
    }

    #[test]
    fn scan_empty_and_tiny() {
        let pool = Pool::new(2);
        assert_eq!(pool.scan_exclusive(0, |_| 1), vec![0]);
        assert_eq!(pool.scan_exclusive(1, |_| 5), vec![0, 5]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized fan-out, too slow
    fn atomic_list_collects_everything() {
        for pool in pools() {
            let list = AtomicList::with_capacity(10_000);
            pool.parallel_for(10_000, |i| {
                if i % 3 == 0 {
                    list.push(i as u64);
                }
            });
            assert!(!list.overflowed());
            let mut v = list.to_vec();
            v.sort_unstable();
            let expect: Vec<u64> = (0..10_000).filter(|i| i % 3 == 0).map(|i| i as u64).collect();
            assert_eq!(v, expect);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized fan-out, too slow
    fn atomic_list_saturates_instead_of_panicking() {
        // Regression: appends past capacity used to index out of bounds.
        for pool in pools() {
            let list = AtomicList::with_capacity(64);
            pool.parallel_for(10_000, |i| {
                list.push(i as u64);
            });
            assert_eq!(list.len(), 64);
            assert!(list.overflowed(), "threads={}", pool.threads());
            assert_eq!(list.to_vec().len(), 64);
            list.reset();
            assert!(!list.overflowed());
            list.push(7);
            assert_eq!(list.to_vec(), vec![7]);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized fan-out, too slow
    fn atomic_f64_add_accumulates() {
        let pool = Pool::new(4);
        let cell = AtomicU64::new(0f64.to_bits());
        pool.parallel_for(10_000, |_| atomic_f64_add(&cell, 0.5));
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 5_000.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 60 rounds of dispatch-sized kernels, far too slow
    fn persistent_pool_reuse_many_kernels() {
        // One pool, many sequential kernels of every primitive: the
        // workers park and wake without being respawned, and results stay
        // deterministic throughout.
        let pool = Pool::new(4);
        let n = 20_000;
        for round in 0..60u64 {
            let s = pool.reduce_sum_u64(n, |i| i as u64 + round);
            assert_eq!(s, (n as u64 - 1) * n as u64 / 2 + round * n as u64);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            let scan = pool.scan_exclusive(n, |_| 1);
            assert_eq!(scan[n], n as u64);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized nested launch, too slow
    fn nested_launch_runs_inline() {
        // A kernel body that launches another kernel must not deadlock on
        // the barrier; the inner launch degrades to inline execution.
        let pool = Pool::new(2);
        let pool2 = pool.clone();
        let total = pool.reduce_sum_u64(20_000, |i| {
            if i == 0 {
                // Nested launch from inside a kernel: degrades to serial.
                assert_eq!(pool2.reduce_sum_u64(20_000, |j| j as u64), 19_999 * 20_000 / 2);
            }
            1
        });
        assert_eq!(total, 20_000);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: unwinding across a 50k-unit dispatch, too slow
    fn worker_panic_propagates_without_deadlock() {
        // The panic may surface either as the wrapped "worker panicked in
        // pool kernel" (a spawned worker hit it) or as the original payload
        // (the submitting thread hit it inline); either way the launch must
        // unwind rather than deadlock, and the pool must stay usable.
        let pool = Pool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(50_000, |i| {
                if i == 49_999 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: dispatch-sized reductions on a shared worker set, too slow
    fn clones_share_workers() {
        let pool = Pool::new(3);
        let clone = pool.clone();
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
        assert_eq!(clone.reduce_sum_u64(30_000, |_| 1), 30_000);
        drop(clone);
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SharedMut::write out of bounds")]
    fn shared_mut_write_bounds_checked() {
        let mut data = vec![0u32; 8];
        let p = SharedMut::new(&mut data);
        // SAFETY: intentionally out of bounds to exercise the debug assert.
        unsafe { p.write(8, 1) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SharedMut::read out of bounds")]
    fn shared_mut_read_bounds_checked() {
        let mut data = vec![0u32; 8];
        let p = SharedMut::new(&mut data);
        // SAFETY: intentionally out of bounds to exercise the debug assert.
        let _ = unsafe { p.read(9) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SharedMut::slice out of bounds")]
    fn shared_mut_slice_bounds_checked() {
        let mut data = vec![0u32; 8];
        let p = SharedMut::new(&mut data);
        // SAFETY: intentionally out of bounds to exercise the debug assert.
        let _ = unsafe { p.slice(4, 5) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SharedMut::slice overlap")]
    fn shared_mut_overlapping_slices_detected() {
        let mut data = vec![0u32; 16];
        let p = SharedMut::new(&mut data);
        // SAFETY: in bounds; the second claim intentionally overlaps the
        // first to exercise the debug overlap check.
        unsafe {
            let _a = p.slice(0, 8);
            let _b = p.slice(7, 4);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn shared_mut_disjoint_slices_allowed() {
        let mut data = vec![0u32; 16];
        let p = SharedMut::new(&mut data);
        // SAFETY: the two claims are disjoint and in bounds.
        unsafe {
            p.slice(0, 8)[0] = 1;
            p.slice(8, 8)[7] = 2;
        }
        assert_eq!((data[0], data[15]), (1, 2));
    }

    #[test]
    fn device_check_active_matches_build() {
        // Without the feature this is constant `false`; with it, it follows
        // HEIPA_DEVICE_CHECK (default on). Either way it must not panic.
        let active = device_check_active();
        assert_eq!(active, cfg!(feature = "device-check") && active);
    }
}
