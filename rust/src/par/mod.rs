//! Bulk-synchronous data-parallel execution substrate (the "device").
//!
//! The paper implements its kernels with Kokkos' three primitives —
//! `parallel_for`, `parallel_reduce`, `parallel_scan` — on a CUDA GPU
//! (§3.3). This environment has no GPU, so the same primitives are
//! provided over a CPU worker pool. Algorithms upstack are written
//! *exactly* as the paper's kernels: flat loops over vertices or over the
//! extended-CSR edge list, atomic CAS insertion, atomically-appended move
//! lists, and prefix-sum based compaction.
//!
//! The pool is **persistent**: workers are spawned once when the [`Pool`]
//! is created and parked on a condvar between kernels, so a launch costs
//! one wake + one barrier instead of an OS `clone`/`join` pair. A mapping
//! run issues thousands of kernels, so steady-state launch overhead is the
//! CPU analogue of the paper's CUDA launch latency — see [`cost`]. The
//! execution semantics are unchanged: one logical work unit per index and
//! a full barrier between kernels (BSP).
//!
//! Every launch is recorded in a [`ledger`], from which the calibrated
//! GPU cost model ([`cost`]) estimates what the kernel sequence would cost
//! on the paper's RTX 4090 — see DESIGN.md §1 for the substitution
//! rationale. Wall-clock on this host and modeled device time are reported
//! side by side by the benchmark harness.

pub mod cost;
pub mod ledger;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A worker pool executing bulk-synchronous parallel primitives.
///
/// `threads == 1` executes inline (no workers are spawned); this is the
/// default on the single-core evaluation host. For `threads > 1`,
/// `threads - 1` long-lived workers are spawned once and woken per kernel;
/// the submitting thread acts as worker 0. The execution *semantics* (one
/// logical work unit per index, barriers between kernels) are identical
/// for any thread count, and the test suite runs key kernels at 1, 2 and 4
/// threads to check determinism-insensitivity.
///
/// `Pool` is cheap to clone (clones share the same workers) and the
/// workers are joined when the last clone is dropped. [`crate::engine::Engine`]
/// owns one pool for the process lifetime, so every solver run reuses the
/// same warm workers.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    workers: Option<Arc<WorkerSet>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Self::new(default_threads())
    }
}

/// Thread count from `HEIPA_THREADS`, else available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HEIPA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

thread_local! {
    /// Set for the lifetime of pool worker threads (and while a submitter
    /// executes its inline share of a kernel): nested launches from inside
    /// a kernel body run serially instead of deadlocking on the barrier.
    static IN_KERNEL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

#[inline]
fn in_kernel() -> bool {
    IN_KERNEL.with(|c| c.get())
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let workers =
            if threads > 1 { Some(Arc::new(WorkerSet::spawn(threads - 1))) } else { None };
        Pool { threads, workers }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The worker set, when this launch should fan out (`None` ⇒ run the
    /// kernel inline: single-threaded pool, tiny `n`, or a nested launch
    /// from inside another kernel).
    #[inline]
    fn dispatchable(&self, n: usize) -> Option<&WorkerSet> {
        if n < 2 * MIN_CHUNK || in_kernel() {
            return None;
        }
        self.workers.as_deref()
    }

    /// `parallel_for`: execute `f(i)` for all `i in 0..n`.
    ///
    /// One kernel launch; `n` work items are charged to the ledger.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        ledger::record_launch(n as u64);
        let Some(ws) = self.dispatchable(n) else {
            for i in 0..n {
                f(i);
            }
            return;
        };
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        ws.run(&|_w| loop {
            let start = next.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            for i in start..end {
                f(i);
            }
        });
    }

    /// `parallel_reduce` with an associative combiner:
    /// `R = combine(f(0), f(1), …, f(n-1))` starting from `identity`.
    pub fn parallel_reduce<T, F, C>(&self, n: usize, identity: T, f: F, combine: C) -> T
    where
        T: Send + Clone,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync + Send,
    {
        ledger::record_launch(n as u64);
        let Some(ws) = self.dispatchable(n) else {
            let mut acc = identity;
            for i in 0..n {
                acc = combine(acc, f(i));
            }
            return acc;
        };
        let next = AtomicUsize::new(0);
        let chunk = chunk_size(n, self.threads);
        // Per-worker accumulators, seeded on the submitting thread so `T`
        // only needs `Send` (each worker exclusively owns its slot).
        let mut partials: Vec<Option<T>> =
            (0..self.threads).map(|_| Some(identity.clone())).collect();
        {
            let pp = SharedMut::new(&mut partials);
            let f = &f;
            let combine = &combine;
            ws.run(&move |w| {
                // SAFETY: worker ids are distinct, so slots are disjoint.
                let slot = unsafe { pp.slice(w, 1) };
                let mut acc = slot[0].take().expect("partial seeded");
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        acc = combine(acc, f(i));
                    }
                }
                slot[0] = Some(acc);
            });
        }
        partials.into_iter().flatten().fold(identity, |a, b| combine(a, b))
    }

    /// Convenience: `Σ f(i)` over `u64`.
    pub fn reduce_sum_u64<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> u64 + Sync,
    {
        self.parallel_reduce(n, 0u64, f, |a, b| a + b)
    }

    /// Convenience: `Σ f(i)` over `f64`.
    pub fn reduce_sum_f64<F>(&self, n: usize, f: F) -> f64
    where
        F: Fn(usize) -> f64 + Sync,
    {
        self.parallel_reduce(n, 0f64, f, |a, b| a + b)
    }

    /// `parallel_scan`: exclusive prefix sum of `f(i)`; returns a vector of
    /// length `n + 1` whose last element is the total (Kokkos semantics
    /// plus the total, which every call site in the paper needs anyway).
    pub fn scan_exclusive<F>(&self, n: usize, f: F) -> Vec<u64>
    where
        F: Fn(usize) -> u64 + Sync,
    {
        // Two-pass blocked scan: 2 launches, 2n work items.
        ledger::record_launch(n as u64);
        ledger::record_launch(n as u64);
        let mut out = vec![0u64; n + 1];
        let ws = match self.dispatchable(n) {
            Some(ws) => ws,
            None => {
                let mut acc = 0u64;
                for i in 0..n {
                    out[i] = acc;
                    acc += f(i);
                }
                out[n] = acc;
                return out;
            }
        };
        let nblocks = self.threads * 4;
        let block = n.div_ceil(nblocks);
        let mut block_sums = vec![0u64; nblocks];
        // Pass 1: per-block sums (blocks claimed via an atomic counter).
        {
            let bs = SharedMut::new(&mut block_sums);
            let next = AtomicUsize::new(0);
            let f = &f;
            ws.run(&move |_w| loop {
                let b = next.fetch_add(1, Ordering::Relaxed);
                if b >= nblocks {
                    break;
                }
                let start = b * block;
                let end = ((b + 1) * block).min(n);
                let mut acc = 0u64;
                for i in start..end {
                    acc += f(i);
                }
                // SAFETY: one work unit per block index.
                unsafe { bs.write(b, acc) };
            });
        }
        // Serial scan of the block sums.
        let mut block_off = vec![0u64; nblocks + 1];
        for b in 0..nblocks {
            block_off[b + 1] = block_off[b] + block_sums[b];
        }
        // Pass 2: per-block exclusive scan into the output.
        {
            let op = SharedMut::new(&mut out);
            let next = AtomicUsize::new(0);
            let f = &f;
            let off = &block_off;
            ws.run(&move |_w| loop {
                let b = next.fetch_add(1, Ordering::Relaxed);
                if b >= nblocks {
                    break;
                }
                let start = b * block;
                let end = ((b + 1) * block).min(n);
                let mut acc = off[b];
                for i in start..end {
                    // SAFETY: disjoint index ranges per block.
                    unsafe { op.write(i, acc) };
                    acc += f(i);
                }
            });
        }
        out[n] = block_off[nblocks];
        out
    }
}

const MIN_CHUNK: usize = 4096;

fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(MIN_CHUNK / 4, 1 << 16).max(1)
}

/// The long-lived workers behind a multi-threaded [`Pool`].
///
/// A kernel launch publishes a type-erased job under the state mutex,
/// bumps the epoch and wakes every worker; each worker runs the job
/// exactly once (the job body loops over an atomic work counter), then
/// decrements `active`. The submitter executes the job inline as worker 0
/// and blocks on `done_cv` until `active` returns to zero — that barrier
/// is what makes the lifetime erasure of the borrowed closure sound.
struct WorkerSet {
    shared: Arc<Shared>,
    spawned: usize,
    /// Serializes kernel launches from different host threads.
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct Shared {
    state: Mutex<JobState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

struct JobState {
    epoch: u64,
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Spawned workers still running the current epoch's job.
    active: usize,
    panicked: bool,
    shutdown: bool,
}

/// Lock ignoring poisoning: the pool's mutexes only guard launch
/// serialization and barrier counters maintained by straight-line code, so
/// a panic that unwound through [`WorkerSet::run`] leaves them in a valid
/// state — treating poison as fatal would permanently brick the
/// process-lifetime pool after one caught kernel panic.
fn lock_pool<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait_pool<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

impl WorkerSet {
    fn spawn(spawned: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..=spawned)
            .map(|id| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("heipa-worker-{id}"))
                    .spawn(move || worker_loop(sh, id))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerSet { shared, spawned, submit: Mutex::new(()), handles }
    }

    /// Execute `per_worker(w)` once for every worker id `w in 0..threads`
    /// (0 runs inline on the calling thread) and barrier until all are done.
    fn run(&self, per_worker: &(dyn Fn(usize) + Sync)) {
        let _serial = lock_pool(&self.submit);
        // SAFETY: the completion guard below blocks this frame until every
        // worker has finished running `per_worker`, so the erased lifetime
        // is never outlived.
        let job: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                per_worker,
            )
        };
        {
            let mut st = lock_pool(&self.shared.state);
            st.job = Some(job);
            st.active = self.spawned;
            st.panicked = false;
            st.epoch = st.epoch.wrapping_add(1);
        }
        self.shared.work_cv.notify_all();
        let guard = CompletionGuard { shared: &self.shared };
        // The submitter is worker 0; nested launches inside `per_worker`
        // fall back to inline execution via the thread-local flag.
        IN_KERNEL.with(|c| c.set(true));
        let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| per_worker(0)));
        IN_KERNEL.with(|c| c.set(false));
        drop(guard); // barrier: wait for the spawned workers
        let mut st = lock_pool(&self.shared.state);
        st.job = None;
        let worker_panicked = std::mem::replace(&mut st.panicked, false);
        drop(st);
        if let Err(payload) = inline {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker panicked in pool kernel");
        }
    }
}

/// Waits for all spawned workers to finish the current job — also on the
/// unwind path, so a panicking submitter cannot free state the workers
/// still reference.
struct CompletionGuard<'a> {
    shared: &'a Shared,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_pool(&self.shared.state);
        while st.active != 0 {
            st = wait_pool(&self.shared.done_cv, st);
        }
    }
}

impl Drop for WorkerSet {
    fn drop(&mut self) {
        {
            let mut st = lock_pool(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    IN_KERNEL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_pool(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = wait_pool(&shared.work_cv, st);
            }
            seen = st.epoch;
            st.job.expect("epoch bumped without a job")
        };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(id))).is_ok();
        let mut st = lock_pool(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            drop(st);
            shared.done_cv.notify_all();
        }
    }
}

/// A shared mutable pointer for device-kernel-style *disjoint-index*
/// writes: many work units write non-overlapping slots of one output
/// array (the GPU programming model). The caller must guarantee
/// disjointness; helpers are `unsafe` to keep that contract visible.
pub struct SharedMut<T>(*mut T);
unsafe impl<T> Send for SharedMut<T> {}
unsafe impl<T> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(data: &mut [T]) -> Self {
        SharedMut(data.as_mut_ptr())
    }

    /// Write `val` to slot `i`.
    ///
    /// # Safety
    /// No two concurrent work units may write the same `i`, and `i` must
    /// be in bounds of the source slice.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        *self.0.add(i) = val;
    }

    /// Read slot `i`.
    ///
    /// # Safety
    /// `i` must be in bounds and no other work unit may be writing slot
    /// `i` concurrently.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        *self.0.add(i)
    }

    /// Exclusive sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// Ranges handed to concurrent work units must be pairwise disjoint
    /// and in bounds.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

/// An atomically-appended list, as used for the move lists `X` and `M` in
/// paper Alg. 4/5 ("inserted via an atomically incremented index").
///
/// Appends beyond capacity are *saturating*: the element is dropped and
/// the [`AtomicList::overflowed`] flag is raised instead of indexing out
/// of bounds. Fallible callers (e.g. the delta conn-table update) check
/// the flag after the kernel barrier and fall back to an exact rebuild.
pub struct AtomicList {
    data: Vec<AtomicU64>,
    len: AtomicUsize,
    overflow: AtomicBool,
}

impl AtomicList {
    pub fn with_capacity(cap: usize) -> Self {
        let mut data = Vec::with_capacity(cap);
        data.resize_with(cap, || AtomicU64::new(0));
        AtomicList { data, len: AtomicUsize::new(0), overflow: AtomicBool::new(false) }
    }

    /// Append `x`; returns its claimed slot index. Past-capacity appends
    /// are dropped and raise [`AtomicList::overflowed`].
    #[inline]
    pub fn push(&self, x: u64) -> usize {
        let i = self.len.fetch_add(1, Ordering::Relaxed);
        if let Some(slot) = self.data.get(i) {
            slot.store(x, Ordering::Relaxed);
        } else {
            self.overflow.store(true, Ordering::Relaxed);
        }
        i
    }

    /// Number of retained elements (≤ capacity).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed).min(self.data.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Did any append get dropped since the last [`AtomicList::reset`]?
    pub fn overflowed(&self) -> bool {
        self.overflow.load(Ordering::Relaxed)
    }

    /// Element `i` (must be `< len()`; call between kernels only).
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        self.data[i].load(Ordering::Relaxed)
    }

    /// Snapshot the contents into a `Vec` (barrier between kernels).
    pub fn to_vec(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.data[i].load(Ordering::Relaxed)).collect()
    }

    pub fn reset(&self) {
        self.len.store(0, Ordering::Relaxed);
        self.overflow.store(false, Ordering::Relaxed);
    }
}

/// Atomic `f64` add via CAS on the bit pattern (device-style atomic_add).
#[inline]
pub fn atomic_f64_add(cell: &AtomicU64, add: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match cell.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools() -> Vec<Pool> {
        vec![Pool::new(1), Pool::new(2), Pool::new(4)]
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        for pool in pools() {
            let n = 10_000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={}", pool.threads());
        }
    }

    #[test]
    fn reduce_matches_serial() {
        for pool in pools() {
            let n = 50_000;
            let total = pool.reduce_sum_u64(n, |i| i as u64);
            assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
        }
    }

    #[test]
    fn reduce_f64_close() {
        for pool in pools() {
            let n = 10_000;
            let total = pool.reduce_sum_f64(n, |i| (i as f64).sqrt());
            let serial: f64 = (0..n).map(|i| (i as f64).sqrt()).sum();
            assert!((total - serial).abs() < 1e-6 * serial.abs());
        }
    }

    #[test]
    fn scan_matches_serial() {
        for pool in pools() {
            let n = 30_000;
            let xs: Vec<u64> = (0..n).map(|i| (i % 7) as u64).collect();
            let scan = pool.scan_exclusive(n, |i| xs[i]);
            let mut acc = 0;
            for i in 0..n {
                assert_eq!(scan[i], acc, "i={} threads={}", i, pool.threads());
                acc += xs[i];
            }
            assert_eq!(scan[n], acc);
        }
    }

    #[test]
    fn scan_empty_and_tiny() {
        let pool = Pool::new(2);
        assert_eq!(pool.scan_exclusive(0, |_| 1), vec![0]);
        assert_eq!(pool.scan_exclusive(1, |_| 5), vec![0, 5]);
    }

    #[test]
    fn atomic_list_collects_everything() {
        for pool in pools() {
            let list = AtomicList::with_capacity(10_000);
            pool.parallel_for(10_000, |i| {
                if i % 3 == 0 {
                    list.push(i as u64);
                }
            });
            assert!(!list.overflowed());
            let mut v = list.to_vec();
            v.sort_unstable();
            let expect: Vec<u64> = (0..10_000).filter(|i| i % 3 == 0).map(|i| i as u64).collect();
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn atomic_list_saturates_instead_of_panicking() {
        // Regression: appends past capacity used to index out of bounds.
        for pool in pools() {
            let list = AtomicList::with_capacity(64);
            pool.parallel_for(10_000, |i| {
                list.push(i as u64);
            });
            assert_eq!(list.len(), 64);
            assert!(list.overflowed(), "threads={}", pool.threads());
            assert_eq!(list.to_vec().len(), 64);
            list.reset();
            assert!(!list.overflowed());
            list.push(7);
            assert_eq!(list.to_vec(), vec![7]);
        }
    }

    #[test]
    fn atomic_f64_add_accumulates() {
        let pool = Pool::new(4);
        let cell = AtomicU64::new(0f64.to_bits());
        pool.parallel_for(10_000, |_| atomic_f64_add(&cell, 0.5));
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 5_000.0);
    }

    #[test]
    fn persistent_pool_reuse_many_kernels() {
        // One pool, many sequential kernels of every primitive: the
        // workers park and wake without being respawned, and results stay
        // deterministic throughout.
        let pool = Pool::new(4);
        let n = 20_000;
        for round in 0..60u64 {
            let s = pool.reduce_sum_u64(n, |i| i as u64 + round);
            assert_eq!(s, (n as u64 - 1) * n as u64 / 2 + round * n as u64);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            let scan = pool.scan_exclusive(n, |_| 1);
            assert_eq!(scan[n], n as u64);
        }
    }

    #[test]
    fn nested_launch_runs_inline() {
        // A kernel body that launches another kernel must not deadlock on
        // the barrier; the inner launch degrades to inline execution.
        let pool = Pool::new(2);
        let pool2 = pool.clone();
        let total = pool.reduce_sum_u64(20_000, |i| {
            if i == 0 {
                // Nested launch from inside a kernel: degrades to serial.
                assert_eq!(pool2.reduce_sum_u64(20_000, |j| j as u64), 19_999 * 20_000 / 2);
            }
            1
        });
        assert_eq!(total, 20_000);
    }

    #[test]
    fn worker_panic_propagates_without_deadlock() {
        // The panic may surface either as the wrapped "worker panicked in
        // pool kernel" (a spawned worker hit it) or as the original payload
        // (the submitting thread hit it inline); either way the launch must
        // unwind rather than deadlock, and the pool must stay usable.
        let pool = Pool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(50_000, |i| {
                if i == 49_999 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
    }

    #[test]
    fn clones_share_workers() {
        let pool = Pool::new(3);
        let clone = pool.clone();
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
        assert_eq!(clone.reduce_sum_u64(30_000, |_| 1), 30_000);
        drop(clone);
        assert_eq!(pool.reduce_sum_u64(30_000, |_| 1), 30_000);
    }
}
