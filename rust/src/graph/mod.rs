//! Graphs: CSR storage, the extended CSR edge list, generators, IO,
//! GPU-style subgraph extraction (paper Alg. 1), and validation.

pub mod builder;
pub mod gen;
pub mod io;
pub mod subgraph;

use crate::{EWeight, VWeight, Vertex};

/// An undirected graph in Compressed Sparse Row format (paper §3.4).
///
/// Every undirected edge `{u, v}` is stored twice (once per direction), so
/// `adj.len() == 2 m`. Adjacency lists are sorted by target vertex.
#[derive(Clone, Debug, Default)]
pub struct CsrGraph {
    /// Offset array `O` of size `n + 1`.
    pub xadj: Vec<u32>,
    /// Edge targets `E_v`, size `2m`.
    pub adj: Vec<Vertex>,
    /// Edge weights `E_w`, size `2m`.
    pub ew: Vec<EWeight>,
    /// Vertex weights `c(v)`, size `n`.
    pub vw: Vec<VWeight>,
}

impl CsrGraph {
    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.vw.len()
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.adj.len() / 2
    }

    /// Number of directed edge slots (`2m`).
    #[inline]
    pub fn num_directed(&self) -> usize {
        self.adj.len()
    }

    #[inline]
    pub fn degree(&self, v: Vertex) -> usize {
        (self.xadj[v as usize + 1] - self.xadj[v as usize]) as usize
    }

    /// Neighbor targets of `v`.
    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        &self.adj[self.xadj[v as usize] as usize..self.xadj[v as usize + 1] as usize]
    }

    /// Neighbor targets and edge weights of `v`.
    #[inline]
    pub fn neighbors_w(&self, v: Vertex) -> (&[Vertex], &[EWeight]) {
        let r = self.xadj[v as usize] as usize..self.xadj[v as usize + 1] as usize;
        (&self.adj[r.clone()], &self.ew[r])
    }

    /// Total vertex weight `c(V)`.
    pub fn total_vweight(&self) -> VWeight {
        self.vw.iter().sum()
    }

    /// Total edge weight `ω(E)` (undirected; each edge counted once).
    pub fn total_eweight(&self) -> EWeight {
        self.ew.iter().sum::<EWeight>() / 2.0
    }

    /// Build the extended-CSR source array `E_u` (paper §4, "Extended CSR
    /// Format"): `eu[i]` is the *source* endpoint of directed edge slot
    /// `i`, enabling flat edge-parallel kernels without nested loops.
    pub fn edge_sources(&self) -> Vec<Vertex> {
        let mut eu = vec![0 as Vertex; self.adj.len()];
        for v in 0..self.n() {
            for i in self.xadj[v] as usize..self.xadj[v + 1] as usize {
                eu[i] = v as Vertex;
            }
        }
        eu
    }

    /// Maximum vertex degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v as Vertex)).max().unwrap_or(0)
    }

    /// Structural invariants: monotone offsets, in-range targets, no self
    /// loops, sorted adjacency, symmetric with matching weights.
    /// Used by tests and by `debug_assert!`s after coarsening/subgraphs.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n();
        if self.xadj.len() != n + 1 {
            return Err(format!("xadj len {} != n+1 {}", self.xadj.len(), n + 1));
        }
        if *self.xadj.last().unwrap() as usize != self.adj.len() {
            return Err("xadj[n] != adj.len()".into());
        }
        if self.ew.len() != self.adj.len() {
            return Err("ew.len() != adj.len()".into());
        }
        for v in 0..n {
            if self.xadj[v] > self.xadj[v + 1] {
                return Err(format!("xadj not monotone at {v}"));
            }
            let nbrs = self.neighbors(v as Vertex);
            for (i, &u) in nbrs.iter().enumerate() {
                if u as usize >= n {
                    return Err(format!("edge target {u} out of range at vertex {v}"));
                }
                if u as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if i > 0 && nbrs[i - 1] >= u {
                    return Err(format!("adjacency of {v} not strictly sorted"));
                }
            }
        }
        // Symmetry via binary search on the (sorted) reverse adjacency.
        for v in 0..n {
            let (nbrs, ws) = self.neighbors_w(v as Vertex);
            for (&u, &w) in nbrs.iter().zip(ws) {
                match self.find_edge(u, v as Vertex) {
                    Some(wrev) if (wrev - w).abs() <= 1e-9 * w.abs().max(1.0) => {}
                    Some(wrev) => {
                        return Err(format!("asymmetric weight {v}-{u}: {w} vs {wrev}"));
                    }
                    None => return Err(format!("missing reverse edge {u}->{v}")),
                }
            }
        }
        Ok(())
    }

    /// Weight of edge `{u, v}` if present (binary search, adjacency sorted).
    pub fn find_edge(&self, u: Vertex, v: Vertex) -> Option<EWeight> {
        let nbrs = self.neighbors(u);
        nbrs.binary_search(&v).ok().map(|i| self.ew[self.xadj[u as usize] as usize + i])
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} m={} maxdeg={} c(V)={} w(E)={:.0}",
            self.n(),
            self.m(),
            self.max_degree(),
            self.total_vweight(),
            self.total_eweight()
        )
    }
}

/// Flat edge-list view (the paper's `𝔼`): directed edge `i` is
/// `(eu[i], adj[i], ew[i])`. Constructed once per graph and reused by all
/// edge-parallel kernels.
pub struct EdgeList {
    /// Source endpoint per directed edge slot.
    pub eu: Vec<Vertex>,
}

impl EdgeList {
    pub fn build(g: &CsrGraph) -> Self {
        EdgeList { eu: g.edge_sources() }
    }

    /// Device-kernel flavor: vertex-parallel fill of the source array
    /// (each vertex owns its disjoint CSR range).
    pub fn build_par(pool: &crate::par::Pool, g: &CsrGraph) -> Self {
        let mut eu = vec![0 as Vertex; g.adj.len()];
        let ptr = crate::par::SharedMut::new(&mut eu);
        let _k = crate::par::ledger::kernel("graph:edge_sources");
        pool.parallel_for(g.n(), |v| {
            for i in g.xadj[v] as usize..g.xadj[v + 1] as usize {
                // SAFETY: CSR ranges are disjoint per vertex.
                unsafe { ptr.write(i, v as Vertex) };
            }
        });
        EdgeList { eu }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.eu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.eu.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::builder::GraphBuilder;
    use super::*;

    fn triangle() -> CsrGraph {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 2.0);
        b.add_edge(0, 2, 3.0);
        b.build()
    }

    #[test]
    fn triangle_structure() {
        let g = triangle();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn find_edge_weights() {
        let g = triangle();
        assert_eq!(g.find_edge(0, 1), Some(1.0));
        assert_eq!(g.find_edge(2, 1), Some(2.0));
        assert_eq!(g.find_edge(0, 2), Some(3.0));
        assert_eq!(g.find_edge(1, 1), None);
    }

    #[test]
    fn edge_sources_align_with_csr() {
        let g = triangle();
        let el = EdgeList::build(&g);
        assert_eq!(el.len(), 6);
        for v in 0..g.n() as Vertex {
            for i in g.xadj[v as usize] as usize..g.xadj[v as usize + 1] as usize {
                assert_eq!(el.eu[i], v);
            }
        }
    }

    #[test]
    fn totals() {
        let g = triangle();
        assert_eq!(g.total_vweight(), 3);
        assert!((g.total_eweight() - 6.0).abs() < 1e-12);
    }
}
