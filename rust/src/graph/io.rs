//! METIS `.graph` file format IO (the format of the paper's benchmark
//! instances: SuiteSparse / Walshaw / DIMACS archives ship as METIS files).
//!
//! Header: `n m [fmt [ncon]]` where `fmt` is a 3-digit flag string
//! (`1xx` vertex sizes — unsupported, `x1x` vertex weights, `xx1` edge
//! weights). 1-indexed adjacency; each undirected edge appears in both
//! endpoint lines.

use super::{builder::GraphBuilder, CsrGraph};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a METIS `.graph` file.
pub fn read_metis(path: &Path) -> Result<CsrGraph> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(file);
    parse_metis(reader)
}

/// Parse METIS format from any reader (testable without files).
pub fn parse_metis<R: BufRead>(reader: R) -> Result<CsrGraph> {
    // Fault plane: `graph_load` (global plane; fails the parse cleanly).
    if crate::fault::fire_global(crate::fault::FaultPoint::GraphLoad) {
        bail!("{}", crate::fault::failure(crate::fault::FaultPoint::GraphLoad));
    }
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if !t.is_empty() && !t.starts_with('%') {
                    break t.to_string();
                }
            }
            None => bail!("empty METIS file"),
        }
    };
    let head: Vec<&str> = header.split_whitespace().collect();
    if head.len() < 2 {
        bail!("METIS header needs at least n and m");
    }
    let n: usize = head[0].parse().context("n")?;
    let m: usize = head[1].parse().context("m")?;
    let fmt = if head.len() > 2 { head[2] } else { "0" };
    let fmt_num: u32 = fmt.parse().unwrap_or(0);
    let has_vsize = fmt_num / 100 % 10 == 1;
    let has_vw = fmt_num / 10 % 10 == 1;
    let has_ew = fmt_num % 10 == 1;
    if has_vsize {
        bail!("vertex sizes (fmt 1xx) not supported");
    }
    let ncon: usize = if head.len() > 3 { head[3].parse().context("ncon")? } else { 1 };
    if ncon > 1 {
        bail!("multi-constraint graphs not supported");
    }

    let mut b = GraphBuilder::with_edge_capacity(n, m);
    let mut v: usize = 0;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.starts_with('%') {
            continue;
        }
        if v >= n {
            if t.is_empty() {
                continue;
            }
            bail!("more vertex lines than n={n}");
        }
        let mut tok = t.split_whitespace();
        if has_vw {
            let w: i64 = tok.next().context("missing vertex weight")?.parse()?;
            b.set_vweight(v as u32, w);
        }
        loop {
            let Some(u) = tok.next() else { break };
            let u: usize = u.parse().with_context(|| format!("vertex line {v}"))?;
            if u == 0 || u > n {
                bail!("neighbor {u} out of range 1..={n}");
            }
            let w: f64 = if has_ew { tok.next().context("missing edge weight")?.parse()? } else { 1.0 };
            // Each edge appears twice; add once.
            if u - 1 > v {
                b.add_edge(v as u32, (u - 1) as u32, w);
            }
        }
        v += 1;
    }
    if v != n {
        bail!("expected {n} vertex lines, found {v}");
    }
    let g = b.build();
    if g.m() != m {
        // Not fatal: some archives count self loops; warn via error context.
        // We accept the parsed structure.
    }
    Ok(g)
}

/// Write a METIS `.graph` file (always with vertex and edge weights: fmt 011).
pub fn write_metis(g: &CsrGraph, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "{} {} 011", g.n(), g.m())?;
    for v in 0..g.n() {
        write!(w, "{}", g.vw[v])?;
        let (nbrs, ws) = g.neighbors_w(v as u32);
        for (&u, &ew) in nbrs.iter().zip(ws) {
            write!(w, " {} {}", u + 1, ew as i64)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write a partition file: one block id per line (METIS convention).
pub fn write_partition(part: &[u32], path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for &b in part {
        writeln!(w, "{b}")?;
    }
    Ok(())
}

/// Read a partition file.
pub fn read_partition(path: &Path) -> Result<Vec<u32>> {
    let content = std::fs::read_to_string(path)?;
    content
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<u32>().map_err(Into::into))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_unweighted() {
        let txt = "% comment\n3 2\n2 3\n1\n1\n";
        let g = parse_metis(Cursor::new(txt)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(0), &[1, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn parse_weighted() {
        let txt = "2 1 011\n5 2 3\n7 1 3\n";
        let g = parse_metis(Cursor::new(txt)).unwrap();
        assert_eq!(g.vw, vec![5, 7]);
        assert_eq!(g.find_edge(0, 1), Some(3.0));
    }

    #[test]
    fn parse_rejects_bad_neighbor() {
        let txt = "2 1\n3\n1\n";
        assert!(parse_metis(Cursor::new(txt)).is_err());
    }

    #[test]
    fn roundtrip_via_tmpfile() {
        let g = crate::graph::gen::grid2d(4, 3, false);
        let dir = std::env::temp_dir().join("heipa_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.graph");
        write_metis(&g, &p).unwrap();
        let g2 = read_metis(&p).unwrap();
        assert_eq!(g2.n(), g.n());
        assert_eq!(g2.m(), g.m());
        g2.validate().unwrap();
    }

    #[test]
    fn partition_roundtrip() {
        let dir = std::env::temp_dir().join("heipa_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("p.part");
        write_partition(&[0, 1, 2, 1], &p).unwrap();
        assert_eq!(read_partition(&p).unwrap(), vec![0, 1, 2, 1]);
    }
}
