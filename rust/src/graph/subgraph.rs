//! Subgraph extraction on the device — paper Algorithm 1.
//!
//! Given a partition Π, build for each block the induced subgraph entirely
//! with data-parallel primitives (three `parallel_reduce`s, one
//! `parallel_scan` for the remap `M : [n] → [n']`, a degree pass + scan
//! for the new offsets, then an edge-insertion pass). This mirrors the
//! paper's GPU implementation; a serial all-blocks-at-once variant is
//! provided for the CPU baselines and as a differential-testing oracle.

use super::CsrGraph;
use crate::par::{ledger, Pool};
use crate::{Block, Vertex};
use std::sync::atomic::{AtomicU32, Ordering};

/// A subgraph plus the vertex correspondence to its parent.
pub struct Subgraph {
    pub graph: CsrGraph,
    /// `local_to_parent[v'] = v`: parent vertex of each subgraph vertex.
    pub local_to_parent: Vec<Vertex>,
}

/// Paper Algorithm 1: build the induced subgraph of block `k'` using
/// bulk-parallel kernels.
pub fn build_subgraph(pool: &Pool, g: &CsrGraph, part: &[Block], block: Block) -> Subgraph {
    let n = g.n();
    debug_assert_eq!(part.len(), n);

    // Phase 1: n', m' (directed), w' via parallel_reduce.
    let _k = ledger::kernel("graph/subgraph:count");
    let n_sub = pool.reduce_sum_u64(n, |v| (part[v] == block) as u64) as usize;
    drop(_k);
    // (w' is not needed by the construction itself; the caller computes it.)

    // Phase 2: remap M via parallel_scan over the indicator.
    let _k = ledger::kernel("graph/subgraph:remap_scan");
    let map = pool.scan_exclusive(n, |v| (part[v] == block) as u64);
    drop(_k);

    // Phase 3a: new degrees, then offsets by prefix sum.
    let deg = {
        let deg: Vec<AtomicU32> = (0..n_sub).map(|_| AtomicU32::new(0)).collect();
        let _k = ledger::kernel("graph/subgraph:degrees");
        pool.parallel_for(n, |v| {
            if part[v] == block {
                let mut d = 0u32;
                for &u in g.neighbors(v as Vertex) {
                    d += (part[u as usize] == block) as u32;
                }
                // relaxed: `map[v]` is unique per selected `v` (exclusive
                // scan of the indicator), so each slot has one writer and
                // is read only after the barrier.
                deg[map[v] as usize].store(d, Ordering::Relaxed);
            }
        });
        deg
    };
    let _k = ledger::kernel("graph/subgraph:offsets_scan");
    // relaxed: degrees are frozen after the barrier above.
    let xadj_scan = pool.scan_exclusive(n_sub, |v| deg[v].load(Ordering::Relaxed) as u64);
    drop(_k);
    let m_sub_dir = xadj_scan[n_sub] as usize;

    // Phase 3b: insert edges. Each vertex owns a disjoint output range, so
    // plain (unsynchronized) writes through a shared pointer are safe.
    let mut adj = vec![0 as Vertex; m_sub_dir];
    let mut ew = vec![0.0f64; m_sub_dir];
    let mut local_to_parent = vec![0 as Vertex; n_sub];
    {
        let adj_ptr = crate::par::SharedMut::new(&mut adj);
        let ew_ptr = crate::par::SharedMut::new(&mut ew);
        let l2p_ptr = crate::par::SharedMut::new(&mut local_to_parent);
        let _k = ledger::kernel("graph/subgraph:insert_edges");
        pool.parallel_for(n, |v| {
            if part[v] != block {
                return;
            }
            let lv = map[v] as usize;
            // SAFETY: lv is unique per v; ranges are disjoint.
            unsafe { l2p_ptr.write(lv, v as Vertex) };
            let mut i = xadj_scan[lv] as usize;
            let (nbrs, ws) = g.neighbors_w(v as Vertex);
            for (&u, &w) in nbrs.iter().zip(ws) {
                if part[u as usize] == block {
                    // SAFETY: unit `v` writes only inside its own output
                    // range [xadj_scan[lv], xadj_scan[lv+1]) — disjoint by
                    // construction of the offsets prefix sum.
                    unsafe {
                        adj_ptr.write(i, map[u as usize] as Vertex);
                        ew_ptr.write(i, w);
                    }
                    i += 1;
                }
            }
        });
    }

    let mut xadj = vec![0u32; n_sub + 1];
    for v in 0..=n_sub {
        xadj[v] = xadj_scan[v] as u32;
    }
    let mut vw = vec![0i64; n_sub];
    for v in 0..n_sub {
        vw[v] = g.vw[local_to_parent[v] as usize];
    }
    let graph = CsrGraph { xadj, adj, ew, vw };
    debug_assert!(graph.validate().is_ok());
    Subgraph { graph, local_to_parent }
}

/// Build all `k` induced subgraphs. The paper loops Algorithm 1 over the
/// blocks; we expose exactly that.
pub fn build_all_subgraphs(pool: &Pool, g: &CsrGraph, part: &[Block], k: usize) -> Vec<Subgraph> {
    (0..k as Block).map(|b| build_subgraph(pool, g, part, b)).collect()
}

/// Serial single-pass oracle: extract every block's subgraph in one sweep.
/// Used by the CPU baselines and by differential tests against the
/// parallel Algorithm 1.
pub fn build_all_subgraphs_serial(g: &CsrGraph, part: &[Block], k: usize) -> Vec<Subgraph> {
    let n = g.n();
    let mut counts = vec![0u32; k];
    let mut local = vec![0u32; n];
    for v in 0..n {
        let b = part[v] as usize;
        local[v] = counts[b];
        counts[b] += 1;
    }
    let mut out: Vec<Subgraph> = (0..k)
        .map(|b| Subgraph {
            graph: CsrGraph::default(),
            local_to_parent: vec![0; counts[b] as usize],
        })
        .collect();
    // Degrees.
    let mut degs: Vec<Vec<u32>> = (0..k).map(|b| vec![0u32; counts[b] as usize]).collect();
    for v in 0..n {
        let b = part[v] as usize;
        out[b].local_to_parent[local[v] as usize] = v as Vertex;
        let mut d = 0;
        for &u in g.neighbors(v as Vertex) {
            d += (part[u as usize] == part[v]) as u32;
        }
        degs[b][local[v] as usize] = d;
    }
    for b in 0..k {
        let nb = counts[b] as usize;
        let mut xadj = vec![0u32; nb + 1];
        for v in 0..nb {
            xadj[v + 1] = xadj[v] + degs[b][v];
        }
        let md = xadj[nb] as usize;
        out[b].graph = CsrGraph {
            xadj,
            adj: vec![0; md],
            ew: vec![0.0; md],
            vw: out[b].local_to_parent.iter().map(|&v| g.vw[v as usize]).collect(),
        };
    }
    let mut pos: Vec<Vec<u32>> = (0..k).map(|b| out[b].graph.xadj[..counts[b] as usize].to_vec()).collect();
    for v in 0..n {
        let b = part[v] as usize;
        let lv = local[v] as usize;
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        for (&u, &w) in nbrs.iter().zip(ws) {
            if part[u as usize] == part[v] {
                let p = pos[b][lv] as usize;
                out[b].graph.adj[p] = local[u as usize];
                out[b].graph.ew[p] = w;
                pos[b][lv] += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::rng::Rng;

    fn random_partition(n: usize, k: usize, seed: u64) -> Vec<Block> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(k as u64) as Block).collect()
    }

    #[test]
    fn subgraph_of_grid_is_valid() {
        let pool = Pool::new(1);
        let g = gen::grid2d(10, 10, false);
        let part = random_partition(g.n(), 4, 1);
        for b in 0..4 {
            let sub = build_subgraph(&pool, &g, &part, b);
            sub.graph.validate().unwrap();
            // Every subgraph vertex maps back to a vertex of block b.
            for &pv in &sub.local_to_parent {
                assert_eq!(part[pv as usize], b);
            }
        }
    }

    #[test]
    fn vertex_and_weight_conservation() {
        let pool = Pool::new(2);
        let g = gen::rgg(1_000, 0.08, 7);
        let part = random_partition(g.n(), 3, 2);
        let subs = build_all_subgraphs(&pool, &g, &part, 3);
        let total_n: usize = subs.iter().map(|s| s.graph.n()).sum();
        assert_eq!(total_n, g.n());
        let total_w: i64 = subs.iter().map(|s| s.graph.total_vweight()).sum();
        assert_eq!(total_w, g.total_vweight());
    }

    #[test]
    fn edges_match_induced_definition() {
        let pool = Pool::new(1);
        let g = gen::grid2d(8, 8, true);
        let part = random_partition(g.n(), 2, 3);
        let sub = build_subgraph(&pool, &g, &part, 0);
        // Each subgraph edge corresponds to a parent edge within block 0.
        for lv in 0..sub.graph.n() {
            let pv = sub.local_to_parent[lv];
            let (nbrs, ws) = sub.graph.neighbors_w(lv as Vertex);
            for (&lu, &w) in nbrs.iter().zip(ws) {
                let pu = sub.local_to_parent[lu as usize];
                assert_eq!(g.find_edge(pv, pu), Some(w));
            }
        }
        // Counting: directed internal edges of block 0 == subgraph directed.
        let mut internal = 0usize;
        for v in 0..g.n() {
            if part[v] != 0 {
                continue;
            }
            for &u in g.neighbors(v as Vertex) {
                internal += (part[u as usize] == 0) as usize;
            }
        }
        assert_eq!(internal, sub.graph.num_directed());
    }

    #[test]
    fn parallel_matches_serial_oracle() {
        let pool = Pool::new(4);
        let g = gen::rgg(2_000, 0.06, 11);
        let part = random_partition(g.n(), 5, 4);
        let par = build_all_subgraphs(&pool, &g, &part, 5);
        let ser = build_all_subgraphs_serial(&g, &part, 5);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.local_to_parent, b.local_to_parent);
            assert_eq!(a.graph.xadj, b.graph.xadj);
            assert_eq!(a.graph.adj, b.graph.adj);
            assert_eq!(a.graph.ew, b.graph.ew);
            assert_eq!(a.graph.vw, b.graph.vw);
        }
    }

    #[test]
    fn empty_block_yields_empty_subgraph() {
        let pool = Pool::new(1);
        let g = gen::grid2d(4, 4, false);
        let part = vec![0 as Block; g.n()];
        let sub = build_subgraph(&pool, &g, &part, 1);
        assert_eq!(sub.graph.n(), 0);
        assert_eq!(sub.graph.m(), 0);
    }
}
