//! The benchmark-instance registry — Table 1 of the paper, scaled.
//!
//! Three groups mirror the paper's table: *SuiteSparse* (FEM/circuit
//! matrices → weighted stencils & meshes), *Other* (DIMACS meshes, road
//! networks, rgg/del random instances) and *Walshaw* (FEM meshes). Sizes
//! are scaled ≈64× down (this host has one core; the paper used 16 384);
//! the scaling factor is uniform so relative instance difficulty is kept.

use super::*;

/// Size class, used by Table 2 ("small" < 1 M vertices in the paper;
/// scaled threshold here is 64 k).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeClass {
    Small,
    Large,
}

/// A named generator invocation.
#[derive(Clone, Debug)]
pub struct InstanceSpec {
    pub name: &'static str,
    /// Paper group: "suitesparse", "other", "walshaw".
    pub group: &'static str,
    /// Which paper instance this stands in for.
    pub stand_in_for: &'static str,
}

impl InstanceSpec {
    pub fn generate(&self) -> CsrGraph {
        generate_by_name(self.name)
    }

    pub fn size_class(&self) -> SizeClass {
        // Classify by vertex count threshold 60k (paper: 1M, scaled).
        match self.name {
            "rgg16" | "rgg17" | "del16" | "del17" | "road_deu" | "road_eu" | "grid3d_large"
            | "wal_auto" => SizeClass::Large,
            _ => SizeClass::Small,
        }
    }
}

/// Generate an instance by registry name.
pub fn generate_by_name(name: &str) -> CsrGraph {
    match name {
        // --- SuiteSparse stand-ins (weighted matrix graphs, ~1.5–4k wide stencils) ---
        "sten_cop20k" => stencil9(125, 125, 101),     // cop20k_A
        "sten_cubes" => stencil9(126, 126, 102),      // 2cubes_sphere
        "sten_thermo" => grid2d(160, 100, false),     // thermomech_TC (sparse)
        "sten_cfd2" => stencil9(139, 139, 104),       // cfd2
        "sten_bone" => stencil9(141, 141, 105),       // boneS01 (dense rows)
        "sten_dubcova" => stencil9(151, 151, 106),    // Dubcova3
        "sten_bmwcra" => stencil9(152, 152, 107),     // bmwcra_1
        "sten_g2circ" => road_like(153, 153, 108),    // G2_circuit (very sparse)
        "sten_shipsec" => stencil9(167, 167, 109),    // shipsec5
        "sten_cont300" => grid2d(168, 168, false),    // cont-300
        // --- Walshaw stand-ins (FEM meshes) ---
        "wal_598a" => mesh_with_holes(145, 145, 4, 201), // 598a
        "wal_feocean" => mesh_with_holes(165, 165, 8, 202), // fe_ocean
        "wal_144" => grid3d(33, 33, 22),              // 144
        "wal_wave" => grid3d(35, 35, 20),             // wave
        "wal_m14b" => grid3d(38, 38, 26),             // m14b
        "wal_auto" => grid3d(48, 48, 30),             // auto
        // --- Other: DIMACS / road / synthetic ---
        "afshell_s" => stencil9(177, 178, 301),       // afshell9
        "thermal2_s" => delaunay_like(139, 302),      // thermal2
        "nlr_s" => delaunay_like(160, 303),           // nlr
        "road_deu" => road_like(300, 280, 304),       // deu
        "road_eu" => road_like(540, 520, 305),        // europe_osm
        "del15" => delaunay_like(181, 306),           // del23 (scaled)
        "del16" => delaunay_like(256, 307),           // del24 (scaled)
        "del17" => delaunay_like(362, 308),           // (extra density point)
        "rgg15" => rgg(1 << 15, rgg_paper_radius(1 << 15), 309), // rgg23 (scaled)
        "rgg16" => rgg(1 << 16, rgg_paper_radius(1 << 16), 310), // rgg24 (scaled)
        "rgg17" => rgg(1 << 17, rgg_paper_radius(1 << 17), 311), // (extra)
        "grid3d_large" => grid3d(64, 64, 32),         // large DIMACS mesh
        other => panic!("unknown instance {other}"),
    }
}

/// The full paper suite (28 instances; the paper uses 25 graphs × 6
/// hierarchies = 150 instance pairs — we match the graph count closely).
pub fn paper_suite() -> Vec<InstanceSpec> {
    let mk = |name, group, stand_in_for| InstanceSpec { name, group, stand_in_for };
    vec![
        mk("sten_cop20k", "suitesparse", "cop20k_A"),
        mk("sten_cubes", "suitesparse", "2cubes_sphere"),
        mk("sten_thermo", "suitesparse", "thermomech_TC"),
        mk("sten_cfd2", "suitesparse", "cfd2"),
        mk("sten_bone", "suitesparse", "boneS01"),
        mk("sten_dubcova", "suitesparse", "Dubcova3"),
        mk("sten_bmwcra", "suitesparse", "bmwcra_1"),
        mk("sten_g2circ", "suitesparse", "G2_circuit"),
        mk("sten_shipsec", "suitesparse", "shipsec5"),
        mk("sten_cont300", "suitesparse", "cont-300"),
        mk("wal_598a", "walshaw", "598a"),
        mk("wal_feocean", "walshaw", "fe_ocean"),
        mk("wal_144", "walshaw", "144"),
        mk("wal_wave", "walshaw", "wave"),
        mk("wal_m14b", "walshaw", "m14b"),
        mk("wal_auto", "walshaw", "auto"),
        mk("afshell_s", "other", "afshell9"),
        mk("thermal2_s", "other", "thermal2"),
        mk("nlr_s", "other", "nlr"),
        mk("road_deu", "other", "deu"),
        mk("road_eu", "other", "europe_osm"),
        mk("del15", "other", "del23"),
        mk("del16", "other", "del24"),
        mk("rgg15", "other", "rgg23"),
        mk("rgg16", "other", "rgg24"),
    ]
}

/// A quick sub-suite for smoke tests and CI-style runs.
pub fn smoke_suite() -> Vec<InstanceSpec> {
    paper_suite()
        .into_iter()
        .filter(|s| matches!(s.name, "sten_cop20k" | "wal_598a" | "del15" | "rgg15" | "road_deu"))
        .collect()
}

/// Look up a spec by name.
pub fn instance_by_name(name: &str) -> Option<InstanceSpec> {
    paper_suite().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_suite_instances_generate_and_validate() {
        for spec in paper_suite() {
            let g = spec.generate();
            assert!(g.n() > 1_000, "{} too small: {}", spec.name, g.n());
            g.validate().unwrap_or_else(|e| panic!("{}: {}", spec.name, e));
        }
    }

    #[test]
    fn suite_has_both_size_classes() {
        let suite = paper_suite();
        assert!(suite.iter().any(|s| s.size_class() == SizeClass::Small));
        assert!(suite.iter().any(|s| s.size_class() == SizeClass::Large));
    }

    #[test]
    fn lookup_by_name() {
        assert!(instance_by_name("rgg15").is_some());
        assert!(instance_by_name("nope").is_none());
    }
}
