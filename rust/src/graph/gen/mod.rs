//! Synthetic task-graph generators.
//!
//! The paper's benchmark set (Table 1) mixes SuiteSparse FEM/circuit
//! matrices, Walshaw archive meshes, DIMACS meshes, road networks, random
//! geometric graphs (`rgg23/24`) and Delaunay triangulations (`del23/24`).
//! Those archives are unavailable offline and the largest graphs do not
//! fit this host, so we generate the same *families* at scaled sizes (see
//! DESIGN.md §1). `rgg*` uses the paper's exact radius rule
//! `0.55·sqrt(ln n / n)`.

mod suite;
pub use suite::{generate_by_name, instance_by_name, paper_suite, smoke_suite, InstanceSpec, SizeClass};

use super::{builder::GraphBuilder, CsrGraph};
use crate::rng::Rng;
use crate::Vertex;

/// 2D grid mesh `w × h`; `torus` wraps both dimensions. Walshaw-style FEM
/// stand-in (unit weights, degree ≤ 4).
pub fn grid2d(w: usize, h: usize, torus: bool) -> CsrGraph {
    let n = w * h;
    let mut b = GraphBuilder::with_edge_capacity(n, 2 * n);
    let id = |x: usize, y: usize| (y * w + x) as Vertex;
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                b.add_edge(id(x, y), id(x + 1, y), 1.0);
            } else if torus && w > 2 {
                b.add_edge(id(x, y), id(0, y), 1.0);
            }
            if y + 1 < h {
                b.add_edge(id(x, y), id(x, y + 1), 1.0);
            } else if torus && h > 2 {
                b.add_edge(id(x, y), id(x, 0), 1.0);
            }
        }
    }
    b.build()
}

/// 3D grid mesh `w × h × d` (DIMACS-style numerical mesh stand-in).
pub fn grid3d(w: usize, h: usize, d: usize) -> CsrGraph {
    let n = w * h * d;
    let mut b = GraphBuilder::with_edge_capacity(n, 3 * n);
    let id = |x: usize, y: usize, z: usize| (z * w * h + y * w + x) as Vertex;
    for z in 0..d {
        for y in 0..h {
            for x in 0..w {
                if x + 1 < w {
                    b.add_edge(id(x, y, z), id(x + 1, y, z), 1.0);
                }
                if y + 1 < h {
                    b.add_edge(id(x, y, z), id(x, y + 1, z), 1.0);
                }
                if z + 1 < d {
                    b.add_edge(id(x, y, z), id(x, y, z + 1), 1.0);
                }
            }
        }
    }
    b.build()
}

/// 3D torus mesh `w × h × d` — [`grid3d`] with all three dimensions
/// wrapped. The halo-exchange communication pattern of periodic stencil
/// codes, and the natural workload for `topology=torus:…` machines.
pub fn torus3d(w: usize, h: usize, d: usize) -> CsrGraph {
    let n = w * h * d;
    let mut b = GraphBuilder::with_edge_capacity(n, 3 * n);
    let id = |x: usize, y: usize, z: usize| (z * w * h + y * w + x) as Vertex;
    for z in 0..d {
        for y in 0..h {
            for x in 0..w {
                if x + 1 < w {
                    b.add_edge(id(x, y, z), id(x + 1, y, z), 1.0);
                } else if w > 2 {
                    b.add_edge(id(x, y, z), id(0, y, z), 1.0);
                }
                if y + 1 < h {
                    b.add_edge(id(x, y, z), id(x, y + 1, z), 1.0);
                } else if h > 2 {
                    b.add_edge(id(x, y, z), id(x, 0, z), 1.0);
                }
                if z + 1 < d {
                    b.add_edge(id(x, y, z), id(x, y, z + 1), 1.0);
                } else if d > 2 {
                    b.add_edge(id(x, y, z), id(x, y, 0), 1.0);
                }
            }
        }
    }
    b.build()
}

/// Random geometric graph: `n` uniform points in the unit square, edge if
/// distance < `radius`. The paper's rgg instances use
/// `radius = 0.55·sqrt(ln n / n)` — see [`rgg_paper_radius`].
pub fn rgg(n: usize, radius: f64, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
    // Uniform grid hashing for neighbor search.
    let cell = radius.max(1e-9);
    let cells = (1.0 / cell).ceil().max(1.0) as usize;
    let cell_of = |p: (f64, f64)| {
        let cx = ((p.0 / cell) as usize).min(cells - 1);
        let cy = ((p.1 / cell) as usize).min(cells - 1);
        cy * cells + cx
    };
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); cells * cells];
    for (i, &p) in pts.iter().enumerate() {
        buckets[cell_of(p)].push(i as u32);
    }
    let r2 = radius * radius;
    let mut b = GraphBuilder::new(n);
    for (i, &p) in pts.iter().enumerate() {
        let cx = ((p.0 / cell) as usize).min(cells - 1) as isize;
        let cy = ((p.1 / cell) as usize).min(cells - 1) as isize;
        for dy in -1..=1isize {
            for dx in -1..=1isize {
                let (nx, ny) = (cx + dx, cy + dy);
                if nx < 0 || ny < 0 || nx >= cells as isize || ny >= cells as isize {
                    continue;
                }
                for &j in &buckets[ny as usize * cells + nx as usize] {
                    if (j as usize) <= i {
                        continue;
                    }
                    let q = pts[j as usize];
                    let (ddx, ddy) = (p.0 - q.0, p.1 - q.1);
                    if ddx * ddx + ddy * ddy < r2 {
                        b.add_edge(i as Vertex, j, 1.0);
                    }
                }
            }
        }
    }
    b.build()
}

/// The paper's radius rule for rgg instances.
pub fn rgg_paper_radius(n: usize) -> f64 {
    0.55 * ((n as f64).ln() / n as f64).sqrt()
}

/// Delaunay-like triangulation: jittered `s × s` grid points, each cell
/// split into two triangles (random diagonal). Planar, mesh-like,
/// degree ≈ 6 — the structural profile of the paper's `del*` instances
/// without implementing a full Delaunay kernel.
pub fn delaunay_like(s: usize, seed: u64) -> CsrGraph {
    let n = s * s;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_edge_capacity(n, 3 * n);
    let id = |x: usize, y: usize| (y * s + x) as Vertex;
    for y in 0..s {
        for x in 0..s {
            if x + 1 < s {
                b.add_edge(id(x, y), id(x + 1, y), 1.0);
            }
            if y + 1 < s {
                b.add_edge(id(x, y), id(x, y + 1), 1.0);
            }
            if x + 1 < s && y + 1 < s {
                // Random diagonal orientation per cell.
                if rng.next_u64() & 1 == 0 {
                    b.add_edge(id(x, y), id(x + 1, y + 1), 1.0);
                } else {
                    b.add_edge(id(x + 1, y), id(x, y + 1), 1.0);
                }
            }
        }
    }
    b.build()
}

/// 9-point stencil matrix graph with varying communication volumes —
/// SuiteSparse FEM-matrix stand-in (denser rows, weighted entries).
pub fn stencil9(w: usize, h: usize, seed: u64) -> CsrGraph {
    let n = w * h;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_edge_capacity(n, 4 * n);
    let id = |x: usize, y: usize| (y * w + x) as Vertex;
    for y in 0..h {
        for x in 0..w {
            let deltas: [(isize, isize); 4] = [(1, 0), (0, 1), (1, 1), (1, -1)];
            for (dx, dy) in deltas {
                let (nx, ny) = (x as isize + dx, y as isize + dy);
                if nx >= 0 && ny >= 0 && (nx as usize) < w && (ny as usize) < h {
                    let wgt = 1.0 + rng.below(8) as f64;
                    b.add_edge(id(x, y), id(nx as usize, ny as usize), wgt);
                }
            }
        }
    }
    b.build()
}

/// Road-network-like graph: a sparse grid with random edge deletions and a
/// few long-range "highway" shortcuts; low average degree (≈2.5), long
/// diameter — the profile of `deu`/`europe_osm`.
pub fn road_like(w: usize, h: usize, seed: u64) -> CsrGraph {
    let n = w * h;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_edge_capacity(n, 2 * n);
    let id = |x: usize, y: usize| (y * w + x) as Vertex;
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w && rng.f64() < 0.72 {
                b.add_edge(id(x, y), id(x + 1, y), 1.0);
            }
            if y + 1 < h && rng.f64() < 0.72 {
                b.add_edge(id(x, y), id(x, y + 1), 1.0);
            }
        }
    }
    // Highways: connect random distant pairs along rows.
    let highways = (n / 64).max(1);
    for _ in 0..highways {
        let y = rng.below_usize(h);
        let x1 = rng.below_usize(w);
        let x2 = rng.below_usize(w);
        if x1 != x2 {
            b.add_edge(id(x1, y), id(x2, y), 2.0);
        }
    }
    b.build()
}

/// FEM-like 2D mesh with circular holes (Walshaw `fe_ocean`-style
/// irregular boundary): grid2d with disks removed, remapped to compact ids.
pub fn mesh_with_holes(w: usize, h: usize, holes: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut removed = vec![false; w * h];
    for _ in 0..holes {
        let cx = rng.below_usize(w) as f64;
        let cy = rng.below_usize(h) as f64;
        let r = (w.min(h) as f64) * (0.04 + 0.06 * rng.f64());
        for y in 0..h {
            for x in 0..w {
                let (dx, dy) = (x as f64 - cx, y as f64 - cy);
                if dx * dx + dy * dy < r * r {
                    removed[y * w + x] = true;
                }
            }
        }
    }
    let mut remap = vec![u32::MAX; w * h];
    let mut n = 0u32;
    for (i, &r) in removed.iter().enumerate() {
        if !r {
            remap[i] = n;
            n += 1;
        }
    }
    let mut b = GraphBuilder::new(n as usize);
    for y in 0..h {
        for x in 0..w {
            let i = y * w + x;
            if removed[i] {
                continue;
            }
            if x + 1 < w && !removed[i + 1] {
                b.add_edge(remap[i], remap[i + 1], 1.0);
            }
            if y + 1 < h && !removed[i + w] {
                b.add_edge(remap[i], remap[i + w], 1.0);
            }
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid2d_counts() {
        let g = grid2d(5, 4, false);
        assert_eq!(g.n(), 20);
        assert_eq!(g.m(), 4 * 4 + 5 * 3); // horizontal + vertical
        g.validate().unwrap();
    }

    #[test]
    fn torus_regular_degree() {
        let g = grid2d(6, 6, true);
        for v in 0..g.n() {
            assert_eq!(g.degree(v as u32), 4);
        }
        g.validate().unwrap();
    }

    #[test]
    fn torus3d_regular_degree() {
        let g = torus3d(4, 4, 4);
        assert_eq!(g.n(), 64);
        for v in 0..g.n() {
            assert_eq!(g.degree(v as u32), 6);
        }
        g.validate().unwrap();
    }

    #[test]
    fn grid3d_counts() {
        let g = grid3d(3, 3, 3);
        assert_eq!(g.n(), 27);
        assert_eq!(g.m(), 3 * (2 * 3 * 3)); // 2*3*3 edges per direction
        g.validate().unwrap();
    }

    #[test]
    fn rgg_has_paper_degree_profile() {
        let n = 4_096;
        let g = rgg(n, rgg_paper_radius(n), 1);
        g.validate().unwrap();
        // Expected average degree ≈ n·π·r² ≈ 0.3025·π·ln n ≈ 7.9.
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!(avg > 4.0 && avg < 14.0, "avg degree {avg}");
    }

    #[test]
    fn delaunay_like_is_planarish() {
        let g = delaunay_like(32, 2);
        g.validate().unwrap();
        // Planar: m ≤ 3n − 6.
        assert!(g.m() <= 3 * g.n() - 6);
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!(avg > 4.0 && avg < 6.0, "avg degree {avg}");
    }

    #[test]
    fn stencil9_weighted() {
        let g = stencil9(16, 16, 3);
        g.validate().unwrap();
        assert!(g.ew.iter().any(|&w| w > 1.0));
    }

    #[test]
    fn road_like_sparse() {
        let g = road_like(64, 64, 4);
        g.validate().unwrap();
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!(avg < 3.5, "avg degree {avg}");
    }

    #[test]
    fn mesh_with_holes_smaller_than_grid() {
        let g = mesh_with_holes(40, 40, 3, 5);
        g.validate().unwrap();
        assert!(g.n() < 1_600);
        assert!(g.n() > 800);
    }

    #[test]
    fn generators_deterministic() {
        let a = rgg(500, 0.07, 9);
        let b = rgg(500, 0.07, 9);
        assert_eq!(a.adj, b.adj);
        assert_eq!(a.xadj, b.xadj);
    }
}
