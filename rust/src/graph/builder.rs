//! Incremental graph construction: collect undirected edges, then build a
//! deduplicated, sorted, symmetric [`CsrGraph`]. Parallel edges are fused
//! and their weights summed (the contraction semantics from §2.1).

use super::CsrGraph;
use crate::{EWeight, VWeight, Vertex};

/// Builder for [`CsrGraph`]; add each undirected edge once.
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(Vertex, Vertex, EWeight)>,
    vw: Vec<VWeight>,
}

impl GraphBuilder {
    /// `n` vertices, all with weight 1 until changed via [`Self::set_vweight`].
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new(), vw: vec![1; n] }
    }

    pub fn with_edge_capacity(n: usize, m: usize) -> Self {
        let mut b = Self::new(n);
        b.edges.reserve(m);
        b
    }

    /// Add undirected edge `{u, v}` with weight `w`. Self loops are
    /// silently dropped (they carry no mapping cost: `D_xx` terms are
    /// constant under any Π). Duplicate edges are summed at build time.
    pub fn add_edge(&mut self, u: Vertex, v: Vertex, w: EWeight) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u == v {
            return;
        }
        self.edges.push((u, v, w));
    }

    pub fn set_vweight(&mut self, v: Vertex, w: VWeight) {
        self.vw[v as usize] = w;
    }

    pub fn set_all_vweights(&mut self, vw: Vec<VWeight>) {
        assert_eq!(vw.len(), self.n);
        self.vw = vw;
    }

    /// Build the CSR graph: symmetrize, sort, fuse duplicates.
    pub fn build(self) -> CsrGraph {
        let n = self.n;
        // Count directed degrees (upper bound, before dedup).
        let mut deg = vec![0u32; n];
        for &(u, v, _) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut xadj = vec![0u32; n + 1];
        for v in 0..n {
            xadj[v + 1] = xadj[v] + deg[v];
        }
        let total = xadj[n] as usize;
        let mut adj = vec![0 as Vertex; total];
        let mut ew = vec![0.0; total];
        let mut pos = xadj.clone();
        for &(u, v, w) in &self.edges {
            let pu = pos[u as usize] as usize;
            adj[pu] = v;
            ew[pu] = w;
            pos[u as usize] += 1;
            let pv = pos[v as usize] as usize;
            adj[pv] = u;
            ew[pv] = w;
            pos[v as usize] += 1;
        }
        // Per-vertex sort + dedup (sum weights of parallel edges).
        let mut nadj = Vec::with_capacity(total);
        let mut new_ew = Vec::with_capacity(total);
        let mut nxadj = vec![0u32; n + 1];
        let mut scratch: Vec<(Vertex, EWeight)> = Vec::new();
        for v in 0..n {
            scratch.clear();
            for i in xadj[v] as usize..xadj[v + 1] as usize {
                scratch.push((adj[i], ew[i]));
            }
            scratch.sort_unstable_by_key(|&(t, _)| t);
            let mut i = 0;
            while i < scratch.len() {
                let t = scratch[i].0;
                let mut w = 0.0;
                while i < scratch.len() && scratch[i].0 == t {
                    w += scratch[i].1;
                    i += 1;
                }
                nadj.push(t);
                new_ew.push(w);
            }
            nxadj[v + 1] = nadj.len() as u32;
        }
        CsrGraph { xadj: nxadj, adj: nadj, ew: new_ew, vw: self.vw }
    }
}

/// Build directly from a deduplicated undirected edge list.
pub fn from_edges(n: usize, edges: &[(Vertex, Vertex, EWeight)], vw: Option<Vec<VWeight>>) -> CsrGraph {
    let mut b = GraphBuilder::with_edge_capacity(n, edges.len());
    if let Some(vw) = vw {
        b.set_all_vweights(vw);
    }
    for &(u, v, w) in edges {
        b.add_edge(u, v, w);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_edges_are_summed() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 0, 2.5);
        let g = b.build();
        assert_eq!(g.m(), 1);
        assert_eq!(g.find_edge(0, 1), Some(3.5));
        g.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 0, 9.0);
        b.add_edge(0, 1, 1.0);
        let g = b.build();
        assert_eq!(g.m(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn isolated_vertices_allowed() {
        let b = GraphBuilder::new(5);
        let g = b.build();
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn vertex_weights_preserved() {
        let mut b = GraphBuilder::new(3);
        b.set_vweight(1, 7);
        b.add_edge(0, 2, 1.0);
        let g = b.build();
        assert_eq!(g.vw, vec![1, 7, 1]);
        assert_eq!(g.total_vweight(), 9);
    }

    #[test]
    fn from_edges_roundtrip() {
        let g = from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.0)], None);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        g.validate().unwrap();
    }
}
