//! The crate's single front door: one spec, one job API, one worker pool.
//!
//! Every caller — the `heipa` CLI, the TCP coordinator, the benchmark
//! harness and library users — builds a [`MapSpec`] and hands it to an
//! [`Engine`]. The engine is **job-oriented**: [`Engine::submit`] places
//! the spec on a bounded priority queue and returns a [`JobHandle`]
//! immediately; a pool of N engine workers (each owning its device
//! [`crate::par::Pool`] and lazily-started PJRT runtime) drains the
//! queue. The old blocking call survives as [`Engine::map`] =
//! `submit(..)` + `wait()`. In-flight jobs stop at coarsening-level and
//! Jet-round boundaries when their [`CancelToken`] trips (explicit
//! cancel or per-job deadline).
//!
//! Graphs resolve through a shared [`cache::GraphStore`]: a bounded LRU
//! tier for named instances/files plus a pinned session tier
//! ([`Engine::put_graph`]) for the upload-once/map-many pattern. The
//! result of every job is a [`MapOutcome`].
//!
//! ```no_run
//! use heipa::engine::{Engine, MapSpec};
//!
//! let engine = Engine::with_defaults();
//! // Blocking:
//! let outcome = engine.map(&MapSpec::named("rgg15").hierarchy("4:8:2").polish(true))?;
//! println!("J = {:.0} on {} PEs", outcome.comm_cost, outcome.k);
//! // Asynchronous:
//! let job = engine.submit(&MapSpec::named("rgg15").seed(2))?;
//! println!("submitted job {}", job.id());
//! let outcome = job.wait()?;
//! # anyhow::Ok(())
//! ```

pub mod cache;
pub mod job;
pub(crate) mod queue;
pub mod registry;
pub mod spec;

pub use crate::cancel::CancelToken;
pub use crate::incremental::RemapKind;
pub use job::{JobHandle, JobId, JobState, JobStatus, RetryPolicy, SubmitError, SubmitOpts};
pub use registry::{solver, solver_by_name, solver_names, solvers};
pub use spec::{Backend, GraphSource, MapSpec, Refinement};

use crate::algo::{qap, Algorithm};
use crate::fault::{self, FaultPlane, FaultPoint};
use crate::graph::{gen, io, CsrGraph};
use crate::incremental::{self, GraphPatch, PatchError, PatchSummary, RemapPlan, Remapper};
use crate::metrics::PhaseBreakdown;
use crate::multilevel::{CoarseHierarchy, HierarchyHandle, HierarchyParams};
use crate::par::cost::DeviceTimer;
use crate::par::{ledger, Pool};
use crate::partition::{block_comm_matrix, comm_cost_blocks, imbalance};
use crate::runtime::{device, offload, Runtime};
use crate::topology::{DistanceOracle, Machine};
use crate::Block;
use anyhow::{Context, Result};
use std::cell::OnceCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Unified result of one mapping run — replaces the old
/// `MappingResult`/`MapResponse` split.
#[derive(Clone, Debug)]
pub struct MapOutcome {
    /// The solver that actually ran (after routing + refinement upgrade).
    pub algorithm: Algorithm,
    pub n: usize,
    pub k: usize,
    /// The seed this outcome was solved with.
    pub seed: u64,
    /// Vertex → PE assignment. Empty when the spec set
    /// `return_mapping = false`.
    pub mapping: Vec<Block>,
    /// Communication cost `J(C, D, Π)` (after polish, if enabled).
    pub comm_cost: f64,
    /// Achieved imbalance.
    pub imbalance: f64,
    /// Host wall time (ms).
    pub host_ms: f64,
    /// Modeled device time (ms); equals `host_ms` for CPU-only solvers.
    pub device_ms: f64,
    /// Per-phase breakdown (device solvers only).
    pub phases: Option<PhaseBreakdown>,
    /// `J` improvement from the polish stage (0 when disabled).
    pub polish_improvement: f64,
    /// Whether this job's multilevel hierarchy came from the engine's
    /// hierarchy cache: `Some(true)` = cache hit (Coarsening/Contraction
    /// skipped), `Some(false)` = built by this job, `None` = the solver
    /// has no engine-cacheable hierarchy.
    pub hierarchy_cache: Option<bool>,
    /// True when this outcome came from the graceful-degradation
    /// fallback chain (all regular attempts failed): the mapping is
    /// valid, but a cheaper solver than configured produced it.
    pub degraded: bool,
    /// 1-based number of execution attempts this job took (> 1 only
    /// under [`RetryPolicy`] retries).
    pub attempts: u32,
    /// How this job relates to the session's remap history: `Some(Warm)`
    /// = warm-start refinement from the previous mapping (after a
    /// `graph patch`), `Some(Cold)` = a remap was pending but fell back
    /// to a full solve, `None` = no patch pending (plain solve).
    pub remap: Option<RemapKind>,
    /// The backend that actually executed this job — `Device` only when
    /// a real PJRT device session was active for the solve. A job that
    /// *requested* `device` but fell back (artifacts missing, client
    /// down) reports `Cpu` here and counts in
    /// [`Engine::backend_fallbacks`]; `auto` resolves silently.
    pub backend: Backend,
}

/// One solver in the registry. `solve` runs the algorithm end to end and
/// measures it; routing, graph resolution and polish belong to the
/// [`Engine`], not the solver. Implementations must poll `cancel` at
/// coarsening-level and Jet-round boundaries and bail out early (with any
/// structurally valid mapping) once it trips — the engine discards the
/// result of a cancelled run.
pub trait Solver: Sync {
    fn algorithm(&self) -> Algorithm;

    fn name(&self) -> &'static str {
        self.algorithm().name()
    }

    /// The multilevel hierarchy this solver would build for
    /// `(g, m, spec)` — the engine uses it to serve and populate the
    /// hierarchy cache before calling [`Solver::solve`]. `None` (the
    /// default) for solvers without an engine-cacheable hierarchy
    /// (multisection recursion, serial baselines). Implementations must
    /// return exactly the parameters their `solve` builds with, or the
    /// cached hierarchy would diverge from a fresh run.
    fn hierarchy_params(&self, _g: &CsrGraph, _m: &Machine, _spec: &MapSpec) -> Option<HierarchyParams> {
        None
    }

    /// Run the algorithm end to end. `hier` is the prebuilt multilevel
    /// hierarchy for solvers that declared [`Solver::hierarchy_params`]
    /// (`None` when driven outside the engine); cached handles skip the
    /// Coarsening/Contraction phases entirely.
    fn solve(
        &self,
        ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        hier: Option<&HierarchyHandle>,
    ) -> MapOutcome;
}

/// Router policy for specs that did not pin an algorithm: small graphs get
/// the quality flavor, large ones the throughput flavor (threshold = the
/// suite's size-class boundary).
pub fn route(n: usize, pinned: Option<Algorithm>) -> Algorithm {
    if let Some(a) = pinned {
        return a;
    }
    if n <= 60_000 {
        Algorithm::GpuHmUltra
    } else {
        Algorithm::GpuIm
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Device worker threads per engine worker (0 = auto).
    pub threads: usize,
    /// Artifact directory for the PJRT offload kernels. The engine still
    /// maps (host polish only) when the runtime cannot come up.
    pub artifacts_dir: String,
    /// Graph cache entry cap (LRU tier; pinned session graphs live
    /// outside it).
    pub graph_cache_cap: usize,
    /// Hierarchy cache entry cap (bounded LRU of built multilevel
    /// hierarchies, keyed by graph identity + coarsening parameters).
    /// Each entry holds roughly 2× its graph, so the cap stays small.
    pub hierarchy_cache_cap: usize,
    /// Engine workers draining the job queue (0 = 1). Each owns its own
    /// device pool and PJRT runtime; jobs on different workers overlap.
    pub workers: usize,
    /// Bounded job-queue capacity — the backpressure knob. A full queue
    /// blocks in-process submitters and rejects wire submits with
    /// `err code=busy`.
    pub queue_cap: usize,
    /// Default retry policy for jobs that did not set
    /// [`SubmitOpts::retry`]. The default (`max_attempts = 1`) keeps
    /// failures single-shot; degradation still applies.
    pub retry: RetryPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 0,
            artifacts_dir: "artifacts".into(),
            graph_cache_cap: 64,
            hierarchy_cache_cap: 8,
            workers: 1,
            queue_cap: 256,
            retry: RetryPolicy::default(),
        }
    }
}

/// Per-worker execution state: the device [`Pool`] and the PJRT
/// [`Runtime`]. Not `Sync` (the runtime holds a single PJRT client);
/// every engine worker owns one, matching the paper's
/// one-client-per-device model.
///
/// The pool's workers are persistent (spawned once, parked between
/// kernels), so an engine worker that serves many jobs pays thread spawn
/// cost exactly once for the process lifetime.
pub struct EngineCtx {
    pool: Pool,
    artifacts_dir: String,
    /// Lazily-initialized PJRT client: front-ends that never polish (or
    /// offload) must not pay XLA client startup.
    runtime: OnceCell<Option<Runtime>>,
}

impl EngineCtx {
    /// Context without a device runtime — for shims and tests that drive
    /// a solver directly.
    pub fn host_only(pool: Pool) -> Self {
        EngineCtx { pool, artifacts_dir: String::new(), runtime: OnceCell::from(None) }
    }

    /// Context with a lazily-started runtime rooted at `artifacts_dir`.
    pub fn with_runtime(pool: Pool, artifacts_dir: String) -> Self {
        EngineCtx { pool, artifacts_dir, runtime: OnceCell::new() }
    }

    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The artifact directory this context resolves PJRT kernels from
    /// (empty for [`EngineCtx::host_only`]).
    pub fn artifacts_dir(&self) -> &str {
        &self.artifacts_dir
    }

    /// The PJRT runtime, brought up on first use; `None` when the client
    /// cannot start (the engine still maps, host polish only).
    pub fn runtime(&self) -> Option<&Runtime> {
        self.runtime.get_or_init(|| Runtime::new(&self.artifacts_dir).ok()).as_ref()
    }
}

/// Entry cap of the per-engine machine cache.
const MACHINE_CACHE_CAP: usize = 16;

/// Cache key for a `topology=` spec: `file:` specs fold in the file's
/// length and mtime so an edited distance table invalidates the entry
/// (an unreadable file keys on the bare spec and fails in the parser).
fn machine_cache_key(topology: &str) -> String {
    if let Some(path) = topology.trim().strip_prefix("file:") {
        if let Ok(md) = std::fs::metadata(path) {
            let mtime = md
                .modified()
                .ok()
                .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                .map(|d| d.as_nanos())
                .unwrap_or(0);
            return format!("{topology}@{}:{mtime}", md.len());
        }
    }
    topology.to_string()
}

/// State shared by the engine handle and its workers.
struct EngineShared {
    cfg: EngineConfig,
    queue: Mutex<queue::JobQueue>,
    /// Workers park here waiting for jobs.
    work_cv: Condvar,
    /// Blocking submitters park here waiting for queue space.
    space_cv: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    next_seq: AtomicU64,
    in_flight: AtomicUsize,
    graphs: Mutex<cache::GraphStore>,
    /// Built multilevel hierarchies, keyed by graph identity + coarsening
    /// parameters (bounded LRU). Repeat jobs on a session graph — and
    /// seed sweeps, whose coarsening salt is seed-independent — skip the
    /// Coarsening/Contraction phases entirely.
    hierarchies: Mutex<cache::HierarchyCache>,
    hierarchy_hits: AtomicU64,
    hierarchy_misses: AtomicU64,
    /// Parsed machines keyed by `topology=` spec string (bounded FIFO):
    /// `file:PATH` models re-read and re-validate an O(k²) table on every
    /// parse, which a long-lived `serve` worker must not pay per job.
    machines: Mutex<Vec<(String, Machine)>>,
    /// Failed attempts re-queued for retry (cumulative).
    retries: AtomicU64,
    /// Failures attributed to the fault plane (message carries
    /// [`fault::INJECTED_MARKER`]), cumulative across attempts.
    faults_injected: AtomicU64,
    /// Jobs completed through the degradation fallback chain.
    degraded: AtomicU64,
    /// Incremental-remap state: the last mapping and pending patch
    /// region per pinned session graph.
    remapper: Mutex<Remapper>,
    /// Batch-id source for [`Engine::submit_batch`].
    next_batch: AtomicU64,
    /// Patches applied to pinned session graphs (cumulative).
    patches_applied: AtomicU64,
    /// Jobs completed through the warm-start remap path.
    warm_remaps: AtomicU64,
    /// Pending remaps that fell back to a full (cold) solve.
    cold_fallbacks: AtomicU64,
    /// `submit_batch` calls admitted (cumulative).
    batches: AtomicU64,
    /// Jobs admitted through `submit_batch` (cumulative).
    batched_jobs: AtomicU64,
    /// `graph put` uploads that replaced an existing pinned name.
    graphs_replaced: AtomicU64,
    /// Real PJRT kernel launches executed by jobs (cumulative; folded
    /// from the worker-thread device ledger after every attempt).
    device_launches: AtomicU64,
    /// Host→device bytes uploaded by jobs (cumulative).
    h2d_bytes: AtomicU64,
    /// Device→host bytes downloaded by jobs (cumulative).
    d2h_bytes: AtomicU64,
    /// Device→cpu fallbacks: jobs that requested `backend=device` but
    /// resolved to the CPU pool, plus kernel-level PJRT failures that
    /// fell back mid-solve (cumulative).
    backend_fallbacks: AtomicU64,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panicked job must not poison the whole engine: the shared state is
    // only ever left consistent under these locks.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl EngineShared {
    fn resolve_graph(&self, src: &GraphSource) -> Result<Arc<CsrGraph>> {
        match src {
            GraphSource::InMemory(g) => Ok(g.clone()),
            GraphSource::Named(name) => {
                if let Some(g) = lock(&self.graphs).get(name) {
                    return Ok(g);
                }
                // Generate/parse outside the lock: resolving a big
                // instance must not stall every other worker's lookups.
                let g = if gen::instance_by_name(name).is_some() {
                    gen::generate_by_name(name)
                } else {
                    io::read_metis(Path::new(name)).with_context(|| {
                        format!("instance `{name}` is neither a pinned graph, a registry name nor a readable METIS file")
                    })?
                };
                let g = Arc::new(g);
                lock(&self.graphs).insert_cached(name.clone(), g.clone());
                Ok(g)
            }
        }
    }

    fn resolve_machine(&self, spec: &MapSpec) -> Result<Machine> {
        if let Some(m) = spec.cached_machine() {
            return Ok(m.clone());
        }
        let Some(topology) = &spec.topology else {
            return spec.machine(); // plain hierarchy strings parse in O(ℓ)
        };
        let key = machine_cache_key(topology);
        if let Some((_, m)) = lock(&self.machines).iter().find(|(k, _)| *k == key) {
            return Ok(m.clone());
        }
        let m = spec.machine()?;
        let mut cache = lock(&self.machines);
        cache.push((key, m.clone()));
        if cache.len() > MACHINE_CACHE_CAP {
            cache.remove(0);
        }
        Ok(m)
    }

    /// The hierarchy for `(graph identity, params)`: served from the
    /// bounded cache on a hit, built on this worker's pool (and
    /// inserted) on a miss. `None` means the build was cancelled.
    fn hierarchy_for(
        &self,
        ctx: &EngineCtx,
        g: &Arc<CsrGraph>,
        params: &HierarchyParams,
        cancel: &CancelToken,
    ) -> Option<HierarchyHandle> {
        if let Some(hier) = lock(&self.hierarchies).get(g, params) {
            // relaxed: monotone statistics counter, read approximately.
            self.hierarchy_hits.fetch_add(1, Ordering::Relaxed);
            return Some(HierarchyHandle { hier, cached: true });
        }
        // Build outside the lock: coarsening a big graph must not stall
        // every other worker's lookups. Two workers racing on the same
        // key build identical hierarchies; the second insert wins.
        let hier = Arc::new(CoarseHierarchy::build(
            ctx.pool(),
            g.clone(),
            &params.build,
            &params.cfg,
            cancel,
            None,
        )?);
        // relaxed: monotone statistics counter, read approximately.
        self.hierarchy_misses.fetch_add(1, Ordering::Relaxed);
        lock(&self.hierarchies).insert(g.clone(), params.clone(), hier.clone());
        Some(HierarchyHandle { hier, cached: false })
    }

    /// Solve one spec on this worker's ctx. `Ok(None)` means the token
    /// tripped before a result was produced (the job is not `Done`).
    /// `plane` is the job's fault plane (from `__fault.*` options);
    /// injection points here also consult the process-global plane.
    ///
    /// Wraps the solve proper in a device-counter fold: the thread-local
    /// PJRT ledger deltas of the attempt (launches, transfer bytes,
    /// kernel-level fallbacks) accumulate into the engine-wide metrics.
    /// A panicked attempt loses its deltas — acceptable for approximate
    /// statistics.
    fn execute(
        &self,
        ctx: &EngineCtx,
        spec: &MapSpec,
        cancel: &CancelToken,
        plane: Option<&FaultPlane>,
    ) -> Result<Option<MapOutcome>> {
        let dev_before = ledger::device_snapshot();
        let fb_before = device::fallback_events();
        let result = self.execute_solve(ctx, spec, cancel, plane);
        let delta = ledger::device_snapshot().since(dev_before);
        // relaxed: monotone statistics counters, read approximately.
        self.device_launches.fetch_add(delta.device_launches, Ordering::Relaxed);
        self.h2d_bytes.fetch_add(delta.h2d_bytes, Ordering::Relaxed);
        self.d2h_bytes.fetch_add(delta.d2h_bytes, Ordering::Relaxed);
        self.backend_fallbacks
            .fetch_add(device::fallback_events() - fb_before, Ordering::Relaxed);
        result
    }

    fn execute_solve(
        &self,
        ctx: &EngineCtx,
        spec: &MapSpec,
        cancel: &CancelToken,
        plane: Option<&FaultPlane>,
    ) -> Result<Option<MapOutcome>> {
        // Test hook (used by the cancellation/overlap suites; never set
        // by real solvers): `__sleep_ms` busy-waits in small cancellable
        // slices. Synthetic failures go through the fault plane
        // (`__fault.*` options / HEIPA_FAULTS) instead.
        if let Some(ms) = spec.options.get("__sleep_ms").and_then(|v| v.parse::<u64>().ok()) {
            let end = Instant::now() + Duration::from_millis(ms);
            while Instant::now() < end && !cancel.is_cancelled() {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        if cancel.is_cancelled() {
            return Ok(None);
        }
        if fault::fire(plane, FaultPoint::GraphStore) {
            anyhow::bail!(fault::failure(FaultPoint::GraphStore));
        }
        let g = self.resolve_graph(&spec.graph)?;
        let m = self.resolve_machine(spec)?;
        let algo = spec.resolve_algorithm(g.n());
        let solver = registry::solver(algo);
        // Job-plane device fault: a non-CPU job's backend resolution is
        // the first place a flaky accelerator surfaces (the global plane
        // fires per launch inside `runtime::device` instead).
        if spec.backend != Backend::Cpu
            && plane.is_some_and(|p| p.should_fire(FaultPoint::DeviceLaunch))
        {
            panic!("{}", fault::failure(FaultPoint::DeviceLaunch));
        }
        // Backend resolution: `device` activates the thread-local PJRT
        // session for the whole solve (hierarchy build included) and
        // counts a fallback when it cannot; `auto` resolves quietly —
        // device only when the artifacts exist and the graph fits a
        // compiled class. The guard deactivates when the attempt ends.
        let (_device_guard, backend) = match spec.backend {
            Backend::Cpu => (None, Backend::Cpu),
            Backend::Device => match device::activate(ctx.artifacts_dir()) {
                Some(guard) if device::graph_kernels_available() => {
                    (Some(guard), Backend::Device)
                }
                _ => {
                    // relaxed: monotone statistics counter, read approximately.
                    self.backend_fallbacks.fetch_add(1, Ordering::Relaxed);
                    (None, Backend::Cpu)
                }
            },
            Backend::Auto => {
                let fits = device::graph_class(g.n(), g.num_directed()).is_some();
                match if fits { device::activate(ctx.artifacts_dir()) } else { None } {
                    Some(guard) if device::graph_kernels_available() => {
                        (Some(guard), Backend::Device)
                    }
                    _ => (None, Backend::Cpu),
                }
            }
        };
        // Job-plane hierarchy fault: fires here (once, before the build)
        // rather than inside `CoarseHierarchy` — the global plane fires
        // per level in the build itself.
        if plane.is_some_and(|p| p.should_fire(FaultPoint::HierarchyBuild)) {
            panic!("{}", fault::failure(FaultPoint::HierarchyBuild));
        }
        // Incremental remap planning: only Named specs still resolving
        // to the pinned session graph participate (LRU/registry graphs
        // have no patch history).
        let session = match &spec.graph {
            GraphSource::Named(name) => lock(&self.graphs)
                .pinned(name)
                .filter(|(pg, _)| Arc::ptr_eq(pg, &g))
                .map(|(_, version)| (name.clone(), version)),
            GraphSource::InMemory(_) => None,
        };
        let mut remap = None;
        if let Some((name, version)) = &session {
            let machine_spec = m.spec_string();
            let halo = spec.opt_usize("remap.halo").unwrap_or(1);
            let frac = spec.opt_f64("remap.max_region_frac").unwrap_or(0.25);
            let plan =
                lock(&self.remapper).plan(name, *version, &g, m.k(), &machine_spec, halo, frac);
            match plan {
                RemapPlan::Skip => {}
                RemapPlan::Warm { start, .. }
                    if solver.hierarchy_params(&g, &m, spec).is_some() =>
                {
                    return match self.warm_execute(ctx, spec, cancel, &g, &m, algo, start, backend)? {
                        Some(mut out) => {
                            lock(&self.remapper)
                                .record(name, *version, g.n(), m.k(), &machine_spec, &out.mapping);
                            // relaxed: monotone statistics counter, read approximately.
                            self.warm_remaps.fetch_add(1, Ordering::Relaxed);
                            if !spec.return_mapping {
                                out.mapping = Vec::new();
                            }
                            Ok(Some(out))
                        }
                        None => Ok(None),
                    };
                }
                // Pending remap, but the warm conditions failed (or the
                // solver has no warm-startable refinement): full solve,
                // tagged cold.
                RemapPlan::Cold | RemapPlan::Warm { .. } => {
                    remap = Some(RemapKind::Cold);
                }
            }
        }
        let hier = match solver.hierarchy_params(&g, &m, spec) {
            Some(params) => match self.hierarchy_for(ctx, &g, &params, cancel) {
                Some(h) => Some(h),
                // Cancelled mid-coarsening — the job is not `Done`.
                None => return Ok(None),
            },
            None => None,
        };
        if fault::fire(plane, FaultPoint::Solve) {
            panic!("{}", fault::failure(FaultPoint::Solve));
        }
        let mut out = solver.solve(ctx, &g, &m, spec, cancel, hier.as_ref());
        out.backend = backend;
        if cancel.is_cancelled() {
            return Ok(None);
        }
        if spec.polish {
            out.polish_improvement = polish_mapping(ctx, &g, &m, &mut out.mapping)?;
            out.comm_cost -= out.polish_improvement;
        }
        // Session bookkeeping: remember the (post-polish) mapping so a
        // later `graph patch` can warm-start from it, and tag a pending
        // remap that ran cold.
        if let Some((name, version)) = &session {
            if out.mapping.len() == g.n() {
                lock(&self.remapper)
                    .record(name, *version, g.n(), m.k(), &m.spec_string(), &out.mapping);
            }
        }
        out.remap = remap;
        if remap == Some(RemapKind::Cold) {
            // relaxed: monotone statistics counter, read approximately.
            self.cold_fallbacks.fetch_add(1, Ordering::Relaxed);
        }
        if !spec.return_mapping {
            out.mapping = Vec::new();
        }
        Ok(Some(out))
    }

    /// The warm remap path: skip coarsen→initial→uncoarsen entirely and
    /// run one Jet refinement pass seeded from the session's previous
    /// mapping ([`incremental::warm_refine`]). `Ok(None)` = cancelled
    /// (the pending patch state is untouched — `plan` is read-only — so
    /// the next attempt re-plans). `hierarchy_cache` reports
    /// `Some(true)` when re-keyed coarse levels of the patched graph
    /// survive in the cache (the patch was provably intra-cluster at
    /// some level), `None` when nothing survived — the warm path builds
    /// no hierarchy either way.
    #[allow(clippy::too_many_arguments)]
    fn warm_execute(
        &self,
        ctx: &EngineCtx,
        spec: &MapSpec,
        cancel: &CancelToken,
        g: &Arc<CsrGraph>,
        m: &Machine,
        algo: Algorithm,
        start: Vec<Block>,
        backend: Backend,
    ) -> Result<Option<MapOutcome>> {
        let cached = registry::solver(algo)
            .hierarchy_params(g, m, spec)
            .and_then(|params| lock(&self.hierarchies).get_partial(g, &params))
            .is_some_and(|(_, mask)| mask != 0);
        if cached {
            // relaxed: monotone statistics counter, read approximately.
            self.hierarchy_hits.fetch_add(1, Ordering::Relaxed);
        }
        let seed = spec.primary_seed();
        let timer = DeviceTimer::start();
        let mut mapping = start;
        let stats =
            incremental::warm_refine(ctx.pool(), g, &mut mapping, m, spec.eps, seed, cancel.clone());
        let meas = timer.stop();
        if cancel.is_cancelled() {
            return Ok(None);
        }
        let mut out = MapOutcome {
            algorithm: algo,
            n: g.n(),
            k: m.k(),
            seed,
            comm_cost: stats.final_objective,
            imbalance: imbalance(g, &mapping, m.k()),
            mapping,
            host_ms: meas.host_ms,
            device_ms: if algo.is_device() { meas.device_ms } else { meas.host_ms },
            phases: None,
            polish_improvement: 0.0,
            hierarchy_cache: cached.then_some(true),
            degraded: false,
            attempts: 1,
            remap: Some(RemapKind::Warm),
            backend,
        };
        if spec.polish {
            out.polish_improvement = polish_mapping(ctx, g, m, &mut out.mapping)?;
            out.comm_cost -= out.polish_improvement;
        }
        Ok(Some(out))
    }
}

/// Human-readable payload of a caught panic.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "solver panicked".into())
}

/// The graceful-degradation ladder for `spec`: the configured solver
/// first (with every `__`-prefixed test/fault option stripped), then
/// `jet`, then the serial `intmap-f` baseline (polish disabled — the
/// cheapest rung must be as dependable as possible).
fn fallback_chain(spec: &MapSpec) -> Vec<MapSpec> {
    let mut base = spec.clone();
    base.options.retain(|k, _| !k.starts_with("__"));
    // A non-CPU job degrades to the CPU backend *before* any solver
    // swap: the first rung is the configured solver on the pool, and
    // the cheaper rungs inherit it — a device flaky enough to exhaust
    // retries must not be re-entered further down the ladder.
    base.backend = Backend::Cpu;
    let mut chain = vec![base.clone()];
    if base.algorithm != Some(Algorithm::Jet) {
        let mut jet = base.clone();
        jet.algorithm = Some(Algorithm::Jet);
        jet.refinement = Refinement::Standard;
        chain.push(jet);
    }
    if base.algorithm != Some(Algorithm::IntMapF) {
        let mut intmap = base;
        intmap.algorithm = Some(Algorithm::IntMapF);
        intmap.refinement = Refinement::Standard;
        intmap.polish = false;
        chain.push(intmap);
    }
    chain
}

/// Retries exhausted: walk the fallback chain and complete the job with
/// a degraded (but valid) mapping if any rung succeeds. Rungs run with
/// fault checks [suppressed](fault::suppress) — degradation must not be
/// re-faulted into oblivion by an always-on plane. Only when every rung
/// fails does the job turn terminal `Failed`.
fn degrade(
    shared: &EngineShared,
    ctx: &EngineCtx,
    spec: &MapSpec,
    token: &CancelToken,
    attempt: u32,
    original_error: String,
    handle: &JobHandle,
    hook: Option<&job::CompletionHook>,
) {
    let mut notes: Vec<String> = Vec::new();
    for fspec in fallback_chain(spec) {
        if token.cancel_requested() {
            handle.finish(JobState::Cancelled, None, Some("cancelled during solve".into()), hook);
            return;
        }
        if token.deadline_exceeded() {
            handle.finish(
                JobState::Expired,
                None,
                Some("deadline exceeded during solve".into()),
                hook,
            );
            return;
        }
        let label = fspec.algorithm.map_or("auto", Algorithm::name);
        shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fault::suppress(|| shared.execute(ctx, &fspec, token, None))
        }));
        shared.in_flight.fetch_sub(1, Ordering::SeqCst);
        match result {
            Ok(Ok(Some(mut out))) => {
                if token.cancel_requested() {
                    handle.finish(
                        JobState::Cancelled,
                        None,
                        Some("cancelled during solve".into()),
                        hook,
                    );
                } else if token.deadline_exceeded() {
                    handle.finish(
                        JobState::Expired,
                        None,
                        Some("deadline exceeded during solve".into()),
                        hook,
                    );
                } else {
                    out.degraded = true;
                    out.attempts = attempt;
                    // relaxed: monotone statistics counter, read approximately.
                    shared.degraded.fetch_add(1, Ordering::Relaxed);
                    handle.finish(JobState::Done, Some(out), None, hook);
                }
                return;
            }
            Ok(Ok(None)) => {
                let (state, why) = if token.cancel_requested() {
                    (JobState::Cancelled, "cancelled during solve")
                } else {
                    (JobState::Expired, "deadline exceeded during solve")
                };
                handle.finish(state, None, Some(why.into()), hook);
                return;
            }
            Ok(Err(e)) => notes.push(format!("{label}: {e:#}")),
            Err(panic) => notes.push(format!("{label}: panicked: {}", panic_message(&*panic))),
        }
    }
    handle.finish(
        JobState::Failed,
        None,
        Some(format!(
            "{original_error} (after {attempt} attempt(s); fallback chain failed: {})",
            notes.join("; ")
        )),
        hook,
    );
}

/// Retire one popped job: state checks, the (panic-fenced) solve, and —
/// on failure — the self-healing path: re-queue with backoff while the
/// [`RetryPolicy`] allows, then degrade down the fallback chain. Every
/// job still reaches exactly one terminal state exactly once.
fn run_job(shared: &EngineShared, ctx: &EngineCtx, job: queue::QueuedJob) {
    let queue::QueuedJob { priority, seq, attempt, retry, spec, handle, hook, batch } = job;
    let token = handle.token().clone();
    if token.deadline_exceeded() {
        handle.finish(
            JobState::Expired,
            None,
            Some("deadline exceeded while queued".into()),
            hook.as_ref(),
        );
        return;
    }
    if token.cancel_requested() || !handle.start_running() {
        handle.finish(JobState::Cancelled, None, Some("cancelled before start".into()), hook.as_ref());
        return;
    }
    // Per-job fault plane from `__fault.*` options, salted with the
    // attempt number (a retry draws fresh decisions). A malformed option
    // is a spec error: terminal, no retry, no fallback.
    let plane = match FaultPlane::from_options(&spec.options, attempt as u64) {
        Ok(p) => p,
        Err(e) => {
            handle.finish(JobState::Failed, None, Some(format!("{e:#}")), hook.as_ref());
            return;
        }
    };
    shared.in_flight.fetch_add(1, Ordering::SeqCst);
    let result = if fault::fire(plane.as_ref(), FaultPoint::JobPickup) {
        Ok(Err(anyhow::anyhow!(fault::failure(FaultPoint::JobPickup))))
    } else {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared.execute(ctx, &spec, &token, plane.as_ref())
        }))
    };
    shared.in_flight.fetch_sub(1, Ordering::SeqCst);
    let failure = match result {
        Ok(Ok(Some(mut out))) => {
            let (state, outcome, error) = if token.cancel_requested() {
                (JobState::Cancelled, None, Some("cancelled during solve".into()))
            } else if token.deadline_exceeded() {
                (JobState::Expired, None, Some("deadline exceeded during solve".into()))
            } else {
                out.attempts = attempt;
                (JobState::Done, Some(out), None)
            };
            handle.finish(state, outcome, error, hook.as_ref());
            return;
        }
        Ok(Ok(None)) => {
            let (state, why) = if token.cancel_requested() {
                (JobState::Cancelled, "cancelled during solve")
            } else {
                (JobState::Expired, "deadline exceeded during solve")
            };
            handle.finish(state, None, Some(why.into()), hook.as_ref());
            return;
        }
        Ok(Err(e)) => format!("{e:#}"),
        Err(panic) => format!("solver panicked: {}", panic_message(&*panic)),
    };
    if failure.contains(fault::INJECTED_MARKER) {
        // relaxed: monotone statistics counter, read approximately.
        shared.faults_injected.fetch_add(1, Ordering::Relaxed);
    }
    // Retry while the policy allows, the job is not cancelled, and the
    // remaining deadline can still cover the backoff sleep.
    let backoff = retry.backoff_for(attempt);
    let deadline_allows = !token.deadline_exceeded()
        && token.deadline_remaining().is_none_or(|left| left > backoff);
    if attempt < retry.max_attempts && !token.cancel_requested() && deadline_allows {
        if !handle.requeue_for_retry() {
            // A cancel raced the failure: the cell is already terminal.
            handle.finish(
                JobState::Cancelled,
                None,
                Some("cancelled during solve".into()),
                hook.as_ref(),
            );
            return;
        }
        // relaxed: monotone statistics counter, read approximately.
        shared.retries.fetch_add(1, Ordering::Relaxed);
        let requeued = queue::QueuedJob {
            priority,
            seq,
            attempt: attempt + 1,
            retry,
            spec,
            handle: handle.clone(),
            hook,
            batch,
        };
        let pushed = lock(&shared.queue).push_delayed(Instant::now() + backoff, requeued);
        match pushed {
            Ok(()) => {
                shared.work_cv.notify_one();
            }
            Err(back) => {
                // The queue closed (engine shutting down) between the
                // failure and the re-queue: retire the job here instead
                // of losing it.
                back.handle.finish(
                    JobState::Cancelled,
                    None,
                    Some("engine shut down".into()),
                    back.hook.as_ref(),
                );
            }
        }
        return;
    }
    degrade(shared, ctx, &spec, &token, attempt, failure, &handle, hook.as_ref());
}

/// The vertex count a queued spec would solve, *without* resolving it:
/// in-memory graphs answer directly, named ones only when already in the
/// graph store. `None` (unknown — would need generate/parse) stops a
/// batch drain rather than stall the queue on graph I/O.
fn drainable_n(shared: &EngineShared, spec: &MapSpec) -> Option<usize> {
    match &spec.graph {
        GraphSource::InMemory(g) => Some(g.n()),
        GraphSource::Named(name) => lock(&shared.graphs).get(name).map(|g| g.n()),
    }
}

fn worker_loop(shared: Arc<EngineShared>) {
    let pool =
        if shared.cfg.threads == 0 { Pool::default() } else { Pool::new(shared.cfg.threads) };
    let ctx = EngineCtx::with_runtime(pool, shared.cfg.artifacts_dir.clone());
    loop {
        let (job, group) = {
            let mut q = lock(&shared.queue);
            let job = loop {
                q.promote_ready(Instant::now());
                if let Some(j) = q.pop() {
                    shared.space_cv.notify_one();
                    break j;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // With retries parked in the delayed lane, bound the wait
                // by the earliest backoff expiry so the promotion above
                // happens on time even when no fresh submit notifies.
                q = match q.next_ready_at() {
                    Some(at) => {
                        let wait = at
                            .saturating_duration_since(Instant::now())
                            .max(Duration::from_millis(1));
                        shared
                            .work_cv
                            .wait_timeout(q, wait)
                            .unwrap_or_else(PoisonError::into_inner)
                            .0
                    }
                    None => shared.work_cv.wait(q).unwrap_or_else(PoisonError::into_inner),
                };
            };
            // Batch drain: greedily take same-batch machine-compatible
            // small jobs from the queue head into this worker pass —
            // never past a higher-priority or foreign job (only the head
            // is taken), never more than BATCH_DRAIN_MAX in total.
            let mut group = Vec::new();
            if let Some(b) = job.batch {
                while group.len() + 1 < incremental::BATCH_DRAIN_MAX
                    && q.peek().is_some_and(|next| {
                        next.batch == Some(b)
                            && incremental::compatible(&job.spec, &next.spec)
                            && drainable_n(&shared, &next.spec)
                                .is_some_and(|n| n <= incremental::BATCH_SMALL_N)
                    })
                {
                    let next = q.pop().expect("peek just matched");
                    shared.space_cv.notify_one();
                    group.push(next);
                }
            }
            (job, group)
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            // Draining on shutdown: retire without running.
            for j in std::iter::once(job).chain(group) {
                j.handle.finish(
                    JobState::Cancelled,
                    None,
                    Some("engine shut down".into()),
                    j.hook.as_ref(),
                );
            }
            continue;
        }
        run_job(&shared, &ctx, job);
        for j in group {
            run_job(&shared, &ctx, j);
        }
    }
}

/// The mapping engine. See the module docs for the job-API contract.
///
/// `Engine` is `Send + Sync`: clones of its handles may submit from many
/// threads. Dropping the engine stops the workers after their current
/// job; still-queued jobs retire as `Cancelled`.
pub struct Engine {
    shared: Arc<EngineShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        let worker_count = cfg.workers.max(1);
        let shared = Arc::new(EngineShared {
            queue: Mutex::new(queue::JobQueue::new(cfg.queue_cap)),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            next_seq: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            graphs: Mutex::new(cache::GraphStore::new(cfg.graph_cache_cap)),
            hierarchies: Mutex::new(cache::HierarchyCache::new(cfg.hierarchy_cache_cap)),
            hierarchy_hits: AtomicU64::new(0),
            hierarchy_misses: AtomicU64::new(0),
            machines: Mutex::new(Vec::new()),
            retries: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            remapper: Mutex::new(Remapper::new()),
            next_batch: AtomicU64::new(1),
            patches_applied: AtomicU64::new(0),
            warm_remaps: AtomicU64::new(0),
            cold_fallbacks: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_jobs: AtomicU64::new(0),
            graphs_replaced: AtomicU64::new(0),
            device_launches: AtomicU64::new(0),
            h2d_bytes: AtomicU64::new(0),
            d2h_bytes: AtomicU64::new(0),
            backend_fallbacks: AtomicU64::new(0),
            cfg,
        });
        let workers = (0..worker_count)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("heipa-engine-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn engine worker")
            })
            .collect();
        Engine { shared, workers }
    }

    pub fn with_defaults() -> Engine {
        Engine::new(EngineConfig::default())
    }

    /// Enqueue a job with default options (priority 0, no deadline,
    /// non-blocking). Returns immediately; `Err(Busy)` when the bounded
    /// queue is full.
    pub fn submit(&self, spec: &MapSpec) -> std::result::Result<JobHandle, SubmitError> {
        self.submit_opts(spec, SubmitOpts::default())
    }

    /// Enqueue a job with explicit [`SubmitOpts`].
    pub fn submit_opts(
        &self,
        spec: &MapSpec,
        opts: SubmitOpts,
    ) -> std::result::Result<JobHandle, SubmitError> {
        let shared = &self.shared;
        if shared.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShutDown);
        }
        // relaxed: the fetch_add itself guarantees unique ids; no other
        // data is published through these counters.
        let id = JobId(shared.next_id.fetch_add(1, Ordering::Relaxed));
        let token = match opts.deadline {
            Some(d) => CancelToken::with_deadline(d),
            None => CancelToken::new(),
        };
        let handle = JobHandle::new_queued(id, token);
        let retry = opts.retry.unwrap_or(shared.cfg.retry);
        let retry = RetryPolicy { max_attempts: retry.max_attempts.max(1), ..retry };
        let mut job = queue::QueuedJob {
            priority: opts.priority,
            // relaxed: uniqueness comes from the RMW; FIFO tie-breaking
            // only needs distinct, not globally ordered, values.
            seq: shared.next_seq.fetch_add(1, Ordering::Relaxed),
            attempt: 1,
            retry,
            spec: spec.clone(),
            handle: handle.clone(),
            hook: opts.on_complete,
            batch: None,
        };
        let mut q = lock(&shared.queue);
        loop {
            match q.push(job) {
                Ok(()) => break,
                Err(back) => {
                    // Cancelled/expired-while-queued jobs must not hold
                    // capacity against live work: evict them (and retire
                    // them — their hooks still owe a firing) before
                    // deciding the queue is actually full.
                    let purged = q.purge_terminal();
                    if !purged.is_empty() {
                        for dead in purged {
                            dead.handle.finish(
                                JobState::Cancelled,
                                None,
                                Some("cancelled before start".into()),
                                dead.hook.as_ref(),
                            );
                        }
                        job = back;
                        continue;
                    }
                    if !opts.block_when_full {
                        return Err(SubmitError::Busy { cap: q.cap() });
                    }
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return Err(SubmitError::ShutDown);
                    }
                    job = back;
                    q = shared.space_cv.wait(q).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
        drop(q);
        shared.work_cv.notify_one();
        Ok(handle)
    }

    /// Enqueue a group of specs as **one unit**: one queue lock,
    /// consecutive sequence numbers and a shared batch id, admitted
    /// all-or-nothing (`Err(Busy)` rejects the entire batch when it does
    /// not fit — no partial admission). A worker that pops a batched job
    /// greedily drains machine-compatible small jobs of the same batch
    /// from the queue head into one worker pass (see
    /// [`crate::incremental::batch`]); the returned handles behave
    /// exactly like [`Engine::submit`] handles otherwise. `opts` applies
    /// to every job in the batch (the hook fires once per job).
    pub fn submit_batch(
        &self,
        specs: &[MapSpec],
        opts: SubmitOpts,
    ) -> std::result::Result<Vec<JobHandle>, SubmitError> {
        let shared = &self.shared;
        if specs.is_empty() {
            return Ok(Vec::new());
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShutDown);
        }
        let retry = opts.retry.unwrap_or(shared.cfg.retry);
        let retry = RetryPolicy { max_attempts: retry.max_attempts.max(1), ..retry };
        // relaxed: the fetch_add itself guarantees unique batch ids.
        let batch = shared.next_batch.fetch_add(1, Ordering::Relaxed);
        let mut handles = Vec::with_capacity(specs.len());
        let mut jobs = Vec::with_capacity(specs.len());
        for spec in specs {
            // relaxed: the fetch_add itself guarantees unique ids.
            let id = JobId(shared.next_id.fetch_add(1, Ordering::Relaxed));
            let token = match opts.deadline {
                Some(d) => CancelToken::with_deadline(d),
                None => CancelToken::new(),
            };
            let handle = JobHandle::new_queued(id, token);
            handles.push(handle.clone());
            jobs.push(queue::QueuedJob {
                priority: opts.priority,
                // relaxed: uniqueness comes from the RMW; FIFO
                // tie-breaking only needs distinct values.
                seq: shared.next_seq.fetch_add(1, Ordering::Relaxed),
                attempt: 1,
                retry,
                spec: spec.clone(),
                handle,
                hook: opts.on_complete.clone(),
                batch: Some(batch),
            });
        }
        let mut q = lock(&shared.queue);
        loop {
            match q.push_all(jobs) {
                Ok(()) => break,
                Err(back) => {
                    // Same eviction dance as submit_opts: free slots held
                    // by already-terminal queued jobs before giving up.
                    let purged = q.purge_terminal();
                    if !purged.is_empty() {
                        for dead in purged {
                            dead.handle.finish(
                                JobState::Cancelled,
                                None,
                                Some("cancelled before start".into()),
                                dead.hook.as_ref(),
                            );
                        }
                        jobs = back;
                        continue;
                    }
                    // A batch larger than the queue can never be
                    // admitted atomically — typed error, even when the
                    // caller asked to block.
                    if !opts.block_when_full || back.len() > q.cap() {
                        return Err(SubmitError::Busy { cap: q.cap() });
                    }
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return Err(SubmitError::ShutDown);
                    }
                    jobs = back;
                    q = shared.space_cv.wait(q).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
        drop(q);
        shared.work_cv.notify_all();
        // relaxed: monotone statistics counters, read approximately.
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.batched_jobs.fetch_add(handles.len() as u64, Ordering::Relaxed);
        Ok(handles)
    }

    /// Map with the spec's primary seed: `submit` (blocking on queue
    /// space) + `wait`. Identical results to the pre-job API.
    pub fn map(&self, spec: &MapSpec) -> Result<MapOutcome> {
        self.submit_opts(spec, SubmitOpts { block_when_full: true, ..SubmitOpts::default() })
            .map_err(anyhow::Error::from)?
            .wait()
    }

    /// Map once per seed in the spec. All seeds are submitted up front,
    /// so with `workers > 1` they solve concurrently; results come back
    /// in seed order.
    pub fn map_all_seeds(&self, spec: &MapSpec) -> Result<Vec<MapOutcome>> {
        let handles: Vec<JobHandle> = spec
            .seeds
            .iter()
            .map(|&s| {
                self.submit_opts(
                    &spec.with_seed(s),
                    SubmitOpts { block_when_full: true, ..SubmitOpts::default() },
                )
                .map_err(anyhow::Error::from)
            })
            .collect::<Result<_>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Resolve a [`GraphSource`] through the shared store: in-memory
    /// graphs pass through; named ones hit the pinned tier, the LRU
    /// cache, the instance registry, then METIS I/O.
    pub fn resolve_graph(&self, src: &GraphSource) -> Result<Arc<CsrGraph>> {
        self.shared.resolve_graph(src)
    }

    /// Resolve the spec's machine (through the bounded machine cache).
    pub fn resolve_machine(&self, spec: &MapSpec) -> Result<Machine> {
        self.shared.resolve_machine(spec)
    }

    /// Pin a session graph: later specs naming `name` reuse this exact
    /// `Arc<CsrGraph>` across jobs, workers and connections, exempt from
    /// LRU eviction, until [`Engine::drop_graph`].
    ///
    /// Returns the session version (1 for a fresh name) and whether an
    /// existing pin was **replaced** — a put over a live name bumps the
    /// version, discards the old graph's hierarchy-cache entries and
    /// remap history, and leaves in-flight jobs completing against the
    /// `Arc` they already resolved.
    pub fn put_graph(&self, name: impl Into<String>, g: Arc<CsrGraph>) -> (u64, bool) {
        let name = name.into();
        let (version, old) = lock(&self.shared.graphs).pin(name.clone(), g);
        if let Some(old) = old {
            lock(&self.shared.hierarchies).purge_graph(&old);
            lock(&self.shared.remapper).forget(&name);
            // relaxed: monotone statistics counter, read approximately.
            self.shared.graphs_replaced.fetch_add(1, Ordering::Relaxed);
            (version, true)
        } else {
            (version, false)
        }
    }

    /// Apply a [`GraphPatch`] to the pinned session graph `name`: the
    /// patched graph becomes a **new version** of the session graph
    /// (atomically — concurrent jobs see either the old or the new
    /// `Arc`, never a half-applied patch), hierarchy-cache entries are
    /// re-keyed with only the levels the patch provably kept intact
    /// ([`incremental::level_validity_mask`]), and the remapper notes
    /// the touched region so the next map can plan a warm restart.
    pub fn patch_graph(&self, name: &str, patch: &GraphPatch) -> Result<PatchSummary, PatchError> {
        // The graphs lock is held across apply + swap so concurrent
        // patches serialize; nested lock order (graphs → hierarchies /
        // remapper) is taken nowhere in reverse.
        let mut graphs = lock(&self.shared.graphs);
        let Some((old, _)) = graphs.pinned(name) else {
            return Err(PatchError::UnknownGraph(name.to_string()));
        };
        let applied = patch.apply(&old).map_err(PatchError::Invalid)?;
        let new_g = Arc::new(applied.graph);
        let (version, old) =
            graphs.repin_patched(name, new_g.clone()).expect("pin checked above");
        lock(&self.shared.hierarchies)
            .rekey_patched(&old, &new_g, |h| incremental::level_validity_mask(h, patch));
        lock(&self.shared.remapper).note_patch(
            name,
            version,
            new_g.n(),
            &applied.touched,
            applied.vertex_ops,
        );
        drop(graphs);
        // relaxed: monotone statistics counter, read approximately.
        self.shared.patches_applied.fetch_add(1, Ordering::Relaxed);
        Ok(PatchSummary {
            n: new_g.n(),
            m: new_g.m(),
            version,
            touched: applied.touched.len(),
            ops: patch.ops.len(),
        })
    }

    /// Unpin a session graph; false when `name` was not pinned. Also
    /// purges the dropped graph's hierarchy-cache entries — they could
    /// never be hit again (identity is gone) but would otherwise pin the
    /// graph and its hierarchy in memory until LRU churn — and its remap
    /// history.
    pub fn drop_graph(&self, name: &str) -> bool {
        let removed = lock(&self.shared.graphs).unpin(name);
        if let Some(g) = &removed {
            lock(&self.shared.hierarchies).purge_graph(g);
            lock(&self.shared.remapper).forget(name);
        }
        removed.is_some()
    }

    /// Names of the pinned session graphs, sorted.
    pub fn graph_names(&self) -> Vec<String> {
        lock(&self.shared.graphs).pinned_names()
    }

    /// `(name, session version)` of every pinned graph, sorted by name.
    pub fn graph_entries(&self) -> Vec<(String, u64)> {
        lock(&self.shared.graphs).pinned_entries()
    }

    /// Number of graphs in the LRU cache tier (pinned graphs excluded).
    pub fn cached_graphs(&self) -> usize {
        lock(&self.shared.graphs).cached_len()
    }

    /// Number of multilevel hierarchies in the bounded hierarchy cache.
    pub fn cached_hierarchies(&self) -> usize {
        lock(&self.shared.hierarchies).len()
    }

    /// Jobs whose multilevel hierarchy was served from the cache
    /// (cumulative since engine start).
    pub fn hierarchy_cache_hits(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.hierarchy_hits.load(Ordering::Relaxed)
    }

    /// Jobs that had to build (and cache) their multilevel hierarchy
    /// (cumulative since engine start).
    pub fn hierarchy_cache_misses(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.hierarchy_misses.load(Ordering::Relaxed)
    }

    /// Jobs waiting in the queue.
    pub fn queue_depth(&self) -> usize {
        lock(&self.shared.queue).len()
    }

    /// Jobs currently being solved.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Capacity of the bounded job queue.
    pub fn queue_cap(&self) -> usize {
        self.shared.cfg.queue_cap.max(1)
    }

    /// Number of engine workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Failed attempts re-queued for retry (cumulative since start).
    pub fn retries(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.retries.load(Ordering::Relaxed)
    }

    /// Failures attributed to the fault plane (cumulative across
    /// attempts; an injected fault retried twice counts every firing).
    pub fn faults_injected(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.faults_injected.load(Ordering::Relaxed)
    }

    /// Jobs that completed `Done` through the degradation fallback chain
    /// (their outcomes carry `degraded = true`).
    pub fn degraded_completions(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.degraded.load(Ordering::Relaxed)
    }

    /// Patches applied to pinned session graphs (cumulative).
    pub fn patches_applied(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.patches_applied.load(Ordering::Relaxed)
    }

    /// Jobs completed through the warm-start remap path (cumulative;
    /// their outcomes carry `remap = Some(Warm)`).
    pub fn warm_remaps(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.warm_remaps.load(Ordering::Relaxed)
    }

    /// Pending remaps that fell back to a full solve (cumulative; their
    /// outcomes carry `remap = Some(Cold)`).
    pub fn cold_fallbacks(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.cold_fallbacks.load(Ordering::Relaxed)
    }

    /// Batches admitted through [`Engine::submit_batch`] (cumulative).
    pub fn batches(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.batches.load(Ordering::Relaxed)
    }

    /// Jobs admitted through [`Engine::submit_batch`] (cumulative).
    pub fn batched_jobs(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.batched_jobs.load(Ordering::Relaxed)
    }

    /// `graph put` uploads that replaced an existing pinned name
    /// (cumulative).
    pub fn graphs_replaced(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.graphs_replaced.load(Ordering::Relaxed)
    }

    /// Real PJRT kernel launches executed by jobs (cumulative).
    pub fn device_launches(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.device_launches.load(Ordering::Relaxed)
    }

    /// Host→device bytes uploaded by jobs (cumulative). Device-resident
    /// graphs charge their upload exactly once per `Arc<CsrGraph>` per
    /// worker session — repeat jobs on a pinned graph add only per-round
    /// state here.
    pub fn h2d_bytes(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.h2d_bytes.load(Ordering::Relaxed)
    }

    /// Device→host bytes downloaded by jobs (cumulative).
    pub fn d2h_bytes(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.d2h_bytes.load(Ordering::Relaxed)
    }

    /// Device→cpu fallbacks (cumulative): `backend=device` jobs that
    /// resolved to the CPU pool plus kernel-level PJRT failures that
    /// fell back mid-solve. `backend=auto` CPU resolutions do not count.
    pub fn backend_fallbacks(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.shared.backend_fallbacks.load(Ordering::Relaxed)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Seal the queue *before* waking anyone: a worker about to
        // re-queue a failed attempt observes `closed`, retires the job as
        // `Cancelled` itself, and the final drain below cannot race a
        // late retry back into a lane it has already emptied.
        lock(&self.shared.queue).close();
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Belt and braces: retire anything the workers did not drain.
        for job in lock(&self.shared.queue).drain() {
            job.handle.finish(
                JobState::Cancelled,
                None,
                Some("engine shut down".into()),
                job.hook.as_ref(),
            );
        }
    }
}

/// Largest machine the QAP polish stage will touch: the block
/// communication matrix it searches over is inherently O(k²).
pub const QAP_POLISH_K_MAX: usize = crate::topology::DENSE_K_MAX;

/// The QAP polish stage: re-map blocks to PEs with the pairwise-swap
/// search — the device-offloaded kernel when the runtime has a fitting
/// `qap_step_k*` artifact, the host kernel otherwise. Distances come
/// from the machine's [`DistanceOracle`] (dense rows for small `k`,
/// blocked row cache above), and machines past [`QAP_POLISH_K_MAX`] skip
/// the stage entirely (returning 0.0) rather than materialize O(k²).
/// Rewrites `mapping` in place and returns the `J` improvement (≥ 0).
/// Every front-end goes through this one function, so polish is
/// identical from the library, `heipa map --polish`, and the TCP
/// service.
pub fn polish_mapping(ctx: &EngineCtx, g: &CsrGraph, m: &Machine, mapping: &mut [Block]) -> Result<f64> {
    let k = m.k();
    if k > QAP_POLISH_K_MAX {
        eprintln!("polish: skipped for k={k} > {QAP_POLISH_K_MAX} (O(k²) block matrix)");
        return Ok(0.0);
    }
    let bmat = block_comm_matrix(g, mapping, k);
    let oracle = DistanceOracle::auto(m);
    let mut sigma: Vec<Block> = (0..k as Block).collect();
    let before = comm_cost_blocks(&bmat, k, &sigma, &oracle);
    let offloaded = match (ctx.runtime(), offload::qap_kernel_size(k)) {
        // Batched sweeps when the artifact set has them: sigma stays on
        // the device for up to 16 sweeps per launch.
        (Some(rt), Ok(kp)) if rt.available(&format!("qap_sweep_k{kp}")) => {
            offload::swap_refine_batched(rt, &bmat, k, m, &mut sigma, 20)?;
            true
        }
        (Some(rt), Ok(kp)) if rt.available(&format!("qap_step_k{kp}")) => {
            offload::swap_refine_offload(rt, &bmat, k, m, &mut sigma, 20)?;
            true
        }
        _ => false,
    };
    if !offloaded {
        qap::swap_refine(&bmat, k, &mut sigma, &oracle, 20);
    }
    let after = comm_cost_blocks(&bmat, k, &sigma, &oracle);
    if after < before {
        for pe in mapping.iter_mut() {
            *pe = sigma[*pe as usize];
        }
        Ok(before - after)
    } else {
        Ok(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::validate_mapping;

    fn engine() -> Engine {
        Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() })
    }

    #[test]
    fn maps_a_named_instance() {
        let e = engine();
        let spec = MapSpec::named("sten_cop20k").hierarchy("2:2:2").distance("1:10:100");
        let out = e.map(&spec).unwrap();
        assert_eq!(out.k, 8);
        assert!(out.comm_cost > 0.0);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        assert_eq!(e.cached_graphs(), 1);
    }

    #[test]
    fn maps_an_in_memory_graph_without_caching() {
        let e = engine();
        let g = Arc::new(gen::grid2d(20, 20, false));
        let out = e
            .map(&MapSpec::in_memory(g.clone()).hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm)))
            .unwrap();
        assert_eq!(out.n, g.n());
        assert_eq!(out.algorithm, Algorithm::GpuIm);
        assert_eq!(e.cached_graphs(), 0);
    }

    #[test]
    fn graph_cache_is_bounded() {
        let e = Engine::new(EngineConfig { threads: 1, graph_cache_cap: 2, ..EngineConfig::default() });
        for name in ["sten_cop20k", "wal_598a", "sten_cont300"] {
            e.map(&MapSpec::named(name).hierarchy("2:2").distance("1:10")).unwrap();
        }
        assert_eq!(e.cached_graphs(), 2);
    }

    #[test]
    fn pinned_session_graphs_resolve_by_name() {
        let e = engine();
        let g = Arc::new(gen::grid2d(16, 16, false));
        e.put_graph("session_grid", g.clone());
        let out = e
            .map(&MapSpec::named("session_grid").hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm)))
            .unwrap();
        assert_eq!(out.n, g.n());
        assert_eq!(e.graph_names(), vec!["session_grid".to_string()]);
        assert_eq!(e.cached_graphs(), 0, "pinned graphs bypass the LRU tier");
        assert!(e.drop_graph("session_grid"));
        assert!(e.map(&MapSpec::named("session_grid")).is_err(), "dropped graph no longer resolves");
    }

    #[test]
    fn hierarchy_cache_serves_repeat_jobs_on_pinned_graphs() {
        use crate::metrics::Phase;
        let e = engine();
        let g = Arc::new(gen::rgg(2_000, 0.05, 3));
        e.put_graph("sess", g.clone());
        let spec = MapSpec::named("sess")
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm))
            .seed(1);
        let first = e.map(&spec).unwrap();
        assert_eq!(first.hierarchy_cache, Some(false), "first job builds the hierarchy");
        assert_eq!((e.hierarchy_cache_misses(), e.hierarchy_cache_hits()), (1, 0));
        assert_eq!(e.cached_hierarchies(), 1);
        let p1 = first.phases.as_ref().unwrap();
        assert!(p1.device_ms(Phase::Coarsening) > 0.0);
        assert!(p1.device_ms(Phase::Contraction) > 0.0);
        // Second job — different seed, same coarsening key (the salt is
        // deliberately seed-independent) — skips Coarsening/Contraction
        // entirely via the cache.
        let second = e.map(&spec.clone().seed(2)).unwrap();
        assert_eq!(second.hierarchy_cache, Some(true));
        assert_eq!(e.hierarchy_cache_hits(), 1);
        let p2 = second.phases.as_ref().unwrap();
        assert!(p2.device_ms(Phase::Coarsening) == 0.0, "cache hit must skip coarsening");
        assert!(p2.device_ms(Phase::Contraction) == 0.0, "cache hit must skip contraction");
        // Determinism parity: a seed-1 rerun through the cache is
        // bit-identical to the cold run that populated it.
        let again = e.map(&spec).unwrap();
        assert_eq!(again.mapping, first.mapping);
        assert_eq!(again.comm_cost, first.comm_cost);
    }

    #[test]
    fn coarsening_scheme_is_part_of_the_hierarchy_key() {
        let e = engine();
        let g = Arc::new(gen::grid2d(30, 30, false));
        e.put_graph("sess", g);
        let base = MapSpec::named("sess").hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm));
        e.map(&base.clone().coarsening(crate::multilevel::SchemeKind::Matching)).unwrap();
        e.map(&base.clone().coarsening(crate::multilevel::SchemeKind::Cluster)).unwrap();
        assert_eq!(e.hierarchy_cache_misses(), 2, "different schemes must not share entries");
        assert_eq!(e.hierarchy_cache_hits(), 0);
        e.map(&base.coarsening(crate::multilevel::SchemeKind::Cluster)).unwrap();
        assert_eq!(e.hierarchy_cache_hits(), 1);
    }

    #[test]
    fn seeds_fan_out() {
        let e = engine();
        let spec = MapSpec::named("wal_598a").hierarchy("2:2").distance("1:10").seeds(vec![1, 2, 3]);
        let outs = e.map_all_seeds(&spec).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs.iter().map(|o| o.seed).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn polish_never_worsens_and_drops_mapping_on_request() {
        let e = engine();
        let base = MapSpec::named("sten_cont300").hierarchy("2:2:2").distance("1:10:100").algo(Some(Algorithm::Jet));
        let plain = e.map(&base.clone()).unwrap();
        let polished = e.map(&base.clone().polish(true)).unwrap();
        assert!(polished.comm_cost <= plain.comm_cost + 1e-6);
        assert!(polished.polish_improvement >= 0.0);
        let silent = e.map(&base.return_mapping(false)).unwrap();
        assert!(silent.mapping.is_empty());
        assert!(silent.comm_cost > 0.0);
    }

    #[test]
    fn unknown_instance_is_a_clean_error() {
        let e = engine();
        assert!(e.map(&MapSpec::named("no_such_instance")).is_err());
    }

    #[test]
    fn maps_onto_non_hierarchical_machines() {
        // topology= spec → engine → solver → metrics, end to end.
        let e = engine();
        for spec_str in ["torus:2x2x2", "fattree:2,4/1,5", "dragonfly:2:2:2", "hetero:3+5/1,10"] {
            let spec = MapSpec::named("sten_cop20k").topology_spec(spec_str);
            let out = e.map(&spec).unwrap_or_else(|err| panic!("{spec_str}: {err}"));
            assert_eq!(out.k, 8, "{spec_str}");
            assert!(out.comm_cost > 0.0, "{spec_str}");
            validate_mapping(&out.mapping, out.n, out.k).unwrap();
        }
        // Bad topology specs fail cleanly, before any solver runs.
        assert!(e.map(&MapSpec::named("sten_cop20k").topology_spec("torus:0x2")).is_err());
    }

    #[test]
    fn router_prefers_quality_for_small() {
        assert_eq!(route(10_000, None), Algorithm::GpuHmUltra);
        assert_eq!(route(1_000_000, None), Algorithm::GpuIm);
        assert_eq!(route(10, Some(Algorithm::IntMapS)), Algorithm::IntMapS);
    }

    #[test]
    fn machine_cache_does_not_serve_stale_file_tables() {
        // Same spec string, regenerated file: the cache key folds in
        // len+mtime, so the second map sees the new table (here k
        // changes, which a stale entry could not produce).
        let e = engine();
        let path = std::env::temp_dir().join(format!("heipa_engine_{}.mat", std::process::id()));
        std::fs::write(&path, "4\n0 1 10 10\n1 0 10 10\n10 10 0 1\n10 10 1 0\n").unwrap();
        let spec = MapSpec::named("sten_cop20k")
            .topology_spec(format!("file:{}", path.display()))
            .algo(Some(Algorithm::GpuIm));
        assert_eq!(e.map(&spec).unwrap().k, 4);
        // Warm cache hit: same machine again.
        assert_eq!(e.map(&spec).unwrap().k, 4);
        std::fs::write(&path, "2\n0 1\n1 0\n").unwrap();
        assert_eq!(e.map(&spec).unwrap().k, 2, "stale machine served from cache");
        std::fs::remove_file(&path).ok();
    }

    // ---- job API ---------------------------------------------------

    /// A fast in-memory spec with the cancellable sleep test hook.
    fn sleepy_spec(ms: u64) -> MapSpec {
        MapSpec::in_memory(Arc::new(gen::grid2d(8, 8, false)))
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::SharedMapF))
            .option("__sleep_ms", ms.to_string())
    }

    #[test]
    fn submit_returns_before_the_job_finishes() {
        let e = engine();
        let job = e.submit(&sleepy_spec(400)).unwrap();
        assert!(!job.is_finished(), "submit must not block on the solve");
        assert!(matches!(job.status().state, JobState::Queued | JobState::Running));
        let out = job.wait().unwrap();
        assert!(out.comm_cost > 0.0);
        assert_eq!(job.status().state, JobState::Done);
    }

    #[test]
    fn queue_rejects_when_full_and_busy_error_is_typed() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, queue_cap: 1, ..Default::default() });
        // Worker busy with the first job, queue holds the second.
        let a = e.submit(&sleepy_spec(500)).unwrap();
        // Give the worker a moment to pick up `a` so `b` occupies the queue.
        while e.queue_depth() > 0 && !a.is_finished() {
            std::thread::sleep(Duration::from_millis(2));
        }
        let b = e.submit(&sleepy_spec(500)).unwrap();
        let c = e.submit(&sleepy_spec(0));
        assert_eq!(c.unwrap_err(), SubmitError::Busy { cap: 1 });
        a.cancel();
        b.cancel();
        let _ = a.wait_timeout(Duration::from_secs(10));
        let _ = b.wait_timeout(Duration::from_secs(10));
    }

    #[test]
    fn two_workers_overlap_jobs() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 2, ..Default::default() });
        let t0 = Instant::now();
        let a = e.submit(&sleepy_spec(500)).unwrap();
        let b = e.submit(&sleepy_spec(500)).unwrap();
        a.wait().unwrap();
        b.wait().unwrap();
        let elapsed = t0.elapsed();
        // Serial execution would need ≥ 1000ms of sleep alone.
        assert!(
            elapsed < Duration::from_millis(900),
            "two 500ms jobs took {elapsed:?} on two workers — no overlap"
        );
    }

    #[test]
    fn cancel_stops_an_in_flight_job_quickly() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let job = e.submit(&sleepy_spec(60_000)).unwrap();
        // Let it start.
        while job.status().state == JobState::Queued {
            std::thread::sleep(Duration::from_millis(2));
        }
        let t0 = Instant::now();
        job.cancel();
        let err = job.wait().unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5), "cancel took {:?}", t0.elapsed());
        assert!(err.contains("cancelled"), "{err}");
        assert_eq!(job.status().state, JobState::Cancelled);
        // The worker survives and serves the next job.
        assert!(e.map(&sleepy_spec(0)).is_ok());
    }

    #[test]
    fn deadline_expires_queued_and_running_work() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        // Occupy the single worker…
        let blocker = e.submit(&sleepy_spec(400)).unwrap();
        // …so this one's 50ms deadline passes while it waits in the queue.
        let late = e
            .submit_opts(
                &sleepy_spec(0),
                SubmitOpts { deadline: Some(Duration::from_millis(50)), ..Default::default() },
            )
            .unwrap();
        let err = late.wait().unwrap_err().to_string();
        assert!(err.contains("deadline"), "{err}");
        assert_eq!(late.status().state, JobState::Expired);
        blocker.wait().unwrap();
        // A running job also aborts once its deadline trips mid-solve.
        let slow = e
            .submit_opts(
                &sleepy_spec(60_000),
                SubmitOpts { deadline: Some(Duration::from_millis(80)), ..Default::default() },
            )
            .unwrap();
        let t0 = Instant::now();
        let err = slow.wait().unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(err.contains("deadline"), "{err}");
        assert_eq!(slow.status().state, JobState::Expired);
    }

    #[test]
    fn priorities_run_before_fifo_backlog() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        // Worker busy; then a low- and a high-priority job queue up, in
        // that (FIFO-losing) order. The single worker must pick the
        // high-priority job first — observable because the low one
        // sleeps 500ms: when `high` completes, `low` cannot be done yet.
        let blocker = e.submit(&sleepy_spec(300)).unwrap();
        while e.queue_depth() > 0 && !blocker.is_finished() {
            std::thread::sleep(Duration::from_millis(2));
        }
        let low = e.submit(&sleepy_spec(500)).unwrap();
        let high = e
            .submit_opts(&sleepy_spec(0), SubmitOpts { priority: 10, ..Default::default() })
            .unwrap();
        let high_out = high.wait().unwrap();
        assert!(high_out.comm_cost > 0.0);
        assert!(
            !low.is_finished(),
            "low-priority job finished before the high-priority one — priority inverted"
        );
        assert!(low.wait().unwrap().comm_cost > 0.0);
        blocker.wait().unwrap();
    }

    #[test]
    fn cancelled_queued_jobs_free_their_queue_slots() {
        // A cancelled (or deadline-expired) job sitting in the queue must
        // not hold capacity against live submits while the worker is busy.
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, queue_cap: 1, ..Default::default() });
        let blocker = e.submit(&sleepy_spec(2_000)).unwrap();
        while e.queue_depth() > 0 && !blocker.is_finished() {
            std::thread::sleep(Duration::from_millis(2));
        }
        let zombie = e.submit(&sleepy_spec(0)).unwrap();
        // Queue is now full: a live submit is rejected…
        assert!(matches!(e.submit(&sleepy_spec(0)), Err(SubmitError::Busy { .. })));
        // …but cancelling the queued job frees its slot immediately.
        zombie.cancel();
        let fresh = e.submit(&sleepy_spec(0)).expect("cancelled zombie must free its slot");
        assert!(zombie.wait().is_err());
        assert!(fresh.wait().is_ok());
        blocker.wait().unwrap();
    }

    #[test]
    fn device_backend_falls_back_to_cpu_without_artifacts() {
        // Deterministic in every environment: the artifact dir is bogus,
        // so the device session can never offer the graph kernels.
        let e = Engine::new(EngineConfig {
            threads: 1,
            artifacts_dir: "definitely_missing_artifacts".into(),
            ..EngineConfig::default()
        });
        let base = MapSpec::in_memory(Arc::new(gen::grid2d(12, 12, false)))
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm));
        let out = e.map(&base.clone().backend(Backend::Device)).unwrap();
        assert_eq!(out.backend, Backend::Cpu, "missing artifacts must fall back");
        assert!(!out.degraded, "a backend fallback is not degradation");
        assert_eq!(e.backend_fallbacks(), 1);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        // `auto` resolves to cpu silently — no fallback counted.
        let out = e.map(&base.clone().backend(Backend::Auto)).unwrap();
        assert_eq!(out.backend, Backend::Cpu);
        assert_eq!(e.backend_fallbacks(), 1, "auto must not count fallbacks");
        // Plain cpu jobs never touch the device path at all.
        let out = e.map(&base).unwrap();
        assert_eq!(out.backend, Backend::Cpu);
        assert_eq!(e.device_launches(), 0);
        assert_eq!((e.h2d_bytes(), e.d2h_bytes()), (0, 0));
    }

    #[test]
    fn fallback_chain_forces_cpu_backend_first() {
        let spec = MapSpec::named("x").algo(Some(Algorithm::GpuIm)).backend(Backend::Device);
        let chain = fallback_chain(&spec);
        assert_eq!(chain.len(), 3);
        assert!(chain.iter().all(|s| s.backend == Backend::Cpu));
        // First rung keeps the configured solver — only the backend drops.
        assert_eq!(chain[0].algorithm, Some(Algorithm::GpuIm));
    }

    #[test]
    fn injected_solver_fault_degrades_to_a_valid_mapping() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        // The solve fires (panics) on every attempt; with the default
        // single-shot policy the job must complete through the fallback
        // chain instead of failing.
        let bad = sleepy_spec(0).option("__fault.solve", "1").option("__fault.seed", "7");
        let out = e.map(&bad).unwrap();
        assert!(out.degraded, "all-attempts fault must degrade, not fail");
        assert_eq!(out.attempts, 1);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        assert_eq!(e.faults_injected(), 1);
        assert_eq!(e.degraded_completions(), 1);
        assert_eq!(e.retries(), 0);
        // Same worker keeps serving — and organically.
        let ok = e.map(&sleepy_spec(0)).unwrap();
        assert!(!ok.degraded);
        assert_eq!(ok.attempts, 1);
    }

    #[test]
    fn malformed_fault_option_is_a_terminal_spec_error() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let err = e.map(&sleepy_spec(0).option("__fault.bogus", "0.5")).unwrap_err().to_string();
        assert!(err.contains("unknown fault point"), "{err}");
        assert_eq!(e.degraded_completions(), 0, "spec errors must not degrade");
    }

    /// A `__fault.seed` whose solve arm fires on attempt 1's stream but
    /// not on attempt 2's — the deterministic "flaky once" job.
    fn flaky_once_seed(prob: &str) -> u64 {
        use std::collections::BTreeMap;
        (0..10_000u64)
            .find(|seed| {
                let mut opts = BTreeMap::new();
                opts.insert("__fault.solve".to_string(), prob.to_string());
                opts.insert("__fault.seed".to_string(), seed.to_string());
                let fires = |salt: u64| {
                    FaultPlane::from_options(&opts, salt)
                        .unwrap()
                        .unwrap()
                        .should_fire(FaultPoint::Solve)
                };
                fires(1) && !fires(2)
            })
            .expect("a flaky-once seed exists in 0..10000")
    }

    #[test]
    fn retry_recovers_a_flaky_job_without_degradation() {
        let seed = flaky_once_seed("0.5");
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let spec = sleepy_spec(0)
            .option("__fault.solve", "0.5")
            .option("__fault.seed", seed.to_string());
        let job = e
            .submit_opts(
                &spec,
                SubmitOpts {
                    retry: Some(RetryPolicy {
                        max_attempts: 2,
                        base_backoff: Duration::from_millis(1),
                    }),
                    ..Default::default()
                },
            )
            .unwrap();
        let out = job.wait().unwrap();
        assert!(!out.degraded, "the second attempt must succeed organically");
        assert_eq!(out.attempts, 2);
        assert_eq!(job.status().attempts, 2);
        assert_eq!(e.retries(), 1);
        assert_eq!(e.faults_injected(), 1);
        assert_eq!(e.degraded_completions(), 0);
    }

    #[test]
    fn exhausted_retries_fall_back_to_degradation() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let spec = sleepy_spec(0).option("__fault.solve", "1");
        let job = e
            .submit_opts(
                &spec,
                SubmitOpts {
                    retry: Some(RetryPolicy {
                        max_attempts: 3,
                        base_backoff: Duration::from_millis(1),
                    }),
                    ..Default::default()
                },
            )
            .unwrap();
        let out = job.wait().unwrap();
        assert!(out.degraded);
        assert_eq!(out.attempts, 3);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        assert_eq!(e.retries(), 2);
        assert_eq!(e.faults_injected(), 3, "every attempt's fault counts");
        assert_eq!(e.degraded_completions(), 1);
    }

    #[test]
    fn engine_default_retry_policy_applies_to_plain_submits() {
        let e = Engine::new(EngineConfig {
            threads: 1,
            workers: 1,
            retry: RetryPolicy { max_attempts: 2, base_backoff: Duration::from_millis(1) },
            ..Default::default()
        });
        let out = e.map(&sleepy_spec(0).option("__fault.solve", "1")).unwrap();
        assert!(out.degraded);
        assert_eq!(out.attempts, 2, "engine-level policy must apply");
        assert_eq!(e.retries(), 1);
    }

    #[test]
    fn dropping_the_engine_cancels_pending_retries() {
        // Regression: a retry parked in the delayed lane (long backoff)
        // when the engine drops must retire as `Cancelled`, not linger
        // queued forever or be re-queued after the final drain.
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let spec = sleepy_spec(0).option("__fault.solve", "1");
        let job = e
            .submit_opts(
                &spec,
                SubmitOpts {
                    retry: Some(RetryPolicy {
                        max_attempts: 10,
                        base_backoff: Duration::from_secs(60),
                    }),
                    ..Default::default()
                },
            )
            .unwrap();
        // Wait until the first attempt failed and the retry is parked.
        let t0 = Instant::now();
        while e.retries() == 0 && t0.elapsed() < Duration::from_secs(30) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(e.retries(), 1, "first attempt should have re-queued");
        drop(e);
        let st = job.status();
        assert_eq!(st.state, JobState::Cancelled, "pending retry must not outlive the engine");
        assert!(st.error.unwrap().contains("shut down"));
    }

    // ---- incremental remapping & batching --------------------------

    #[test]
    fn patch_bumps_version_and_put_replaces() {
        let e = engine();
        let g = Arc::new(gen::grid2d(10, 10, false));
        assert_eq!(e.put_graph("sess", g.clone()), (1, false));
        let p = GraphPatch::parse("ae:0:99:1.0").unwrap();
        let s = e.patch_graph("sess", &p).unwrap();
        assert_eq!((s.version, s.ops, s.touched), (2, 1, 2));
        assert_eq!(s.m, g.m() + 1);
        assert_eq!(e.graph_entries(), vec![("sess".to_string(), 2)]);
        assert_eq!(e.patches_applied(), 1);
        // Replacing via `graph put` bumps the version again and counts.
        assert_eq!(e.put_graph("sess", g.clone()), (3, true));
        assert_eq!(e.graphs_replaced(), 1);
        // Unknown graphs and invalid patches are typed errors.
        assert!(matches!(e.patch_graph("nope", &p), Err(PatchError::UnknownGraph(_))));
        let bad = GraphPatch::parse("re:0:99").unwrap();
        assert!(matches!(e.patch_graph("sess", &bad), Err(PatchError::Invalid(_))));
        assert_eq!(e.patches_applied(), 1, "failed patches must not count");
    }

    #[test]
    fn patch_then_map_warm_remaps_with_exact_objective() {
        let e = engine();
        let g = Arc::new(gen::rgg(2_000, 0.05, 3));
        e.put_graph("sess", g.clone());
        let spec = MapSpec::named("sess")
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm))
            .seed(1);
        let first = e.map(&spec).unwrap();
        assert_eq!(first.remap, None, "no patch pending on the first map");
        // Edge-only patch between two provably non-adjacent endpoints.
        let u = 0u32;
        let v = (1..g.n() as u32).rev().find(|&v| g.find_edge(u, v).is_none()).unwrap();
        let p = GraphPatch::parse(&format!("ae:{u}:{v}:1.0")).unwrap();
        e.patch_graph("sess", &p).unwrap();
        let warm = e.map(&spec).unwrap();
        assert_eq!(warm.remap, Some(RemapKind::Warm));
        assert_eq!((e.warm_remaps(), e.cold_fallbacks()), (1, 0));
        validate_mapping(&warm.mapping, warm.n, warm.k).unwrap();
        // Exactness oracle: the reported J matches a from-scratch
        // recompute on the patched graph.
        let m = e.resolve_machine(&spec).unwrap();
        let patched = e.resolve_graph(&spec.graph).unwrap();
        let j = crate::partition::comm_cost(&patched, &warm.mapping, &m);
        assert!(
            (warm.comm_cost - j).abs() <= 1e-6 * j.max(1.0),
            "warm J {} vs oracle {j}",
            warm.comm_cost
        );
        // The warm result was recorded: no pending patch, plain solve.
        let again = e.map(&spec).unwrap();
        assert_eq!(again.remap, None);
        assert_eq!(e.warm_remaps(), 1);
    }

    #[test]
    fn vertex_patch_falls_back_cold() {
        let e = engine();
        let g = Arc::new(gen::grid2d(16, 16, false));
        e.put_graph("sess", g);
        let spec = MapSpec::named("sess")
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm))
            .seed(1);
        e.map(&spec).unwrap();
        // `av` + `rv` of the same fresh vertex: structurally a no-op, but
        // a vertex op poisons the stored mapping — forced cold.
        let p = GraphPatch::parse("av:1,rv:256").unwrap();
        e.patch_graph("sess", &p).unwrap();
        let out = e.map(&spec).unwrap();
        assert_eq!(out.remap, Some(RemapKind::Cold));
        assert_eq!((e.warm_remaps(), e.cold_fallbacks()), (0, 1));
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        // Cold completion re-recorded the mapping: pending cleared.
        assert_eq!(e.map(&spec).unwrap().remap, None);
    }

    #[test]
    fn region_threshold_option_forces_cold() {
        let e = engine();
        let g = Arc::new(gen::grid2d(12, 12, false));
        e.put_graph("sess", g);
        let spec = MapSpec::named("sess")
            .hierarchy("2:2")
            .distance("1:10")
            .algo(Some(Algorithm::GpuIm))
            .seed(1);
        e.map(&spec).unwrap();
        let p = GraphPatch::parse("ae:0:143:1.0").unwrap();
        e.patch_graph("sess", &p).unwrap();
        // Any non-empty region exceeds a zero threshold.
        let strict = spec.clone().option("remap.max_region_frac", "0");
        assert_eq!(e.map(&strict).unwrap().remap, Some(RemapKind::Cold));
        assert_eq!(e.cold_fallbacks(), 1);
    }

    #[test]
    fn batch_submit_runs_all_jobs_and_counts() {
        let e = Engine::new(EngineConfig { threads: 1, workers: 1, ..Default::default() });
        let g = Arc::new(gen::grid2d(12, 12, false));
        let base =
            MapSpec::in_memory(g).hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm));
        let specs: Vec<MapSpec> = (1..=4).map(|s| base.clone().seed(s)).collect();
        let handles = e.submit_batch(&specs, SubmitOpts::default()).unwrap();
        assert_eq!(handles.len(), 4);
        let outs: Vec<MapOutcome> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        assert_eq!(outs.iter().map(|o| o.seed).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert_eq!((e.batches(), e.batched_jobs()), (1, 4));
        // An empty batch is a no-op.
        assert!(e.submit_batch(&[], SubmitOpts::default()).unwrap().is_empty());
        assert_eq!(e.batches(), 1);
    }

    #[test]
    fn batch_larger_than_the_queue_is_refused_whole() {
        let e = Engine::new(EngineConfig {
            threads: 1,
            workers: 1,
            queue_cap: 2,
            ..Default::default()
        });
        let specs: Vec<MapSpec> = (0..5).map(|s| sleepy_spec(0).seed(s)).collect();
        let err = e
            .submit_batch(&specs, SubmitOpts { block_when_full: true, ..Default::default() })
            .unwrap_err();
        assert_eq!(err, SubmitError::Busy { cap: 2 });
        assert_eq!((e.batches(), e.batched_jobs()), (0, 0));
        // A fitting batch still goes through afterwards.
        let ok = e.submit_batch(&specs[..2], SubmitOpts::default()).unwrap();
        for h in ok {
            h.wait().unwrap();
        }
        assert_eq!((e.batches(), e.batched_jobs()), (1, 2));
    }
}
