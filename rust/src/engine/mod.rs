//! The crate's single front door: one spec, one solver trait, one context.
//!
//! Every caller — the `heipa` CLI, the TCP coordinator, the benchmark
//! harness and library users — builds a [`MapSpec`] and hands it to an
//! [`Engine`]. The engine resolves the graph (through a bounded LRU
//! cache), parses the hierarchy, routes to a [`Solver`] from the
//! name-indexed [`registry`], and optionally runs the QAP polish stage
//! with the device-offloaded kernel when PJRT artifacts are available.
//! The result is always a [`MapOutcome`].
//!
//! ```no_run
//! use heipa::engine::{Engine, MapSpec};
//!
//! let engine = Engine::with_defaults();
//! let outcome = engine.map(&MapSpec::named("rgg15").hierarchy("4:8:2").polish(true))?;
//! println!("J = {:.0} on {} PEs", outcome.comm_cost, outcome.k);
//! # anyhow::Ok(())
//! ```

pub mod cache;
pub mod registry;
pub mod spec;

pub use registry::{solver, solver_by_name, solver_names, solvers};
pub use spec::{GraphSource, MapSpec, Refinement};

use crate::algo::{qap, Algorithm};
use crate::graph::{gen, io, CsrGraph};
use crate::metrics::PhaseBreakdown;
use crate::par::Pool;
use crate::partition::{block_comm_matrix, comm_cost_blocks};
use crate::runtime::{offload, Runtime};
use crate::topology::{DistanceOracle, Machine};
use crate::Block;
use anyhow::{Context, Result};
use std::cell::{OnceCell, RefCell};
use std::path::Path;
use std::sync::Arc;

/// Unified result of one mapping run — replaces the old
/// `MappingResult`/`MapResponse` split.
#[derive(Clone, Debug)]
pub struct MapOutcome {
    /// The solver that actually ran (after routing + refinement upgrade).
    pub algorithm: Algorithm,
    pub n: usize,
    pub k: usize,
    /// The seed this outcome was solved with.
    pub seed: u64,
    /// Vertex → PE assignment. Empty when the spec set
    /// `return_mapping = false`.
    pub mapping: Vec<Block>,
    /// Communication cost `J(C, D, Π)` (after polish, if enabled).
    pub comm_cost: f64,
    /// Achieved imbalance.
    pub imbalance: f64,
    /// Host wall time (ms).
    pub host_ms: f64,
    /// Modeled device time (ms); equals `host_ms` for CPU-only solvers.
    pub device_ms: f64,
    /// Per-phase breakdown (device solvers only).
    pub phases: Option<PhaseBreakdown>,
    /// `J` improvement from the polish stage (0 when disabled).
    pub polish_improvement: f64,
}

/// One solver in the registry. `solve` runs the algorithm end to end and
/// measures it; routing, graph resolution and polish belong to the
/// [`Engine`], not the solver.
pub trait Solver: Sync {
    fn algorithm(&self) -> Algorithm;

    fn name(&self) -> &'static str {
        self.algorithm().name()
    }

    fn solve(&self, ctx: &EngineCtx, g: &CsrGraph, m: &Machine, spec: &MapSpec) -> MapOutcome;
}

/// Router policy for specs that did not pin an algorithm: small graphs get
/// the quality flavor, large ones the throughput flavor (threshold = the
/// suite's size-class boundary).
pub fn route(n: usize, pinned: Option<Algorithm>) -> Algorithm {
    if let Some(a) = pinned {
        return a;
    }
    if n <= 60_000 {
        Algorithm::GpuHmUltra
    } else {
        Algorithm::GpuIm
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Device worker threads (0 = auto).
    pub threads: usize,
    /// Artifact directory for the PJRT offload kernels. The engine still
    /// maps (host polish only) when the runtime cannot come up.
    pub artifacts_dir: String,
    /// Graph cache entry cap (LRU).
    pub graph_cache_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: 0, artifacts_dir: "artifacts".into(), graph_cache_cap: 64 }
    }
}

/// Shared execution state: the worker [`Pool`], the PJRT [`Runtime`] and
/// the graph cache, owned once per engine. Not `Sync` (the runtime holds a
/// single PJRT client); long-lived services keep the engine on one worker
/// thread, matching the paper's one-client-per-device model.
///
/// The pool's workers are persistent (spawned once, parked between
/// kernels), so an engine that serves many requests pays thread spawn cost
/// exactly once for the process lifetime — every solver run reuses the
/// same warm workers.
pub struct EngineCtx {
    pool: Pool,
    artifacts_dir: String,
    /// Lazily-initialized PJRT client: front-ends that never polish (or
    /// offload) must not pay XLA client startup.
    runtime: OnceCell<Option<Runtime>>,
    cache: RefCell<cache::GraphCache>,
    /// Parsed machines keyed by `topology=` spec string (bounded FIFO):
    /// `file:PATH` models re-read and re-validate an O(k²) table on every
    /// parse, which a long-lived `serve` worker must not pay per request.
    machines: RefCell<Vec<(String, Machine)>>,
}

/// Entry cap of the per-engine machine cache.
const MACHINE_CACHE_CAP: usize = 16;

/// Cache key for a `topology=` spec: `file:` specs fold in the file's
/// length and mtime so an edited distance table invalidates the entry
/// (an unreadable file keys on the bare spec and fails in the parser).
fn machine_cache_key(topology: &str) -> String {
    if let Some(path) = topology.trim().strip_prefix("file:") {
        if let Ok(md) = std::fs::metadata(path) {
            let mtime = md
                .modified()
                .ok()
                .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                .map(|d| d.as_nanos())
                .unwrap_or(0);
            return format!("{topology}@{}:{mtime}", md.len());
        }
    }
    topology.to_string()
}

impl EngineCtx {
    /// Context without a device runtime or meaningful cache — for shims and
    /// tests that drive a solver directly.
    pub fn host_only(pool: Pool) -> Self {
        EngineCtx {
            pool,
            artifacts_dir: String::new(),
            runtime: OnceCell::from(None),
            cache: RefCell::new(cache::GraphCache::new(1)),
            machines: RefCell::new(Vec::new()),
        }
    }

    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The PJRT runtime, brought up on first use; `None` when the client
    /// cannot start (the engine still maps, host polish only).
    pub fn runtime(&self) -> Option<&Runtime> {
        self.runtime.get_or_init(|| Runtime::new(&self.artifacts_dir).ok()).as_ref()
    }

    /// Number of graphs currently cached.
    pub fn cached_graphs(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// The mapping engine. See the module docs for the one-spec/one-context
/// contract.
pub struct Engine {
    ctx: EngineCtx,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        let pool = if cfg.threads == 0 { Pool::default() } else { Pool::new(cfg.threads) };
        Engine {
            ctx: EngineCtx {
                pool,
                artifacts_dir: cfg.artifacts_dir,
                runtime: OnceCell::new(),
                cache: RefCell::new(cache::GraphCache::new(cfg.graph_cache_cap)),
                machines: RefCell::new(Vec::new()),
            },
        }
    }

    pub fn with_defaults() -> Engine {
        Engine::new(EngineConfig::default())
    }

    pub fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    /// Resolve a [`GraphSource`]: in-memory graphs pass through; named ones
    /// hit the LRU cache, then the instance registry, then METIS I/O.
    pub fn resolve_graph(&self, src: &GraphSource) -> Result<Arc<CsrGraph>> {
        match src {
            GraphSource::InMemory(g) => Ok(g.clone()),
            GraphSource::Named(name) => {
                if let Some(g) = self.ctx.cache.borrow_mut().get(name) {
                    return Ok(g);
                }
                let g = if gen::instance_by_name(name).is_some() {
                    gen::generate_by_name(name)
                } else {
                    io::read_metis(Path::new(name)).with_context(|| {
                        format!("instance `{name}` is neither a registry name nor a readable METIS file")
                    })?
                };
                let g = Arc::new(g);
                self.ctx.cache.borrow_mut().insert(name.clone(), g.clone());
                Ok(g)
            }
        }
    }

    /// Resolve the spec's machine: the machine carried by the spec when
    /// present, otherwise parse — through the bounded per-engine cache
    /// for `topology=` strings (so `file:PATH` tables are read once, not
    /// per request). `file:` entries key on the file's length + mtime, so
    /// a regenerated table is picked up instead of served stale.
    pub fn resolve_machine(&self, spec: &MapSpec) -> Result<Machine> {
        if let Some(m) = spec.cached_machine() {
            return Ok(m.clone());
        }
        let Some(topology) = &spec.topology else {
            return spec.machine(); // plain hierarchy strings parse in O(ℓ)
        };
        let key = machine_cache_key(topology);
        if let Some((_, m)) = self.ctx.machines.borrow().iter().find(|(k, _)| *k == key) {
            return Ok(m.clone());
        }
        let m = spec.machine()?;
        let mut cache = self.ctx.machines.borrow_mut();
        cache.push((key, m.clone()));
        if cache.len() > MACHINE_CACHE_CAP {
            cache.remove(0);
        }
        Ok(m)
    }

    /// Map with the spec's primary seed.
    pub fn map(&self, spec: &MapSpec) -> Result<MapOutcome> {
        let g = self.resolve_graph(&spec.graph)?;
        let m = self.resolve_machine(spec)?;
        let algo = spec.resolve_algorithm(g.n());
        let mut out = registry::solver(algo).solve(&self.ctx, &g, &m, spec);
        if spec.polish {
            out.polish_improvement = polish_mapping(&self.ctx, &g, &m, &mut out.mapping)?;
            out.comm_cost -= out.polish_improvement;
        }
        if !spec.return_mapping {
            out.mapping = Vec::new();
        }
        Ok(out)
    }

    /// Map once per seed in the spec, in order.
    pub fn map_all_seeds(&self, spec: &MapSpec) -> Result<Vec<MapOutcome>> {
        spec.seeds.iter().map(|&s| self.map(&spec.with_seed(s))).collect()
    }
}

/// Largest machine the QAP polish stage will touch: the block
/// communication matrix it searches over is inherently O(k²).
pub const QAP_POLISH_K_MAX: usize = crate::topology::DENSE_K_MAX;

/// The QAP polish stage: re-map blocks to PEs with the pairwise-swap
/// search — the device-offloaded kernel when the runtime has a fitting
/// `qap_step_k*` artifact, the host kernel otherwise. Distances come
/// from the machine's [`DistanceOracle`] (dense rows for small `k`,
/// blocked row cache above), and machines past [`QAP_POLISH_K_MAX`] skip
/// the stage entirely (returning 0.0) rather than materialize O(k²).
/// Rewrites `mapping` in place and returns the `J` improvement (≥ 0).
/// Every front-end goes through this one function, so polish is
/// identical from the library, `heipa map --polish`, and the TCP
/// service.
pub fn polish_mapping(ctx: &EngineCtx, g: &CsrGraph, m: &Machine, mapping: &mut [Block]) -> Result<f64> {
    let k = m.k();
    if k > QAP_POLISH_K_MAX {
        eprintln!("polish: skipped for k={k} > {QAP_POLISH_K_MAX} (O(k²) block matrix)");
        return Ok(0.0);
    }
    let bmat = block_comm_matrix(g, mapping, k);
    let oracle = DistanceOracle::auto(m);
    let mut sigma: Vec<Block> = (0..k as Block).collect();
    let before = comm_cost_blocks(&bmat, k, &sigma, &oracle);
    let offloaded = match (ctx.runtime(), offload::qap_kernel_size(k)) {
        (Some(rt), Ok(kp)) if rt.available(&format!("qap_step_k{kp}")) => {
            offload::swap_refine_offload(rt, &bmat, k, m, &mut sigma, 20)?;
            true
        }
        _ => false,
    };
    if !offloaded {
        qap::swap_refine(&bmat, k, &mut sigma, &oracle, 20);
    }
    let after = comm_cost_blocks(&bmat, k, &sigma, &oracle);
    if after < before {
        for pe in mapping.iter_mut() {
            *pe = sigma[*pe as usize];
        }
        Ok(before - after)
    } else {
        Ok(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::validate_mapping;

    fn engine() -> Engine {
        Engine::new(EngineConfig { threads: 1, ..EngineConfig::default() })
    }

    #[test]
    fn maps_a_named_instance() {
        let e = engine();
        let spec = MapSpec::named("sten_cop20k").hierarchy("2:2:2").distance("1:10:100");
        let out = e.map(&spec).unwrap();
        assert_eq!(out.k, 8);
        assert!(out.comm_cost > 0.0);
        validate_mapping(&out.mapping, out.n, out.k).unwrap();
        assert_eq!(e.ctx().cached_graphs(), 1);
    }

    #[test]
    fn maps_an_in_memory_graph_without_caching() {
        let e = engine();
        let g = Arc::new(gen::grid2d(20, 20, false));
        let out = e
            .map(&MapSpec::in_memory(g.clone()).hierarchy("2:2").distance("1:10").algo(Some(Algorithm::GpuIm)))
            .unwrap();
        assert_eq!(out.n, g.n());
        assert_eq!(out.algorithm, Algorithm::GpuIm);
        assert_eq!(e.ctx().cached_graphs(), 0);
    }

    #[test]
    fn graph_cache_is_bounded() {
        let e = Engine::new(EngineConfig { threads: 1, graph_cache_cap: 2, ..EngineConfig::default() });
        for name in ["sten_cop20k", "wal_598a", "sten_cont300"] {
            e.map(&MapSpec::named(name).hierarchy("2:2").distance("1:10")).unwrap();
        }
        assert_eq!(e.ctx().cached_graphs(), 2);
    }

    #[test]
    fn seeds_fan_out() {
        let e = engine();
        let spec = MapSpec::named("wal_598a").hierarchy("2:2").distance("1:10").seeds(vec![1, 2, 3]);
        let outs = e.map_all_seeds(&spec).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs.iter().map(|o| o.seed).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn polish_never_worsens_and_drops_mapping_on_request() {
        let e = engine();
        let base = MapSpec::named("sten_cont300").hierarchy("2:2:2").distance("1:10:100").algo(Some(Algorithm::Jet));
        let plain = e.map(&base.clone()).unwrap();
        let polished = e.map(&base.clone().polish(true)).unwrap();
        assert!(polished.comm_cost <= plain.comm_cost + 1e-6);
        assert!(polished.polish_improvement >= 0.0);
        let silent = e.map(&base.return_mapping(false)).unwrap();
        assert!(silent.mapping.is_empty());
        assert!(silent.comm_cost > 0.0);
    }

    #[test]
    fn unknown_instance_is_a_clean_error() {
        let e = engine();
        assert!(e.map(&MapSpec::named("no_such_instance")).is_err());
    }

    #[test]
    fn maps_onto_non_hierarchical_machines() {
        // topology= spec → engine → solver → metrics, end to end.
        let e = engine();
        for spec_str in ["torus:2x2x2", "fattree:2,4/1,5", "dragonfly:2:2:2", "hetero:3+5/1,10"] {
            let spec = MapSpec::named("sten_cop20k").topology_spec(spec_str);
            let out = e.map(&spec).unwrap_or_else(|err| panic!("{spec_str}: {err}"));
            assert_eq!(out.k, 8, "{spec_str}");
            assert!(out.comm_cost > 0.0, "{spec_str}");
            validate_mapping(&out.mapping, out.n, out.k).unwrap();
        }
        // Bad topology specs fail cleanly, before any solver runs.
        assert!(e.map(&MapSpec::named("sten_cop20k").topology_spec("torus:0x2")).is_err());
    }

    #[test]
    fn router_prefers_quality_for_small() {
        assert_eq!(route(10_000, None), Algorithm::GpuHmUltra);
        assert_eq!(route(1_000_000, None), Algorithm::GpuIm);
        assert_eq!(route(10, Some(Algorithm::IntMapS)), Algorithm::IntMapS);
    }

    #[test]
    fn machine_cache_does_not_serve_stale_file_tables() {
        // Same spec string, regenerated file: the cache key folds in
        // len+mtime, so the second map sees the new table (here k
        // changes, which a stale entry could not produce).
        let e = engine();
        let path = std::env::temp_dir().join(format!("heipa_engine_{}.mat", std::process::id()));
        std::fs::write(&path, "4\n0 1 10 10\n1 0 10 10\n10 10 0 1\n10 10 1 0\n").unwrap();
        let spec = MapSpec::named("sten_cop20k")
            .topology_spec(format!("file:{}", path.display()))
            .algo(Some(Algorithm::GpuIm));
        assert_eq!(e.map(&spec).unwrap().k, 4);
        // Warm cache hit: same machine again.
        assert_eq!(e.map(&spec).unwrap().k, 4);
        std::fs::write(&path, "2\n0 1\n1 0\n").unwrap();
        assert_eq!(e.map(&spec).unwrap().k, 2, "stale machine served from cache");
        std::fs::remove_file(&path).ok();
    }
}
