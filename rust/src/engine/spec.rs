//! The unified problem specification every front-end lowers into.
//!
//! A [`MapSpec`] describes one mapping job — *what* to map ([`GraphSource`]),
//! *onto what* (hierarchy + distance strings), and *how* (ε, seeds,
//! algorithm or auto-route, refinement flavor, polish, solver options).
//! `config::RunConfig` files, the CLI flags and the wire-protocol
//! `MapRequest` all produce a `MapSpec`; the [`crate::engine::Engine`]
//! consumes nothing else.

use crate::algo::Algorithm;
use crate::graph::CsrGraph;
use crate::topology::Hierarchy;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Where the task graph comes from.
#[derive(Clone, Debug)]
pub enum GraphSource {
    /// Instance registry name (`rgg15`, …) or a path to a METIS file;
    /// resolved — and cached — by the engine.
    Named(String),
    /// An already-built graph owned by the caller (library / harness path;
    /// bypasses the engine's graph cache).
    InMemory(Arc<CsrGraph>),
}

impl PartialEq for GraphSource {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (GraphSource::Named(a), GraphSource::Named(b)) => a == b,
            (GraphSource::InMemory(a), GraphSource::InMemory(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Refinement flavor: `Strong` upgrades a solver to its quality variant
/// (gpu-hm → gpu-hm-ultra, jet → jet-ultra, sharedmap-f → sharedmap-s,
/// intmap-f → intmap-s); solvers without a stronger variant are unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Refinement {
    #[default]
    Standard,
    Strong,
}

impl Refinement {
    pub fn name(self) -> &'static str {
        match self {
            Refinement::Standard => "standard",
            Refinement::Strong => "strong",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "standard" | "default" => Ok(Refinement::Standard),
            "strong" | "ultra" => Ok(Refinement::Strong),
            other => bail!("unknown refinement `{other}` (standard|strong)"),
        }
    }

    fn upgrade(self, algo: Algorithm) -> Algorithm {
        if self == Refinement::Standard {
            return algo;
        }
        match algo {
            Algorithm::GpuHm => Algorithm::GpuHmUltra,
            Algorithm::Jet => Algorithm::JetUltra,
            Algorithm::SharedMapF => Algorithm::SharedMapS,
            Algorithm::IntMapF => Algorithm::IntMapS,
            other => other,
        }
    }
}

/// One mapping job, front-end agnostic. Build with [`MapSpec::named`] /
/// [`MapSpec::in_memory`] and the chainable setters.
#[derive(Clone, Debug, PartialEq)]
pub struct MapSpec {
    pub graph: GraphSource,
    /// Machine hierarchy `a_1:…:a_ℓ`, e.g. `4:8:6`.
    pub hierarchy: String,
    /// Distance vector `d_1:…:d_ℓ`, e.g. `1:10:100`.
    pub distance: String,
    /// Imbalance ε.
    pub eps: f64,
    /// Seeds. [`crate::engine::Engine::map`] uses the first; `map_all_seeds`
    /// runs every one.
    pub seeds: Vec<u64>,
    /// Pinned algorithm, or `None` for router choice.
    pub algorithm: Option<Algorithm>,
    pub refinement: Refinement,
    /// Run the QAP polish stage (device-offloaded when artifacts exist).
    pub polish: bool,
    /// Keep the full mapping vector in the outcome (cleared when false).
    pub return_mapping: bool,
    /// Solver-specific knobs, e.g. `adaptive = 0` for the GPU-HM Eq. 2
    /// ablation. Unknown keys are ignored by solvers.
    pub options: BTreeMap<String, String>,
}

impl MapSpec {
    fn with_graph(graph: GraphSource) -> Self {
        MapSpec {
            graph,
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            eps: 0.03,
            seeds: vec![1],
            algorithm: None,
            refinement: Refinement::Standard,
            polish: false,
            return_mapping: true,
            options: BTreeMap::new(),
        }
    }

    /// Spec for a registry instance name or METIS file path.
    pub fn named(name: impl Into<String>) -> Self {
        Self::with_graph(GraphSource::Named(name.into()))
    }

    /// Spec for a caller-owned graph.
    pub fn in_memory(g: Arc<CsrGraph>) -> Self {
        Self::with_graph(GraphSource::InMemory(g))
    }

    pub fn hierarchy(mut self, hier: impl Into<String>) -> Self {
        self.hierarchy = hier.into();
        self
    }

    pub fn distance(mut self, dist: impl Into<String>) -> Self {
        self.distance = dist.into();
        self
    }

    /// Set hierarchy + distance from a parsed [`Hierarchy`].
    pub fn topology(mut self, h: &Hierarchy) -> Self {
        self.hierarchy = h.a.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(":");
        self.distance = h.d.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(":");
        self
    }

    pub fn eps(mut self, eps: f64) -> Self {
        self.eps = eps;
        self
    }

    /// Single-seed shorthand.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seeds = vec![seed];
        self
    }

    pub fn seeds(mut self, seeds: Vec<u64>) -> Self {
        assert!(!seeds.is_empty(), "MapSpec needs at least one seed");
        self.seeds = seeds;
        self
    }

    /// Pin an algorithm (`None` restores auto-routing).
    pub fn algo(mut self, algorithm: Option<Algorithm>) -> Self {
        self.algorithm = algorithm;
        self
    }

    pub fn refinement(mut self, refinement: Refinement) -> Self {
        self.refinement = refinement;
        self
    }

    pub fn polish(mut self, polish: bool) -> Self {
        self.polish = polish;
        self
    }

    pub fn return_mapping(mut self, yes: bool) -> Self {
        self.return_mapping = yes;
        self
    }

    /// Set one solver option (`adaptive = 0`, …).
    pub fn option(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.options.insert(key.into(), value.into());
        self
    }

    pub fn options(mut self, options: BTreeMap<String, String>) -> Self {
        self.options = options;
        self
    }

    /// The seed [`crate::engine::Engine::map`] solves with.
    pub fn primary_seed(&self) -> u64 {
        self.seeds.first().copied().unwrap_or(1)
    }

    /// Clone with a single seed (the engine's per-seed fan-out).
    pub fn with_seed(&self, seed: u64) -> Self {
        let mut s = self.clone();
        s.seeds = vec![seed];
        s
    }

    /// Parse and validate the machine description.
    pub fn parse_hierarchy(&self) -> Result<Hierarchy> {
        Hierarchy::parse(&self.hierarchy, &self.distance)
    }

    /// The concrete solver for a graph of `n` vertices: pinned algorithm or
    /// router choice, upgraded by the refinement flavor.
    pub fn resolve_algorithm(&self, n: usize) -> Algorithm {
        self.refinement.upgrade(super::route(n, self.algorithm))
    }

    /// Boolean option lookup (`1`/`true` → true, `0`/`false` → false).
    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        match self.options.get(key).map(|s| s.as_str()) {
            Some("1") | Some("true") => Some(true),
            Some("0") | Some("false") => Some(false),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let spec = MapSpec::named("rgg15")
            .hierarchy("4:8:2")
            .distance("1:10:100")
            .eps(0.05)
            .seed(7)
            .algo(Some(Algorithm::GpuIm))
            .polish(true)
            .option("adaptive", "0");
        assert_eq!(spec.graph, GraphSource::Named("rgg15".into()));
        assert_eq!(spec.primary_seed(), 7);
        assert_eq!(spec.parse_hierarchy().unwrap().k(), 64);
        assert_eq!(spec.opt_bool("adaptive"), Some(false));
        assert!(spec.polish);
    }

    #[test]
    fn topology_setter_roundtrips() {
        let h = Hierarchy::parse("4:8:2", "1:10:100").unwrap();
        let spec = MapSpec::named("x").topology(&h);
        assert_eq!(spec.hierarchy, "4:8:2");
        assert_eq!(spec.distance, "1:10:100");
        assert_eq!(spec.parse_hierarchy().unwrap(), h);
    }

    #[test]
    fn refinement_upgrades_flavors() {
        let spec = MapSpec::named("x").algo(Some(Algorithm::GpuHm)).refinement(Refinement::Strong);
        assert_eq!(spec.resolve_algorithm(1000), Algorithm::GpuHmUltra);
        let spec = spec.algo(Some(Algorithm::GpuIm));
        assert_eq!(spec.resolve_algorithm(1000), Algorithm::GpuIm);
        assert_eq!(Refinement::from_name("strong").unwrap(), Refinement::Strong);
        assert!(Refinement::from_name("bogus").is_err());
    }

    #[test]
    fn auto_route_by_size() {
        let spec = MapSpec::named("x");
        assert_eq!(spec.resolve_algorithm(10_000), Algorithm::GpuHmUltra);
        assert_eq!(spec.resolve_algorithm(1_000_000), Algorithm::GpuIm);
    }
}
