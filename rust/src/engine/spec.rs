//! The unified problem specification every front-end lowers into.
//!
//! A [`MapSpec`] describes one mapping job — *what* to map ([`GraphSource`]),
//! *onto what* (hierarchy + distance strings), and *how* (ε, seeds,
//! algorithm or auto-route, refinement flavor, polish, solver options).
//! `config::RunConfig` files, the CLI flags and the wire-protocol
//! `MapRequest` all produce a `MapSpec`; the [`crate::engine::Engine`]
//! consumes nothing else.

use crate::algo::Algorithm;
use crate::graph::CsrGraph;
use crate::multilevel::SchemeKind;
use crate::topology::{Hierarchy, Machine};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Where the task graph comes from.
#[derive(Clone, Debug)]
pub enum GraphSource {
    /// Instance registry name (`rgg15`, …) or a path to a METIS file;
    /// resolved — and cached — by the engine.
    Named(String),
    /// An already-built graph owned by the caller (library / harness path;
    /// bypasses the engine's graph cache).
    InMemory(Arc<CsrGraph>),
}

impl PartialEq for GraphSource {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (GraphSource::Named(a), GraphSource::Named(b)) => a == b,
            (GraphSource::InMemory(a), GraphSource::InMemory(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Refinement flavor: `Strong` upgrades a solver to its quality variant
/// (gpu-hm → gpu-hm-ultra, jet → jet-ultra, sharedmap-f → sharedmap-s,
/// intmap-f → intmap-s); solvers without a stronger variant are unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Refinement {
    #[default]
    Standard,
    Strong,
}

impl Refinement {
    pub fn name(self) -> &'static str {
        match self {
            Refinement::Standard => "standard",
            Refinement::Strong => "strong",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "standard" | "default" => Ok(Refinement::Standard),
            "strong" | "ultra" => Ok(Refinement::Strong),
            other => bail!("unknown refinement `{other}` (standard|strong)"),
        }
    }

    fn upgrade(self, algo: Algorithm) -> Algorithm {
        if self == Refinement::Standard {
            return algo;
        }
        match algo {
            Algorithm::GpuHm => Algorithm::GpuHmUltra,
            Algorithm::Jet => Algorithm::JetUltra,
            Algorithm::SharedMapF => Algorithm::SharedMapS,
            Algorithm::IntMapF => Algorithm::IntMapS,
            other => other,
        }
    }
}

/// Execution backend for the hot multilevel kernels (`backend=` on the
/// wire, `--backend` on the CLI): `Cpu` runs the device-style kernels on
/// the worker pool (the default, bit-for-bit the historical behavior),
/// `Device` runs them through the PJRT runtime's AOT-compiled artifacts
/// (falling back to the pool — counted as a `backend_fallback` — when the
/// runtime or an artifact is missing), and `Auto` probes artifact
/// availability and problem size, silently choosing per job.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    #[default]
    Cpu,
    Device,
    Auto,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Cpu => "cpu",
            Backend::Device => "device",
            Backend::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "cpu" => Ok(Backend::Cpu),
            "device" => Ok(Backend::Device),
            "auto" => Ok(Backend::Auto),
            other => bail!("unknown backend `{other}` (cpu|device|auto)"),
        }
    }
}

/// One mapping job, front-end agnostic. Build with [`MapSpec::named`] /
/// [`MapSpec::in_memory`] and the chainable setters.
#[derive(Clone, Debug)]
pub struct MapSpec {
    pub graph: GraphSource,
    /// Machine hierarchy `a_1:…:a_ℓ`, e.g. `4:8:6`. Ignored when
    /// `topology` is set.
    pub hierarchy: String,
    /// Distance vector `d_1:…:d_ℓ`, e.g. `1:10:100`. Ignored when
    /// `topology` is set.
    pub distance: String,
    /// Machine-model spec string (`torus:4x4x4`, `fattree:…`, `file:…`;
    /// see [`crate::topology::parse_topology`]). When set, it overrides
    /// `hierarchy`/`distance`.
    pub topology: Option<String>,
    /// Already-validated machine cached by [`MapSpec::topology`], so
    /// library callers with programmatic models (and the matrix runner)
    /// skip the per-map re-parse/re-read. Excluded from equality — the
    /// wire-visible fields define the spec.
    machine: Option<Machine>,
    /// Imbalance ε.
    pub eps: f64,
    /// Seeds. [`crate::engine::Engine::map`] uses the first; `map_all_seeds`
    /// runs every one.
    pub seeds: Vec<u64>,
    /// Pinned algorithm, or `None` for router choice.
    pub algorithm: Option<Algorithm>,
    pub refinement: Refinement,
    /// Coarsening scheme of the multilevel pipelines
    /// (`coarsening = matching|cluster|auto`): preference matching,
    /// size-constrained cluster LP, or matching with per-level cluster
    /// fallback when it stalls.
    pub coarsening: SchemeKind,
    /// Run the QAP polish stage (device-offloaded when artifacts exist).
    pub polish: bool,
    /// Execution backend for the hot kernels (see [`Backend`]).
    pub backend: Backend,
    /// Keep the full mapping vector in the outcome (cleared when false).
    pub return_mapping: bool,
    /// Solver-specific knobs, e.g. `adaptive = 0` for the GPU-HM Eq. 2
    /// ablation. Unknown keys are ignored by solvers.
    pub options: BTreeMap<String, String>,
}

/// Equality over the wire-visible fields only — the cached machine is a
/// derived convenience, not part of the spec's identity.
impl PartialEq for MapSpec {
    fn eq(&self, other: &Self) -> bool {
        self.graph == other.graph
            && self.hierarchy == other.hierarchy
            && self.distance == other.distance
            && self.topology == other.topology
            && self.eps == other.eps
            && self.seeds == other.seeds
            && self.algorithm == other.algorithm
            && self.refinement == other.refinement
            && self.coarsening == other.coarsening
            && self.polish == other.polish
            && self.backend == other.backend
            && self.return_mapping == other.return_mapping
            && self.options == other.options
    }
}

impl MapSpec {
    fn with_graph(graph: GraphSource) -> Self {
        MapSpec {
            graph,
            hierarchy: "4:8:6".into(),
            distance: "1:10:100".into(),
            topology: None,
            machine: None,
            eps: 0.03,
            seeds: vec![1],
            algorithm: None,
            refinement: Refinement::Standard,
            coarsening: SchemeKind::Auto,
            polish: false,
            backend: Backend::Cpu,
            return_mapping: true,
            options: BTreeMap::new(),
        }
    }

    /// Spec for a registry instance name or METIS file path.
    pub fn named(name: impl Into<String>) -> Self {
        Self::with_graph(GraphSource::Named(name.into()))
    }

    /// Spec for a caller-owned graph.
    pub fn in_memory(g: Arc<CsrGraph>) -> Self {
        Self::with_graph(GraphSource::InMemory(g))
    }

    /// Set the hierarchy string. Last machine setter wins: this clears a
    /// previously set `topology`, mirroring how the CLI treats explicit
    /// `--hier`/`--dist` flags.
    pub fn hierarchy(mut self, hier: impl Into<String>) -> Self {
        self.hierarchy = hier.into();
        self.topology = None;
        self.machine = None;
        self
    }

    /// Set the distance string. Last machine setter wins (see
    /// [`MapSpec::hierarchy`]).
    pub fn distance(mut self, dist: impl Into<String>) -> Self {
        self.distance = dist.into();
        self.topology = None;
        self.machine = None;
        self
    }

    /// Pin the machine model from a parsed [`Machine`]. The machine is
    /// carried in the spec (no re-parse per map, and models without a
    /// re-parsable source — e.g. an in-memory `MatrixModel` — work);
    /// its canonical spec string is stored alongside so wire/config
    /// round trips stay lossless.
    pub fn topology(mut self, m: &Machine) -> Self {
        self.topology = Some(m.spec_string());
        self.machine = Some(m.clone());
        self
    }

    /// Pin the machine model from a raw `topology=` spec string
    /// (`torus:4x4x4`, …); validated when the engine parses the spec.
    /// Clears any machine cached by [`MapSpec::topology`].
    pub fn topology_spec(mut self, spec: impl Into<String>) -> Self {
        self.topology = Some(spec.into());
        self.machine = None;
        self
    }

    pub fn eps(mut self, eps: f64) -> Self {
        self.eps = eps;
        self
    }

    /// Single-seed shorthand.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seeds = vec![seed];
        self
    }

    pub fn seeds(mut self, seeds: Vec<u64>) -> Self {
        assert!(!seeds.is_empty(), "MapSpec needs at least one seed");
        self.seeds = seeds;
        self
    }

    /// Pin an algorithm (`None` restores auto-routing).
    pub fn algo(mut self, algorithm: Option<Algorithm>) -> Self {
        self.algorithm = algorithm;
        self
    }

    pub fn refinement(mut self, refinement: Refinement) -> Self {
        self.refinement = refinement;
        self
    }

    /// Pick the multilevel coarsening scheme (default `Auto`).
    pub fn coarsening(mut self, coarsening: SchemeKind) -> Self {
        self.coarsening = coarsening;
        self
    }

    pub fn polish(mut self, polish: bool) -> Self {
        self.polish = polish;
        self
    }

    /// Pick the execution backend (default [`Backend::Cpu`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn return_mapping(mut self, yes: bool) -> Self {
        self.return_mapping = yes;
        self
    }

    /// Set one solver option (`adaptive = 0`, …).
    pub fn option(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.options.insert(key.into(), value.into());
        self
    }

    pub fn options(mut self, options: BTreeMap<String, String>) -> Self {
        self.options = options;
        self
    }

    /// The seed [`crate::engine::Engine::map`] solves with.
    pub fn primary_seed(&self) -> u64 {
        self.seeds.first().copied().unwrap_or(1)
    }

    /// Clone with a single seed (the engine's per-seed fan-out).
    pub fn with_seed(&self, seed: u64) -> Self {
        let mut s = self.clone();
        s.seeds = vec![seed];
        s
    }

    /// Resolve the machine model this spec maps onto: the machine cached
    /// by [`MapSpec::topology`] when present, else the `topology` spec
    /// string, else the `hierarchy`/`distance` pair.
    pub fn machine(&self) -> Result<Machine> {
        if let Some(m) = self.cached_machine() {
            return Ok(m.clone());
        }
        Machine::resolve(self.topology.as_deref(), &self.hierarchy, &self.distance)
    }

    /// The machine cached by [`MapSpec::topology`] — only while it still
    /// agrees with the (publicly writable) `topology` field, so a direct
    /// field write can never make `machine()` return a model the spec no
    /// longer names.
    pub fn cached_machine(&self) -> Option<&Machine> {
        let m = self.machine.as_ref()?;
        (self.topology.as_deref() == Some(m.spec_string().as_str())).then_some(m)
    }

    /// Parse and validate the homogeneous hierarchy fields. Ignores
    /// `topology`; prefer [`MapSpec::machine`].
    pub fn parse_hierarchy(&self) -> Result<Hierarchy> {
        Hierarchy::parse(&self.hierarchy, &self.distance)
    }

    /// The concrete solver for a graph of `n` vertices: pinned algorithm or
    /// router choice, upgraded by the refinement flavor.
    pub fn resolve_algorithm(&self, n: usize) -> Algorithm {
        self.refinement.upgrade(super::route(n, self.algorithm))
    }

    /// Boolean option lookup (`1`/`true` → true, `0`/`false` → false).
    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        match self.options.get(key).map(|s| s.as_str()) {
            Some("1") | Some("true") => Some(true),
            Some("0") | Some("false") => Some(false),
            _ => None,
        }
    }

    /// Float option lookup (`remap.max_region_frac = 0.4`, …); unset or
    /// unparsable values read as `None`.
    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.options.get(key).and_then(|s| s.parse::<f64>().ok()).filter(|v| v.is_finite())
    }

    /// Integer option lookup (`remap.halo = 2`, …); unset or unparsable
    /// values read as `None`.
    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.options.get(key).and_then(|s| s.parse::<usize>().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let spec = MapSpec::named("rgg15")
            .hierarchy("4:8:2")
            .distance("1:10:100")
            .eps(0.05)
            .seed(7)
            .algo(Some(Algorithm::GpuIm))
            .polish(true)
            .option("adaptive", "0");
        assert_eq!(spec.graph, GraphSource::Named("rgg15".into()));
        assert_eq!(spec.primary_seed(), 7);
        assert_eq!(spec.parse_hierarchy().unwrap().k(), 64);
        assert_eq!(spec.opt_bool("adaptive"), Some(false));
        assert!(spec.polish);
        let spec = spec.option("remap.halo", "2").option("remap.max_region_frac", "0.4");
        assert_eq!(spec.opt_usize("remap.halo"), Some(2));
        assert_eq!(spec.opt_f64("remap.max_region_frac"), Some(0.4));
        assert_eq!(spec.opt_usize("remap.max_region_frac"), None);
        assert_eq!(spec.opt_f64("missing"), None);
    }

    #[test]
    fn topology_setter_roundtrips() {
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let spec = MapSpec::named("x").topology(&h);
        assert_eq!(spec.topology.as_deref(), Some("hier:4:8:2/1:10:100"));
        assert_eq!(spec.machine().unwrap(), h);
    }

    #[test]
    fn machine_resolves_topology_over_hierarchy() {
        // Default hier fields are present, but topology wins.
        let spec = MapSpec::named("x").topology_spec("torus:4x4x4");
        let m = spec.machine().unwrap();
        assert_eq!(m.k(), 64);
        assert_eq!(m.spec_string(), "torus:4x4x4");
        // Without topology, the hier/dist pair resolves as before.
        let spec = MapSpec::named("x").hierarchy("4:8:2").distance("1:10:100");
        assert_eq!(spec.machine().unwrap().k(), 64);
        // Bad specs surface as clean errors.
        assert!(MapSpec::named("x").topology_spec("bogus:1").machine().is_err());
        // Last machine setter wins: hierarchy()/distance() after
        // topology() clear it (builder semantics match the CLI).
        let t = Machine::parse_spec("torus:4x4x4").unwrap();
        let spec = MapSpec::named("x").topology(&t).hierarchy("2:2:2").distance("1:10:100");
        assert_eq!(spec.machine().unwrap().k(), 8);
        assert!(spec.topology.is_none());
    }

    #[test]
    fn refinement_upgrades_flavors() {
        let spec = MapSpec::named("x").algo(Some(Algorithm::GpuHm)).refinement(Refinement::Strong);
        assert_eq!(spec.resolve_algorithm(1000), Algorithm::GpuHmUltra);
        let spec = spec.algo(Some(Algorithm::GpuIm));
        assert_eq!(spec.resolve_algorithm(1000), Algorithm::GpuIm);
        assert_eq!(Refinement::from_name("strong").unwrap(), Refinement::Strong);
        assert!(Refinement::from_name("bogus").is_err());
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in [Backend::Cpu, Backend::Device, Backend::Auto] {
            assert_eq!(Backend::from_name(b.name()).unwrap(), b);
        }
        assert!(Backend::from_name("warp").is_err());
        assert_eq!(MapSpec::named("x").backend, Backend::Cpu);
        assert_eq!(MapSpec::named("x").backend(Backend::Auto).backend, Backend::Auto);
    }

    #[test]
    fn auto_route_by_size() {
        let spec = MapSpec::named("x");
        assert_eq!(spec.resolve_algorithm(10_000), Algorithm::GpuHmUltra);
        assert_eq!(spec.resolve_algorithm(1_000_000), Algorithm::GpuIm);
    }
}
