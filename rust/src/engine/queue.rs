//! Bounded priority queue feeding the engine's worker pool.
//!
//! Ordering: highest [`priority`](QueuedJob::priority) first, FIFO
//! (submit sequence) within a priority class. The capacity bound is the
//! engine's backpressure signal — a full queue either blocks the
//! submitter or surfaces [`super::SubmitError::Busy`].
//!
//! Retries ride a separate **delayed lane**: [`JobQueue::push_delayed`]
//! parks a job until its backoff elapses, [`JobQueue::promote_ready`]
//! moves due jobs into the heap (bypassing the capacity bound — a retry
//! already holds its slot and must never be dropped for backpressure).
//! [`JobQueue::close`] seals both lanes so a shutdown drain cannot race
//! a late re-queue (see `Engine::drop`).

use super::job::{CompletionHook, JobHandle, RetryPolicy};
use super::MapSpec;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

pub(crate) struct QueuedJob {
    pub priority: i32,
    /// Monotonic submit sequence; lower = earlier.
    pub seq: u64,
    /// 1-based attempt number this pop will execute.
    pub attempt: u32,
    pub retry: RetryPolicy,
    pub spec: MapSpec,
    pub handle: JobHandle,
    pub hook: Option<CompletionHook>,
    /// Batch id when submitted via `Engine::submit_batch`; a worker that
    /// pops a batched job may drain same-batch compatible jobs from the
    /// queue head into one worker pass. Preserved across retries.
    pub batch: Option<u64>,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}

impl Eq for QueuedJob {}

impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedJob {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: larger compares first. Higher priority wins; within a
        // class the *smaller* sequence number must pop first.
        self.priority.cmp(&other.priority).then_with(|| other.seq.cmp(&self.seq))
    }
}

pub(crate) struct JobQueue {
    cap: usize,
    heap: BinaryHeap<QueuedJob>,
    /// Backoff lane: jobs waiting for their retry moment, unordered (the
    /// list stays tiny — bounded by in-flight retries).
    delayed: Vec<(Instant, QueuedJob)>,
    /// Once closed (engine shutdown), pushes into either lane fail and
    /// hand the job back so the caller retires it.
    closed: bool,
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue { cap: cap.max(1), heap: BinaryHeap::new(), delayed: Vec::new(), closed: false }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Jobs in the queue, both ready and backoff-delayed.
    pub fn len(&self) -> usize {
        self.heap.len() + self.delayed.len()
    }

    /// Seal the queue: all further pushes (fresh or delayed) are refused.
    /// Called by `Engine::drop` *before* the final drain so a retry that
    /// lost the race finishes `Cancelled` instead of being lost.
    pub fn close(&mut self) {
        self.closed = true;
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Enqueue, or hand the job back when full or closed.
    pub fn push(&mut self, job: QueuedJob) -> Result<(), QueuedJob> {
        if self.closed || self.heap.len() >= self.cap {
            return Err(job);
        }
        self.heap.push(job);
        Ok(())
    }

    /// Park a retry until `ready_at`. Not capacity-bounded (the job held
    /// a slot when first admitted); refused only once the queue closed.
    pub fn push_delayed(&mut self, ready_at: Instant, job: QueuedJob) -> Result<(), QueuedJob> {
        if self.closed {
            return Err(job);
        }
        self.delayed.push((ready_at, job));
        Ok(())
    }

    /// Move every delayed job whose backoff has elapsed into the ready
    /// heap. Returns how many were promoted.
    pub fn promote_ready(&mut self, now: Instant) -> usize {
        let mut promoted = 0;
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, job) = self.delayed.swap_remove(i);
                self.heap.push(job);
                promoted += 1;
            } else {
                i += 1;
            }
        }
        promoted
    }

    /// The earliest instant at which a delayed job becomes ready.
    pub fn next_ready_at(&self) -> Option<Instant> {
        self.delayed.iter().map(|(t, _)| *t).min()
    }

    pub fn pop(&mut self) -> Option<QueuedJob> {
        self.heap.pop()
    }

    /// The job the next [`JobQueue::pop`] would return, if any. Batch
    /// draining peeks before popping so it never takes a job it would
    /// have to put back.
    pub fn peek(&self) -> Option<&QueuedJob> {
        self.heap.peek()
    }

    /// All-or-nothing batch admission: every job is enqueued, or none is
    /// and the whole batch is handed back (queue closed, or fewer than
    /// `jobs.len()` free slots).
    pub fn push_all(&mut self, jobs: Vec<QueuedJob>) -> Result<(), Vec<QueuedJob>> {
        if self.closed || self.heap.len() + jobs.len() > self.cap {
            return Err(jobs);
        }
        for job in jobs {
            self.heap.push(job);
        }
        Ok(())
    }

    /// Remove jobs that already reached a terminal state (cancelled or
    /// deadline-expired while queued) so they stop occupying capacity.
    /// Returns the removed jobs — the caller must still retire them
    /// (fire their completion hooks). Scans both lanes.
    pub fn purge_terminal(&mut self) -> Vec<QueuedJob> {
        let mut purged = Vec::new();
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].1.handle.is_finished() {
                purged.push(self.delayed.swap_remove(i).1);
            } else {
                i += 1;
            }
        }
        if self.heap.iter().any(|j| j.handle.is_finished()) {
            let mut keep = BinaryHeap::with_capacity(self.heap.len());
            for j in self.heap.drain() {
                if j.handle.is_finished() {
                    purged.push(j);
                } else {
                    keep.push(j);
                }
            }
            self.heap = keep;
        }
        purged
    }

    /// Empty both lanes (shutdown drain).
    pub fn drain(&mut self) -> Vec<QueuedJob> {
        let mut out = Vec::with_capacity(self.len());
        while let Some(j) = self.heap.pop() {
            out.push(j);
        }
        out.extend(self.delayed.drain(..).map(|(_, j)| j));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cancel::CancelToken;
    use crate::engine::job::JobId;
    use std::time::Duration;

    fn job(priority: i32, seq: u64) -> QueuedJob {
        QueuedJob {
            priority,
            seq,
            attempt: 1,
            retry: RetryPolicy::default(),
            spec: MapSpec::named("x"),
            handle: JobHandle::new_queued(JobId(seq), CancelToken::new()),
            hook: None,
            batch: None,
        }
    }

    #[test]
    fn fifo_within_priority_and_priority_wins() {
        let mut q = JobQueue::new(8);
        for (p, s) in [(0, 1), (0, 2), (5, 3), (0, 4), (5, 5)] {
            q.push(job(p, s)).map_err(|_| ()).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|j| j.seq)).collect();
        assert_eq!(order, vec![3, 5, 1, 2, 4]);
    }

    #[test]
    fn purge_removes_only_terminal_jobs() {
        let mut q = JobQueue::new(4);
        let a = job(0, 1);
        let cancelled_handle = a.handle.clone();
        q.push(a).map_err(|_| ()).unwrap();
        q.push(job(0, 2)).map_err(|_| ()).unwrap();
        assert!(q.purge_terminal().is_empty(), "live jobs must not be purged");
        cancelled_handle.cancel();
        let purged = q.purge_terminal();
        assert_eq!(purged.len(), 1);
        assert_eq!(purged[0].seq, 1);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().seq, 2);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut q = JobQueue::new(2);
        assert!(q.push(job(0, 1)).is_ok());
        assert!(q.push(job(0, 2)).is_ok());
        let rejected = q.push(job(9, 3));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().seq, 3);
        assert_eq!(q.len(), 2);
        q.pop();
        assert!(q.push(job(9, 3)).is_ok());
    }

    #[test]
    fn delayed_jobs_promote_when_due_and_bypass_cap() {
        let mut q = JobQueue::new(1);
        assert!(q.push(job(0, 1)).is_ok());
        let now = Instant::now();
        // Queue is full, but the retry lane must still admit the job.
        assert!(q.push_delayed(now + Duration::from_millis(50), job(0, 2)).is_ok());
        assert_eq!(q.len(), 2);
        assert_eq!(q.promote_ready(now), 0, "not due yet");
        assert!(q.next_ready_at().is_some());
        assert_eq!(q.promote_ready(now + Duration::from_millis(60)), 1);
        assert!(q.next_ready_at().is_none());
        assert_eq!(q.pop().unwrap().seq, 1);
        assert_eq!(q.pop().unwrap().seq, 2);
    }

    #[test]
    fn closed_queue_refuses_both_lanes_and_drains_everything() {
        let mut q = JobQueue::new(4);
        assert!(q.push(job(0, 1)).is_ok());
        assert!(q.push_delayed(Instant::now() + Duration::from_secs(60), job(0, 2)).is_ok());
        q.close();
        assert!(q.is_closed());
        assert!(q.push(job(0, 3)).is_err());
        assert!(q.push_delayed(Instant::now(), job(0, 4)).is_err());
        let drained: Vec<u64> = q.drain().into_iter().map(|j| j.seq).collect();
        assert_eq!(drained.len(), 2);
        assert!(drained.contains(&1) && drained.contains(&2));
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn push_all_is_all_or_nothing_and_peek_matches_pop() {
        let mut q = JobQueue::new(3);
        assert!(q.push(job(0, 1)).is_ok());
        // Three more don't fit into the two free slots: nothing lands.
        let refused = q.push_all(vec![job(0, 2), job(0, 3), job(0, 4)]);
        assert_eq!(refused.unwrap_err().len(), 3);
        assert_eq!(q.len(), 1);
        // Two do, atomically.
        assert!(q.push_all(vec![job(5, 2), job(5, 3)]).is_ok());
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek().unwrap().seq, 2);
        assert_eq!(q.pop().unwrap().seq, 2);
        assert_eq!(q.peek().unwrap().seq, 3);
        q.close();
        assert!(q.push_all(vec![job(0, 9)]).is_err());
    }

    #[test]
    fn purge_scans_the_delayed_lane() {
        let mut q = JobQueue::new(4);
        let a = job(0, 1);
        let h = a.handle.clone();
        assert!(q.push_delayed(Instant::now() + Duration::from_secs(60), a).is_ok());
        h.cancel();
        let purged = q.purge_terminal();
        assert_eq!(purged.len(), 1);
        assert_eq!(purged[0].seq, 1);
        assert_eq!(q.len(), 0);
    }
}
