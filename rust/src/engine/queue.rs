//! Bounded priority queue feeding the engine's worker pool.
//!
//! Ordering: highest [`priority`](QueuedJob::priority) first, FIFO
//! (submit sequence) within a priority class. The capacity bound is the
//! engine's backpressure signal — a full queue either blocks the
//! submitter or surfaces [`super::SubmitError::Busy`].

use super::job::{CompletionHook, JobHandle};
use super::MapSpec;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub(crate) struct QueuedJob {
    pub priority: i32,
    /// Monotonic submit sequence; lower = earlier.
    pub seq: u64,
    pub spec: MapSpec,
    pub handle: JobHandle,
    pub hook: Option<CompletionHook>,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}

impl Eq for QueuedJob {}

impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedJob {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: larger compares first. Higher priority wins; within a
        // class the *smaller* sequence number must pop first.
        self.priority.cmp(&other.priority).then_with(|| other.seq.cmp(&self.seq))
    }
}

pub(crate) struct JobQueue {
    cap: usize,
    heap: BinaryHeap<QueuedJob>,
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue { cap: cap.max(1), heap: BinaryHeap::new() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Enqueue, or hand the job back when full.
    pub fn push(&mut self, job: QueuedJob) -> Result<(), QueuedJob> {
        if self.heap.len() >= self.cap {
            return Err(job);
        }
        self.heap.push(job);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<QueuedJob> {
        self.heap.pop()
    }

    /// Remove jobs that already reached a terminal state (cancelled or
    /// deadline-expired while queued) so they stop occupying capacity.
    /// Returns the removed jobs — the caller must still retire them
    /// (fire their completion hooks).
    pub fn purge_terminal(&mut self) -> Vec<QueuedJob> {
        if self.heap.iter().all(|j| !j.handle.is_finished()) {
            return Vec::new();
        }
        let mut purged = Vec::new();
        let mut keep = BinaryHeap::with_capacity(self.heap.len());
        for j in self.heap.drain() {
            if j.handle.is_finished() {
                purged.push(j);
            } else {
                keep.push(j);
            }
        }
        self.heap = keep;
        purged
    }

    pub fn drain(&mut self) -> Vec<QueuedJob> {
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(j) = self.heap.pop() {
            out.push(j);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cancel::CancelToken;
    use crate::engine::job::JobId;

    fn job(priority: i32, seq: u64) -> QueuedJob {
        QueuedJob {
            priority,
            seq,
            spec: MapSpec::named("x"),
            handle: JobHandle::new_queued(JobId(seq), CancelToken::new()),
            hook: None,
        }
    }

    #[test]
    fn fifo_within_priority_and_priority_wins() {
        let mut q = JobQueue::new(8);
        for (p, s) in [(0, 1), (0, 2), (5, 3), (0, 4), (5, 5)] {
            q.push(job(p, s)).map_err(|_| ()).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|j| j.seq)).collect();
        assert_eq!(order, vec![3, 5, 1, 2, 4]);
    }

    #[test]
    fn purge_removes_only_terminal_jobs() {
        let mut q = JobQueue::new(4);
        let a = job(0, 1);
        let cancelled_handle = a.handle.clone();
        q.push(a).map_err(|_| ()).unwrap();
        q.push(job(0, 2)).map_err(|_| ()).unwrap();
        assert!(q.purge_terminal().is_empty(), "live jobs must not be purged");
        cancelled_handle.cancel();
        let purged = q.purge_terminal();
        assert_eq!(purged.len(), 1);
        assert_eq!(purged[0].seq, 1);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().seq, 2);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut q = JobQueue::new(2);
        assert!(q.push(job(0, 1)).is_ok());
        assert!(q.push(job(0, 2)).is_ok());
        let rejected = q.push(job(9, 3));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().seq, 3);
        assert_eq!(q.len(), 2);
        q.pop();
        assert!(q.push(job(9, 3)).is_ok());
    }
}
