//! Graph storage for the engine: a bounded LRU cache for resolved
//! graphs (so a long-lived engine — the `heipa serve` coordinator in
//! particular — cannot grow memory without limit when clients cycle
//! through many instances) plus a **pinned session store** for graphs
//! uploaded once and mapped many times (`graph put` on the wire). Pinned
//! entries are exempt from LRU eviction and shared — as one
//! `Arc<CsrGraph>` — across jobs, workers and connections.

use crate::graph::CsrGraph;
use std::collections::HashMap;
use std::sync::Arc;

/// Name → graph cache with least-recently-used eviction. Recency is a
/// monotonic stamp bumped on every hit; eviction is O(len), which is
/// irrelevant next to the cost of generating or parsing a graph.
#[derive(Debug)]
pub struct GraphCache {
    cap: usize,
    stamp: u64,
    map: HashMap<String, (u64, Arc<CsrGraph>)>,
}

impl GraphCache {
    /// `cap` is the maximum number of cached graphs (min 1).
    pub fn new(cap: usize) -> Self {
        GraphCache { cap: cap.max(1), stamp: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<CsrGraph>> {
        self.stamp += 1;
        let stamp = self.stamp;
        self.map.get_mut(key).map(|slot| {
            slot.0 = stamp;
            slot.1.clone()
        })
    }

    /// Insert `key`, evicting the least-recently-used entry when full.
    pub fn insert(&mut self, key: String, g: Arc<CsrGraph>) {
        if !self.map.contains_key(&key) && self.map.len() >= self.cap {
            if let Some(oldest) = self.map.iter().min_by_key(|(_, (s, _))| *s).map(|(k, _)| k.clone()) {
                self.map.remove(&oldest);
            }
        }
        self.stamp += 1;
        self.map.insert(key, (self.stamp, g));
    }
}

/// The engine's shared graph storage: pinned session graphs in front of
/// the LRU cache. Lookups prefer pinned entries, so an uploaded graph
/// shadows a registry instance of the same name for as long as it lives.
#[derive(Debug)]
pub struct GraphStore {
    pinned: HashMap<String, Arc<CsrGraph>>,
    lru: GraphCache,
}

impl GraphStore {
    pub fn new(lru_cap: usize) -> GraphStore {
        GraphStore { pinned: HashMap::new(), lru: GraphCache::new(lru_cap) }
    }

    /// Resolve `name`: pinned store first, then the LRU cache.
    pub fn get(&mut self, name: &str) -> Option<Arc<CsrGraph>> {
        if let Some(g) = self.pinned.get(name) {
            return Some(g.clone());
        }
        self.lru.get(name)
    }

    /// Cache a resolved (registry/file) graph in the LRU tier.
    pub fn insert_cached(&mut self, name: String, g: Arc<CsrGraph>) {
        self.lru.insert(name, g);
    }

    /// Pin a session graph under `name` (replacing any previous pin).
    pub fn pin(&mut self, name: String, g: Arc<CsrGraph>) {
        self.pinned.insert(name, g);
    }

    /// Drop a pinned graph; false when `name` was not pinned.
    pub fn unpin(&mut self, name: &str) -> bool {
        self.pinned.remove(name).is_some()
    }

    /// Names of the pinned session graphs, sorted.
    pub fn pinned_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.pinned.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn pinned_len(&self) -> usize {
        self.pinned.len()
    }

    pub fn cached_len(&self) -> usize {
        self.lru.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Arc<CsrGraph> {
        Arc::new(crate::graph::gen::grid2d(4, 4, false))
    }

    #[test]
    fn bounded_at_capacity() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("c".into(), g());
        assert_eq!(c.len(), 2);
        assert!(c.get("a").is_none(), "oldest entry evicted");
        assert!(c.get("b").is_some());
        assert!(c.get("c").is_some());
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert!(c.get("a").is_some()); // a is now newer than b
        c.insert("c".into(), g());
        assert!(c.get("b").is_none(), "b was the LRU entry");
        assert!(c.get("a").is_some());
    }

    #[test]
    fn reinsert_does_not_evict() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("a".into(), g()); // same key: no eviction needed
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_some());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut c = GraphCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn pinned_graphs_survive_lru_churn_and_shadow_cached_names() {
        let mut s = GraphStore::new(1);
        let pinned = g();
        s.pin("session".into(), pinned.clone());
        s.insert_cached("a".into(), g());
        s.insert_cached("b".into(), g()); // evicts `a` from the LRU tier
        assert_eq!(s.cached_len(), 1);
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        // A pinned entry shadows a cached one of the same name.
        s.insert_cached("session".into(), g());
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        assert_eq!(s.pinned_names(), vec!["session".to_string()]);
        assert!(s.unpin("session"));
        assert!(!s.unpin("session"));
    }
}
