//! Graph storage for the engine: a bounded LRU cache for resolved
//! graphs (so a long-lived engine — the `heipa serve` coordinator in
//! particular — cannot grow memory without limit when clients cycle
//! through many instances) plus a **pinned session store** for graphs
//! uploaded once and mapped many times (`graph put` on the wire). Pinned
//! entries are exempt from LRU eviction and shared — as one
//! `Arc<CsrGraph>` — across jobs, workers and connections.
//!
//! Alongside it lives the [`HierarchyCache`]: bounded LRU of built
//! [`CoarseHierarchy`] instances keyed by **graph identity** (the
//! resolved `Arc`, compared by pointer — entries hold the `Arc` strongly,
//! so an address can never be reused while its entry lives) plus the
//! full [`HierarchyParams`]. Repeat jobs against a pinned session graph
//! — and `run_matrix` seed sweeps over one in-memory graph — skip the
//! Coarsening/Contraction phases entirely.

use crate::graph::CsrGraph;
use crate::multilevel::{CoarseHierarchy, HierarchyParams};
use std::collections::HashMap;
use std::sync::Arc;

/// Name → graph cache with least-recently-used eviction. Recency is a
/// monotonic stamp bumped on every hit; eviction is O(len), which is
/// irrelevant next to the cost of generating or parsing a graph.
#[derive(Debug)]
pub struct GraphCache {
    cap: usize,
    stamp: u64,
    map: HashMap<String, (u64, Arc<CsrGraph>)>,
}

impl GraphCache {
    /// `cap` is the maximum number of cached graphs (min 1).
    pub fn new(cap: usize) -> Self {
        GraphCache { cap: cap.max(1), stamp: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<CsrGraph>> {
        self.stamp += 1;
        let stamp = self.stamp;
        self.map.get_mut(key).map(|slot| {
            slot.0 = stamp;
            slot.1.clone()
        })
    }

    /// Insert `key`, evicting the least-recently-used entry when full.
    pub fn insert(&mut self, key: String, g: Arc<CsrGraph>) {
        if !self.map.contains_key(&key) && self.map.len() >= self.cap {
            if let Some(oldest) = self.map.iter().min_by_key(|(_, (s, _))| *s).map(|(k, _)| k.clone()) {
                self.map.remove(&oldest);
            }
        }
        self.stamp += 1;
        self.map.insert(key, (self.stamp, g));
    }
}

/// One pinned session graph plus its monotone session version: 1 on the
/// first `graph put`, bumped on every replace and every applied patch.
#[derive(Debug, Clone)]
struct PinnedGraph {
    graph: Arc<CsrGraph>,
    version: u64,
}

/// The engine's shared graph storage: pinned session graphs in front of
/// the LRU cache. Lookups prefer pinned entries, so an uploaded graph
/// shadows a registry instance of the same name for as long as it lives.
#[derive(Debug)]
pub struct GraphStore {
    pinned: HashMap<String, PinnedGraph>,
    lru: GraphCache,
}

impl GraphStore {
    pub fn new(lru_cap: usize) -> GraphStore {
        GraphStore { pinned: HashMap::new(), lru: GraphCache::new(lru_cap) }
    }

    /// Resolve `name`: pinned store first, then the LRU cache.
    pub fn get(&mut self, name: &str) -> Option<Arc<CsrGraph>> {
        if let Some(p) = self.pinned.get(name) {
            return Some(p.graph.clone());
        }
        self.lru.get(name)
    }

    /// Cache a resolved (registry/file) graph in the LRU tier.
    pub fn insert_cached(&mut self, name: String, g: Arc<CsrGraph>) {
        self.lru.insert(name, g);
    }

    /// Pin a session graph under `name`. Returns the new session version
    /// (1 for a fresh name, previous + 1 on replace) and the replaced
    /// `Arc` when one existed — the caller purges its derived state
    /// (hierarchy-cache entries, remap history). In-flight jobs that
    /// already resolved the old `Arc` keep it alive and complete against
    /// the graph they started with.
    pub fn pin(&mut self, name: String, g: Arc<CsrGraph>) -> (u64, Option<Arc<CsrGraph>>) {
        match self.pinned.get_mut(&name) {
            Some(p) => {
                let old = std::mem::replace(&mut p.graph, g);
                p.version += 1;
                (p.version, Some(old))
            }
            None => {
                self.pinned.insert(name, PinnedGraph { graph: g, version: 1 });
                (1, None)
            }
        }
    }

    /// The pinned graph and its session version, without touching the
    /// LRU tier.
    pub fn pinned(&self, name: &str) -> Option<(Arc<CsrGraph>, u64)> {
        self.pinned.get(name).map(|p| (p.graph.clone(), p.version))
    }

    /// Swap in a patched graph under an existing pin, bumping the
    /// session version. Returns the new version and the replaced `Arc`
    /// (for hierarchy-cache re-keying); `None` when `name` is not
    /// pinned.
    pub fn repin_patched(
        &mut self,
        name: &str,
        g: Arc<CsrGraph>,
    ) -> Option<(u64, Arc<CsrGraph>)> {
        let p = self.pinned.get_mut(name)?;
        let old = std::mem::replace(&mut p.graph, g);
        p.version += 1;
        Some((p.version, old))
    }

    /// Drop a pinned graph, returning it so the caller can purge
    /// derived state (hierarchy-cache entries keyed on its identity);
    /// `None` when `name` was not pinned.
    pub fn unpin(&mut self, name: &str) -> Option<Arc<CsrGraph>> {
        self.pinned.remove(name).map(|p| p.graph)
    }

    /// Names of the pinned session graphs, sorted.
    pub fn pinned_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.pinned.keys().cloned().collect();
        names.sort();
        names
    }

    /// `(name, session version)` of every pinned graph, sorted by name
    /// (the wire's `graph list` renders them as `name@vN`).
    pub fn pinned_entries(&self) -> Vec<(String, u64)> {
        let mut entries: Vec<(String, u64)> =
            self.pinned.iter().map(|(k, p)| (k.clone(), p.version)).collect();
        entries.sort();
        entries
    }

    pub fn pinned_len(&self) -> usize {
        self.pinned.len()
    }

    pub fn cached_len(&self) -> usize {
        self.lru.len()
    }
}

struct HierEntry {
    graph: Arc<CsrGraph>,
    params: HierarchyParams,
    hier: Arc<CoarseHierarchy>,
    stamp: u64,
    /// Bit `l` set ⇔ the level-`l` coarse graph is still exact for
    /// `graph`. Freshly built entries are fully valid; a `graph patch`
    /// re-keys the entry to the patched `Arc` and clears the bits the
    /// patch touched (bit 0 — the finest graph — always goes). Entries
    /// with a partial mask serve warm remaps only; [`HierarchyCache::get`]
    /// demands full validity.
    valid_mask: u64,
}

/// The fully-valid mask for a hierarchy with `levels` contractions:
/// bits `0..=levels` (capped at the `u64` width).
fn full_mask(levels: usize) -> u64 {
    let top = levels.min(u64::BITS as usize - 2);
    (1u64 << (top + 1)) - 1
}

/// Bounded LRU of built hierarchies. Lookup is a linear scan — the cap
/// is small and an entry is worth an entire coarsening pipeline.
pub struct HierarchyCache {
    cap: usize,
    stamp: u64,
    entries: Vec<HierEntry>,
}

impl HierarchyCache {
    pub fn new(cap: usize) -> Self {
        HierarchyCache { cap: cap.max(1), stamp: 0, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn position(&self, g: &Arc<CsrGraph>, params: &HierarchyParams) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| Arc::ptr_eq(&e.graph, g) && e.params == *params)
    }

    /// Look up the hierarchy for `(graph identity, params)`, refreshing
    /// its recency on a hit. Only **fully valid** entries hit — a cold
    /// multilevel solve needs every level exact; partially valid
    /// (patched) entries are reachable via [`HierarchyCache::get_partial`].
    pub fn get(&mut self, g: &Arc<CsrGraph>, params: &HierarchyParams) -> Option<Arc<CoarseHierarchy>> {
        let pos = self.position(g, params)?;
        if self.entries[pos].valid_mask != full_mask(self.entries[pos].hier.levels()) {
            return None;
        }
        self.stamp += 1;
        self.entries[pos].stamp = self.stamp;
        Some(self.entries[pos].hier.clone())
    }

    /// Look up regardless of validity, returning the hierarchy and its
    /// level-validity mask. The warm remap path uses this to account a
    /// `hier_cache=hit` when any coarse level survived the patch.
    pub fn get_partial(
        &mut self,
        g: &Arc<CsrGraph>,
        params: &HierarchyParams,
    ) -> Option<(Arc<CoarseHierarchy>, u64)> {
        let pos = self.position(g, params)?;
        self.stamp += 1;
        self.entries[pos].stamp = self.stamp;
        Some((self.entries[pos].hier.clone(), self.entries[pos].valid_mask))
    }

    /// Insert (or refresh) an entry, evicting the least recently used
    /// one when full. A fresh build is fully valid, so inserting over a
    /// partially valid re-keyed entry restores it.
    pub fn insert(&mut self, g: Arc<CsrGraph>, params: HierarchyParams, hier: Arc<CoarseHierarchy>) {
        self.stamp += 1;
        let valid_mask = full_mask(hier.levels());
        if let Some(pos) = self.position(&g, &params) {
            self.entries[pos].hier = hier;
            self.entries[pos].stamp = self.stamp;
            self.entries[pos].valid_mask = valid_mask;
            return;
        }
        if self.entries.len() >= self.cap {
            if let Some(oldest) =
                self.entries.iter().enumerate().min_by_key(|(_, e)| e.stamp).map(|(i, _)| i)
            {
                self.entries.swap_remove(oldest);
            }
        }
        let stamp = self.stamp;
        self.entries.push(HierEntry { graph: g, params, hier, stamp, valid_mask });
    }

    /// Re-key every entry of `old` onto the patched graph `new_g`,
    /// intersecting each entry's validity with `mask_of(hier)` (the
    /// patch's [`crate::incremental::level_validity_mask`]). Entries
    /// whose intersection leaves no valid level are dropped — they could
    /// never serve either path again.
    pub fn rekey_patched(
        &mut self,
        old: &Arc<CsrGraph>,
        new_g: &Arc<CsrGraph>,
        mask_of: impl Fn(&CoarseHierarchy) -> u64,
    ) {
        self.entries.retain_mut(|e| {
            if !Arc::ptr_eq(&e.graph, old) {
                return true;
            }
            let mask = e.valid_mask & mask_of(&e.hier);
            if mask == 0 {
                return false;
            }
            e.graph = new_g.clone();
            e.valid_mask = mask;
            true
        });
    }

    /// Drop every entry built for `g` (by identity). Called when a
    /// session graph is unpinned: the entries could never be hit again,
    /// yet would keep the graph — and its whole hierarchy — alive until
    /// LRU churn happened to evict them.
    pub fn purge_graph(&mut self, g: &Arc<CsrGraph>) {
        self.entries.retain(|e| !Arc::ptr_eq(&e.graph, g));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Arc<CsrGraph> {
        Arc::new(crate::graph::gen::grid2d(4, 4, false))
    }

    #[test]
    fn bounded_at_capacity() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("c".into(), g());
        assert_eq!(c.len(), 2);
        assert!(c.get("a").is_none(), "oldest entry evicted");
        assert!(c.get("b").is_some());
        assert!(c.get("c").is_some());
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert!(c.get("a").is_some()); // a is now newer than b
        c.insert("c".into(), g());
        assert!(c.get("b").is_none(), "b was the LRU entry");
        assert!(c.get("a").is_some());
    }

    #[test]
    fn reinsert_does_not_evict() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("a".into(), g()); // same key: no eviction needed
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_some());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut c = GraphCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hierarchy_cache_keys_on_graph_identity_and_params() {
        use crate::cancel::CancelToken;
        use crate::multilevel::{CoarsenConfig, SchemeKind};
        let build = |g: &Arc<CsrGraph>, params: &HierarchyParams| {
            Arc::new(
                CoarseHierarchy::build_serial(g, &params.build, &params.cfg, &CancelToken::new())
                    .unwrap(),
            )
        };
        let pa = HierarchyParams::device(&g(), 2, 0.03, CoarsenConfig::device());
        let pb = HierarchyParams::device(
            &g(),
            2,
            0.03,
            CoarsenConfig { scheme: SchemeKind::Cluster, ..CoarsenConfig::device() },
        );
        let (g1, g2, g3) = (g(), g(), g());
        let mut c = HierarchyCache::new(2);
        c.insert(g1.clone(), pa.clone(), build(&g1, &pa));
        assert!(c.get(&g1, &pa).is_some());
        // Same content, different Arc: identity miss.
        assert!(c.get(&g2, &pa).is_none());
        // Same graph, different scheme: param miss.
        assert!(c.get(&g1, &pb).is_none());
        // Bounded: inserting past the cap evicts the LRU entry.
        c.insert(g2.clone(), pa.clone(), build(&g2, &pa));
        assert!(c.get(&g1, &pa).is_some(), "refresh g1 so g2 is the LRU entry");
        c.insert(g3.clone(), pa.clone(), build(&g3, &pa));
        assert_eq!(c.len(), 2);
        assert!(c.get(&g2, &pa).is_none(), "LRU entry evicted");
        assert!(c.get(&g1, &pa).is_some());
        assert!(c.get(&g3, &pa).is_some());
    }

    #[test]
    fn pinned_graphs_survive_lru_churn_and_shadow_cached_names() {
        let mut s = GraphStore::new(1);
        let pinned = g();
        let (v, replaced) = s.pin("session".into(), pinned.clone());
        assert_eq!((v, replaced.is_none()), (1, true));
        s.insert_cached("a".into(), g());
        s.insert_cached("b".into(), g()); // evicts `a` from the LRU tier
        assert_eq!(s.cached_len(), 1);
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        // A pinned entry shadows a cached one of the same name.
        s.insert_cached("session".into(), g());
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        assert_eq!(s.pinned_names(), vec!["session".to_string()]);
        assert!(Arc::ptr_eq(&s.unpin("session").unwrap(), &pinned));
        assert!(s.unpin("session").is_none());
    }

    #[test]
    fn pin_and_patch_bump_the_session_version() {
        let mut s = GraphStore::new(1);
        let (g1, g2, g3) = (g(), g(), g());
        assert_eq!(s.pin("sess".into(), g1.clone()), (1, None));
        assert_eq!(s.pinned("sess").map(|(_, v)| v), Some(1));
        // Replacing via put returns the shadowed Arc and bumps.
        let (v, old) = s.pin("sess".into(), g2.clone());
        assert_eq!(v, 2);
        assert!(Arc::ptr_eq(&old.unwrap(), &g1));
        // Patching swaps in place and bumps again.
        let (v, old) = s.repin_patched("sess", g3.clone()).unwrap();
        assert_eq!(v, 3);
        assert!(Arc::ptr_eq(&old, &g2));
        assert!(Arc::ptr_eq(&s.pinned("sess").unwrap().0, &g3));
        assert_eq!(s.pinned_entries(), vec![("sess".to_string(), 3)]);
        assert!(s.repin_patched("nope", g()).is_none());
    }

    #[test]
    fn rekey_patched_masks_levels_and_gates_cold_hits() {
        use crate::cancel::CancelToken;
        use crate::multilevel::{CoarseHierarchy, CoarsenConfig};
        let g1 = Arc::new(crate::graph::gen::grid2d(12, 12, false));
        let g2 = Arc::new(crate::graph::gen::grid2d(12, 12, false));
        let params = HierarchyParams::device(&g1, 2, 0.03, CoarsenConfig::device());
        let hier = Arc::new(
            CoarseHierarchy::build_serial(&g1, &params.build, &params.cfg, &CancelToken::new())
                .unwrap(),
        );
        let levels = hier.levels();
        assert!(levels >= 1);
        let mut c = HierarchyCache::new(4);
        c.insert(g1.clone(), params.clone(), hier);
        // Fresh entry: fully valid, cold `get` hits.
        assert!(c.get(&g1, &params).is_some());
        // Patch keeps all levels except the finest: cold `get` misses,
        // `get_partial` serves with the reduced mask.
        let keep_coarse = full_mask(levels) & !1;
        c.rekey_patched(&g1, &g2, |_| keep_coarse);
        assert!(c.get(&g1, &params).is_none(), "old identity gone");
        assert!(c.get(&g2, &params).is_none(), "partial entry must not serve cold");
        let (_, mask) = c.get_partial(&g2, &params).unwrap();
        assert_eq!(mask, keep_coarse);
        // A rebuild over the re-keyed slot restores full validity.
        let rebuilt = Arc::new(
            CoarseHierarchy::build_serial(&g2, &params.build, &params.cfg, &CancelToken::new())
                .unwrap(),
        );
        c.insert(g2.clone(), params.clone(), rebuilt);
        assert!(c.get(&g2, &params).is_some());
        assert_eq!(c.len(), 1, "rekey + insert reuse one slot");
        // A mask intersection that leaves nothing drops the entry.
        c.rekey_patched(&g2, &g1, |_| 0);
        assert!(c.is_empty());
    }
}
