//! Graph storage for the engine: a bounded LRU cache for resolved
//! graphs (so a long-lived engine — the `heipa serve` coordinator in
//! particular — cannot grow memory without limit when clients cycle
//! through many instances) plus a **pinned session store** for graphs
//! uploaded once and mapped many times (`graph put` on the wire). Pinned
//! entries are exempt from LRU eviction and shared — as one
//! `Arc<CsrGraph>` — across jobs, workers and connections.
//!
//! Alongside it lives the [`HierarchyCache`]: bounded LRU of built
//! [`CoarseHierarchy`] instances keyed by **graph identity** (the
//! resolved `Arc`, compared by pointer — entries hold the `Arc` strongly,
//! so an address can never be reused while its entry lives) plus the
//! full [`HierarchyParams`]. Repeat jobs against a pinned session graph
//! — and `run_matrix` seed sweeps over one in-memory graph — skip the
//! Coarsening/Contraction phases entirely.

use crate::graph::CsrGraph;
use crate::multilevel::{CoarseHierarchy, HierarchyParams};
use std::collections::HashMap;
use std::sync::Arc;

/// Name → graph cache with least-recently-used eviction. Recency is a
/// monotonic stamp bumped on every hit; eviction is O(len), which is
/// irrelevant next to the cost of generating or parsing a graph.
#[derive(Debug)]
pub struct GraphCache {
    cap: usize,
    stamp: u64,
    map: HashMap<String, (u64, Arc<CsrGraph>)>,
}

impl GraphCache {
    /// `cap` is the maximum number of cached graphs (min 1).
    pub fn new(cap: usize) -> Self {
        GraphCache { cap: cap.max(1), stamp: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<CsrGraph>> {
        self.stamp += 1;
        let stamp = self.stamp;
        self.map.get_mut(key).map(|slot| {
            slot.0 = stamp;
            slot.1.clone()
        })
    }

    /// Insert `key`, evicting the least-recently-used entry when full.
    pub fn insert(&mut self, key: String, g: Arc<CsrGraph>) {
        if !self.map.contains_key(&key) && self.map.len() >= self.cap {
            if let Some(oldest) = self.map.iter().min_by_key(|(_, (s, _))| *s).map(|(k, _)| k.clone()) {
                self.map.remove(&oldest);
            }
        }
        self.stamp += 1;
        self.map.insert(key, (self.stamp, g));
    }
}

/// The engine's shared graph storage: pinned session graphs in front of
/// the LRU cache. Lookups prefer pinned entries, so an uploaded graph
/// shadows a registry instance of the same name for as long as it lives.
#[derive(Debug)]
pub struct GraphStore {
    pinned: HashMap<String, Arc<CsrGraph>>,
    lru: GraphCache,
}

impl GraphStore {
    pub fn new(lru_cap: usize) -> GraphStore {
        GraphStore { pinned: HashMap::new(), lru: GraphCache::new(lru_cap) }
    }

    /// Resolve `name`: pinned store first, then the LRU cache.
    pub fn get(&mut self, name: &str) -> Option<Arc<CsrGraph>> {
        if let Some(g) = self.pinned.get(name) {
            return Some(g.clone());
        }
        self.lru.get(name)
    }

    /// Cache a resolved (registry/file) graph in the LRU tier.
    pub fn insert_cached(&mut self, name: String, g: Arc<CsrGraph>) {
        self.lru.insert(name, g);
    }

    /// Pin a session graph under `name` (replacing any previous pin).
    pub fn pin(&mut self, name: String, g: Arc<CsrGraph>) {
        self.pinned.insert(name, g);
    }

    /// Drop a pinned graph, returning it so the caller can purge
    /// derived state (hierarchy-cache entries keyed on its identity);
    /// `None` when `name` was not pinned.
    pub fn unpin(&mut self, name: &str) -> Option<Arc<CsrGraph>> {
        self.pinned.remove(name)
    }

    /// Names of the pinned session graphs, sorted.
    pub fn pinned_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.pinned.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn pinned_len(&self) -> usize {
        self.pinned.len()
    }

    pub fn cached_len(&self) -> usize {
        self.lru.len()
    }
}

struct HierEntry {
    graph: Arc<CsrGraph>,
    params: HierarchyParams,
    hier: Arc<CoarseHierarchy>,
    stamp: u64,
}

/// Bounded LRU of built hierarchies. Lookup is a linear scan — the cap
/// is small and an entry is worth an entire coarsening pipeline.
pub struct HierarchyCache {
    cap: usize,
    stamp: u64,
    entries: Vec<HierEntry>,
}

impl HierarchyCache {
    pub fn new(cap: usize) -> Self {
        HierarchyCache { cap: cap.max(1), stamp: 0, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn position(&self, g: &Arc<CsrGraph>, params: &HierarchyParams) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| Arc::ptr_eq(&e.graph, g) && e.params == *params)
    }

    /// Look up the hierarchy for `(graph identity, params)`, refreshing
    /// its recency on a hit.
    pub fn get(&mut self, g: &Arc<CsrGraph>, params: &HierarchyParams) -> Option<Arc<CoarseHierarchy>> {
        let pos = self.position(g, params)?;
        self.stamp += 1;
        self.entries[pos].stamp = self.stamp;
        Some(self.entries[pos].hier.clone())
    }

    /// Insert (or refresh) an entry, evicting the least recently used
    /// one when full.
    pub fn insert(&mut self, g: Arc<CsrGraph>, params: HierarchyParams, hier: Arc<CoarseHierarchy>) {
        self.stamp += 1;
        if let Some(pos) = self.position(&g, &params) {
            self.entries[pos].hier = hier;
            self.entries[pos].stamp = self.stamp;
            return;
        }
        if self.entries.len() >= self.cap {
            if let Some(oldest) =
                self.entries.iter().enumerate().min_by_key(|(_, e)| e.stamp).map(|(i, _)| i)
            {
                self.entries.swap_remove(oldest);
            }
        }
        let stamp = self.stamp;
        self.entries.push(HierEntry { graph: g, params, hier, stamp });
    }

    /// Drop every entry built for `g` (by identity). Called when a
    /// session graph is unpinned: the entries could never be hit again,
    /// yet would keep the graph — and its whole hierarchy — alive until
    /// LRU churn happened to evict them.
    pub fn purge_graph(&mut self, g: &Arc<CsrGraph>) {
        self.entries.retain(|e| !Arc::ptr_eq(&e.graph, g));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Arc<CsrGraph> {
        Arc::new(crate::graph::gen::grid2d(4, 4, false))
    }

    #[test]
    fn bounded_at_capacity() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("c".into(), g());
        assert_eq!(c.len(), 2);
        assert!(c.get("a").is_none(), "oldest entry evicted");
        assert!(c.get("b").is_some());
        assert!(c.get("c").is_some());
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert!(c.get("a").is_some()); // a is now newer than b
        c.insert("c".into(), g());
        assert!(c.get("b").is_none(), "b was the LRU entry");
        assert!(c.get("a").is_some());
    }

    #[test]
    fn reinsert_does_not_evict() {
        let mut c = GraphCache::new(2);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        c.insert("a".into(), g()); // same key: no eviction needed
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_some());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut c = GraphCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert("a".into(), g());
        c.insert("b".into(), g());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hierarchy_cache_keys_on_graph_identity_and_params() {
        use crate::cancel::CancelToken;
        use crate::multilevel::{CoarsenConfig, SchemeKind};
        let build = |g: &Arc<CsrGraph>, params: &HierarchyParams| {
            Arc::new(
                CoarseHierarchy::build_serial(g, &params.build, &params.cfg, &CancelToken::new())
                    .unwrap(),
            )
        };
        let pa = HierarchyParams::device(&g(), 2, 0.03, CoarsenConfig::device());
        let pb = HierarchyParams::device(
            &g(),
            2,
            0.03,
            CoarsenConfig { scheme: SchemeKind::Cluster, ..CoarsenConfig::device() },
        );
        let (g1, g2, g3) = (g(), g(), g());
        let mut c = HierarchyCache::new(2);
        c.insert(g1.clone(), pa.clone(), build(&g1, &pa));
        assert!(c.get(&g1, &pa).is_some());
        // Same content, different Arc: identity miss.
        assert!(c.get(&g2, &pa).is_none());
        // Same graph, different scheme: param miss.
        assert!(c.get(&g1, &pb).is_none());
        // Bounded: inserting past the cap evicts the LRU entry.
        c.insert(g2.clone(), pa.clone(), build(&g2, &pa));
        assert!(c.get(&g1, &pa).is_some(), "refresh g1 so g2 is the LRU entry");
        c.insert(g3.clone(), pa.clone(), build(&g3, &pa));
        assert_eq!(c.len(), 2);
        assert!(c.get(&g2, &pa).is_none(), "LRU entry evicted");
        assert!(c.get(&g1, &pa).is_some());
        assert!(c.get(&g3, &pa).is_some());
    }

    #[test]
    fn pinned_graphs_survive_lru_churn_and_shadow_cached_names() {
        let mut s = GraphStore::new(1);
        let pinned = g();
        s.pin("session".into(), pinned.clone());
        s.insert_cached("a".into(), g());
        s.insert_cached("b".into(), g()); // evicts `a` from the LRU tier
        assert_eq!(s.cached_len(), 1);
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        // A pinned entry shadows a cached one of the same name.
        s.insert_cached("session".into(), g());
        assert!(Arc::ptr_eq(&s.get("session").unwrap(), &pinned));
        assert_eq!(s.pinned_names(), vec!["session".to_string()]);
        assert!(Arc::ptr_eq(&s.unpin("session").unwrap(), &pinned));
        assert!(s.unpin("session").is_none());
    }
}
