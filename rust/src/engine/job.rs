//! Job identity, lifecycle and handles for the asynchronous engine.
//!
//! [`crate::engine::Engine::submit`] enqueues a [`crate::engine::MapSpec`]
//! and returns a [`JobHandle`] immediately; the job itself runs on one of
//! the engine's workers. The handle is the only way to observe or steer a
//! job: [`JobHandle::status`] polls, [`JobHandle::wait`] blocks,
//! [`JobHandle::cancel`] trips the job's [`CancelToken`].

pub use crate::cancel::CancelToken;

use super::MapOutcome;
use anyhow::{anyhow, Result};
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Engine-wide job identity (monotonic, starts at 1). Printed bare on the
/// wire: `ok job=17`.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Lifecycle of a job. Terminal states are `Done`, `Failed`, `Cancelled`
/// and `Expired`; a job reaches exactly one of them, exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is solving it.
    Running,
    /// Finished; the outcome is available via `result`.
    Done,
    /// The solver (or graph/machine resolution) errored or panicked.
    Failed,
    /// Explicitly cancelled (before or during the solve).
    Cancelled,
    /// The per-job deadline passed (while queued, or mid-solve).
    Expired,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Expired => "expired",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled | JobState::Expired)
    }
}

/// Point-in-time snapshot of a job.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: JobId,
    pub state: JobState,
    /// Failure / cancellation detail (terminal non-`Done` states only).
    pub error: Option<String>,
    /// 1-based execution attempt (grows past 1 only under retry).
    pub attempts: u32,
}

/// Retry policy for failed attempts: a job whose solve fails (injected
/// fault, solver panic, resolution error) is re-queued up to
/// `max_attempts` total executions, sleeping a capped exponential
/// backoff between them — `base_backoff · 2^(attempt-1)`, capped at
/// [`RetryPolicy::MAX_BACKOFF`]. Retries are deadline- and cancel-aware:
/// a job whose remaining deadline cannot cover the backoff skips
/// straight to the degradation fallback chain, and a cancelled job is
/// never re-queued.
///
/// `max_attempts == 1` (the default) means no retries — failures go
/// directly to the fallback chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total execution attempts (≥ 1; 1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub base_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 1, base_backoff: Duration::from_millis(100) }
    }
}

impl RetryPolicy {
    /// Backoff cap — exponential growth never exceeds this.
    pub const MAX_BACKOFF: Duration = Duration::from_secs(5);

    /// Backoff to sleep after attempt number `attempt` (1-based) failed:
    /// `base · 2^(attempt-1)`, capped at [`Self::MAX_BACKOFF`].
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(1).min(32);
        self.base_backoff
            .checked_mul(1u32 << exp.min(31))
            .map_or(Self::MAX_BACKOFF, |d| d.min(Self::MAX_BACKOFF))
    }
}

/// Completion hook invoked by the worker *before* the terminal state
/// becomes observable through the handle, so side effects (metrics) are
/// ordered before any `wait` returns. Receives the terminal status and,
/// for `Done`, the outcome.
pub type CompletionHook = Arc<dyn Fn(&JobStatus, Option<&MapOutcome>) + Send + Sync>;

/// Options for [`crate::engine::Engine::submit_opts`].
#[derive(Clone, Default)]
pub struct SubmitOpts {
    /// Higher runs first; FIFO within a priority class.
    pub priority: i32,
    /// Reject (queued) or abort (running) the job once this much time has
    /// passed since submit.
    pub deadline: Option<Duration>,
    /// Block until queue space frees up instead of failing with
    /// [`SubmitError::Busy`]. In-process callers (CLI, harness) block;
    /// the wire front-end does not, surfacing `err code=busy`.
    pub block_when_full: bool,
    /// Invoked once, on whichever worker retires the job.
    pub on_complete: Option<CompletionHook>,
    /// Per-job retry policy; `None` inherits the engine's
    /// [`crate::engine::EngineConfig::retry`].
    pub retry: Option<RetryPolicy>,
}

/// Why a submit was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded job queue is full.
    Busy { cap: usize },
    /// The engine is shutting down.
    ShutDown,
    /// The service is draining (`drain` wire command): in-flight jobs
    /// finish, new admissions are refused.
    Draining,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Busy { cap } => write!(f, "job queue full (cap {cap})"),
            SubmitError::ShutDown => write!(f, "engine is shutting down"),
            SubmitError::Draining => write!(f, "service is draining"),
        }
    }
}

impl std::error::Error for SubmitError {}

pub(crate) struct JobCell {
    pub state: JobState,
    pub outcome: Option<MapOutcome>,
    pub error: Option<String>,
    /// 1-based execution attempt; bumped by [`JobHandle::requeue_for_retry`].
    pub attempts: u32,
}

pub(crate) struct JobShared {
    pub cell: Mutex<JobCell>,
    pub cv: Condvar,
    pub cancel: CancelToken,
    /// The completion hook fires exactly once per job, whichever path
    /// retires it (worker, shutdown drain, or a cancel that already
    /// transitioned the cell).
    hook_fired: std::sync::atomic::AtomicBool,
}

fn lock_cell(shared: &JobShared) -> MutexGuard<'_, JobCell> {
    // A panicking waiter cannot corrupt a JobCell (it only ever holds the
    // lock to read); recover instead of propagating the poison.
    shared.cell.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Handle to a submitted job. Clones observe the same job.
///
/// Cancellation contract: [`JobHandle::cancel`] marks a still-queued job
/// `Cancelled` immediately; a running job observes the token at the next
/// **coarsening-level or Jet-round boundary** (see
/// [`CancelToken`]) and returns within one such step — its partial result
/// is discarded, and [`JobHandle::wait`] yields an error.
#[derive(Clone)]
pub struct JobHandle {
    id: JobId,
    shared: Arc<JobShared>,
}

impl fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.status();
        f.debug_struct("JobHandle").field("id", &self.id).field("state", &st.state).finish()
    }
}

impl JobHandle {
    pub(crate) fn new_queued(id: JobId, cancel: CancelToken) -> JobHandle {
        JobHandle {
            id,
            shared: Arc::new(JobShared {
                cell: Mutex::new(JobCell {
                    state: JobState::Queued,
                    outcome: None,
                    error: None,
                    attempts: 1,
                }),
                cv: Condvar::new(),
                cancel,
                hook_fired: std::sync::atomic::AtomicBool::new(false),
            }),
        }
    }

    pub fn id(&self) -> JobId {
        self.id
    }

    /// The job's cancellation token (for threading into nested work).
    pub fn token(&self) -> &CancelToken {
        &self.shared.cancel
    }

    /// Request cancellation. A job still in the queue transitions to
    /// `Cancelled` right away; a running job stops at its next poll
    /// point. Idempotent; has no effect on already-terminal jobs.
    pub fn cancel(&self) {
        self.shared.cancel.cancel();
        let mut cell = lock_cell(&self.shared);
        if cell.state == JobState::Queued {
            cell.state = JobState::Cancelled;
            cell.error = Some("cancelled before start".into());
            self.shared.cv.notify_all();
        }
    }

    /// A queued job whose deadline has passed expires the moment anyone
    /// observes it — no worker needs to pop it first, so `status` never
    /// reports a stale `queued` past the deadline and `wait` does not
    /// outlive it. (A *running* job past its deadline keeps reporting
    /// `running` until the solver hits its next poll point — that is the
    /// cooperative-cancellation contract.)
    fn expire_if_overdue(&self, cell: &mut JobCell) {
        if cell.state == JobState::Queued && self.shared.cancel.deadline_exceeded() {
            cell.state = JobState::Expired;
            cell.error = Some("deadline exceeded while queued".into());
            self.shared.cv.notify_all();
        }
    }

    pub fn status(&self) -> JobStatus {
        let mut cell = lock_cell(&self.shared);
        self.expire_if_overdue(&mut cell);
        JobStatus {
            id: self.id,
            state: cell.state,
            error: cell.error.clone(),
            attempts: cell.attempts,
        }
    }

    pub fn is_finished(&self) -> bool {
        let mut cell = lock_cell(&self.shared);
        self.expire_if_overdue(&mut cell);
        cell.state.is_terminal()
    }

    /// Read the outcome without cloning it (metrics hooks, renderers).
    /// `f` sees `Some` only for `Done` jobs.
    pub fn peek_outcome<R>(&self, f: impl FnOnce(Option<&MapOutcome>) -> R) -> R {
        let cell = lock_cell(&self.shared);
        f(cell.outcome.as_ref())
    }

    fn result_of(id: JobId, cell: &JobCell) -> Result<MapOutcome> {
        match cell.state {
            JobState::Done => Ok(cell.outcome.clone().expect("done job has an outcome")),
            JobState::Failed => {
                Err(anyhow!("job {id} failed: {}", cell.error.as_deref().unwrap_or("unknown error")))
            }
            JobState::Cancelled => Err(anyhow!("job {id} cancelled")),
            JobState::Expired => Err(anyhow!("job {id} deadline exceeded")),
            JobState::Queued | JobState::Running => unreachable!("non-terminal result"),
        }
    }

    /// The outcome if the job already reached a terminal state.
    pub fn try_result(&self) -> Option<Result<MapOutcome>> {
        let mut cell = lock_cell(&self.shared);
        self.expire_if_overdue(&mut cell);
        cell.state.is_terminal().then(|| Self::result_of(self.id, &cell))
    }

    /// Block until the job is terminal; `Ok` only for `Done`. Sleeps are
    /// bounded by the job's deadline (if any), so a queued job expires on
    /// time even when every worker is busy elsewhere.
    pub fn wait(&self) -> Result<MapOutcome> {
        let mut cell = lock_cell(&self.shared);
        loop {
            self.expire_if_overdue(&mut cell);
            if cell.state.is_terminal() {
                break;
            }
            // Bound the sleep only while the deadline is still ahead (to
            // wake up and expire a queued job on time). Once it passed,
            // the loop-top check has done all it can — a running job
            // simply awaits the worker's notify.
            let pending = self.shared.cancel.deadline_remaining().filter(|l| *l > Duration::ZERO);
            cell = match pending {
                Some(left) => {
                    let (c, _) = self
                        .shared
                        .cv
                        .wait_timeout(cell, left)
                        .unwrap_or_else(PoisonError::into_inner);
                    c
                }
                None => self.shared.cv.wait(cell).unwrap_or_else(PoisonError::into_inner),
            };
        }
        Self::result_of(self.id, &cell)
    }

    /// Block up to `timeout`; `None` when the job is still pending.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<MapOutcome>> {
        let until = Instant::now() + timeout;
        let mut cell = lock_cell(&self.shared);
        loop {
            self.expire_if_overdue(&mut cell);
            if cell.state.is_terminal() {
                return Some(Self::result_of(self.id, &cell));
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let mut sleep = until - now;
            if let Some(left) =
                self.shared.cancel.deadline_remaining().filter(|l| *l > Duration::ZERO)
            {
                sleep = sleep.min(left);
            }
            let (c, _) = self
                .shared
                .cv
                .wait_timeout(cell, sleep)
                .unwrap_or_else(PoisonError::into_inner);
            cell = c;
        }
    }

    /// Publish the terminal state and fire the completion hook (exactly
    /// once per job, *before* waiters can observe the state, so metrics
    /// are consistent by the time `wait` returns). If the cell is already
    /// terminal (a cancel landed while the job was queued), the existing
    /// state wins — but the hook still fires with it.
    pub(crate) fn finish(
        &self,
        state: JobState,
        outcome: Option<MapOutcome>,
        error: Option<String>,
        hook: Option<&CompletionHook>,
    ) {
        use std::sync::atomic::Ordering;
        debug_assert!(state.is_terminal());
        let (pub_state, pub_error, pub_attempts) = {
            let cell = lock_cell(&self.shared);
            if cell.state.is_terminal() {
                (cell.state, cell.error.clone(), cell.attempts)
            } else {
                (state, error.clone(), cell.attempts)
            }
        };
        if let Some(h) = hook {
            if !self.shared.hook_fired.swap(true, Ordering::SeqCst) {
                let status = JobStatus {
                    id: self.id,
                    state: pub_state,
                    error: pub_error,
                    attempts: pub_attempts,
                };
                let out_ref = if pub_state == JobState::Done { outcome.as_ref() } else { None };
                h(&status, out_ref);
            }
        }
        let mut cell = lock_cell(&self.shared);
        if !cell.state.is_terminal() {
            cell.state = state;
            cell.outcome = outcome;
            cell.error = error;
        }
        self.shared.cv.notify_all();
    }

    /// Mark `Running`; returns false when the job is already terminal
    /// (cancelled while queued), in which case the worker must skip it.
    pub(crate) fn start_running(&self) -> bool {
        let mut cell = lock_cell(&self.shared);
        if cell.state.is_terminal() {
            return false;
        }
        cell.state = JobState::Running;
        true
    }

    /// Transition a failed attempt back to `Queued` for a retry, bumping
    /// the attempt counter. Returns false when the job already reached a
    /// terminal state (a cancel raced the failure) — the caller must not
    /// re-queue it.
    pub(crate) fn requeue_for_retry(&self) -> bool {
        let mut cell = lock_cell(&self.shared);
        if cell.state.is_terminal() {
            return false;
        }
        cell.state = JobState::Queued;
        cell.attempts += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queued_job_cancel_is_immediate() {
        let h = JobHandle::new_queued(JobId(7), CancelToken::new());
        assert_eq!(h.status().state, JobState::Queued);
        h.cancel();
        assert_eq!(h.status().state, JobState::Cancelled);
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        assert!(!h.start_running(), "terminal job must not start");
    }

    #[test]
    fn queued_job_expires_on_observation_without_a_worker() {
        // No worker ever pops this handle: the deadline must still be
        // honored — wait() wakes itself at the deadline and status flips
        // to Expired instead of reporting a stale `queued` forever.
        let h =
            JobHandle::new_queued(JobId(9), CancelToken::with_deadline(Duration::from_millis(20)));
        assert_eq!(h.status().state, JobState::Queued);
        let t0 = Instant::now();
        let err = h.wait().unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5), "wait outlived the deadline");
        assert!(err.contains("deadline"), "{err}");
        assert_eq!(h.status().state, JobState::Expired);
    }

    #[test]
    fn wait_timeout_reports_pending() {
        let h = JobHandle::new_queued(JobId(1), CancelToken::new());
        assert!(h.wait_timeout(Duration::from_millis(10)).is_none());
        assert!(!h.is_finished());
    }

    #[test]
    fn retry_backoff_doubles_and_caps() {
        let p = RetryPolicy { max_attempts: 10, base_backoff: Duration::from_millis(100) };
        assert_eq!(p.backoff_for(1), Duration::from_millis(100));
        assert_eq!(p.backoff_for(2), Duration::from_millis(200));
        assert_eq!(p.backoff_for(3), Duration::from_millis(400));
        assert_eq!(p.backoff_for(7), Duration::from_millis(6400).min(RetryPolicy::MAX_BACKOFF));
        assert_eq!(p.backoff_for(40), RetryPolicy::MAX_BACKOFF, "huge exponents must cap");
        let z = RetryPolicy { max_attempts: 3, base_backoff: Duration::ZERO };
        assert_eq!(z.backoff_for(5), Duration::ZERO, "zero base stays zero");
    }

    #[test]
    fn requeue_bumps_attempts_and_respects_terminal_states() {
        let h = JobHandle::new_queued(JobId(4), CancelToken::new());
        assert_eq!(h.status().attempts, 1);
        assert!(h.start_running());
        assert!(h.requeue_for_retry());
        assert_eq!(h.status().state, JobState::Queued);
        assert_eq!(h.status().attempts, 2);
        assert!(h.start_running());
        h.cancel();
        h.finish(JobState::Cancelled, None, Some("cancelled".into()), None);
        assert!(!h.requeue_for_retry(), "terminal jobs must not re-queue");
        assert_eq!(h.status().attempts, 2);
    }

    #[test]
    fn finish_is_idempotent_and_fires_hook_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let h = JobHandle::new_queued(JobId(3), CancelToken::new());
        let hits = Arc::new(AtomicU64::new(0));
        let hits2 = hits.clone();
        let hook: CompletionHook = Arc::new(move |st, out| {
            assert_eq!(st.state, JobState::Failed);
            assert!(out.is_none());
            hits2.fetch_add(1, Ordering::SeqCst);
        });
        assert!(h.start_running());
        h.finish(JobState::Failed, None, Some("boom".into()), Some(&hook));
        h.finish(JobState::Done, None, None, Some(&hook));
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        assert_eq!(h.status().state, JobState::Failed);
        assert!(h.try_result().unwrap().unwrap_err().to_string().contains("boom"));
    }
}
