//! Name-indexed solver registry: all nine algorithms of the paper's
//! evaluation behind the one [`Solver`] trait — no dispatch `match`
//! anywhere else in the crate.

use super::{Backend, CancelToken, EngineCtx, MapOutcome, MapSpec, Solver};
use crate::algo::{gpu_hm, gpu_im, intmap, jet, sharedmap, Algorithm};
use crate::graph::CsrGraph;
use crate::metrics::PhaseBreakdown;
use crate::multilevel::{CoarsenConfig, HierarchyHandle, HierarchyParams};
use crate::par::cost::DeviceTimer;
use crate::partition::{comm_cost, imbalance};
use crate::topology::Machine;
use crate::Block;

/// Time a solver run and assemble the [`MapOutcome`]: device solvers get
/// the modeled device timeline (phase sum vs ledger, whichever is larger),
/// CPU baselines their wall time.
fn measured(
    algo: Algorithm,
    g: &CsrGraph,
    m: &Machine,
    seed: u64,
    run: impl FnOnce(&mut PhaseBreakdown) -> Vec<Block>,
) -> MapOutcome {
    let mut phases = PhaseBreakdown::default();
    let timer = DeviceTimer::start();
    let mapping = run(&mut phases);
    let meas = timer.stop();
    let device_ms =
        if algo.is_device() { phases.total_device_ms().max(meas.device_ms) } else { meas.host_ms };
    MapOutcome {
        algorithm: algo,
        n: g.n(),
        k: m.k(),
        seed,
        comm_cost: comm_cost(g, &mapping, m),
        imbalance: imbalance(g, &mapping, m.k()),
        mapping,
        host_ms: meas.host_ms,
        device_ms,
        phases: if algo.is_device() { Some(phases) } else { None },
        polish_improvement: 0.0,
        hierarchy_cache: None,
        degraded: false,
        attempts: 1,
        remap: None,
        // Solvers don't know how the engine resolved the backend; the
        // engine overwrites this right after `solve` returns.
        backend: Backend::Cpu,
    }
}

/// The coarsening configuration of the device multilevel pipelines for a
/// spec — the single definition both [`Solver::hierarchy_params`] and the
/// solver configs derive from, so the cache key can never diverge from
/// what `solve` actually builds.
fn device_coarsen(spec: &MapSpec) -> CoarsenConfig {
    CoarsenConfig { scheme: spec.coarsening, ..CoarsenConfig::device() }
}

/// GPU hierarchical multisection (paper Alg. 2 with Jet). Honors the
/// `adaptive` option (Eq. 2 ablation).
pub struct GpuHmSolver {
    ultra: bool,
}

impl Solver for GpuHmSolver {
    fn algorithm(&self) -> Algorithm {
        if self.ultra {
            Algorithm::GpuHmUltra
        } else {
            Algorithm::GpuHm
        }
    }

    fn solve(
        &self,
        ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        _hier: Option<&HierarchyHandle>,
    ) -> MapOutcome {
        let mut cfg = if self.ultra { gpu_hm::GpuHmConfig::ultra() } else { gpu_hm::GpuHmConfig::default_flavor() };
        if let Some(adaptive) = spec.opt_bool("adaptive") {
            cfg.adaptive = adaptive;
        }
        // The multisection recursion partitions fresh subgraphs at every
        // node, so GPU-HM has no engine-cacheable hierarchy; the scheme
        // knob still reaches the inner Jet partitioner.
        cfg.jet.coarsen = device_coarsen(spec);
        cfg.cancel = cancel.clone();
        cfg.jet.cancel = cancel.clone();
        let seed = spec.primary_seed();
        measured(self.algorithm(), g, m, seed, |ph| {
            gpu_hm::gpu_hm(ctx.pool(), g, m, spec.eps, seed, &cfg, Some(ph))
        })
    }
}

/// GPU integrated mapping (paper Alg. 3–6). Honors the
/// `rebalance_comm_obj` option (ablation A2: rebalance with the J loss
/// instead of the edge-cut loss).
pub struct GpuImSolver;

impl Solver for GpuImSolver {
    fn algorithm(&self) -> Algorithm {
        Algorithm::GpuIm
    }

    fn hierarchy_params(&self, g: &CsrGraph, m: &Machine, spec: &MapSpec) -> Option<HierarchyParams> {
        Some(HierarchyParams::device(g, m.k(), spec.eps, device_coarsen(spec)))
    }

    fn solve(
        &self,
        ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        hier: Option<&HierarchyHandle>,
    ) -> MapOutcome {
        let mut cfg =
            gpu_im::GpuImConfig { coarsen: device_coarsen(spec), ..gpu_im::GpuImConfig::default() };
        if let Some(v) = spec.opt_bool("rebalance_comm_obj") {
            cfg.rebalance_with_comm_obj = v;
        }
        cfg.cancel = cancel.clone();
        cfg.init.cancel = cancel.clone();
        let seed = spec.primary_seed();
        let mut out = measured(self.algorithm(), g, m, seed, |ph| match hier {
            Some(h) => {
                if !h.cached {
                    // This job triggered the build: its phase times (and
                    // the modeled H2D charge) belong to this outcome.
                    ph.merge(h.hier.phases());
                }
                gpu_im::gpu_im_with(ctx.pool(), g, m, spec.eps, seed, &cfg, Some(ph), Some(h.hier.as_ref()))
            }
            None => gpu_im::gpu_im(ctx.pool(), g, m, spec.eps, seed, &cfg, Some(ph)),
        });
        if let Some(h) = hier {
            if !h.cached {
                // The engine built the hierarchy just before this solve
                // (outside the timer): its wall time belongs to this
                // job's host_ms; device time is already in the merged
                // phase breakdown.
                out.host_ms += h.hier.phases().total_host_ms();
            }
        }
        out.hierarchy_cache = hier.map(|h| h.cached);
        out
    }
}

/// SharedMap-like serial multisection baseline.
pub struct SharedMapSolver {
    strong: bool,
}

impl Solver for SharedMapSolver {
    fn algorithm(&self) -> Algorithm {
        if self.strong {
            Algorithm::SharedMapS
        } else {
            Algorithm::SharedMapF
        }
    }

    fn solve(
        &self,
        _ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        _hier: Option<&HierarchyHandle>,
    ) -> MapOutcome {
        let mut cfg = if self.strong { sharedmap::SharedMapConfig::strong() } else { sharedmap::SharedMapConfig::fast() };
        cfg.ml.coarsen.scheme = spec.coarsening;
        cfg.cancel = cancel.clone();
        let seed = spec.primary_seed();
        measured(self.algorithm(), g, m, seed, |_ph| sharedmap::sharedmap(g, m, spec.eps, seed, &cfg))
    }
}

/// IntMap-like serial integrated mapping baseline.
pub struct IntMapSolver {
    strong: bool,
}

impl Solver for IntMapSolver {
    fn algorithm(&self) -> Algorithm {
        if self.strong {
            Algorithm::IntMapS
        } else {
            Algorithm::IntMapF
        }
    }

    fn solve(
        &self,
        _ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        _hier: Option<&HierarchyHandle>,
    ) -> MapOutcome {
        let mut cfg = if self.strong { intmap::IntMapConfig::strong() } else { intmap::IntMapConfig::fast() };
        cfg.coarsen.scheme = spec.coarsening;
        cfg.cancel = cancel.clone();
        cfg.init.cancel = cancel.clone();
        let seed = spec.primary_seed();
        measured(self.algorithm(), g, m, seed, |_ph| intmap::intmap(g, m, spec.eps, seed, &cfg))
    }
}

/// Plain edge-cut Jet (§5.4: unfit for mapping by construction; kept as
/// the paper's ablation).
pub struct JetSolver {
    ultra: bool,
}

impl Solver for JetSolver {
    fn algorithm(&self) -> Algorithm {
        if self.ultra {
            Algorithm::JetUltra
        } else {
            Algorithm::Jet
        }
    }

    fn hierarchy_params(&self, g: &CsrGraph, m: &Machine, spec: &MapSpec) -> Option<HierarchyParams> {
        // Identical parameters to GPU-IM on the same (graph, k, eps), so
        // the two solvers share cache entries — the hierarchy is
        // objective-agnostic.
        Some(HierarchyParams::device(g, m.k(), spec.eps, device_coarsen(spec)))
    }

    fn solve(
        &self,
        ctx: &EngineCtx,
        g: &CsrGraph,
        m: &Machine,
        spec: &MapSpec,
        cancel: &CancelToken,
        hier: Option<&HierarchyHandle>,
    ) -> MapOutcome {
        let mut cfg = if self.ultra { jet::JetPartConfig::ultra() } else { jet::JetPartConfig::default() };
        cfg.coarsen = device_coarsen(spec);
        cfg.cancel = cancel.clone();
        let seed = spec.primary_seed();
        let mut out = measured(self.algorithm(), g, m, seed, |ph| match hier {
            Some(h) => {
                if !h.cached {
                    ph.merge(h.hier.phases());
                }
                jet::jet_partition_with(ctx.pool(), g, m.k(), spec.eps, seed, &cfg, Some(ph), Some(h.hier.as_ref()))
            }
            None => jet::jet_partition(ctx.pool(), g, m.k(), spec.eps, seed, &cfg, Some(ph)),
        });
        if let Some(h) = hier {
            if !h.cached {
                out.host_ms += h.hier.phases().total_host_ms();
            }
        }
        out.hierarchy_cache = hier.map(|h| h.cached);
        out
    }
}

static GPU_HM: GpuHmSolver = GpuHmSolver { ultra: false };
static GPU_HM_ULTRA: GpuHmSolver = GpuHmSolver { ultra: true };
static GPU_IM: GpuImSolver = GpuImSolver;
static SHAREDMAP_F: SharedMapSolver = SharedMapSolver { strong: false };
static SHAREDMAP_S: SharedMapSolver = SharedMapSolver { strong: true };
static INTMAP_F: IntMapSolver = IntMapSolver { strong: false };
static INTMAP_S: IntMapSolver = IntMapSolver { strong: true };
static JET: JetSolver = JetSolver { ultra: false };
static JET_ULTRA: JetSolver = JetSolver { ultra: true };

static REGISTRY: [&(dyn Solver); 9] = [
    &GPU_HM,
    &GPU_HM_ULTRA,
    &GPU_IM,
    &SHAREDMAP_F,
    &SHAREDMAP_S,
    &INTMAP_F,
    &INTMAP_S,
    &JET,
    &JET_ULTRA,
];

/// Every registered solver, in the paper's presentation order.
pub fn solvers() -> &'static [&'static dyn Solver] {
    &REGISTRY
}

/// The solver for an [`Algorithm`] id. The registry covers the whole enum.
pub fn solver(algo: Algorithm) -> &'static dyn Solver {
    REGISTRY
        .iter()
        .copied()
        .find(|s| s.algorithm() == algo)
        .expect("registry covers every Algorithm")
}

/// Name-indexed lookup (`gpu-im`, `sharedmap-s`, …).
pub fn solver_by_name(name: &str) -> Option<&'static dyn Solver> {
    REGISTRY.iter().copied().find(|s| s.name() == name)
}

/// All registered solver names.
pub fn solver_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_algorithm_and_name() {
        for a in Algorithm::all() {
            assert_eq!(solver(a).algorithm(), a);
            let by_name = solver_by_name(a.name()).expect("name resolves");
            assert_eq!(by_name.algorithm(), a);
        }
        assert!(solver_by_name("nope").is_none());
        assert_eq!(solver_names().len(), Algorithm::all().len());
    }

    #[test]
    fn every_solver_solves_a_smoke_instance() {
        let g = crate::graph::gen::grid2d(20, 20, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let ctx = EngineCtx::host_only(crate::par::Pool::new(1));
        let spec = MapSpec::named("unused");
        for s in solvers() {
            let out = s.solve(&ctx, &g, &h, &spec, &CancelToken::new(), None);
            crate::partition::validate_mapping(&out.mapping, g.n(), h.k())
                .unwrap_or_else(|e| panic!("{}: {e}", s.name()));
            assert!(out.comm_cost > 0.0, "{}", s.name());
            assert!(out.host_ms > 0.0, "{}", s.name());
            assert_eq!(out.phases.is_some(), out.algorithm.is_device(), "{}", s.name());
        }
    }

    #[test]
    fn every_solver_bails_fast_on_a_cancelled_token() {
        // A pre-cancelled token must still yield a structurally valid
        // mapping (the engine discards it) — and must not loop to
        // completion on a graph large enough to coarsen.
        let g = crate::graph::gen::grid2d(40, 40, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let ctx = EngineCtx::host_only(crate::par::Pool::new(1));
        let spec = MapSpec::named("unused");
        let cancelled = CancelToken::new();
        cancelled.cancel();
        for s in solvers() {
            let out = s.solve(&ctx, &g, &h, &spec, &cancelled, None);
            assert_eq!(out.mapping.len(), g.n(), "{}", s.name());
            assert!(
                out.mapping.iter().all(|&b| (b as usize) < h.k()),
                "{}: out-of-range block in cancelled result",
                s.name()
            );
        }
    }

    #[test]
    fn gpu_hm_honors_adaptive_option() {
        // Just behavioral smoke: both settings produce valid mappings.
        let g = crate::graph::gen::grid2d(24, 24, false);
        let h = Machine::hier("4:4:2", "1:10:100").unwrap();
        let ctx = EngineCtx::host_only(crate::par::Pool::new(1));
        for v in ["1", "0"] {
            let spec = MapSpec::named("unused").option("adaptive", v);
            let out = solver(Algorithm::GpuHm).solve(&ctx, &g, &h, &spec, &CancelToken::new(), None);
            crate::partition::validate_mapping(&out.mapping, g.n(), h.k()).unwrap();
        }
    }
}
