//! Coarsening: edge ratings, matchings, and graph contraction.
//!
//! Serial variants feed the CPU baselines (SharedMap/IntMap-like solvers)
//! and act as differential-testing oracles for the device kernels:
//! the parallel preference matching + two-hop matching ([`match_par`],
//! [`twohop`]) and the CAS-hash contraction of paper Alg. 3
//! ([`contract_cas`]).

pub mod contract_cas;
pub mod match_par;
pub mod twohop;

use crate::graph::CsrGraph;
use crate::rng::{edge_noise, Rng};
use crate::{EWeight, VWeight, Vertex};

/// The `expansion*²` edge rating of Holtgrewe et al. used by the paper:
/// `ω({u,v})² / (c(u)·c(v))` — prefers heavy edges between light vertices.
#[inline]
pub fn rating_exp2(w: EWeight, cu: VWeight, cv: VWeight) -> f64 {
    (w * w) / (cu as f64 * cv as f64)
}

/// The plain `expansion*` rating used by IntMap: `ω/(c(u)·c(v))`.
#[inline]
pub fn rating_exp(w: EWeight, cu: VWeight, cv: VWeight) -> f64 {
    w / (cu as f64 * cv as f64)
}

/// A matching stored as `mate[v] == u` (and `mate[u] == v`); unmatched
/// vertices have `mate[v] == v`.
pub type Matching = Vec<Vertex>;

/// Serial greedy heavy-edge matching with the `expansion*²` rating and
/// deterministic noise (baseline / oracle for the parallel matcher).
/// Pairs whose combined weight exceeds `max_pair_weight` are skipped.
pub fn serial_hem(g: &CsrGraph, max_pair_weight: VWeight, seed: u64) -> Matching {
    let n = g.n();
    let mut mate: Matching = (0..n as Vertex).collect();
    let mut order: Vec<Vertex> = (0..n as Vertex).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);
    for &v in &order {
        if mate[v as usize] != v {
            continue;
        }
        let (nbrs, ws) = g.neighbors_w(v);
        let mut best: Option<(f64, Vertex)> = None;
        for (&u, &w) in nbrs.iter().zip(ws) {
            if mate[u as usize] != u || g.vw[v as usize] + g.vw[u as usize] > max_pair_weight {
                continue;
            }
            let r = rating_exp2(w, g.vw[v as usize], g.vw[u as usize])
                + 1e-12 * edge_noise(v, u, seed);
            if best.map(|(br, _)| r > br).unwrap_or(true) {
                best = Some((r, u));
            }
        }
        if let Some((_, u)) = best {
            mate[v as usize] = u;
            mate[u as usize] = v;
        }
    }
    mate
}

/// Fraction of matched vertices.
pub fn matched_fraction(mate: &Matching) -> f64 {
    if mate.is_empty() {
        return 0.0;
    }
    let matched = mate.iter().enumerate().filter(|&(v, &m)| m as usize != v).count();
    matched as f64 / mate.len() as f64
}

/// Turn a matching into a coarse-vertex map `M : V → [n_c]`
/// (pair leader = smaller endpoint). Returns `(map, n_c)`.
pub fn matching_to_map(mate: &Matching) -> (Vec<Vertex>, usize) {
    let n = mate.len();
    let mut map = vec![u32::MAX; n];
    let mut nc = 0u32;
    for v in 0..n {
        let m = mate[v] as usize;
        debug_assert_eq!(mate[m] as usize, v, "matching not symmetric at {v}");
        if v <= m {
            map[v] = nc;
            map[m] = nc;
            nc += 1;
        }
    }
    (map, nc as usize)
}

/// Serial contraction oracle: contract along `map : V → [n_c]`, fusing
/// parallel edges (weights summed) and dropping self loops. O(n + m) with
/// an epoch-marker array.
pub fn contract_serial(g: &CsrGraph, map: &[Vertex], nc: usize) -> CsrGraph {
    let n = g.n();
    // Inverse lists: coarse vertex → fine members (counting sort).
    let mut count = vec![0u32; nc + 1];
    for v in 0..n {
        count[map[v] as usize + 1] += 1;
    }
    for c in 0..nc {
        count[c + 1] += count[c];
    }
    let mut members = vec![0 as Vertex; n];
    let mut pos = count.clone();
    for v in 0..n {
        members[pos[map[v] as usize] as usize] = v as Vertex;
        pos[map[v] as usize] += 1;
    }

    let mut xadj = vec![0u32; nc + 1];
    let mut adj: Vec<Vertex> = Vec::with_capacity(g.adj.len() / 2);
    let mut ew: Vec<EWeight> = Vec::with_capacity(g.adj.len() / 2);
    let mut vw = vec![0 as VWeight; nc];
    let mut marker = vec![u32::MAX; nc];
    let mut slot_of = vec![0u32; nc];
    for c in 0..nc {
        let start = adj.len();
        for &v in &members[count[c] as usize..count[c + 1] as usize] {
            vw[c] += g.vw[v as usize];
            let (nbrs, ws) = g.neighbors_w(v);
            for (&u, &w) in nbrs.iter().zip(ws) {
                let cu = map[u as usize] as usize;
                if cu == c {
                    continue; // self loop discarded
                }
                if marker[cu] != c as u32 {
                    marker[cu] = c as u32;
                    slot_of[cu] = adj.len() as u32;
                    adj.push(cu as Vertex);
                    ew.push(w);
                } else {
                    ew[slot_of[cu] as usize] += w;
                }
            }
        }
        // Sort this vertex's slice for CSR invariants.
        let slice: Vec<(Vertex, EWeight)> = adj[start..]
            .iter()
            .cloned()
            .zip(ew[start..].iter().cloned())
            .collect();
        let mut slice = slice;
        slice.sort_unstable_by_key(|&(t, _)| t);
        for (i, (t, w)) in slice.into_iter().enumerate() {
            adj[start + i] = t;
            ew[start + i] = w;
        }
        xadj[c + 1] = adj.len() as u32;
    }
    let out = CsrGraph { xadj, adj, ew, vw };
    debug_assert!(out.validate().is_ok());
    out
}

/// One serial coarsening step: HEM + contract. Returns `(coarse, map)`.
pub fn coarsen_step_serial(g: &CsrGraph, max_pair_weight: VWeight, seed: u64) -> (CsrGraph, Vec<Vertex>) {
    let mate = serial_hem(g, max_pair_weight, seed);
    let (map, nc) = matching_to_map(&mate);
    let coarse = contract_serial(g, &map, nc);
    (coarse, map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn hem_is_a_matching() {
        let g = gen::grid2d(10, 10, false);
        let mate = serial_hem(&g, i64::MAX, 1);
        for v in 0..g.n() {
            let m = mate[v] as usize;
            assert_eq!(mate[m] as usize, v);
            if m != v {
                assert!(g.find_edge(v as u32, m as u32).is_some(), "matched non-edge");
            }
        }
        assert!(matched_fraction(&mate) > 0.5);
    }

    #[test]
    fn hem_respects_weight_cap() {
        let mut g = gen::grid2d(6, 1, false);
        g.vw = vec![10, 10, 1, 1, 10, 10];
        let mate = serial_hem(&g, 11, 2);
        for v in 0..g.n() {
            let m = mate[v] as usize;
            if m != v {
                assert!(g.vw[v] + g.vw[m] <= 11);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 2000-vertex rgg generation + full contraction oracle, too slow
    fn contraction_preserves_totals() {
        let g = gen::rgg(2_000, 0.06, 3);
        let (coarse, map) = coarsen_step_serial(&g, i64::MAX, 4);
        assert_eq!(coarse.total_vweight(), g.total_vweight());
        // Total edge weight = original minus weights of intra-pair edges.
        let mut intra = 0.0;
        for v in 0..g.n() {
            let (nbrs, ws) = g.neighbors_w(v as u32);
            for (&u, &w) in nbrs.iter().zip(ws) {
                if map[v] == map[u as usize] {
                    intra += w;
                }
            }
        }
        let expect = g.total_eweight() - intra / 2.0;
        assert!((coarse.total_eweight() - expect).abs() < 1e-6 * expect.max(1.0));
        coarse.validate().unwrap();
    }

    #[test]
    fn contraction_shrinks() {
        let g = gen::grid2d(20, 20, false);
        let (coarse, _) = coarsen_step_serial(&g, i64::MAX, 5);
        assert!(coarse.n() < g.n());
        assert!(coarse.n() >= g.n() / 2);
    }

    #[test]
    fn map_is_surjective_onto_range() {
        let g = gen::grid2d(8, 8, false);
        let mate = serial_hem(&g, i64::MAX, 6);
        let (map, nc) = matching_to_map(&mate);
        let mut seen = vec![false; nc];
        for &c in &map {
            seen[c as usize] = true;
        }
        assert!(seen.into_iter().all(|s| s));
    }

    #[test]
    fn rating_prefers_heavy_light() {
        assert!(rating_exp2(4.0, 1, 1) > rating_exp2(2.0, 1, 1));
        assert!(rating_exp2(2.0, 1, 1) > rating_exp2(2.0, 4, 1));
    }
}
