//! GPU-style contraction — paper Algorithm 3.
//!
//! Edge-parallel contraction over the extended CSR edge list `𝔼`: each
//! coarse vertex gets a hash interval sized by the (overestimated) sum of
//! its members' degrees; every directed fine edge `(u, v, w)` with
//! `M(u) ≠ M(v)` inserts `(M(v), w)` into `M(u)`'s interval with a CAS on
//! the vertex slot and an atomic f64 add on the weight slot. Extraction
//! compacts the hash arrays into CSR form via prefix sums.

use crate::graph::{CsrGraph, EdgeList};
use crate::par::{atomic_f64_add, ledger, Pool};
use crate::runtime::device;
use crate::{EWeight, VWeight, Vertex};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

const NULL: u32 = u32::MAX;

/// Contract `g` along `map : V → [n_c]` using the CAS-hash scheme of
/// Algorithm 3. Produces a sorted, validated CSR graph.
pub fn contract_cas(pool: &Pool, g: &CsrGraph, el: &EdgeList, map: &[Vertex], nc: usize) -> CsrGraph {
    let n = g.n();
    let md = g.num_directed();

    // Lines 1–3: per-coarse-vertex degree upper bounds (atomic adds).
    let bounds: Vec<AtomicU32> = (0..nc).map(|_| AtomicU32::new(0)).collect();
    {
        let _k = ledger::kernel("coarsen/contract_cas:bounds");
        pool.parallel_for(n, |v| {
            // relaxed: commutative tally; totals are read only after the
            // kernel barrier, which publishes them.
            bounds[map[v] as usize].fetch_add(g.degree(v as Vertex) as u32, Ordering::Relaxed);
        });
    }

    // Line 6: offsets via prefix sum.
    let _k = ledger::kernel("coarsen/contract_cas:offsets_scan");
    // relaxed: bounds are frozen once the kernel above has barriered.
    let offsets = pool.scan_exclusive(nc, |c| bounds[c].load(Ordering::Relaxed) as u64);
    drop(_k);
    debug_assert_eq!(offsets[nc] as usize, md);

    // Lines 4–5: hash arrays.
    let hv: Vec<AtomicU32> = (0..md).map(|_| AtomicU32::new(NULL)).collect();
    let hw: Vec<AtomicU64> = (0..md).map(|_| AtomicU64::new(0f64.to_bits())).collect();

    // Device branch for the gather half: one launch maps both endpoints
    // of every directed edge through `map` against the session's
    // device-resident edge list. A pure index gather, so the arrays are
    // bit-identical to the host lookups; the CAS insert below is the
    // same on both backends.
    let gathered = device::contract_gather(g, map);

    // Lines 7–10: edge-parallel insertion.
    let _k = ledger::kernel("coarsen/contract_cas:insert");
    pool.parallel_for(md, |i| {
        let (cu, cv) = match &gathered {
            Some((cus, cvs)) => (cus[i] as usize, cvs[i]),
            None => (map[el.eu[i] as usize] as usize, map[g.adj[i] as usize]),
        };
        if cu == cv as usize {
            return; // self loop discarded
        }
        let w = g.ew[i];
        let start = offsets[cu] as usize;
        let len = (offsets[cu + 1] - offsets[cu]) as usize;
        debug_assert!(len > 0);
        // Hash the target then linear-probe the interval.
        let mut slot = (crate::rng::hash_u64(cv as u64) % len as u64) as usize;
        loop {
            let idx = start + slot;
            // relaxed: the CAS claims the slot atomically; the weight cell
            // is itself atomic (so no data is published *through* the
            // claim), and the extraction kernels read both only after the
            // barrier. Claim/fuse outcome depends solely on this one
            // location's modification order.
            match hv[idx].compare_exchange(NULL, cv, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => {
                    // We claimed this slot for cv.
                    atomic_f64_add(&hw[idx], w);
                    return;
                }
                Err(existing) if existing == cv => {
                    // Edge already present: fuse weights.
                    atomic_f64_add(&hw[idx], w);
                    return;
                }
                Err(_) => {
                    slot = (slot + 1) % len;
                }
            }
        }
    });
    drop(_k);

    // Line 11: ExtractCSR — count true degrees, scan, compact.
    // (§Perf opt 3: vertex-parallel interval scan instead of an
    // edge-parallel loop with a binary search per slot.)
    let true_deg: Vec<AtomicU32> = (0..nc).map(|_| AtomicU32::new(0)).collect();
    {
        let _k = ledger::kernel("coarsen/contract_cas:true_deg");
        pool.parallel_for(nc, |c| {
            let mut d = 0u32;
            // relaxed: hash slots are frozen after the insertion barrier;
            // `true_deg[c]` is written only by unit `c`.
            for i in offsets[c] as usize..offsets[c + 1] as usize {
                d += (hv[i].load(Ordering::Relaxed) != NULL) as u32;
            }
            true_deg[c].store(d, Ordering::Relaxed);
        });
    }
    let _k = ledger::kernel("coarsen/contract_cas:xadj_scan");
    // relaxed: true degrees are frozen after the barrier above.
    let xadj_scan = pool.scan_exclusive(nc, |c| true_deg[c].load(Ordering::Relaxed) as u64);
    drop(_k);
    let m_out = xadj_scan[nc] as usize;

    let mut adj = vec![0 as Vertex; m_out];
    let mut ew = vec![0 as EWeight; m_out];
    {
        let adj_ptr = crate::par::SharedMut::new(&mut adj);
        let ew_ptr = crate::par::SharedMut::new(&mut ew);
        // Vertex-parallel compaction: each coarse vertex owns a disjoint
        // output range, walks its hash interval, then sorts its slice.
        let _k = ledger::kernel("coarsen/contract_cas:compact");
        pool.parallel_for(nc, |c| {
            let mut out = xadj_scan[c] as usize;
            let begin = xadj_scan[c] as usize;
            for i in offsets[c] as usize..offsets[c + 1] as usize {
                // relaxed: hash slots are frozen after the insertion
                // barrier; this kernel only reads them.
                let t = hv[i].load(Ordering::Relaxed);
                if t != NULL {
                    // SAFETY: unit `c` writes only inside its own output
                    // range [xadj_scan[c], xadj_scan[c+1]) — ranges are
                    // pairwise disjoint by construction of the prefix sum.
                    unsafe {
                        adj_ptr.write(out, t);
                        ew_ptr.write(out, f64::from_bits(hw[i].load(Ordering::Relaxed)));
                    }
                    out += 1;
                }
            }
            // Sort slice [begin, out) by target for CSR invariants.
            // Allocation-free paired insertion sort (coarse adjacency
            // lists are short) — §Perf opt 3.
            // SAFETY: the slices cover [begin, out) ⊆ unit `c`'s disjoint
            // output range (see above), so no other unit touches them.
            unsafe {
                let slice_adj = adj_ptr.slice(begin, out - begin);
                let slice_ew = ew_ptr.slice(begin, out - begin);
                for i in 1..slice_adj.len() {
                    let (ka, kw) = (slice_adj[i], slice_ew[i]);
                    let mut j = i;
                    while j > 0 && slice_adj[j - 1] > ka {
                        slice_adj[j] = slice_adj[j - 1];
                        slice_ew[j] = slice_ew[j - 1];
                        j -= 1;
                    }
                    slice_adj[j] = ka;
                    slice_ew[j] = kw;
                }
            }
        });
    }

    // Coarse vertex weights.
    let vw_atomic: Vec<AtomicU64> = (0..nc).map(|_| AtomicU64::new(0)).collect();
    {
        let _k = ledger::kernel("coarsen/contract_cas:vw");
        pool.parallel_for(n, |v| {
            // relaxed: commutative tally, read after the barrier.
            vw_atomic[map[v] as usize].fetch_add(g.vw[v] as u64, Ordering::Relaxed);
        });
    }

    let mut xadj = vec![0u32; nc + 1];
    for c in 0..=nc {
        xadj[c] = xadj_scan[c] as u32;
    }
    // relaxed: host-side read after the kernel barrier.
    let vw: Vec<VWeight> = vw_atomic.iter().map(|a| a.load(Ordering::Relaxed) as VWeight).collect();
    let out = CsrGraph { xadj, adj, ew, vw };
    debug_assert!(out.validate().is_ok(), "contract_cas produced invalid CSR");
    out
}

/// Which coarse vertex owns hash slot `i` (binary search on offsets).
/// Kept for the edge-parallel extraction variant exercised in tests.
#[inline]
#[allow(dead_code)]
fn owner_of(offsets: &[u64], i: usize) -> usize {
    let i = i as u64;
    // offsets is monotone with offsets[0] == 0; find c with
    // offsets[c] <= i < offsets[c+1].
    let mut lo = 0usize;
    let mut hi = offsets.len() - 1;
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if offsets[mid] <= i {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coarsen::{contract_serial, matching_to_map, serial_hem};
    use crate::graph::gen;

    fn check_same_graph(a: &CsrGraph, b: &CsrGraph) {
        assert_eq!(a.n(), b.n());
        assert_eq!(a.xadj, b.xadj);
        assert_eq!(a.adj, b.adj);
        assert_eq!(a.vw, b.vw);
        for (x, y) in a.ew.iter().zip(&b.ew) {
            assert!((x - y).abs() < 1e-9 * x.abs().max(1.0));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: multi-thread contraction over a 256-vertex grid, too slow
    fn matches_serial_oracle_on_grid() {
        let g = gen::grid2d(16, 16, false);
        let mate = serial_hem(&g, i64::MAX, 1);
        let (map, nc) = matching_to_map(&mate);
        let el = EdgeList::build(&g);
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let cas = contract_cas(&pool, &g, &el, &map, nc);
            let ser = contract_serial(&g, &map, nc);
            check_same_graph(&cas, &ser);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 900-vertex stencil contraction, too slow
    fn matches_serial_oracle_on_weighted_rgg() {
        let g = gen::stencil9(30, 30, 3);
        let mate = serial_hem(&g, i64::MAX, 5);
        let (map, nc) = matching_to_map(&mate);
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let cas = contract_cas(&pool, &g, &el, &map, nc);
        let ser = contract_serial(&g, &map, nc);
        check_same_graph(&cas, &ser);
    }

    #[test]
    fn arbitrary_cluster_map() {
        // Contract a grid along a clustering (not a matching): 3 vertices
        // per cluster.
        let g = gen::grid2d(9, 9, false);
        let nc = g.n().div_ceil(3);
        let map: Vec<Vertex> = (0..g.n()).map(|v| (v / 3) as Vertex).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let cas = contract_cas(&pool, &g, &el, &map, nc);
        let ser = contract_serial(&g, &map, nc);
        check_same_graph(&cas, &ser);
        assert_eq!(cas.total_vweight(), g.total_vweight());
    }

    #[test]
    fn owner_of_binary_search() {
        let offsets = vec![0u64, 3, 3, 10];
        assert_eq!(owner_of(&offsets, 0), 0);
        assert_eq!(owner_of(&offsets, 2), 0);
        assert_eq!(owner_of(&offsets, 3), 2);
        assert_eq!(owner_of(&offsets, 9), 2);
    }
}
