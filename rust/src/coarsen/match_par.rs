//! Device-style preference matching (paper §4.2 "Matching").
//!
//! Each round, every unmatched vertex `v` scans its unmatched neighbors in
//! parallel and records the one with the best `expansion*²` rating
//! (+ deterministic noise η to break ties) as its preference `p(v)`. A
//! second kernel matches mutual preferences `p(p(v)) == v`. Rounds repeat
//! until a round produces no matches or ≥75 % of vertices are matched
//! (then the two-hop pass of [`super::twohop`] takes over).

use super::{rating_exp2, Matching};
use crate::graph::CsrGraph;
use crate::par::{ledger, Pool};
use crate::rng::edge_noise;
use crate::runtime::device;
use crate::{VWeight, Vertex};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

const UNMATCHED: u32 = u32::MAX;

/// Parallel preference matching. Returns the matching in `mate[v]` form
/// (`mate[v] == v` ⇔ unmatched).
pub fn preference_matching(
    g: &CsrGraph,
    pool: &Pool,
    max_pair_weight: VWeight,
    seed: u64,
    max_rounds: usize,
) -> Matching {
    let n = g.n();
    let mate: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNMATCHED)).collect();
    let pref: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNMATCHED)).collect();

    // Vertices never unmatch, so the matched total is the running sum of
    // per-round counts — no extra full reduction kernel per round.
    let mut matched_total = 0u64;
    for _round in 0..max_rounds {
        // Device branch: one batched PJRT launch fuses both kernels of
        // the superstep against the session's device-resident graph; the
        // pref/match formulas are bit-identical to the pool kernels
        // below, so both backends produce the same matching. `None`
        // (inactive session, unanchored graph, missing artifact) falls
        // through to the pool.
        if let Some(next) = {
            // relaxed: serial host code between launches — no kernel is
            // in flight while the snapshot is taken or applied below.
            let snap: Vec<Vertex> = mate.iter().map(|m| m.load(Ordering::Relaxed)).collect();
            device::match_round(g, &snap, max_pair_weight as f64, seed)
        } {
            let mut matched_this_round = 0u64;
            for (v, &m) in next.iter().enumerate() {
                // relaxed: host-side apply between launches; the device
                // round only ever matches previously-unmatched pairs.
                if mate[v].load(Ordering::Relaxed) != m {
                    mate[v].store(m, Ordering::Relaxed);
                    matched_this_round += 1;
                }
            }
            if matched_this_round == 0 {
                break;
            }
            matched_total += matched_this_round;
            if matched_total as f64 / n as f64 >= 0.75 {
                break;
            }
            continue;
        }

        // Kernel 1: compute preferences of unmatched vertices.
        let _k = ledger::kernel("coarsen/match_par:prefs");
        pool.parallel_for(n, |v| {
            // relaxed: `mate` is frozen during this kernel (only kernel 2
            // writes it, after a barrier), and `pref[v]` is written only
            // by unit `v` and read only in the next kernel.
            if mate[v].load(Ordering::Relaxed) != UNMATCHED {
                return;
            }
            let (nbrs, ws) = g.neighbors_w(v as Vertex);
            let mut best: Option<(f64, Vertex)> = None;
            for (&u, &w) in nbrs.iter().zip(ws) {
                if mate[u as usize].load(Ordering::Relaxed) != UNMATCHED {
                    continue;
                }
                if g.vw[v] + g.vw[u as usize] > max_pair_weight {
                    continue;
                }
                let r = rating_exp2(w, g.vw[v], g.vw[u as usize])
                    + 1e-12 * edge_noise(v as Vertex, u, seed);
                if best.map(|(br, bu)| r > br || (r == br && u < bu)).unwrap_or(true) {
                    best = Some((r, u));
                }
            }
            // relaxed: `pref[v]` is owned by unit `v` this superstep.
            pref[v].store(best.map(|(_, u)| u).unwrap_or(UNMATCHED), Ordering::Relaxed);
        });
        drop(_k);

        // Kernel 2: match mutual preferences.
        let _k = ledger::kernel("coarsen/match_par:mutual");
        let matched_this_round = pool.reduce_sum_u64(n, |v| {
            // relaxed: `pref` is frozen after kernel 1's barrier. `mate`
            // is written this superstep, but only by the smaller endpoint
            // of a *mutual* pair: unit `v`'s decision depends only on the
            // frozen prefs, so a racy `mate` read can only skip work that
            // would return 0 anyway — the outcome is interleaving-free.
            if mate[v].load(Ordering::Relaxed) != UNMATCHED {
                return 0;
            }
            let u = pref[v].load(Ordering::Relaxed);
            if u == UNMATCHED {
                return 0;
            }
            if pref[u as usize].load(Ordering::Relaxed) == v as u32 {
                // Mutual; the smaller endpoint writes both sides.
                // relaxed: both stores target a mutually-agreed pair — only
                // the smaller endpoint writes, and the values are read
                // host-side after the kernel barrier.
                if (v as u32) < u {
                    mate[v].store(u, Ordering::Relaxed);
                    mate[u as usize].store(v as u32, Ordering::Relaxed);
                    return 2;
                }
            }
            0
        });
        drop(_k);
        if matched_this_round == 0 {
            break;
        }
        matched_total += matched_this_round;
        if matched_total as f64 / n as f64 >= 0.75 {
            break;
        }
    }

    (0..n)
        .map(|v| {
            // relaxed: host-side read after the final kernel barrier.
            let m = mate[v].load(Ordering::Relaxed);
            if m == UNMATCHED {
                v as Vertex
            } else {
                m
            }
        })
        .collect()
}

/// Atomic claim table used by the two-hop pass: claim(v) returns true for
/// exactly one claimer of each vertex.
#[allow(dead_code)] // exercised by tests; available for two-hop device variants
pub(crate) struct ClaimTable {
    slots: Vec<AtomicU64>,
}

impl ClaimTable {
    pub fn new(n: usize) -> Self {
        let mut slots = Vec::with_capacity(n);
        slots.resize_with(n, || AtomicU64::new(u64::MAX));
        ClaimTable { slots }
    }

    /// Try to claim `v` with tag `tag`; true iff this call won.
    #[inline]
    pub fn claim(&self, v: usize, tag: u64) -> bool {
        // relaxed: a pure single-location claim — exactly one CAS wins and
        // no other data is published through it.
        self.slots[v]
            .compare_exchange(u64::MAX, tag, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn check_valid_matching(g: &CsrGraph, mate: &Matching, cap: VWeight) {
        for v in 0..g.n() {
            let m = mate[v] as usize;
            assert_eq!(mate[m] as usize, v, "not symmetric at {v}");
            if m != v {
                assert!(g.find_edge(v as u32, m as u32).is_some(), "matched non-edge {v}-{m}");
                assert!(g.vw[v] + g.vw[m] <= cap);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: multi-thread matching over a 1024-vertex grid, too slow
    fn matches_most_of_a_grid() {
        let g = gen::grid2d(32, 32, false);
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let mate = preference_matching(&g, &pool, i64::MAX, 7, 8);
            check_valid_matching(&g, &mate, i64::MAX);
            assert!(super::super::matched_fraction(&mate) > 0.6, "threads={threads}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 1500-vertex rgg at two thread counts, too slow
    fn deterministic_across_thread_counts() {
        let g = gen::rgg(1_500, 0.06, 9);
        let m1 = preference_matching(&g, &Pool::new(1), i64::MAX, 3, 8);
        let m4 = preference_matching(&g, &Pool::new(4), i64::MAX, 3, 8);
        // Preferences are deterministic, so matchings agree exactly.
        assert_eq!(m1, m4);
    }

    #[test]
    fn respects_weight_cap() {
        let mut g = gen::grid2d(8, 8, false);
        for v in 0..g.n() {
            g.vw[v] = 1 + (v % 5) as i64;
        }
        let pool = Pool::new(1);
        let mate = preference_matching(&g, &pool, 6, 1, 8);
        check_valid_matching(&g, &mate, 6);
    }

    #[test]
    fn claim_table_single_winner() {
        let table = ClaimTable::new(100);
        let pool = Pool::new(4);
        let wins = pool.reduce_sum_u64(1_000, |i| table.claim(i % 100, i as u64) as u64);
        assert_eq!(wins, 100);
    }
}
