//! Two-hop matching (LaSalle et al., adopted by Jet and by the paper):
//! if preference matching leaves too many vertices unmatched, match
//! vertices that are two hops apart — *leaves* (degree-1 vertices sharing
//! a neighbor), *twins* (identical neighborhoods, found by hashing), and
//! *relatives* (sharing at least one neighbor, paired through small-degree
//! "matchmaker" vertices).

use super::Matching;
use crate::graph::CsrGraph;
use crate::rng::hash_u64;
use crate::{VWeight, Vertex};

/// Degree bound for matchmaker vertices in the relative pass (Jet uses
/// small-degree vertices to bound the pairing work).
const MATCHMAKER_MAX_DEGREE: usize = 32;

/// Extend `mate` in place with leaf, twin, and relative two-hop matches.
/// Returns the number of newly matched vertices.
pub fn twohop_matching(g: &CsrGraph, mate: &mut Matching, max_pair_weight: VWeight) -> usize {
    let before = matched_count(mate);
    leaf_matching(g, mate, max_pair_weight);
    twin_matching(g, mate, max_pair_weight);
    relative_matching(g, mate, max_pair_weight);
    matched_count(mate) - before
}

fn matched_count(mate: &Matching) -> usize {
    mate.iter().enumerate().filter(|&(v, &m)| m as usize != v).count()
}

#[inline]
fn unmatched(mate: &Matching, v: usize) -> bool {
    mate[v] as usize == v
}

fn try_pair(g: &CsrGraph, mate: &mut Matching, a: Vertex, b: Vertex, cap: VWeight) -> bool {
    let (a, b) = (a as usize, b as usize);
    if a == b || !unmatched(mate, a) || !unmatched(mate, b) {
        return false;
    }
    if g.vw[a] + g.vw[b] > cap {
        return false;
    }
    mate[a] = b as Vertex;
    mate[b] = a as Vertex;
    true
}

/// Leaves: for each vertex, pair up its unmatched degree-1 neighbors.
fn leaf_matching(g: &CsrGraph, mate: &mut Matching, cap: VWeight) {
    for hub in 0..g.n() {
        let mut pending: Option<Vertex> = None;
        // Collect first to avoid borrowing issues with mate updates.
        let leaves: Vec<Vertex> = g
            .neighbors(hub as Vertex)
            .iter()
            .copied()
            .filter(|&u| g.degree(u) == 1 && unmatched(mate, u as usize))
            .collect();
        for u in leaves {
            match pending {
                None => pending = Some(u),
                Some(p) => {
                    if try_pair(g, mate, p, u, cap) {
                        pending = None;
                    } else {
                        pending = Some(u);
                    }
                }
            }
        }
    }
}

/// Twins: hash each unmatched vertex's (sorted) neighborhood; sort by
/// hash; pair consecutive vertices with equal neighborhoods.
fn twin_matching(g: &CsrGraph, mate: &mut Matching, cap: VWeight) {
    let mut hashed: Vec<(u64, Vertex)> = (0..g.n())
        .filter(|&v| unmatched(mate, v) && g.degree(v as Vertex) >= 2)
        .map(|v| {
            let mut h = 0xcbf29ce484222325u64 ^ (g.degree(v as Vertex) as u64);
            for &u in g.neighbors(v as Vertex) {
                // Order-independent combine is unnecessary: adjacency is
                // sorted, so sequential mixing is canonical.
                h = hash_u64(h ^ u as u64);
            }
            (h, v as Vertex)
        })
        .collect();
    hashed.sort_unstable();
    let mut i = 0;
    while i + 1 < hashed.len() {
        let (h, v) = hashed[i];
        let (h2, u) = hashed[i + 1];
        if h == h2
            && g.neighbors(v) == g.neighbors(u)
            && try_pair(g, mate, v, u, cap)
        {
            i += 2;
        } else {
            i += 1;
        }
    }
}

/// Relatives: small-degree matchmaker vertices pair up their unmatched
/// neighbors (which thereby share a common neighbor — two hops apart).
fn relative_matching(g: &CsrGraph, mate: &mut Matching, cap: VWeight) {
    for hub in 0..g.n() {
        if g.degree(hub as Vertex) > MATCHMAKER_MAX_DEGREE {
            continue;
        }
        let candidates: Vec<Vertex> = g
            .neighbors(hub as Vertex)
            .iter()
            .copied()
            .filter(|&u| unmatched(mate, u as usize))
            .collect();
        let mut pending: Option<Vertex> = None;
        for u in candidates {
            if !unmatched(mate, u as usize) {
                continue;
            }
            match pending {
                None => pending = Some(u),
                Some(p) => {
                    if try_pair(g, mate, p, u, cap) {
                        pending = None;
                    } else {
                        pending = Some(u);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    #[test]
    fn star_leaves_get_matched() {
        // Star: hub 0 with 6 leaves. Preference matching can match at most
        // one leaf to the hub; two-hop pairs up the rest.
        let mut b = GraphBuilder::new(7);
        for leaf in 1..7 {
            b.add_edge(0, leaf, 1.0);
        }
        let g = b.build();
        let mut mate: Matching = (0..7).collect();
        mate[0] = 1;
        mate[1] = 0;
        let newly = twohop_matching(&g, &mut mate, i64::MAX);
        assert!(newly >= 4, "only matched {newly}");
        for v in 0..7usize {
            let m = mate[v] as usize;
            assert_eq!(mate[m] as usize, v);
        }
    }

    #[test]
    fn twins_get_matched() {
        // Vertices 2 and 3 both connect exactly to {0, 1}: twins.
        let mut b = GraphBuilder::new(4);
        b.add_edge(2, 0, 1.0);
        b.add_edge(2, 1, 1.0);
        b.add_edge(3, 0, 1.0);
        b.add_edge(3, 1, 1.0);
        let g = b.build();
        let mut mate: Matching = (0..4).collect();
        twohop_matching(&g, &mut mate, i64::MAX);
        assert_eq!(mate[2], 3);
        assert_eq!(mate[3], 2);
    }

    #[test]
    fn weight_cap_respected() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(2, 0, 1.0);
        b.add_edge(2, 1, 1.0);
        b.add_edge(3, 0, 1.0);
        b.add_edge(3, 1, 1.0);
        b.set_vweight(2, 10);
        b.set_vweight(3, 10);
        let g = b.build();
        let mut mate: Matching = (0..4).collect();
        twohop_matching(&g, &mut mate, 15);
        assert_eq!(mate[2], 2, "cap should prevent twin match");
    }

    #[test]
    fn improves_match_rate_on_star_forest() {
        // Many stars: preference matching leaves most leaves unmatched.
        let mut b = GraphBuilder::new(50);
        for star in 0..5u32 {
            let hub = star * 10;
            for i in 1..10u32 {
                b.add_edge(hub, hub + i, 1.0);
            }
        }
        let g = b.build();
        let pool = crate::par::Pool::new(1);
        let mut mate = super::super::match_par::preference_matching(&g, &pool, i64::MAX, 1, 4);
        let frac_before = super::super::matched_fraction(&mate);
        twohop_matching(&g, &mut mate, i64::MAX);
        let frac_after = super::super::matched_fraction(&mate);
        assert!(frac_after > frac_before);
        assert!(frac_after > 0.8, "frac_after={frac_after}");
    }

    #[test]
    fn no_op_on_fully_matched_grid() {
        let g = gen::grid2d(8, 8, false);
        let pool = crate::par::Pool::new(1);
        let mut mate = super::super::match_par::preference_matching(&g, &pool, i64::MAX, 2, 16);
        let before = mate.clone();
        if super::super::matched_fraction(&mate) == 1.0 {
            twohop_matching(&g, &mut mate, i64::MAX);
            assert_eq!(mate, before);
        }
    }
}
