//! Reusable refinement scratch space (§Perf: allocation-free hot path).
//!
//! `jet_refine` used to allocate per *iteration* (`vec![false; n]` for the
//! affected set, fresh move lists, fresh LP state per call) — thousands of
//! `n`-sized allocations per mapping. A [`RefineWorkspace`] owns all of
//! that scratch once: [`crate::algo::gpu_im::gpu_im`] allocates it at the
//! finest level and reuses it across every multilevel level and every Jet
//! iteration; epoch-stamped mark arrays make "clear" an O(1) counter bump.

use crate::graph::CsrGraph;
use crate::par::{AtomicList, Pool};
use crate::refine::jet_lp::JetLp;
use crate::refine::rebalance::RebalanceScratch;
use crate::{Block, Vertex};
use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};

/// Epoch-stamped mark array: a slot is "marked" iff it carries the current
/// epoch tag, so resetting all marks is one counter increment instead of an
/// `O(n)` clear (the rare `u32` wrap-around does pay the full clear).
pub struct EpochMarks {
    marks: Vec<AtomicU32>,
    epoch: u32,
}

impl Default for EpochMarks {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochMarks {
    pub fn new() -> Self {
        EpochMarks { marks: Vec::new(), epoch: 0 }
    }

    /// Start a new generation covering `n` slots; returns its epoch tag.
    pub fn begin(&mut self, n: usize) -> u32 {
        if self.marks.len() < n {
            self.marks.resize_with(n, || AtomicU32::new(0));
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            for m in &self.marks {
                // relaxed: exclusive &mut self access — no kernel is running.
                m.store(0, Ordering::Relaxed);
            }
            self.epoch = 1;
        }
        self.epoch
    }

    /// Mark `v`; true iff this call was the first to mark it this epoch
    /// (atomic claim — exactly one winner under concurrency).
    #[inline]
    pub fn try_mark(&self, v: usize, epoch: u32) -> bool {
        // relaxed: the swap itself is the claim; no other data is
        // published through this flag.
        self.marks[v].swap(epoch, Ordering::Relaxed) != epoch
    }

    /// Unconditional mark.
    #[inline]
    pub fn mark(&self, v: usize, epoch: u32) {
        // relaxed: idempotent tag store, read after the kernel barrier.
        self.marks[v].store(epoch, Ordering::Relaxed);
    }

    #[inline]
    pub fn is_marked(&self, v: usize, epoch: u32) -> bool {
        // relaxed: marks are frozen by a barrier before cross-unit reads.
        self.marks[v].load(Ordering::Relaxed) == epoch
    }
}

/// All scratch state of the Jet refinement hot path, allocated once per
/// call chain and reused across multilevel levels (buffers only ever grow).
pub struct RefineWorkspace {
    /// Affected-set marks (moved vertices ∪ their neighbors).
    affected_marks: EpochMarks,
    /// Per-round moved-vertex marks for the incremental objective.
    pub(crate) moved_marks: EpochMarks,
    /// Affected-set collector (capacity ≥ n; each vertex pushed ≤ once).
    affected_list: AtomicList,
    /// Previous block of each vertex moved in the current round
    /// (indexed by vertex id, valid where `moved_marks` carries the
    /// round's epoch).
    pub(crate) old_block: Vec<Block>,
    /// Atomic block weights, updated by the parallel move-apply kernel.
    pub(crate) bw: Vec<AtomicI64>,
    /// Label-propagation state (destinations, gains, locks, move lists).
    pub(crate) lp: JetLp,
    /// Rebalancing scratch (proposal arrays, move list).
    pub(crate) reb: RebalanceScratch,
}

impl Default for RefineWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl RefineWorkspace {
    /// An empty workspace; buffers are grown on first use.
    pub fn new() -> Self {
        RefineWorkspace {
            affected_marks: EpochMarks::new(),
            moved_marks: EpochMarks::new(),
            affected_list: AtomicList::with_capacity(0),
            old_block: Vec::new(),
            bw: Vec::new(),
            lp: JetLp::new(0),
            reb: RebalanceScratch::new(),
        }
    }

    /// Pre-size every buffer for `n` vertices and `k` blocks (call with the
    /// finest level's `n` to avoid growth during uncoarsening).
    pub fn with_capacity(n: usize, k: usize) -> Self {
        let mut ws = Self::new();
        ws.ensure(n, k);
        ws
    }

    /// Grow every buffer to cover `n` vertices and `k` blocks.
    pub fn ensure(&mut self, n: usize, k: usize) {
        if self.old_block.len() < n {
            self.old_block.resize(n, 0);
        }
        if self.affected_list.capacity() < n {
            self.affected_list = AtomicList::with_capacity(n);
        }
        if self.bw.len() < k {
            self.bw.resize_with(k, || AtomicI64::new(0));
        }
        self.lp.ensure(n);
        self.reb.ensure(n);
    }

    /// Current block weights as a plain vector copy (for callers that need
    /// a `&[VWeight]` snapshot between kernels).
    pub(crate) fn bw_snapshot(&self, k: usize, out: &mut Vec<i64>) {
        out.clear();
        // relaxed: host-side read between kernels; the move kernel's
        // barrier already published every tally.
        out.extend(self.bw[..k].iter().map(|w| w.load(Ordering::Relaxed)));
    }

    /// The affected set of a move list — moved vertices and their
    /// neighbors, deduplicated — computed with a vertex-parallel kernel
    /// over the epoch-mark array instead of the former serial pass with a
    /// fresh `vec![false; n]`. The result is sorted for determinism.
    pub fn affected_set_into(
        &mut self,
        pool: &Pool,
        g: &CsrGraph,
        moved: &[Vertex],
        out: &mut Vec<Vertex>,
    ) {
        if self.affected_list.capacity() < g.n() {
            self.affected_list = AtomicList::with_capacity(g.n());
        }
        let epoch = self.affected_marks.begin(g.n());
        let marks = &self.affected_marks;
        let list = &self.affected_list;
        list.reset();
        let _k = crate::par::ledger::kernel("refine/workspace:affected_set");
        pool.parallel_for(moved.len(), |i| {
            let v = moved[i];
            if marks.try_mark(v as usize, epoch) {
                list.push(v as u64);
            }
            for &u in g.neighbors(v) {
                if marks.try_mark(u as usize, epoch) {
                    list.push(u as u64);
                }
            }
        });
        debug_assert!(!list.overflowed(), "affected list sized below n");
        out.clear();
        out.reserve(list.len());
        for i in 0..list.len() {
            out.push(list.get(i) as Vertex);
        }
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::refine::gains::ConnTable;
    use crate::rng::Rng;

    #[test]
    fn epoch_marks_claim_exactly_once() {
        let mut m = EpochMarks::new();
        let e1 = m.begin(100);
        assert!(m.try_mark(5, e1));
        assert!(!m.try_mark(5, e1));
        assert!(m.is_marked(5, e1));
        assert!(!m.is_marked(6, e1));
        let e2 = m.begin(100);
        assert_ne!(e1, e2);
        assert!(!m.is_marked(5, e2), "new epoch clears marks");
        assert!(m.try_mark(5, e2));
    }

    #[test]
    fn epoch_marks_grow() {
        let mut m = EpochMarks::new();
        let e = m.begin(10);
        m.mark(9, e);
        let e2 = m.begin(50);
        m.mark(49, e2);
        assert!(m.is_marked(49, e2));
        assert!(!m.is_marked(9, e2));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 1200-vertex rgg at three thread counts, too slow
    fn parallel_affected_set_matches_serial() {
        let g = gen::rgg(1_200, 0.07, 5);
        let mut rng = Rng::new(3);
        let moved: Vec<Vertex> = (0..80).map(|_| rng.below(g.n() as u64) as Vertex).collect();
        let moved2: Vec<Vertex> = (0..40).map(|_| rng.below(g.n() as u64) as Vertex).collect();
        let sorted_serial = |m: &[Vertex]| {
            let mut s = ConnTable::affected_set(&g, m);
            s.sort_unstable();
            s
        };
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            let mut ws = RefineWorkspace::with_capacity(g.n(), 4);
            let mut par = Vec::new();
            ws.affected_set_into(&pool, &g, &moved, &mut par);
            assert_eq!(par, sorted_serial(&moved), "threads={threads}");
            // Reuse: a different move list on the same workspace must not
            // see stale marks from the previous epoch.
            ws.affected_set_into(&pool, &g, &moved2, &mut par);
            assert_eq!(par, sorted_serial(&moved2), "threads={threads} (reuse)");
        }
    }
}
