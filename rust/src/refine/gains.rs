//! Per-vertex block-connectivity structure (paper §4.2, final paragraph).
//!
//! For every vertex a hash array of capacity `min(|N(v)|, k)` stores the
//! neighboring blocks and the summed edge weight to each. It is built with
//! one edge-parallel loop over the extended CSR (each thread CAS-claims a
//! slot in its source vertex's interval). After each move kernel the table
//! is brought back in sync with one of the paper's two update strategies:
//!
//! 1. **Refill** ([`ConnTable::refill`]): rebuild the arrays of every
//!    affected vertex (moved ∪ neighbors) from scratch — vertex-parallel,
//!    no atomics, `Σ_{v ∈ affected} deg(v)` work.
//! 2. **Delta** ([`ConnTable::update_delta`]): one edge-parallel kernel
//!    over only the *moved* vertices' incident edges, applying `−w` to the
//!    source's old block and `+w` to its new block in each neighbor's
//!    array — `Σ_{v ∈ moved} deg(v)` work, atomic. Entries whose weight
//!    reaches zero stay as *tombstones* (key kept, weight 0) so the probe
//!    invariant is preserved; [`ConnTable::gather`] already skips them. A
//!    vertex whose interval fills up with tombstoned keys overflows its
//!    bounded probe and is compacted by refilling just that vertex.
//!
//! [`ConnUpdate`] selects between them; `Auto` picks delta while the moved
//! incident edges are a small fraction of the graph (the common steady
//! state) and refill for avalanche rounds.

use crate::graph::{CsrGraph, EdgeList};
use crate::par::{atomic_f64_add, AtomicList, Pool};
use crate::rng::hash_u64;
use crate::{Block, Vertex};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

const NULL: u32 = u32::MAX;

/// Conn-table update strategy after a move kernel (paper §4.2 describes
/// both; the benchmark `hotpath_refine` compares them head to head).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ConnUpdate {
    /// Strategy 1: rebuild affected vertices' arrays from scratch.
    Refill,
    /// Strategy 2: edge-parallel `−w`/`+w` deltas over moved edges.
    Delta,
    /// `Delta` when the moved vertices' incident edges are < 50% of the
    /// graph's directed edges, `Refill` otherwise.
    #[default]
    Auto,
}

/// Block-connectivity hash arrays for all vertices.
pub struct ConnTable {
    /// Slot interval per vertex (size n+1).
    offsets: Vec<u64>,
    keys: Vec<AtomicU32>,
    vals: Vec<AtomicU64>,
}

impl ConnTable {
    /// Build from scratch with an edge-parallel kernel.
    pub fn build(pool: &Pool, g: &CsrGraph, el: &EdgeList, part: &[Block], k: usize) -> Self {
        let n = g.n();
        let offsets = pool.scan_exclusive(n, |v| (g.degree(v as Vertex).min(k)) as u64);
        let slots = offsets[n] as usize;
        let mut keys = Vec::with_capacity(slots);
        keys.resize_with(slots, || AtomicU32::new(NULL));
        let mut vals = Vec::with_capacity(slots);
        vals.resize_with(slots, || AtomicU64::new(0f64.to_bits()));
        let table = ConnTable { offsets, keys, vals };
        // Edge-parallel fill.
        let _k = crate::par::ledger::kernel("refine/gains:build");
        pool.parallel_for(g.num_directed(), |i| {
            let u = el.eu[i] as usize;
            let b = part[g.adj[i] as usize];
            table.insert_or_add_atomic(u, b, g.ew[i]);
        });
        table
    }

    /// Vertex-parallel build (the pre-ECSR baseline, ablation A3): one
    /// thread per vertex walks its own adjacency — no atomics, but load
    /// balance degrades with skewed degrees.
    pub fn build_vertex_par(pool: &Pool, g: &CsrGraph, part: &[Block], k: usize) -> Self {
        let n = g.n();
        let offsets = pool.scan_exclusive(n, |v| (g.degree(v as Vertex).min(k)) as u64);
        let slots = offsets[n] as usize;
        let mut keys = Vec::with_capacity(slots);
        keys.resize_with(slots, || AtomicU32::new(NULL));
        let mut vals = Vec::with_capacity(slots);
        vals.resize_with(slots, || AtomicU64::new(0f64.to_bits()));
        let table = ConnTable { offsets, keys, vals };
        let all: Vec<Vertex> = (0..n as Vertex).collect();
        table.refill(pool, g, part, &all);
        table
    }

    #[inline]
    fn interval(&self, v: usize) -> (usize, usize) {
        (self.offsets[v] as usize, self.offsets[v + 1] as usize)
    }

    /// CAS insert-or-accumulate into vertex `v`'s interval (fresh build /
    /// refill path, where the interval can always absorb its ≤ `len`
    /// distinct keys — the bounded probe cannot fail).
    #[inline]
    fn insert_or_add_atomic(&self, v: usize, b: Block, w: f64) {
        let ok = self.delta_add(v, b, w);
        debug_assert!(ok, "fresh build cannot saturate an interval");
    }

    /// Bounded-probe CAS insert-or-accumulate: gives up after `len` probes
    /// (interval saturated with other keys, e.g. tombstones left by delta
    /// updates) and returns `false` so the caller can fall back to a
    /// refill. During a fresh build the distinct key count is ≤ `len`, so
    /// the probe always succeeds there.
    #[inline]
    fn delta_add(&self, v: usize, b: Block, w: f64) -> bool {
        let (start, end) = self.interval(v);
        let len = end - start;
        if len == 0 {
            return true;
        }
        let mut slot = (hash_u64(b as u64) % len as u64) as usize;
        for _ in 0..len {
            let idx = start + slot;
            // relaxed: the CAS claims the slot by key only; the weight
            // lives in a separate atomic and is itself accumulated with a
            // commutative CAS loop, so no ordering between them is needed.
            match self.keys[idx].compare_exchange(NULL, b, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => {
                    atomic_f64_add(&self.vals[idx], w);
                    return true;
                }
                Err(existing) if existing == b => {
                    atomic_f64_add(&self.vals[idx], w);
                    return true;
                }
                Err(_) => slot = (slot + 1) % len,
            }
        }
        false
    }

    /// Connectivity of `v` to block `b` (`conn(v, b)` in the paper).
    pub fn conn_to(&self, v: usize, b: Block) -> f64 {
        let (start, end) = self.interval(v);
        for idx in start..end {
            // relaxed: readers run between update kernels; the pool
            // barrier froze the table before they start.
            if self.keys[idx].load(Ordering::Relaxed) == b {
                return f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
            }
        }
        0.0
    }

    /// Iterate the non-empty `(block, weight)` entries of `v` into `buf`.
    pub fn gather(&self, v: usize, buf: &mut Vec<(Block, f64)>) {
        buf.clear();
        let (start, end) = self.interval(v);
        for idx in start..end {
            // relaxed: table frozen by the last update kernel's barrier.
            let b = self.keys[idx].load(Ordering::Relaxed);
            if b != NULL {
                let w = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                if w != 0.0 {
                    buf.push((b, w));
                }
            }
        }
    }

    /// Allocation-free gather into a stack [`super::ConnBuf`] (hot path).
    #[inline]
    pub fn gather_buf(&self, v: usize, buf: &mut super::ConnBuf) {
        buf.clear();
        let (start, end) = self.interval(v);
        for idx in start..end {
            // relaxed: table frozen by the last update kernel's barrier.
            let b = self.keys[idx].load(Ordering::Relaxed);
            if b != NULL {
                let w = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                if w != 0.0 {
                    buf.push(b, w);
                }
            }
        }
    }

    /// Refill the arrays of every vertex in `affected` from scratch
    /// (vertex-parallel; each thread owns its vertex's whole interval so
    /// no atomics are needed). Strategy 1 of paper §4.2.
    pub fn refill(&self, pool: &Pool, g: &CsrGraph, part: &[Block], affected: &[Vertex]) {
        let _k = crate::par::ledger::kernel("refine/gains:refill");
        pool.parallel_for(affected.len(), |i| {
            let v = affected[i] as usize;
            let (start, end) = self.interval(v);
            for idx in start..end {
                // relaxed: unit i owns vertex v's whole interval for this
                // kernel; other units read it only after the barrier.
                self.keys[idx].store(NULL, Ordering::Relaxed);
                self.vals[idx].store(0f64.to_bits(), Ordering::Relaxed);
            }
            let len = end - start;
            if len == 0 {
                return;
            }
            let (nbrs, ws) = g.neighbors_w(v as Vertex);
            'edges: for (&u, &w) in nbrs.iter().zip(ws) {
                let b = part[u as usize];
                let mut slot = (hash_u64(b as u64) % len as u64) as usize;
                loop {
                    let idx = start + slot;
                    // relaxed: interval owned by unit i — these atomics are
                    // effectively private until the kernel barrier.
                    let cur = self.keys[idx].load(Ordering::Relaxed);
                    if cur == NULL {
                        self.keys[idx].store(b, Ordering::Relaxed);
                        self.vals[idx].store(w.to_bits(), Ordering::Relaxed);
                        continue 'edges;
                    } else if cur == b {
                        let old = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                        self.vals[idx].store((old + w).to_bits(), Ordering::Relaxed);
                        continue 'edges;
                    }
                    slot = (slot + 1) % len;
                }
            }
        });
    }

    /// Strategy 2 of paper §4.2: apply the moves as edge-parallel deltas.
    ///
    /// For every incident edge `(v, u, w)` of a moved vertex `v`, subtract
    /// `w` from `old_of[v]` and add `w` to `part[v]` in `u`'s array (both
    /// atomic; `v`'s own array only depends on its *neighbors'* blocks, so
    /// symmetric edges of co-moved neighbors handle it). `part` is the
    /// partition *after* the moves; `old_of` is indexed by vertex id and
    /// must hold the pre-move block of every moved vertex. Vertices whose
    /// bounded probe overflows (interval saturated by tombstones) are
    /// compacted afterwards by an exact per-vertex refill.
    ///
    /// With integer-valued edge weights the result is bit-identical to a
    /// fresh [`ConnTable::build`]; with arbitrary floats, residues of
    /// cancelled entries are O(ε) and removed by the next refill.
    pub fn update_delta(
        &self,
        pool: &Pool,
        g: &CsrGraph,
        part: &[Block],
        moved: &[Vertex],
        old_of: &[Block],
    ) {
        let off = pool.scan_exclusive(moved.len(), |i| g.degree(moved[i]) as u64);
        self.update_delta_with_offsets(pool, g, part, moved, old_of, &off);
    }

    /// [`ConnTable::update_delta`] with a precomputed exclusive scan of
    /// `deg(moved[i])` (callers on the hot path share it with the
    /// incremental-objective kernel).
    pub fn update_delta_with_offsets(
        &self,
        pool: &Pool,
        g: &CsrGraph,
        part: &[Block],
        moved: &[Vertex],
        old_of: &[Block],
        off: &[u64],
    ) {
        debug_assert_eq!(off.len(), moved.len() + 1);
        if moved.is_empty() {
            return;
        }
        let tot = off[moved.len()] as usize;
        // Vertices whose interval could not absorb a delta; refilled below.
        // Saturation of this list is itself handled: the overflow flag
        // widens the fallback to the full affected set.
        let overflow = AtomicList::with_capacity(1024);
        let _k = crate::par::ledger::kernel("refine/gains:update_delta");
        pool.parallel_for(tot, |e| {
            // Owner of directed-edge slot `e` in the concatenated moved
            // adjacency: off[i] <= e < off[i+1].
            let i = off.partition_point(|&x| x <= e as u64) - 1;
            let v = moved[i] as usize;
            let from = old_of[v];
            let to = part[v];
            if from == to {
                return;
            }
            let j = g.xadj[v] as usize + (e - off[i] as usize);
            let u = g.adj[j] as usize;
            let w = g.ew[j];
            if !self.delta_add(u, from, -w) || !self.delta_add(u, to, w) {
                overflow.push(u as u64);
            }
        });
        if overflow.is_empty() && !overflow.overflowed() {
            return;
        }
        if overflow.overflowed() {
            // Rare avalanche: compact the whole affected neighborhood.
            let affected = ConnTable::affected_set(g, moved);
            self.refill(pool, g, part, &affected);
        } else {
            let mut ov: Vec<Vertex> = overflow.to_vec().into_iter().map(|x| x as Vertex).collect();
            ov.sort_unstable();
            ov.dedup();
            self.refill(pool, g, part, &ov);
        }
    }

    /// The affected set of a move list: moved vertices and their neighbors,
    /// deduplicated. Serial reference version; the hot path uses the
    /// parallel [`super::workspace::RefineWorkspace::affected_set_into`].
    pub fn affected_set(g: &CsrGraph, moved: &[Vertex]) -> Vec<Vertex> {
        let mut mark = vec![false; g.n()];
        let mut out = Vec::with_capacity(moved.len() * 4);
        for &v in moved {
            if !mark[v as usize] {
                mark[v as usize] = true;
                out.push(v);
            }
            for &u in g.neighbors(v) {
                if !mark[u as usize] {
                    mark[u as usize] = true;
                    out.push(u);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::rng::Rng;

    fn conn_oracle(g: &CsrGraph, part: &[Block], v: usize) -> Vec<(Block, f64)> {
        let mut m: std::collections::BTreeMap<Block, f64> = Default::default();
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        for (&u, &w) in nbrs.iter().zip(ws) {
            *m.entry(part[u as usize]).or_insert(0.0) += w;
        }
        m.into_iter().collect()
    }

    fn assert_tables_agree(g: &CsrGraph, a: &ConnTable, b: &ConnTable) {
        let mut ga = Vec::new();
        let mut gb = Vec::new();
        for v in 0..g.n() {
            a.gather(v, &mut ga);
            b.gather(v, &mut gb);
            ga.sort_unstable_by_key(|&(x, _)| x);
            gb.sort_unstable_by_key(|&(x, _)| x);
            assert_eq!(ga.len(), gb.len(), "v={v}");
            for (&(ab, aw), &(bb, bw)) in ga.iter().zip(&gb) {
                assert_eq!(ab, bb, "v={v}");
                assert!((aw - bw).abs() < 1e-9, "v={v}: {aw} vs {bw}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: oracle comparison over a 400-vertex stencil at three thread counts, too slow
    fn build_matches_oracle() {
        let g = gen::stencil9(20, 20, 1);
        let k = 8;
        let mut rng = Rng::new(2);
        let part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let table = ConnTable::build(&pool, &g, &el, &part, k);
            let mut buf = Vec::new();
            for v in 0..g.n() {
                table.gather(v, &mut buf);
                buf.sort_unstable_by_key(|&(b, _)| b);
                let oracle = conn_oracle(&g, &part, v);
                assert_eq!(buf.len(), oracle.len(), "v={v}");
                for (&(b, w), &(ob, ow)) in buf.iter().zip(&oracle) {
                    assert_eq!(b, ob);
                    assert!((w - ow).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn conn_to_specific_block() {
        let g = gen::grid2d(4, 4, false);
        let part: Vec<Block> = (0..16).map(|v| (v % 2) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let table = ConnTable::build(&pool, &g, &el, &part, 2);
        for v in 0..16 {
            let oracle = conn_oracle(&g, &part, v);
            for (b, w) in oracle {
                assert!((table.conn_to(v, b) - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 800-vertex rgg, too slow
    fn refill_after_moves_matches_rebuild() {
        let g = gen::rgg(800, 0.08, 3);
        let k = 6;
        let mut rng = Rng::new(4);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let table = ConnTable::build(&pool, &g, &el, &part, k);
        // Move 50 random vertices.
        let moved: Vec<Vertex> = (0..50).map(|_| rng.below(g.n() as u64) as Vertex).collect();
        for &v in &moved {
            part[v as usize] = rng.below(k as u64) as Block;
        }
        let affected = ConnTable::affected_set(&g, &moved);
        table.refill(&pool, &g, &part, &affected);
        // Fresh build must agree everywhere.
        let fresh = ConnTable::build(&pool, &g, &el, &part, k);
        assert_tables_agree(&g, &table, &fresh);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 576-vertex stencil at three thread counts, too slow
    fn delta_update_matches_rebuild_at_all_thread_counts() {
        let g = gen::stencil9(24, 24, 7); // integer weights 1..8 ⇒ exact fp
        let k = 6;
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            let mut rng = Rng::new(11);
            let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
            let el = EdgeList::build(&g);
            let table = ConnTable::build(&pool, &g, &el, &part, k);
            let mut old_of = vec![0 as Block; g.n()];
            // Several successive move rounds on the same table: tombstones
            // must accumulate harmlessly.
            for _round in 0..4 {
                let mut moved: Vec<Vertex> =
                    (0..60).map(|_| rng.below(g.n() as u64) as Vertex).collect();
                moved.sort_unstable();
                moved.dedup();
                for &v in &moved {
                    old_of[v as usize] = part[v as usize];
                    let mut b = rng.below(k as u64) as Block;
                    if b == part[v as usize] {
                        b = (b + 1) % k as Block;
                    }
                    part[v as usize] = b;
                }
                table.update_delta(&pool, &g, &part, &moved, &old_of);
                let fresh = ConnTable::build(&pool, &g, &el, &part, k);
                assert_tables_agree(&g, &table, &fresh);
            }
        }
    }

    #[test]
    fn delta_update_overflow_falls_back_to_refill() {
        // Path a–u–b: u's interval has min(deg, k) = 2 slots. Moving its
        // two neighbors through fresh blocks leaves both slots tombstoned,
        // so the next insert overflows the bounded probe and u must be
        // compacted by the per-vertex refill fallback.
        let g = gen::grid2d(3, 1, false); // path 0-1-2
        let k = 8;
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut part: Vec<Block> = vec![0, 7, 1];
        let table = ConnTable::build(&pool, &g, &el, &part, k);
        let mut old_of = vec![0 as Block; g.n()];
        // Round 1: both endpoints jump to blocks 2 and 3 — vertex 1's two
        // slots now hold tombstones for 0 and 1 plus live keys... which
        // cannot fit: the probe overflows and refill compacts.
        old_of[0] = part[0];
        old_of[2] = part[2];
        part[0] = 2;
        part[2] = 3;
        table.update_delta(&pool, &g, &part, &[0, 2], &old_of);
        let fresh = ConnTable::build(&pool, &g, &el, &part, k);
        assert_tables_agree(&g, &table, &fresh);
        // Round 2: move them again to yet other blocks.
        old_of[0] = part[0];
        old_of[2] = part[2];
        part[0] = 4;
        part[2] = 5;
        table.update_delta(&pool, &g, &part, &[0, 2], &old_of);
        let fresh2 = ConnTable::build(&pool, &g, &el, &part, k);
        assert_tables_agree(&g, &table, &fresh2);
    }

    #[test]
    fn affected_set_contains_moved_and_neighbors() {
        let g = gen::grid2d(5, 5, false);
        let affected = ConnTable::affected_set(&g, &[12]);
        assert!(affected.contains(&12));
        for &u in g.neighbors(12) {
            assert!(affected.contains(&u));
        }
    }
}
