//! Per-vertex block-connectivity structure (paper §4.2, final paragraph).
//!
//! For every vertex a hash array of capacity `min(|N(v)|, k)` stores the
//! neighboring blocks and the summed edge weight to each. It is built with
//! one edge-parallel loop over the extended CSR (each thread CAS-claims a
//! slot in its source vertex's interval), and updated after each move
//! kernel by refilling the arrays of affected vertices from scratch — the
//! first of the two update strategies the paper describes.

use crate::graph::{CsrGraph, EdgeList};
use crate::par::{atomic_f64_add, Pool};
use crate::rng::hash_u64;
use crate::{Block, Vertex};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

const NULL: u32 = u32::MAX;

/// Block-connectivity hash arrays for all vertices.
pub struct ConnTable {
    /// Slot interval per vertex (size n+1).
    offsets: Vec<u64>,
    keys: Vec<AtomicU32>,
    vals: Vec<AtomicU64>,
}

impl ConnTable {
    /// Build from scratch with an edge-parallel kernel.
    pub fn build(pool: &Pool, g: &CsrGraph, el: &EdgeList, part: &[Block], k: usize) -> Self {
        let n = g.n();
        let offsets = pool.scan_exclusive(n, |v| (g.degree(v as Vertex).min(k)) as u64);
        let slots = offsets[n] as usize;
        let mut keys = Vec::with_capacity(slots);
        keys.resize_with(slots, || AtomicU32::new(NULL));
        let mut vals = Vec::with_capacity(slots);
        vals.resize_with(slots, || AtomicU64::new(0f64.to_bits()));
        let table = ConnTable { offsets, keys, vals };
        // Edge-parallel fill.
        pool.parallel_for(g.num_directed(), |i| {
            let u = el.eu[i] as usize;
            let b = part[g.adj[i] as usize];
            table.insert_or_add_atomic(u, b, g.ew[i]);
        });
        table
    }

    /// Vertex-parallel build (the pre-ECSR baseline, ablation A3): one
    /// thread per vertex walks its own adjacency — no atomics, but load
    /// balance degrades with skewed degrees.
    pub fn build_vertex_par(pool: &Pool, g: &CsrGraph, part: &[Block], k: usize) -> Self {
        let n = g.n();
        let offsets = pool.scan_exclusive(n, |v| (g.degree(v as Vertex).min(k)) as u64);
        let slots = offsets[n] as usize;
        let mut keys = Vec::with_capacity(slots);
        keys.resize_with(slots, || AtomicU32::new(NULL));
        let mut vals = Vec::with_capacity(slots);
        vals.resize_with(slots, || AtomicU64::new(0f64.to_bits()));
        let table = ConnTable { offsets, keys, vals };
        let all: Vec<Vertex> = (0..n as Vertex).collect();
        table.refill(pool, g, part, &all);
        table
    }

    #[inline]
    fn interval(&self, v: usize) -> (usize, usize) {
        (self.offsets[v] as usize, self.offsets[v + 1] as usize)
    }

    /// CAS insert-or-accumulate into vertex `v`'s interval.
    #[inline]
    fn insert_or_add_atomic(&self, v: usize, b: Block, w: f64) {
        let (start, end) = self.interval(v);
        let len = end - start;
        debug_assert!(len > 0);
        let mut slot = (hash_u64(b as u64) % len as u64) as usize;
        loop {
            let idx = start + slot;
            match self.keys[idx].compare_exchange(NULL, b, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => {
                    atomic_f64_add(&self.vals[idx], w);
                    return;
                }
                Err(existing) if existing == b => {
                    atomic_f64_add(&self.vals[idx], w);
                    return;
                }
                Err(_) => slot = (slot + 1) % len,
            }
        }
    }

    /// Connectivity of `v` to block `b` (`conn(v, b)` in the paper).
    pub fn conn_to(&self, v: usize, b: Block) -> f64 {
        let (start, end) = self.interval(v);
        for idx in start..end {
            if self.keys[idx].load(Ordering::Relaxed) == b {
                return f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
            }
        }
        0.0
    }

    /// Iterate the non-empty `(block, weight)` entries of `v` into `buf`.
    pub fn gather(&self, v: usize, buf: &mut Vec<(Block, f64)>) {
        buf.clear();
        let (start, end) = self.interval(v);
        for idx in start..end {
            let b = self.keys[idx].load(Ordering::Relaxed);
            if b != NULL {
                let w = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                if w != 0.0 {
                    buf.push((b, w));
                }
            }
        }
    }

    /// Allocation-free gather into a stack [`super::ConnBuf`] (hot path).
    #[inline]
    pub fn gather_buf(&self, v: usize, buf: &mut super::ConnBuf) {
        buf.clear();
        let (start, end) = self.interval(v);
        for idx in start..end {
            let b = self.keys[idx].load(Ordering::Relaxed);
            if b != NULL {
                let w = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                if w != 0.0 {
                    buf.push(b, w);
                }
            }
        }
    }

    /// Refill the arrays of every vertex in `affected` from scratch
    /// (vertex-parallel; each thread owns its vertex's whole interval so
    /// no atomics are needed).
    pub fn refill(&self, pool: &Pool, g: &CsrGraph, part: &[Block], affected: &[Vertex]) {
        pool.parallel_for(affected.len(), |i| {
            let v = affected[i] as usize;
            let (start, end) = self.interval(v);
            for idx in start..end {
                self.keys[idx].store(NULL, Ordering::Relaxed);
                self.vals[idx].store(0f64.to_bits(), Ordering::Relaxed);
            }
            let len = end - start;
            if len == 0 {
                return;
            }
            let (nbrs, ws) = g.neighbors_w(v as Vertex);
            'edges: for (&u, &w) in nbrs.iter().zip(ws) {
                let b = part[u as usize];
                let mut slot = (hash_u64(b as u64) % len as u64) as usize;
                loop {
                    let idx = start + slot;
                    let cur = self.keys[idx].load(Ordering::Relaxed);
                    if cur == NULL {
                        self.keys[idx].store(b, Ordering::Relaxed);
                        self.vals[idx].store(w.to_bits(), Ordering::Relaxed);
                        continue 'edges;
                    } else if cur == b {
                        let old = f64::from_bits(self.vals[idx].load(Ordering::Relaxed));
                        self.vals[idx].store((old + w).to_bits(), Ordering::Relaxed);
                        continue 'edges;
                    }
                    slot = (slot + 1) % len;
                }
            }
        });
    }

    /// The affected set of a move list: moved vertices and their neighbors,
    /// deduplicated.
    pub fn affected_set(g: &CsrGraph, moved: &[Vertex]) -> Vec<Vertex> {
        let mut mark = vec![false; g.n()];
        let mut out = Vec::with_capacity(moved.len() * 4);
        for &v in moved {
            if !mark[v as usize] {
                mark[v as usize] = true;
                out.push(v);
            }
            for &u in g.neighbors(v) {
                if !mark[u as usize] {
                    mark[u as usize] = true;
                    out.push(u);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::rng::Rng;

    fn conn_oracle(g: &CsrGraph, part: &[Block], v: usize) -> Vec<(Block, f64)> {
        let mut m: std::collections::BTreeMap<Block, f64> = Default::default();
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        for (&u, &w) in nbrs.iter().zip(ws) {
            *m.entry(part[u as usize]).or_insert(0.0) += w;
        }
        m.into_iter().collect()
    }

    #[test]
    fn build_matches_oracle() {
        let g = gen::stencil9(20, 20, 1);
        let k = 8;
        let mut rng = Rng::new(2);
        let part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let table = ConnTable::build(&pool, &g, &el, &part, k);
            let mut buf = Vec::new();
            for v in 0..g.n() {
                table.gather(v, &mut buf);
                buf.sort_unstable_by_key(|&(b, _)| b);
                let oracle = conn_oracle(&g, &part, v);
                assert_eq!(buf.len(), oracle.len(), "v={v}");
                for (&(b, w), &(ob, ow)) in buf.iter().zip(&oracle) {
                    assert_eq!(b, ob);
                    assert!((w - ow).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn conn_to_specific_block() {
        let g = gen::grid2d(4, 4, false);
        let part: Vec<Block> = (0..16).map(|v| (v % 2) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let table = ConnTable::build(&pool, &g, &el, &part, 2);
        for v in 0..16 {
            let oracle = conn_oracle(&g, &part, v);
            for (b, w) in oracle {
                assert!((table.conn_to(v, b) - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn refill_after_moves_matches_rebuild() {
        let g = gen::rgg(800, 0.08, 3);
        let k = 6;
        let mut rng = Rng::new(4);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let table = ConnTable::build(&pool, &g, &el, &part, k);
        // Move 50 random vertices.
        let moved: Vec<Vertex> = (0..50).map(|_| rng.below(g.n() as u64) as Vertex).collect();
        for &v in &moved {
            part[v as usize] = rng.below(k as u64) as Block;
        }
        let affected = ConnTable::affected_set(&g, &moved);
        table.refill(&pool, &g, &part, &affected);
        // Fresh build must agree everywhere.
        let fresh = ConnTable::build(&pool, &g, &el, &part, k);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for v in 0..g.n() {
            table.gather(v, &mut a);
            fresh.gather(v, &mut b);
            a.sort_unstable_by_key(|&(x, _)| x);
            b.sort_unstable_by_key(|&(x, _)| x);
            assert_eq!(a.len(), b.len(), "v={v}");
            for (&(ab, aw), &(bb, bw)) in a.iter().zip(&b) {
                assert_eq!(ab, bb);
                assert!((aw - bw).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn affected_set_contains_moved_and_neighbors() {
        let g = gen::grid2d(5, 5, false);
        let affected = ConnTable::affected_set(&g, &[12]);
        assert!(affected.contains(&12));
        for &u in g.neighbors(12) {
            assert!(affected.contains(&u));
        }
    }
}
