//! Unconstrained label propagation — paper Algorithm 4.
//!
//! Two device kernels. Kernel 1 (vertex-parallel): every unlocked vertex
//! picks its best destination block by the mapping gain (Eq. 1, via the
//! connectivity table); non-negative candidates enter the list `X` through
//! an atomic index. For process mapping only non-negative moves pass this
//! first filter (the paper found Jet's negative-move filter ineffective,
//! since `G_b(v)` carries distance factors that dwarf `conn(v, Π(v))`; the
//! original Jet filter is still available for the edge-cut objective used
//! by our Jet reimplementation). Kernel 2 (list-parallel): each candidate's
//! gain is re-evaluated under the approximate future state — neighbors
//! earlier in the implicit ordering (gain desc, id asc) are assumed moved —
//! and survivors enter the final move list `M`.
//!
//! All state (destinations, gains, round-stamped locks, both move lists)
//! lives in [`JetLp`] and is reused across iterations *and* multilevel
//! levels: locks and candidacy are round-stamped, so "resetting" them is a
//! counter bump rather than an `O(n)` clear per iteration.

use super::gains::ConnTable;
use super::Objective;
use crate::graph::CsrGraph;
use crate::par::{AtomicList, Pool};
use crate::runtime::device;
use crate::topology::Machine;
use crate::{Block, Vertex};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

const NO_DEST: u32 = u32::MAX;

/// Scratch state for Algorithm 4, reused across iterations and levels.
pub struct JetLp {
    /// Destination `Π'(v)` of each candidate.
    dest: Vec<AtomicU32>,
    /// First-filter gain `G_{Π'(v)}(v)` of each candidate.
    gain: Vec<f64>,
    /// Candidacy stamp: `dest[v]`/`gain[v]` are valid iff
    /// `stamp[v] == round`.
    stamp: Vec<AtomicU64>,
    /// Lock stamp: `v` may not move in round `r` iff `locked[v] == r`
    /// (it moved in the previous LP round).
    locked: Vec<u64>,
    round: u64,
    /// Candidate list `X` (kernel 1 output).
    cand: AtomicList,
    /// Final move list `M` (kernel 2 output).
    moves: AtomicList,
}

/// The negative-move filter of the first kernel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Filter {
    /// Only `G ≥ 0` (the paper's choice for process mapping).
    NonNegative,
    /// Jet's original: `G ≥ 0 ∨ −G < ⌊c_f · conn(v, Π(v))⌋` (edge-cut).
    JetNegative {
        /// The constant `c ∈ [0,1]` controlling negative-move tolerance.
        c_factor: f64,
    },
}

impl JetLp {
    pub fn new(n: usize) -> Self {
        let mut lp = JetLp {
            dest: Vec::new(),
            gain: Vec::new(),
            stamp: Vec::new(),
            locked: Vec::new(),
            round: 0,
            cand: AtomicList::with_capacity(0),
            moves: AtomicList::with_capacity(0),
        };
        lp.ensure(n);
        lp
    }

    /// Grow the state to cover `n` vertices (contents only ever grow; the
    /// round stamps make stale values from smaller levels harmless after
    /// [`JetLp::new_pass`]).
    pub fn ensure(&mut self, n: usize) {
        if self.dest.len() < n {
            self.dest.resize_with(n, || AtomicU32::new(NO_DEST));
        }
        if self.gain.len() < n {
            self.gain.resize(n, 0.0);
        }
        if self.stamp.len() < n {
            self.stamp.resize_with(n, || AtomicU64::new(0));
        }
        if self.locked.len() < n {
            self.locked.resize(n, 0);
        }
        if self.cand.capacity() < n {
            self.cand = AtomicList::with_capacity(n);
        }
        if self.moves.capacity() < n {
            self.moves = AtomicList::with_capacity(n);
        }
    }

    /// Invalidate every lock (start of a new refinement pass or multilevel
    /// level — vertex ids change meaning between levels).
    pub fn new_pass(&mut self) {
        self.round = self.round.wrapping_add(1);
    }

    /// Run one unconstrained LP step. Returns the final move list `M`
    /// (destinations are in `self.dest`, see [`JetLp::dest_of`]).
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        pool: &Pool,
        g: &CsrGraph,
        conn: &ConnTable,
        part: &[Block],
        obj: &Objective,
        filter: Filter,
    ) -> Vec<Vertex> {
        let n = g.n();
        self.ensure(n);
        self.round = self.round.wrapping_add(1);
        let round = self.round;
        self.cand.reset();
        self.moves.reset();
        let gain_ptr = crate::par::SharedMut::new(&mut self.gain);

        // Kernel 1 (device path): one batched launch scores every block
        // `b < k` for every unlocked vertex against the session's
        // device-resident graph and a cached dense k×k distance matrix.
        // The device candidate set is a *superset* of the host kernel's
        // (which only scans connected blocks) and the dense gain sums in a
        // different order, so gains can differ in the last ulps — kernel 2
        // below re-evaluates every candidate on the host either way, which
        // keeps the move list safe. Only taken for the non-negative filter
        // with a machine-backed objective; `None` falls through to the
        // pool kernel.
        let mut device_done = false;
        if matches!(filter, Filter::NonNegative) {
            let machine: Option<&Machine> = match obj {
                Objective::Comm(m) => Some(*m),
                Objective::Oracle(o) => Some(o.machine()),
                Objective::Cut => None,
            };
            if let Some(m) = machine {
                let k = m.k();
                if k <= device::JET_K_MAX {
                    let mut dmat = vec![0f64; k * k];
                    for a in 0..k {
                        for b in 0..k {
                            dmat[a * k + b] = m.distance(a as Block, b as Block);
                        }
                    }
                    let key = fnv1a_f64(&dmat);
                    let l32: Vec<i32> =
                        (0..n).map(|v| (self.locked[v] == round) as i32).collect();
                    if let Some((dd, dg)) = device::jet_round(g, part, &l32, k, key, &dmat) {
                        for v in 0..n {
                            let (d, gn) = (dd[v], dg[v]);
                            // dest == -1 ⇔ locked or no movable block; the
                            // first filter (G ≥ 0) is applied host-side.
                            if d < 0 || gn < 0.0 {
                                continue;
                            }
                            // relaxed: serial host loop between launches;
                            // kernel 2 reads after its dispatch barrier.
                            self.dest[v].store(d as u32, Ordering::Relaxed);
                            // SAFETY: each v is written exactly once here.
                            unsafe { gain_ptr.write(v, gn) };
                            self.stamp[v].store(round, Ordering::Relaxed);
                            self.cand.push(v as u64);
                        }
                        device_done = true;
                    }
                }
            }
        }

        // Kernel 1: best destination + first filter.
        if !device_done {
            let locked = &self.locked;
            let dest = &self.dest;
            let stamp = &self.stamp;
            let x = &self.cand;
            let _k = crate::par::ledger::kernel("refine/jet_lp:filter1");
            pool.parallel_for(n, |v| {
                if locked[v] == round {
                    return;
                }
                let from = part[v];
                let mut buf = super::ConnBuf::new();
                conn.gather_buf(v, &mut buf);
                let mut best: Option<(f64, Block)> = None;
                buf.for_each(|b, _| {
                    if b == from {
                        return;
                    }
                    let gn = obj.gain_buf(&buf, from, b);
                    if best.map(|(bg, bb)| gn > bg || (gn == bg && b < bb)).unwrap_or(true) {
                        best = Some((gn, b));
                    }
                });
                let Some((gn, b)) = best else { return };
                let pass = match filter {
                    Filter::NonNegative => gn >= 0.0,
                    Filter::JetNegative { c_factor } => {
                        gn >= 0.0 || -gn < (c_factor * conn.conn_to(v, from)).floor()
                    }
                };
                if pass {
                    // relaxed: `dest[v]`/`stamp[v]` are owned by unit `v`
                    // this superstep; kernel 2 reads them after the
                    // barrier, which is the publication point.
                    dest[v].store(b, Ordering::Relaxed);
                    // SAFETY: each v is written by exactly one work unit.
                    unsafe { gain_ptr.write(v, gn) };
                    stamp[v].store(round, Ordering::Relaxed);
                    x.push(v as u64);
                }
            });
        }

        // Kernel 2: re-evaluate under the approximate future state.
        {
            let dest = &self.dest;
            let gain = &self.gain;
            let stamp = &self.stamp;
            let cand = &self.cand;
            let moves = &self.moves;
            let _k = crate::par::ledger::kernel("refine/jet_lp:filter2");
            pool.parallel_for(cand.len(), |i| {
                let v = cand.get(i) as usize;
                let from = part[v];
                // relaxed: `dest`/`stamp`/`gain` are frozen after kernel
                // 1's barrier; this kernel only reads them.
                let to = dest[v].load(Ordering::Relaxed);
                let my_gain = gain[v];
                // Recompute the gain edge-by-edge with neighbors that are
                // earlier in the ordering assumed moved.
                let (nbrs, ws) = g.neighbors_w(v as Vertex);
                let mut buf = super::ConnBuf::new();
                for (&u, &w) in nbrs.iter().zip(ws) {
                    let ui = u as usize;
                    // relaxed: frozen since kernel 1 (see above).
                    let u_is_cand = stamp[ui].load(Ordering::Relaxed) == round;
                    let u_block = if u_is_cand && earlier(gain[ui], u, my_gain, v as Vertex) {
                        dest[ui].load(Ordering::Relaxed)
                    } else {
                        part[ui]
                    };
                    buf.add(u_block, w);
                }
                let new_gain = obj.gain_buf(&buf, from, to);
                if new_gain >= 0.0 {
                    moves.push(v as u64);
                }
            });
        }

        let mut final_moves: Vec<Vertex> =
            (0..self.moves.len()).map(|i| self.moves.get(i) as Vertex).collect();
        final_moves.sort_unstable(); // determinism for tests/benches

        // Lock moved vertices for the next LP round (anti-oscillation);
        // sparse stamping replaces the former O(n) clear-and-set pass.
        for &v in &final_moves {
            self.locked[v as usize] = round + 1;
        }
        final_moves
    }

    /// Destination of `v` from the last run.
    pub fn dest_of(&self, v: Vertex) -> Block {
        // relaxed: host-side read after the kernel barrier.
        self.dest[v as usize].load(Ordering::Relaxed)
    }
}

/// Implicit ordering: `u` earlier than `v` iff gain greater, ties by id.
#[inline]
fn earlier(gain_u: f64, u: Vertex, gain_v: f64, v: Vertex) -> bool {
    gain_u > gain_v || (gain_u == gain_v && u < v)
}

/// FNV-1a over the raw bits of a distance matrix — cache key for the
/// device-resident copy (see [`device::jet_round`]).
fn fnv1a_f64(xs: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in xs {
        for byte in x.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, EdgeList};
    use crate::partition::{comm_cost, edge_cut};
    use crate::rng::Rng;
    use crate::topology::Machine;

    fn apply_moves(part: &mut [Block], lp: &JetLp, moves: &[Vertex]) {
        for &v in moves {
            part[v as usize] = lp.dest_of(v);
        }
    }

    #[test]
    fn lp_step_reduces_comm_cost() {
        let g = gen::grid2d(16, 16, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let k = h.k();
        let mut rng = Rng::new(1);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut lp = JetLp::new(g.n());
        let before = comm_cost(&g, &part, &h);
        let conn = ConnTable::build(&pool, &g, &el, &part, k);
        let moves = lp.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative);
        assert!(!moves.is_empty());
        apply_moves(&mut part, &lp, &moves);
        let after = comm_cost(&g, &part, &h);
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 1000-vertex rgg, too slow
    fn lp_step_reduces_edge_cut_with_jet_filter() {
        let g = gen::rgg(1_000, 0.07, 2);
        let k = 4;
        let mut rng = Rng::new(3);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let mut lp = JetLp::new(g.n());
        let before = edge_cut(&g, &part);
        for _ in 0..4 {
            let conn = ConnTable::build(&pool, &g, &el, &part, k);
            let moves = lp.run(
                &pool,
                &g,
                &conn,
                &part,
                &Objective::Cut,
                Filter::JetNegative { c_factor: 0.25 },
            );
            apply_moves(&mut part, &lp, &moves);
        }
        let after = edge_cut(&g, &part);
        assert!(after < before * 0.9, "{before} -> {after}");
    }

    #[test]
    fn locked_vertices_do_not_move_next_round() {
        let g = gen::grid2d(8, 8, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let mut rng = Rng::new(5);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(4) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut lp = JetLp::new(g.n());
        let conn = ConnTable::build(&pool, &g, &el, &part, 4);
        let moves1 = lp.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative);
        apply_moves(&mut part, &lp, &moves1);
        let conn2 = ConnTable::build(&pool, &g, &el, &part, 4);
        let moves2 = lp.run(&pool, &g, &conn2, &part, &Objective::Comm(&h), Filter::NonNegative);
        for v in &moves2 {
            assert!(!moves1.contains(v), "vertex {v} oscillated");
        }
    }

    #[test]
    fn new_pass_unlocks_everything() {
        let g = gen::grid2d(8, 8, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let mut rng = Rng::new(5);
        let part: Vec<Block> = (0..g.n()).map(|_| rng.below(4) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut lp = JetLp::new(g.n());
        let conn = ConnTable::build(&pool, &g, &el, &part, 4);
        let moves1 = lp.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative);
        assert!(!moves1.is_empty());
        // Without new_pass the same vertices would be locked; with it the
        // identical input yields the identical move list again.
        lp.new_pass();
        let moves2 = lp.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative);
        assert_eq!(moves1, moves2);
    }

    #[test]
    fn deterministic_across_threads() {
        let g = gen::stencil9(16, 16, 7);
        let h = Machine::hier("4:2", "1:10").unwrap();
        let k = h.k();
        let mut rng = Rng::new(9);
        let part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let run = |threads: usize| {
            let pool = Pool::new(threads);
            let mut lp = JetLp::new(g.n());
            let conn = ConnTable::build(&pool, &g, &el, &part, k);
            lp.run(&pool, &g, &conn, &part, &Objective::Comm(&h), Filter::NonNegative)
        };
        assert_eq!(run(1), run(4));
    }
}
